"""AOT lowering: JAX/Pallas model -> HLO *text* artifacts + manifest.

HLO text (NOT ``lowered.compile().serialize()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``); the rust binary is then fully
self-contained.  Python is never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (n, m, k) dense-block configurations compiled by default.  n = terms,
# m = documents, k = topics.  Keep in sync with rust/src/runtime tests and
# examples/xla_offload.rs.
DEFAULT_CONFIGS = [
    (64, 96, 4),  # tiny: integration tests
    (256, 512, 5),  # small: quickstart / unit benches
    (1024, 2048, 8),  # e2e pipeline block size
]

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_als_iter(n: int, m: int, k: int) -> str:
    a = jax.ShapeDtypeStruct((n, m), jnp.float32)
    u = jax.ShapeDtypeStruct((n, k), jnp.float32)
    t = jax.ShapeDtypeStruct((), jnp.int32)
    return to_hlo_text(jax.jit(model.aot_als_iter).lower(a, u, t, t))


def lower_rel_error(n: int, m: int, k: int) -> str:
    a = jax.ShapeDtypeStruct((n, m), jnp.float32)
    u = jax.ShapeDtypeStruct((n, k), jnp.float32)
    v = jax.ShapeDtypeStruct((m, k), jnp.float32)
    return to_hlo_text(jax.jit(model.aot_rel_error).lower(a, u, v))


def program_entries(n: int, m: int, k: int):
    """Manifest records for one (n, m, k) config."""
    shape = lambda dims: list(dims)
    return [
        {
            "name": f"als_iter_{n}x{m}x{k}",
            "kind": "als_iter",
            "n": n,
            "m": m,
            "k": k,
            "file": f"als_iter_{n}x{m}x{k}.hlo.txt",
            "inputs": [
                ["a", shape((n, m)), "f32"],
                ["u", shape((n, k)), "f32"],
                ["t_u", [], "i32"],
                ["t_v", [], "i32"],
            ],
            "outputs": [
                ["u_new", shape((n, k)), "f32"],
                ["v", shape((m, k)), "f32"],
            ],
        },
        {
            "name": f"rel_error_{n}x{m}x{k}",
            "kind": "rel_error",
            "n": n,
            "m": m,
            "k": k,
            "file": f"rel_error_{n}x{m}x{k}.hlo.txt",
            "inputs": [
                ["a", shape((n, m)), "f32"],
                ["u", shape((n, k)), "f32"],
                ["v", shape((m, k)), "f32"],
            ],
            "outputs": [["err", [], "f32"]],
        },
    ]


def parse_configs(spec: str):
    configs = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        n, m, k = (int(x) for x in part.split(","))
        configs.append((n, m, k))
    return configs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--configs",
        default=None,
        help='semicolon-separated "n,m,k" triples (default: built-in list)',
    )
    args = ap.parse_args()

    configs = parse_configs(args.configs) if args.configs else DEFAULT_CONFIGS
    os.makedirs(args.out_dir, exist_ok=True)

    programs = []
    for n, m, k in configs:
        for entry, text_fn in zip(
            program_entries(n, m, k), (lower_als_iter, lower_rel_error)
        ):
            path = os.path.join(args.out_dir, entry["file"])
            text = text_fn(n, m, k)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {entry['name']}: {len(text)} chars -> {path}")
            programs.append(entry)

    manifest = {"version": MANIFEST_VERSION, "programs": programs}
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(programs)} programs -> {manifest_path}")


if __name__ == "__main__":
    main()
