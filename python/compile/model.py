"""Layer-2 JAX compute graph: one enforced-sparsity ALS iteration.

This is the dense-block form of Algorithm 2 of the paper, built from the
Layer-1 Pallas kernels (``matmul_atb``, ``gram``, ``project_threshold``)
plus custom-call-free composition glue, so the whole iteration lowers to a
single self-contained HLO module that the rust runtime can execute on any
PJRT backend.

Design notes
------------
* No ``jnp.linalg`` anywhere: on CPU those lower to LAPACK custom-calls
  that xla_extension 0.5.1 (the version the published ``xla`` crate links)
  cannot resolve.  The small (k,k) Gram inverse is an unrolled Gauss-Jordan
  (k is static per artifact, k <= 64), regularized with a trace-scaled
  ridge — the rust native backend uses the identical regularization so the
  two backends agree to float tolerance.
* The top-t threshold is a full sort + dynamic slice at a *runtime* ``t``
  (i32 scalar input), so one compiled artifact serves every sparsity level.
* ``t <= 0`` disables enforcement (plain projected ALS, Algorithm 1), which
  is how the dense comparator of Figure 2 is produced from the same
  artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import gram, matmul_atb, project_threshold

RIDGE_SCALE = 1e-6  # keep in sync with rust/src/dense/solve.rs
MIN_TAU = 1e-38  # smallest-positive bump so tau=0 never keeps exact zeros


def gauss_inverse(s):
    """Inverse of a small SPD matrix via unrolled Gauss-Jordan (no pivoting).

    The Gram matrices of ALS are SPD up to rank deficiency; the ridge makes
    the pivot strictly positive even for all-zero topics.
    """
    k = s.shape[0]
    eps = RIDGE_SCALE * jnp.trace(s) / k + jnp.float32(1e-10)
    a = s + eps * jnp.eye(k, dtype=jnp.float32)
    inv = jnp.eye(k, dtype=jnp.float32)
    for i in range(k):
        pivot = a[i, i]
        arow = a[i, :] / pivot
        invrow = inv[i, :] / pivot
        a = a.at[i, :].set(arow)
        inv = inv.at[i, :].set(invrow)
        col = a[:, i].at[i].set(0.0)
        a = a - jnp.outer(col, arow)
        inv = inv - jnp.outer(col, invrow)
    return inv


def topt_tau(x, t):
    """Threshold of the t-th largest entry of ``max(x, 0)`` (1-indexed).

    ``t`` is a traced i32 scalar; ``t <= 0`` returns MIN_TAU, i.e. "keep all
    positive entries" — enforcement off.
    """
    pos = jnp.maximum(x, 0.0).reshape(-1)
    size = pos.shape[0]
    enabled = t > 0
    tc = jnp.clip(t, 1, size)
    desc = jnp.sort(pos)[::-1]
    tau = jnp.take(desc, tc - 1)
    tau = jnp.where(enabled, tau, jnp.float32(0.0))
    return jnp.maximum(tau, jnp.float32(MIN_TAU))


def enforce(x, t):
    """Project to the nonnegative orthant, then keep the t largest entries."""
    return project_threshold(x, topt_tau(x, t))


def half_step(a_t_prod, g):
    """Solve the normal equations ``X = B (G)^-1`` for one ALS half-step."""
    return jnp.matmul(a_t_prod, gauss_inverse(g))


def als_iteration(a, u, t_u, t_v):
    """One full Algorithm-2 iteration: update V from U, then U from V.

    a: (n, m) data block, u: (n, k) current term/topic factor,
    t_u/t_v: i32 scalars (<=0 disables enforcement).
    Returns (u_new (n,k), v_new (m,k)).
    """
    # Step 1+2: V = A^T U (U^T U)^-1, project, enforce top-t_v.
    v = enforce(half_step(matmul_atb(a, u), gram(u)), t_v)
    # Step 3+4: U = A V (V^T V)^-1 = (A^T)^T V ... same kernel on A^T.
    u_new = enforce(half_step(matmul_atb(a.T, v), gram(v)), t_u)
    return u_new, v


def rel_error(a, u, v):
    """Relative Frobenius error ||A - U V^T|| / ||A||.

    Computed without materializing U V^T:
    ||A-UV^T||^2 = ||A||^2 - 2 tr(U^T A V) + tr((U^T U)(V^T V)).
    """
    a = a.astype(jnp.float32)
    norm_a2 = jnp.sum(a * a)
    av = matmul_atb(a.T, v)  # (n, k) = A V
    cross = jnp.sum(u * av)  # tr(U^T A V)
    gg = jnp.sum(gram(u) * gram(v))  # tr((U^T U)(V^T V))
    err2 = jnp.maximum(norm_a2 - 2.0 * cross + gg, 0.0)
    return jnp.sqrt(err2) / jnp.maximum(jnp.sqrt(norm_a2), jnp.float32(1e-30))


def rel_residual(u_new, u_old):
    """||U_i - U_{i-1}||_F / ||U_i||_F — the paper's convergence measure."""
    diff = u_new - u_old
    num = jnp.sqrt(jnp.sum(diff * diff))
    den = jnp.sqrt(jnp.sum(u_new * u_new))
    return num / jnp.maximum(den, jnp.float32(1e-30))


# ---------------------------------------------------------------------------
# AOT entry points: exactly the tuples the rust runtime expects.
# ---------------------------------------------------------------------------


def aot_als_iter(a, u, t_u, t_v):
    u_new, v = als_iteration(a, u, t_u, t_v)
    return (u_new, v)


def aot_rel_error(a, u, v):
    return (rel_error(a, u, v),)
