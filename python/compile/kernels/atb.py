"""Tiled ``B = A^T U`` Pallas kernel — the ALS hot spot.

ALS spends nearly all of its FLOPs in the two factor-update products
``A^T U`` (n,m)x(n,k) -> (m,k) and ``A V`` (n,m)x(m,k) -> (n,k); the second
is this same kernel applied to ``A^T``.  The grid walks ``(m/bm)`` output
row-tiles (parallel) by ``(n/bn)`` reduction steps (arbitrary): each step
loads one ``(bn, bm)`` tile of ``A`` and the matching ``(bn, k)`` slab of
``U`` into VMEM and accumulates a ``(bm, k)`` output tile — the BlockSpec
schedule that replaces the paper's "keep it sparse so it fits in RAM" on a
scratchpad machine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import grid_steps, pick_block


def _atb_kernel(a_ref, u_ref, o_ref):
    """One grid step: o[i] += a[j,i]^T @ u[j] (j = reduction index)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # (bn, bm)
    u = u_ref[...]  # (bn, k)
    # MXU-shaped accumulate in f32 regardless of input dtype.
    o_ref[...] += jax.lax.dot_general(
        a,
        u,
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract over bn
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n", "block_m"))
def matmul_atb(a, u, *, block_n: int | None = None, block_m: int | None = None):
    """Compute ``a.T @ u`` with a tiled Pallas kernel (interpret mode).

    a: (n, m), u: (n, k) -> (m, k) f32.
    """
    n, m = a.shape
    n2, k = u.shape
    if n != n2:
        raise ValueError(f"contraction mismatch: a {a.shape} vs u {u.shape}")
    bn = block_n or pick_block(n)
    bm = block_m or pick_block(m)
    grid = (grid_steps(m, bm), grid_steps(n, bn))
    return pl.pallas_call(
        _atb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bm), lambda i, j: (j, i)),  # tile of A
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),  # slab of U
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=True,  # CPU-PJRT execution; Mosaic is TPU-only
    )(a, u)
