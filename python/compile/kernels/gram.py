"""Tiled Gram matrix ``S = U^T U`` Pallas kernel.

The Gram matrix of a factor is tiny ((k,k), k <= 64) but its reduction runs
over the long axis (n = vocabulary or corpus size), so it is tiled the same
way as :mod:`atb`: a 1-D reduction grid where each step holds one ``(bn, k)``
slab of ``U`` in VMEM and accumulates the full ``(k, k)`` output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import grid_steps, pick_block


def _gram_kernel(u_ref, o_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    u = u_ref[...]  # (bn, k)
    o_ref[...] += jax.lax.dot_general(
        u,
        u,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_n",))
def gram(u, *, block_n: int | None = None):
    """Compute ``u.T @ u`` -> (k, k) f32 with a tiled Pallas kernel."""
    n, k = u.shape
    bn = block_n or pick_block(n)
    return pl.pallas_call(
        _gram_kernel,
        grid=(grid_steps(n, bn),),
        in_specs=[pl.BlockSpec((bn, k), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((k, k), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        interpret=True,
    )(u)
