"""Pure-jnp oracles for every Pallas kernel and L2 composite.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas kernels match to float tolerance, and the
rust integration tests cross-check the native sparse backend against HLO
built from the same math.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_atb(a, u):
    """a.T @ u in f32."""
    return jnp.matmul(a.astype(jnp.float32).T, u.astype(jnp.float32))


def ref_gram(u):
    u = u.astype(jnp.float32)
    return jnp.matmul(u.T, u)


def ref_project_threshold(x, tau):
    pos = jnp.maximum(x.astype(jnp.float32), 0.0)
    return jnp.where(pos >= jnp.float32(tau), pos, 0.0)


def ref_topt_tau(x, t):
    """Threshold value of the t-th largest entry of max(x, 0).

    Matches the paper: after projection all entries are >= 0, the t-th
    largest (1-indexed) positive value is the keep threshold; anything
    strictly below it is zeroed.  ``t`` may be a traced scalar.
    """
    pos = jnp.maximum(x, 0.0).reshape(-1)
    size = pos.shape[0]
    t = jnp.clip(t, 1, size)
    desc = jnp.sort(pos)[::-1]
    tau = jnp.take(desc, t - 1)
    # tau == 0 would keep every positive entry, which is correct when there
    # are fewer than t positive entries; bump to smallest positive float to
    # avoid keeping exact zeros as "nonzero".
    return jnp.maximum(tau, jnp.float32(1e-38))


def ref_enforce_top_t(x, t):
    """Project to nonnegative then keep only the t largest entries (ties kept)."""
    return ref_project_threshold(x, ref_topt_tau(x, t))


def ref_gauss_inverse(s, ridge_scale=1e-6):
    """Gauss-Jordan inverse of a small SPD matrix, custom-call-free.

    Mirrors model._gauss_inverse; used to validate it against numpy.
    """
    k = s.shape[0]
    eps = ridge_scale * jnp.trace(s) / k + jnp.float32(1e-10)
    a = s + eps * jnp.eye(k, dtype=jnp.float32)
    inv = jnp.eye(k, dtype=jnp.float32)
    for i in range(k):
        pivot = a[i, i]
        arow = a[i, :] / pivot
        invrow = inv[i, :] / pivot
        a = a.at[i, :].set(arow)
        inv = inv.at[i, :].set(invrow)
        col = a[:, i].at[i].set(0.0)
        a = a - jnp.outer(col, arow)
        inv = inv - jnp.outer(col, invrow)
    return inv


def ref_als_iteration(a, u, t_u, t_v):
    """One full enforced-sparsity ALS iteration (Algorithm 2), dense math."""
    s_u = ref_gram(u)
    b_v = ref_atb(a, u)
    v = ref_enforce_top_t(jnp.matmul(b_v, ref_gauss_inverse(s_u)), t_v)
    s_v = ref_gram(v)
    b_u = ref_atb(a.T, v)
    u_new = ref_enforce_top_t(jnp.matmul(b_u, ref_gauss_inverse(s_v)), t_u)
    return u_new, v


def ref_rel_error(a, u, v):
    """||A - U V^T||_F / ||A||_F."""
    diff = a - jnp.matmul(u, v.T)
    return jnp.sqrt(jnp.sum(diff * diff)) / jnp.maximum(
        jnp.sqrt(jnp.sum(a * a)), jnp.float32(1e-30)
    )
