"""Shared helpers for the Pallas kernels: block-size selection and padding.

The ALS hot loop is dominated by two products, ``B = A^T U`` and
``S = U^T U``.  On a real TPU each grid step should hold one ``(bn, bm)``
tile of ``A`` plus the matching ``(bn, k)`` slab of ``U`` in VMEM and feed
``(bm, k)`` MXU accumulations; the helpers here pick tile sizes that are
MXU-friendly (multiples of 8/128 where the array allows it) while exactly
dividing the operand so BlockSpecs never need masking.
"""

from __future__ import annotations

# Upper bound on a tile edge. 256 keeps the fp32 VMEM footprint of one
# grid step of matmul_atb under ~1 MB for k<=64 (see DESIGN.md §Perf):
#   A tile 256*256*4 = 256 KiB, U slab 256*64*4 = 64 KiB, out 256*64*4.
MAX_BLOCK = 256

# Candidate tile edges, MXU/VPU friendly first.
_CANDIDATES = (256, 128, 64, 32, 16, 8, 4, 2, 1)


def pick_block(dim: int, cap: int = MAX_BLOCK) -> int:
    """Largest candidate tile edge that divides ``dim`` and is <= cap.

    Falls back to ``dim`` itself when the dimension is small.
    """
    if dim <= 0:
        raise ValueError(f"dimension must be positive, got {dim}")
    if dim <= cap:
        return dim
    for c in _CANDIDATES:
        if c <= cap and dim % c == 0:
            return c
    return 1  # always divides


def grid_steps(dim: int, block: int) -> int:
    if dim % block != 0:
        raise ValueError(f"block {block} does not divide dim {dim}")
    return dim // block


def vmem_bytes_atb(bn: int, bm: int, k: int, itemsize: int = 4) -> int:
    """Estimated VMEM working set of one matmul_atb grid step."""
    return itemsize * (bn * bm + bn * k + bm * k)
