"""Fused project+threshold Pallas kernel — enforced sparsity, dense form.

Algorithm 2's inner step is "clamp negatives to zero, then zero everything
strictly below the magnitude of the t-th largest entry".  On a dense tile
machine that is a single fused elementwise pass ``max(x, 0) * (x >= tau)``
with the threshold ``tau`` precomputed at L2 (sort + dynamic slice).  The
kernel runs a 1-D grid over row tiles so arbitrarily tall factors stream
through VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import grid_steps, pick_block


def _project_kernel(x_ref, tau_ref, o_ref):
    x = x_ref[...]
    tau = tau_ref[0]
    pos = jnp.maximum(x, 0.0)
    # Keep entries >= tau (paper keeps ties of the t-th largest); entries
    # that were negative are already zero and tau > 0 removes them too.
    o_ref[...] = jnp.where(pos >= tau, pos, 0.0)


@functools.partial(jax.jit, static_argnames=("block_r",))
def project_threshold(x, tau, *, block_r: int | None = None):
    """``max(x,0)`` with entries strictly below ``tau`` zeroed.

    x: (r, c) f32, tau: () or (1,) f32 scalar threshold (tau <= 0 keeps all
    positive entries). Returns (r, c) f32.
    """
    r, c = x.shape
    br = block_r or pick_block(r)
    tau_arr = jnp.reshape(jnp.asarray(tau, jnp.float32), (1,))
    return pl.pallas_call(
        _project_kernel,
        grid=(grid_steps(r, br),),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.float32),
        interpret=True,
    )(x, tau_arr)
