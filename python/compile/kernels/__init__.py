"""Layer-1 Pallas kernels for ES-NMF.

All kernels are authored for the TPU mental model (VMEM tiles feeding the
MXU) but are lowered with ``interpret=True`` so the resulting HLO runs on
any PJRT backend, including the rust CPU client. See DESIGN.md
§Hardware-Adaptation for the GPU/MATLAB→TPU mapping.
"""

from .atb import matmul_atb
from .gram import gram
from .project import project_threshold

__all__ = ["matmul_atb", "gram", "project_threshold"]
