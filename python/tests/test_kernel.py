"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (and the block-size knobs) so every BlockSpec
branch of the kernels is exercised, not just the happy divisible path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is not in every offline image; skip (not error) when absent
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, matmul_atb, project_threshold
from compile.kernels.common import pick_block, grid_steps, vmem_bytes_atb
from compile.kernels.ref import (
    ref_atb,
    ref_enforce_top_t,
    ref_gram,
    ref_project_threshold,
    ref_topt_tau,
)

jax.config.update("jax_enable_x64", False)

DIMS = st.sampled_from([1, 2, 3, 4, 7, 8, 16, 17, 32, 64])
KS = st.sampled_from([1, 2, 3, 5, 8, 16])


def rand(rng, *shape, negatives=True):
    x = rng.standard_normal(shape).astype(np.float32)
    if not negatives:
        x = np.abs(x)
    return x


# ---------------------------------------------------------------------------
# matmul_atb
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(n=DIMS, m=DIMS, k=KS, seed=st.integers(0, 2**31 - 1))
def test_atb_matches_ref(n, m, k, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, n, m)
    u = rand(rng, n, k)
    got = matmul_atb(a, u)
    want = ref_atb(a, u)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bn,bm", [(1, 1), (2, 4), (4, 2), (8, 8)])
def test_atb_explicit_blocks(bn, bm):
    rng = np.random.default_rng(0)
    a = rand(rng, 16, 8)
    u = rand(rng, 16, 3)
    got = matmul_atb(a, u, block_n=bn, block_m=bm)
    np.testing.assert_allclose(got, ref_atb(a, u), rtol=1e-5, atol=1e-5)


def test_atb_rejects_mismatched_contraction():
    a = jnp.zeros((4, 4))
    u = jnp.zeros((5, 2))
    with pytest.raises(ValueError):
        matmul_atb(a, u)


def test_atb_accumulates_in_f32_from_bf16():
    rng = np.random.default_rng(1)
    a = rand(rng, 32, 16).astype(jnp.bfloat16)
    u = rand(rng, 32, 4).astype(jnp.bfloat16)
    got = matmul_atb(a, u)
    assert got.dtype == jnp.float32
    want = np.asarray(a, np.float32).T @ np.asarray(u, np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(n=DIMS, k=KS, seed=st.integers(0, 2**31 - 1))
def test_gram_matches_ref(n, k, seed):
    rng = np.random.default_rng(seed)
    u = rand(rng, n, k)
    got = gram(u)
    np.testing.assert_allclose(got, ref_gram(u), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=DIMS, k=KS, seed=st.integers(0, 2**31 - 1))
def test_gram_is_symmetric_psd(n, k, seed):
    rng = np.random.default_rng(seed)
    g = np.asarray(gram(rand(rng, n, k)))
    np.testing.assert_allclose(g, g.T, atol=1e-6)
    eig = np.linalg.eigvalsh(g)
    assert eig.min() >= -1e-4 * max(1.0, abs(eig).max())


# ---------------------------------------------------------------------------
# project_threshold
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    r=DIMS,
    c=KS,
    tau=st.floats(-1.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_project_matches_ref(r, c, tau, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, r, c)
    got = project_threshold(x, tau)
    want = ref_project_threshold(x, tau)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_project_clamps_negatives():
    x = jnp.array([[-1.0, 0.5], [2.0, -3.0]])
    out = np.asarray(project_threshold(x, 0.0))
    assert (out >= 0).all()
    np.testing.assert_allclose(out, [[0.0, 0.5], [2.0, 0.0]])


# ---------------------------------------------------------------------------
# top-t enforcement (composite, sort + kernel)
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(r=DIMS, c=KS, t=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_enforce_top_t_nnz_bound(r, c, t, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, r, c)
    out = np.asarray(ref_enforce_top_t(x, t))
    # continuous random data: ties have measure zero -> exactly min(t, #pos)
    pos = int((x > 0).sum())
    assert int((out > 0).sum()) == min(t, pos)
    # kept set dominates dropped set
    kept = out[out > 0]
    if kept.size and kept.size < pos:
        dropped = np.maximum(x, 0)[(np.maximum(x, 0) > 0) & (out == 0)]
        assert kept.min() >= dropped.max()


def test_topt_tau_handles_all_negative():
    x = -np.abs(np.random.default_rng(2).standard_normal((4, 3))).astype(np.float32)
    tau = float(ref_topt_tau(x, 5))
    out = np.asarray(ref_project_threshold(x, tau))
    assert (out == 0).all()


# ---------------------------------------------------------------------------
# block-size helpers
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(dim=st.integers(1, 10_000))
def test_pick_block_divides(dim):
    b = pick_block(dim)
    assert 1 <= b <= max(dim, 1)
    assert dim % b == 0
    assert grid_steps(dim, b) * b == dim


def test_pick_block_rejects_nonpositive():
    with pytest.raises(ValueError):
        pick_block(0)


def test_vmem_estimate_monotone():
    assert vmem_bytes_atb(256, 256, 8) < vmem_bytes_atb(256, 256, 64)
    # the DESIGN.md §Perf budget: default tiles stay under 1 MiB at k=64
    assert vmem_bytes_atb(256, 256, 64) * 1.0 < (1 << 20) * 1.5
