"""AOT pipeline tests: HLO text emission + manifest round-trip."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from compile import aot


def test_parse_configs():
    assert aot.parse_configs("8,12,2; 16,16,3;") == [(8, 12, 2), (16, 16, 3)]


def test_program_entries_shapes():
    entries = aot.program_entries(8, 12, 2)
    assert [e["kind"] for e in entries] == ["als_iter", "rel_error"]
    it = entries[0]
    assert it["inputs"][0][1] == [8, 12]
    assert it["outputs"] == [["u_new", [8, 2], "f32"], ["v", [12, 2], "f32"]]


def test_lower_als_iter_emits_entry_hlo():
    text = aot.lower_als_iter(8, 12, 2)
    assert "ENTRY" in text and "HloModule" in text
    # tuple return convention for the rust loader (to_tuple on our side)
    assert "f32[8,2]" in text and "f32[12,2]" in text


def test_lower_rel_error_emits_scalar():
    text = aot.lower_rel_error(8, 12, 2)
    assert "ENTRY" in text
    assert "f32[]" in text


@pytest.mark.slow
def test_cli_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--configs",
            "8,12,2",
        ],
        cwd=Path(__file__).resolve().parents[1],
        check=True,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == aot.MANIFEST_VERSION
    assert len(manifest["programs"]) == 2
    for prog in manifest["programs"]:
        assert (out / prog["file"]).exists()
