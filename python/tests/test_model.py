"""L2 model tests: ALS iteration semantics, solve correctness, error math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is not in every offline image; skip (not error) when absent
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import ref_als_iteration, ref_rel_error

jax.config.update("jax_enable_x64", False)


def low_rank_data(rng, n, m, k, noise=0.0):
    u = np.abs(rng.standard_normal((n, k))).astype(np.float32)
    v = np.abs(rng.standard_normal((m, k))).astype(np.float32)
    a = u @ v.T
    if noise:
        a += noise * np.abs(rng.standard_normal((n, m))).astype(np.float32)
    return a.astype(np.float32)


# ---------------------------------------------------------------------------
# gauss_inverse
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(k=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_gauss_inverse_matches_numpy(k, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((k + 3, k)).astype(np.float32)
    s = (x.T @ x).astype(np.float32)  # SPD with overwhelming probability
    inv = np.asarray(model.gauss_inverse(jnp.asarray(s)))
    # the ridge perturbs S slightly; compare against the ridged inverse
    eps = model.RIDGE_SCALE * np.trace(s) / k + 1e-10
    want = np.linalg.inv(s + eps * np.eye(k, dtype=np.float32))
    np.testing.assert_allclose(inv, want, rtol=5e-3, atol=5e-3)


def test_gauss_inverse_survives_rank_deficiency():
    s = np.zeros((4, 4), np.float32)
    s[0, 0] = 1.0  # rank 1: three zero topics
    inv = np.asarray(model.gauss_inverse(jnp.asarray(s)))
    assert np.isfinite(inv).all()


# ---------------------------------------------------------------------------
# als_iteration
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_als_iteration_matches_ref(seed):
    rng = np.random.default_rng(seed)
    a = low_rank_data(rng, 16, 24, 3, noise=0.1)
    u0 = np.abs(rng.standard_normal((16, 3))).astype(np.float32)
    got_u, got_v = model.als_iteration(jnp.asarray(a), jnp.asarray(u0), 20, 30)
    want_u, want_v = ref_als_iteration(jnp.asarray(a), jnp.asarray(u0), 20, 30)
    np.testing.assert_allclose(got_v, want_v, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_u, want_u, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    t_u=st.integers(1, 48),
    t_v=st.integers(1, 72),
    seed=st.integers(0, 2**31 - 1),
)
def test_als_iteration_respects_nnz_caps(t_u, t_v, seed):
    rng = np.random.default_rng(seed)
    a = low_rank_data(rng, 16, 24, 3, noise=0.3)
    u0 = np.abs(rng.standard_normal((16, 3))).astype(np.float32)
    u1, v1 = model.als_iteration(jnp.asarray(a), jnp.asarray(u0), t_u, t_v)
    u1, v1 = np.asarray(u1), np.asarray(v1)
    assert (u1 >= 0).all() and (v1 >= 0).all()
    assert int((u1 > 0).sum()) <= t_u
    assert int((v1 > 0).sum()) <= t_v


def test_disabled_enforcement_is_projected_als():
    rng = np.random.default_rng(7)
    a = low_rank_data(rng, 16, 24, 3, noise=0.3)
    u0 = np.abs(rng.standard_normal((16, 3))).astype(np.float32)
    # t <= 0 => plain projected ALS: more nonzeros than any small cap
    u1, v1 = model.als_iteration(jnp.asarray(a), jnp.asarray(u0), 0, 0)
    assert int((np.asarray(v1) > 0).sum()) > 24


def test_error_decreases_over_iterations():
    rng = np.random.default_rng(3)
    a = jnp.asarray(low_rank_data(rng, 32, 48, 4, noise=0.05))
    u = jnp.asarray(np.abs(rng.standard_normal((32, 4))).astype(np.float32))
    v = None
    errs = []
    for _ in range(6):
        u, v = model.als_iteration(a, u, 0, 0)
        errs.append(float(model.rel_error(a, u, v)))
    assert errs[-1] <= errs[0] + 1e-6
    assert errs[-1] < 0.25  # rank-4 data, rank-4 factorization: near-exact


# ---------------------------------------------------------------------------
# error / residual
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rel_error_matches_dense_formula(seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(np.abs(rng.standard_normal((12, 20))).astype(np.float32))
    u = jnp.asarray(np.abs(rng.standard_normal((12, 3))).astype(np.float32))
    v = jnp.asarray(np.abs(rng.standard_normal((20, 3))).astype(np.float32))
    got = float(model.rel_error(a, u, v))
    want = float(ref_rel_error(a, u, v))
    assert abs(got - want) < 1e-4


def test_rel_error_zero_for_exact_factorization():
    rng = np.random.default_rng(11)
    u = jnp.asarray(np.abs(rng.standard_normal((10, 3))).astype(np.float32))
    v = jnp.asarray(np.abs(rng.standard_normal((14, 3))).astype(np.float32))
    a = jnp.matmul(u, v.T)
    assert float(model.rel_error(a, u, v)) < 1e-3


def test_rel_residual():
    u1 = jnp.ones((4, 2))
    assert float(model.rel_residual(u1, u1)) == 0.0
    u0 = jnp.zeros((4, 2))
    assert abs(float(model.rel_residual(u1, u0)) - 1.0) < 1e-6
