#!/usr/bin/env bash
# Before/after markdown report over two bench trajectory documents
# (BENCH_smoke.json-shaped), via `esnmf bench-compare`.
#
#   usage: perf_compare.sh before.json after.json [report.md]
#
# Informational only — it reports ratios, `esnmf bench-check` gates.
# Set ESNMF_BIN to a prebuilt binary to skip the cargo build; set
# PERF_GUARDS to change the metric filter (default wall_s).
set -euo pipefail

root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
usage="usage: perf_compare.sh before.json after.json [report.md]"
before_arg="${1:?$usage}"
after_arg="${2:?$usage}"
out_arg="${3:-}"

# absolutize: the cargo fallback below runs from rust/, so relative
# operands from the caller's directory must be resolved first
abspath() {
  case "$1" in
    /*) printf '%s\n' "$1" ;;
    *) printf '%s/%s\n' "$(cd "$(dirname "$1")" && pwd)" "$(basename "$1")" ;;
  esac
}
before="$(abspath "$before_arg")"
after="$(abspath "$after_arg")"

run_esnmf() {
  if [ -n "${ESNMF_BIN:-}" ]; then
    "$ESNMF_BIN" "$@"
  else
    (cd "$root/rust" && cargo run --release --quiet -- "$@")
  fi
}

set -- bench-compare --before "$before" --after "$after" --guards "${PERF_GUARDS:-wall_s}"
if [ -n "$out_arg" ]; then
  mkdir -p "$(dirname "$out_arg")"
  out="$(cd "$(dirname "$out_arg")" && pwd)/$(basename "$out_arg")"
  run_esnmf "$@" --out "$out"
else
  run_esnmf "$@"
fi
