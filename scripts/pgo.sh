#!/usr/bin/env bash
# PGO build lane for the esnmf hot kernels.
#
#   1. baseline:   plain release bench pass        -> pgo-out/before.json
#   2. instrument: -Cprofile-generate rebuild, profiled on the same
#                  micro-kernel bench corpus the wall-clock gate runs
#   3. merge:      llvm-profdata merge             -> pgo-out/esnmf.profdata
#   4. optimize:   -Cprofile-use rebuild, re-bench -> pgo-out/after.json
#   5. report:     scripts/perf_compare.sh         -> pgo-out/report.md
#
# The report is informational — the CI pgo job is non-blocking; the
# gated wall-clock trajectory lives in the bench-smoke job. Set
# BENCH_SMOKE=0 for full-size (slow, more representative) profiling.
set -euo pipefail

root="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
cd "$root/rust"

out="${PGO_OUT:-$root/rust/pgo-out}"
profdir="$out/profraw"
rm -rf "$out"
mkdir -p "$profdir"

# locate llvm-profdata: PATH first, then the rustup llvm-tools component
# inside the active toolchain's sysroot
llvm_profdata="$(command -v llvm-profdata || true)"
if [ -z "$llvm_profdata" ]; then
  sysroot="$(rustc --print sysroot)"
  llvm_profdata="$(find "$sysroot" -name llvm-profdata -type f 2>/dev/null | head -n1 || true)"
fi
if [ -z "$llvm_profdata" ]; then
  echo "pgo.sh: llvm-profdata not found — install the llvm-tools rustup" >&2
  echo "        component (rustup component add llvm-tools) or put LLVM on PATH" >&2
  exit 2
fi

export BENCH_SMOKE="${BENCH_SMOKE:-1}"

echo "== pgo.sh: baseline bench (plain release) =="
ESNMF_BENCH_COMBINED="$out/before.json" cargo bench --bench micro_kernels
# the CLI for the final report, built now so the profile-use rebuild
# below (which only touches lib + bench targets) can't recompile it
cargo build --release --quiet

echo "== pgo.sh: instrumented build + profiling pass =="
RUSTFLAGS="-Cprofile-generate=$profdir" \
  LLVM_PROFILE_FILE="$profdir/esnmf-%p-%m.profraw" \
  ESNMF_BENCH_COMBINED="" \
  cargo bench --bench micro_kernels
"$llvm_profdata" merge -o "$out/esnmf.profdata" "$profdir"/*.profraw

echo "== pgo.sh: profile-guided rebuild + bench =="
RUSTFLAGS="-Cprofile-use=$out/esnmf.profdata" \
  ESNMF_BENCH_COMBINED="$out/after.json" \
  cargo bench --bench micro_kernels

echo "== pgo.sh: before/after report =="
ESNMF_BIN="$root/rust/target/release/esnmf" "$root/scripts/perf_compare.sh" \
  "$out/before.json" "$out/after.json" "$out/report.md"
echo "pgo.sh: report at $out/report.md"
