//! Runtime + XLA backend integration — requires compiled artifacts
//! (`make artifacts`); every test is skipped gracefully when absent so
//! `cargo test` stays green on a fresh checkout.

use esnmf::backend::{AlsBackend, NativeBackend, XlaBackend};
use esnmf::corpus::{self, Scale};
use esnmf::nmf::{NmfOptions, SparsityMode};
use esnmf::runtime::{self, Engine, ProgramKind, XlaExecutor};
use esnmf::util::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    if runtime::artifacts_available() {
        Some(runtime::artifact_dir())
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_loads_and_engine_compiles() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    assert!(!engine.manifest().programs.is_empty());
    assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());
    let compiled = engine.warmup().unwrap();
    assert_eq!(compiled, engine.manifest().programs.len());
}

#[test]
fn als_iter_artifact_matches_native_math() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let Some(spec) = engine
        .manifest()
        .programs
        .iter()
        .find(|p| p.kind == ProgramKind::AlsIter && p.n == 64)
        .cloned()
    else {
        eprintln!("skipping: no 64x96 artifact");
        return;
    };
    let (n, m, k) = (spec.n, spec.m, spec.k);

    // random nonneg dense A, U (no ties with probability 1)
    let mut rng = Rng::new(99);
    let a: Vec<f32> = (0..n * m)
        .map(|_| if rng.f64() < 0.1 { rng.abs_normal_f32() } else { 0.0 })
        .collect();
    let u: Vec<f32> = (0..n * k).map(|_| rng.abs_normal_f32() + 1e-4).collect();
    let (t_u, t_v) = (40i32, 60i32);

    let out = engine.als_iter(n, m, k, &a, &u, t_u, t_v).unwrap();
    assert_eq!(out.u_new.len(), n * k);
    assert_eq!(out.v.len(), m * k);
    // enforcement held on-device
    let nnz_u = out.u_new.iter().filter(|&&x| x > 0.0).count();
    let nnz_v = out.v.iter().filter(|&&x| x > 0.0).count();
    assert!(nnz_u <= t_u as usize, "u nnz {nnz_u} > {t_u}");
    assert!(nnz_v <= t_v as usize, "v nnz {nnz_v} > {t_v}");
    assert!(out.u_new.iter().all(|&x| x >= 0.0));

    // native reference on the same inputs
    use esnmf::dense::inverse_spd;
    use esnmf::sparse::{ops, topk, Csr, TieMode};
    let a_csr = Csr::from_dense(n, m, &a);
    let u_csr = Csr::from_dense(n, k, &u);
    let mut mem = esnmf::nmf::MemoryTracker::new();
    let opts = NmfOptions::new(k)
        .with_sparsity(SparsityMode::Global {
            t_u: Some(t_u as usize),
            t_v: Some(t_v as usize),
        });
    let v_native = esnmf::nmf::half_step_v(&a_csr.to_csc(), &u_csr, &opts, &mut mem);
    let u_native = esnmf::nmf::half_step_u(&a_csr, &v_native, &opts, &mut mem);
    let _ = (inverse_spd, ops::gram, topk::nth_largest, TieMode::KeepTies); // api smoke

    let v_dev = Csr::from_dense(m, k, &out.v);
    let u_dev = Csr::from_dense(n, k, &out.u_new);
    // same support and close values
    assert_eq!(v_dev.nnz(), v_native.nnz(), "V support size");
    assert_eq!(u_dev.nnz(), u_native.nnz(), "U support size");
    let dv = v_dev.fro_diff(&v_native) / v_native.fro_norm().max(1e-12);
    let du = u_dev.fro_diff(&u_native) / u_native.fro_norm().max(1e-12);
    assert!(dv < 1e-3, "V relative diff {dv}");
    assert!(du < 1e-3, "U relative diff {du}");
}

#[test]
fn rel_error_artifact_matches_sparse_formula() {
    let Some(dir) = artifacts() else { return };
    let mut engine = Engine::load(&dir).unwrap();
    let Some(spec) = engine
        .manifest()
        .programs
        .iter()
        .find(|p| p.kind == ProgramKind::RelError && p.n == 64)
        .cloned()
    else {
        return;
    };
    let (n, m, k) = (spec.n, spec.m, spec.k);
    let mut rng = Rng::new(7);
    let a: Vec<f32> = (0..n * m)
        .map(|_| if rng.f64() < 0.15 { rng.abs_normal_f32() } else { 0.0 })
        .collect();
    let u: Vec<f32> = (0..n * k).map(|_| rng.abs_normal_f32()).collect();
    let v: Vec<f32> = (0..m * k).map(|_| rng.abs_normal_f32()).collect();
    let dev = engine.rel_error(n, m, k, &a, &u, &v).unwrap() as f64;

    use esnmf::sparse::Csr;
    let a_csr = Csr::from_dense(n, m, &a);
    let u_csr = Csr::from_dense(n, k, &u);
    let v_csr = Csr::from_dense(m, k, &v);
    let host = esnmf::nmf::rel_error_sparse(&a_csr, &u_csr, &v_csr, a_csr.fro_norm_sq());
    assert!(
        (dev - host).abs() < 1e-3 * (1.0 + host),
        "device {dev} vs host {host}"
    );
}

#[test]
fn xla_backend_agrees_with_native_over_full_run() {
    let Some(dir) = artifacts() else { return };
    let guard = XlaExecutor::spawn(dir.clone()).unwrap();
    let manifest = esnmf::runtime::Manifest::load(&dir).unwrap();
    let Some(prog) = manifest
        .programs
        .iter()
        .find(|p| p.kind == ProgramKind::AlsIter && p.n == 64)
    else {
        return;
    };

    // corpus that fits the 64 × 96 artifact
    let spec = corpus::CorpusSpec {
        n_docs: 90,
        doc_len_mean: 30,
        topic_tail: 4,
        background_tail: 4,
        ..corpus::reuters_sim(Scale::Tiny)
    };
    let mut tdm = corpus::generate_tdm(&spec, 31);
    // the generator may exceed 64 terms; trim rows to fit by retaining the
    // most frequent terms
    if tdm.n_terms() > prog.n {
        let mut idx: Vec<usize> = (0..tdm.n_terms()).collect();
        idx.sort_by_key(|&r| std::cmp::Reverse(tdm.a.row(r).0.len()));
        idx.truncate(prog.n);
        idx.sort_unstable();
        let mut coo = esnmf::sparse::Coo::new(prog.n, tdm.n_docs());
        let mut terms = Vec::with_capacity(prog.n);
        for (new_r, &old_r) in idx.iter().enumerate() {
            terms.push(tdm.terms[old_r].clone());
            let (cols, vals) = tdm.a.row(old_r);
            for (&c, &v) in cols.iter().zip(vals) {
                coo.push(new_r, c as usize, v);
            }
        }
        let a = coo.to_csr();
        let a_csc = a.to_csc();
        tdm = esnmf::text::TermDocMatrix {
            a,
            a_csc,
            terms,
            doc_labels: tdm.doc_labels.clone(),
            label_names: tdm.label_names.clone(),
        };
    }
    assert!(tdm.n_terms() <= prog.n && tdm.n_docs() <= prog.m);

    let opts = NmfOptions::new(prog.k)
        .with_iters(8)
        .with_seed(5)
        .with_sparsity(SparsityMode::both(50, 80));
    let xr = XlaBackend::new(guard.handle.clone(), prog.n, prog.m, prog.k)
        .factorize(&tdm, &opts)
        .unwrap();
    let nr = NativeBackend::new().factorize(&tdm, &opts).unwrap();

    assert_eq!(xr.iterations, nr.iterations);
    for (i, (x, n)) in xr.residuals.iter().zip(&nr.residuals).enumerate() {
        assert!(
            (x - n).abs() < 1e-3 * (1.0 + n),
            "iteration {i}: residual {x} vs {n}"
        );
    }
    let de = (xr.final_error() - nr.final_error()).abs();
    assert!(de < 1e-3, "final error diff {de}");
    assert_eq!(xr.u.nnz(), nr.u.nnz(), "U support");
}

#[test]
fn xla_backend_rejects_oversized_corpus() {
    let Some(dir) = artifacts() else { return };
    let guard = XlaExecutor::spawn(dir).unwrap();
    let tdm = corpus::generate_tdm(&corpus::reuters_sim(Scale::Tiny), 3);
    // deliberately tiny artifact shape
    let mut backend = XlaBackend::new(guard.handle.clone(), 8, 8, 2);
    let err = backend
        .factorize(&tdm, &NmfOptions::new(2).with_iters(1))
        .unwrap_err();
    assert!(err.to_string().contains("exceeds artifact shape"), "{err}");
}
