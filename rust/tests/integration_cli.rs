//! End-to-end CLI tests: spawn the real `esnmf` binary (cargo builds it
//! for integration tests and exposes the path via CARGO_BIN_EXE_esnmf).

use std::process::Command;

fn esnmf(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_esnmf"))
        .args(args)
        .env("ESNMF_LOG", "warn")
        .output()
        .expect("spawning esnmf")
}

#[test]
fn help_prints_usage() {
    let out = esnmf(&["help"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"), "{text}");
    assert!(text.contains("experiment"), "{text}");
}

#[test]
fn unknown_subcommand_fails() {
    let out = esnmf(&["frobnicate"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn unknown_flag_fails() {
    let out = esnmf(&["factorize", "--corpus", "reuters", "--scale", "tiny", "--oops", "1"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--oops"));
}

#[test]
fn factorize_tiny_reuters() {
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "5",
        "--iters", "10", "--sparsity", "u", "--t-u", "55", "--seed", "1",
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("completed 10 iterations"), "{text}");
    assert!(text.contains("Topic 1"), "{text}");
    assert!(text.contains("mean clustering accuracy"), "{text}");
}

/// Blank out the wall-clock portion of the "completed N iterations in
/// X.XXXs" line — everything else the CLI prints is deterministic.
fn strip_elapsed(text: &str) -> String {
    text.lines()
        .map(|l| match (l.find(" in "), l.find("s  final residual")) {
            (Some(a), Some(b)) if a < b => format!("{}{}", &l[..a], &l[b + 1..]),
            _ => l.to_string(),
        })
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn block_rows_flag_streams_without_changing_the_output() {
    // the blocked pipeline's CLI face: any --block-rows value (including
    // a pathological 1-row block) produces byte-identical human output
    let base = [
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "4",
        "--iters", "6", "--sparsity", "both", "--t-u", "50", "--t-v", "90",
        "--seed", "3", "--threads", "2",
    ];
    let mut reference: Option<String> = None;
    for block_rows in ["1", "17", "auto"] {
        let mut args: Vec<&str> = base.to_vec();
        args.extend_from_slice(&["--block-rows", block_rows]);
        let out = esnmf(&args);
        assert!(
            out.status.success(),
            "--block-rows {block_rows} stderr: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout).into_owned();
        assert!(text.contains("completed 6 iterations"), "{text}");
        let text = strip_elapsed(&text);
        match &reference {
            None => reference = Some(text),
            Some(want) => assert_eq!(&text, want, "--block-rows {block_rows}"),
        }
    }
    // junk values are rejected like junk thread counts
    let mut args: Vec<&str> = base.to_vec();
    args.extend_from_slice(&["--block-rows", "many"]);
    let out = esnmf(&args);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("block-rows"));
}

#[test]
fn factorize_sequential_algorithm() {
    let out = esnmf(&[
        "factorize", "--corpus", "pubmed", "--scale", "tiny", "--k", "5",
        "--algorithm", "seq", "--t-u", "10", "--t-v", "50", "--seed", "2",
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("iterations"));
}

#[test]
fn factorize_threshold_ablation_mode() {
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "5", "--sparsity", "threshold", "--tau-u", "0.05", "--seed", "3",
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn threshold_mode_without_tau_errors() {
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny",
        "--sparsity", "threshold",
    ]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("tau"));
}

#[test]
fn experiment_fig1_writes_json() {
    let out_dir = std::env::temp_dir().join("esnmf_cli_results");
    let _ = std::fs::remove_dir_all(&out_dir);
    let out = esnmf(&[
        "experiment", "fig1", "--scale", "tiny", "--fast", "--seed", "4",
        "--out", out_dir.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let json = std::fs::read_to_string(out_dir.join("fig1.json")).unwrap();
    assert!(json.contains("\"experiment\":\"fig1\""), "{json}");
}

#[test]
fn gen_corpus_roundtrips_through_loader() {
    let dir = std::env::temp_dir().join("esnmf_cli_corpus");
    let _ = std::fs::remove_dir_all(&dir);
    let out = esnmf(&[
        "gen-corpus", "--corpus", "reuters", "--scale", "tiny", "--seed", "5",
        "--out", dir.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    // factorize the written corpus through the dir: loader
    let out = esnmf(&[
        "factorize", "--corpus", &format!("dir:{}", dir.display()),
        "--k", "3", "--iters", "5", "--seed", "6",
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn save_model_then_serve_model_without_refactorizing() {
    use std::io::{BufRead, BufReader, Write};
    let snap = std::env::temp_dir().join("esnmf_cli_model.esnmf");
    let _ = std::fs::remove_file(&snap);
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "6", "--sparsity", "both", "--t-u", "60", "--t-v", "120",
        "--seed", "9", "--save-model", snap.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("saved model snapshot"));
    assert!(snap.exists());

    // cold-start a server from the snapshot on an ephemeral port
    let mut child = Command::new(env!("CARGO_BIN_EXE_esnmf"))
        .args(["serve", "--model", snap.to_str().unwrap(), "--addr", "127.0.0.1:0"])
        .env("ESNMF_LOG", "warn")
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawning esnmf serve");
    let mut banner = String::new();
    BufReader::new(child.stdout.as_mut().unwrap())
        .read_line(&mut banner)
        .unwrap();
    let addr = banner
        .split_whitespace()
        .find(|t| t.contains(':') && t.starts_with("127.0.0.1"))
        .unwrap_or_else(|| panic!("no address in banner {banner:?}"))
        .to_string();
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writeln!(writer, "TOPICS").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "OK k=3", "{banner:?}");
    writeln!(writer, "QUIT").unwrap();
    child.kill().unwrap();
    let _ = child.wait();
    std::fs::remove_file(&snap).unwrap();
}

#[test]
fn serve_model_with_missing_file_fails_clearly() {
    let out = esnmf(&["serve", "--model", "/nonexistent/nope.esnmf", "--addr", "127.0.0.1:0"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("nope.esnmf"), "{err}");
}

#[test]
fn serve_model_refuses_k_mismatch() {
    let snap = std::env::temp_dir().join("esnmf_cli_kmismatch.esnmf");
    let _ = std::fs::remove_file(&snap);
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "3", "--seed", "10", "--save-model", snap.to_str().unwrap(),
    ]);
    assert!(out.status.success());
    let out = esnmf(&[
        "serve", "--model", snap.to_str().unwrap(), "--k", "5",
        "--addr", "127.0.0.1:0",
    ]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("k=5") && err.contains("k=3"), "{err}");
    std::fs::remove_file(&snap).unwrap();
}

#[test]
fn resume_refuses_a_different_corpus() {
    let snap = std::env::temp_dir().join("esnmf_cli_resume_refuse.esnmf");
    let _ = std::fs::remove_file(&snap);
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "4", "--seed", "11", "--save-model", snap.to_str().unwrap(),
    ]);
    assert!(out.status.success());
    // same preset, different seed → different corpus → digest refusal
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "8", "--seed", "12", "--resume", snap.to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("digest"), "{err}");
    std::fs::remove_file(&snap).unwrap();
}

#[test]
fn warm_start_runs_on_a_grown_corpus() {
    let snap = std::env::temp_dir().join("esnmf_cli_warm.esnmf");
    let _ = std::fs::remove_file(&snap);
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "5", "--seed", "13", "--save-model", snap.to_str().unwrap(),
    ]);
    assert!(out.status.success());
    // a different corpus (grown/changed vocabulary) warm-starts fine
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "5", "--seed", "14", "--warm-start", snap.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("completed 5 iterations"));
    std::fs::remove_file(&snap).unwrap();
}

#[test]
fn checkpoint_every_without_save_model_errors() {
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "4", "--checkpoint-every", "2",
    ]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--save-model"));
}

#[test]
fn config_file_drives_factorization() {
    let path = std::env::temp_dir().join("esnmf_cli_config.toml");
    std::fs::write(
        &path,
        "corpus = reuters\nscale = tiny\nseed = 7\n[nmf]\nk = 4\niters = 6\n[sparsity]\nmode = both\nt_u = 40\nt_v = 80\n",
    )
    .unwrap();
    let out = esnmf(&["factorize", "--config", path.to_str().unwrap()]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("completed 6 iterations"));
}
