//! Tracing-plane acceptance tests: tracing is telemetry, never an input
//! — factors are bit-identical with tracing on or off — and a traced run
//! (local blocked, sequential, or distributed) must cover every span
//! kind of the taxonomy with parseable versioned JSONL that
//! `trace-report` can render.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Duration;

use esnmf::coordinator::{run_distributed_on, run_worker, DistOptions};
use esnmf::corpus::{generate_tdm, reuters_sim, Scale};
use esnmf::io::CorpusStore;
use esnmf::nmf::{
    factorize, factorize_corpus, factorize_sequential, NmfOptions, NmfResult, SequentialOptions,
    SparsityMode,
};
use esnmf::sparse::TieMode;
use esnmf::util::json::Json;
use esnmf::util::trace;

/// The tracer is process-global; every test that enables it serializes
/// here (the library's own trace tests have their own lock — different
/// process, different binary).
fn trace_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
}

fn temp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("esnmf_it_trace_{name}"))
}

/// Global enforcement with block_rows well below the corpus height, so
/// the run exercises the two-pass select/emit machinery over real
/// multi-block spans.
fn enforced_opts() -> NmfOptions {
    let mut opts = NmfOptions::new(4)
        .with_iters(3)
        .with_seed(0x7ace)
        .with_sparsity(SparsityMode::both(60, 140))
        .with_threads(2)
        .with_block_rows(3);
    opts.tie_mode = TieMode::Exact;
    opts
}

fn span_of(e: &Json) -> &str {
    e.get("span").and_then(Json::as_str).unwrap_or("?")
}

fn field(e: &Json, name: &str) -> Option<f64> {
    e.get(name).and_then(Json::as_f64)
}

fn kinds_of(events: &[Json]) -> Vec<String> {
    let mut kinds: Vec<String> = events.iter().map(|e| span_of(e).to_string()).collect();
    kinds.sort();
    kinds.dedup();
    kinds
}

#[test]
fn traced_run_is_bit_identical_and_covers_every_local_span_kind() {
    let _guard = trace_lock();
    trace::disable();
    let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 0x7ace);
    let opts = enforced_opts();

    let ck_plain = temp("plain.esnmf");
    let ck_traced = temp("traced.esnmf");
    let trace_path = temp("local.trace.jsonl");
    for p in [&ck_plain, &ck_traced, &trace_path] {
        let _ = std::fs::remove_file(p);
    }

    let plain = factorize(&tdm, &opts.clone().with_checkpoint(&ck_plain, 2));
    trace::enable(Some(&trace_path)).unwrap();
    let traced = factorize(&tdm, &opts.clone().with_checkpoint(&ck_traced, 2));
    trace::disable();

    // telemetry, never an input: the traced run is byte-identical
    assert_eq!(plain.u, traced.u, "U with tracing on vs off");
    assert_eq!(plain.v, traced.v, "V with tracing on vs off");
    assert_eq!(plain.residuals, traced.residuals, "residuals");
    assert_eq!(plain.digest(), traced.digest(), "digest");

    let text = std::fs::read_to_string(&trace_path).unwrap();
    let events = trace::parse_trace(&text).expect("trace file parses");
    let kinds = kinds_of(&events);
    for want in [
        "iteration",
        "half_step_v",
        "half_step_u",
        "select_pass",
        "emit_pass",
        "error_pass",
        "checkpoint",
    ] {
        assert!(kinds.iter().any(|k| k == want), "missing {want}: {kinds:?}");
    }

    // iteration spans carry convergence telemetry, one per iteration
    let iters: Vec<&Json> = events.iter().filter(|e| span_of(e) == "iteration").collect();
    assert_eq!(iters.len(), traced.iterations, "one iteration span per iter");
    for e in &iters {
        assert!(field(e, "iter").is_some(), "iteration has iter field");
        assert!(field(e, "residual").is_some(), "iteration has residual");
    }

    // spans nest: every half-step window sits inside some iteration
    // window (±5 µs for microsecond truncation on both endpoints)
    for e in events.iter().filter(|e| span_of(e).starts_with("half_step_")) {
        let t0 = field(e, "t_us").unwrap();
        let t1 = t0 + field(e, "dur_us").unwrap();
        let contained = iters.iter().any(|it| {
            let it0 = field(it, "t_us").unwrap();
            let it1 = it0 + field(it, "dur_us").unwrap();
            it0 <= t0 + 5.0 && t1 <= it1 + 5.0
        });
        assert!(contained, "{} span outside every iteration window", span_of(e));
    }

    // select passes record the order-statistic threshold and candidate
    // volume; emit passes the post-enforcement nnz
    let select = events.iter().find(|e| span_of(e) == "select_pass").unwrap();
    assert!(field(select, "cand_nnz").is_some_and(|v| v > 0.0));
    assert!(field(select, "tau").is_some());
    let emit = events.iter().find(|e| span_of(e) == "emit_pass").unwrap();
    assert!(field(emit, "nnz").is_some_and(|v| v > 0.0));

    // and the report renderer accepts the real thing
    let md = trace::render_report(&events);
    assert!(md.contains("## Time by span kind"), "{md}");
    assert!(md.contains("| iteration |"), "{md}");
    assert!(md.contains("## Convergence"), "{md}");
    assert!(md.contains("## Sparsity"), "{md}");

    for p in [&ck_plain, &ck_traced, &trace_path] {
        std::fs::remove_file(p).unwrap();
    }
}

#[test]
fn sequential_run_records_its_own_iteration_spans() {
    let _guard = trace_lock();
    let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 0x5e9);
    let sopts = SequentialOptions::new(2, 2)
        .with_budgets(40, 90)
        .with_seed(0x5e9)
        .with_threads(1)
        .with_block_rows(4);

    trace::enable(None).unwrap();
    let r = factorize_sequential(&tdm, &sopts);
    trace::disable();
    assert_eq!(r.u.cols, 2, "rank = blocks × block_topics");

    let events = trace::parse_trace(&trace::ring_jsonl()).unwrap();
    let kinds = kinds_of(&events);
    for want in ["iteration", "half_step_v", "half_step_u", "error_pass"] {
        assert!(kinds.iter().any(|k| k == want), "missing {want}: {kinds:?}");
    }
    // block × inner loop: 2 blocks × 2 inner iterations
    let n_iters = events.iter().filter(|e| span_of(e) == "iteration").count();
    assert_eq!(n_iters, 4, "sequential iteration spans");
}

/// Spawn in-process workers against an ephemeral loopback listener and
/// run the coordinator (the integration_distributed idiom).
fn run_with_workers(
    store: &CorpusStore,
    store_path: &Path,
    opts: &NmfOptions,
    workers: usize,
) -> NmfResult {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let objective = opts.objective;
    let handles: Vec<_> = (0..workers)
        .map(|_| {
            let path = store_path.to_path_buf();
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&path, &addr, objective, 1))
        })
        .collect();
    let dopts = DistOptions {
        listen: addr,
        workers,
        timeout: Duration::from_secs(30),
    };
    let result = run_distributed_on(listener, store, opts, &dopts).expect("distributed run");
    for h in handles {
        h.join().unwrap().expect("worker exits cleanly");
    }
    result
}

#[test]
fn distributed_trace_covers_scatter_merge_and_worker_totals() {
    let _guard = trace_lock();
    let path = temp("dist.estdm");
    let _ = std::fs::remove_file(&path);
    let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 0xd7ace);
    CorpusStore::write(&path, &tdm, 5).unwrap();
    let store = CorpusStore::open(&path).unwrap();
    let opts = enforced_opts();

    let baseline = factorize_corpus(&store, &opts);
    trace::enable(None).unwrap();
    let dist = run_with_workers(&store, &path, &opts, 2);
    trace::disable();
    assert_eq!(baseline.digest(), dist.digest(), "traced distributed digest");

    let events = trace::parse_trace(&trace::ring_jsonl()).unwrap();
    let kinds = kinds_of(&events);
    for want in ["scatter_select", "scatter_emit", "merge", "worker_summary", "dist_totals"] {
        assert!(kinds.iter().any(|k| k == want), "missing {want}: {kinds:?}");
    }

    // scatter spans record the batch geometry
    let scatter = events.iter().find(|e| span_of(e) == "scatter_emit").unwrap();
    assert!(field(scatter, "n_blocks").is_some_and(|v| v > 0.0));
    assert_eq!(field(scatter, "workers"), Some(2.0));
    assert!(field(scatter, "rounds").is_some_and(|v| v >= 1.0));

    // per-worker summaries sum to the coordinator totals, counter by
    // counter — the invariant the CI trace smoke re-checks end-to-end
    let workers: Vec<&Json> = events
        .iter()
        .filter(|e| span_of(e) == "worker_summary")
        .collect();
    assert_eq!(workers.len(), 2, "one summary per admitted worker");
    let totals = events.iter().find(|e| span_of(e) == "dist_totals").unwrap();
    assert_eq!(field(totals, "workers"), Some(2.0));
    let counter_kinds = [
        "requests",
        "compute_us",
        "wait_us",
        "items",
        "straggler_rounds",
        "reassigned_spans",
    ];
    for kind in counter_kinds {
        let sum: f64 = workers.iter().filter_map(|e| field(e, kind)).sum();
        assert_eq!(Some(sum), field(totals, kind), "worker {kind} sums to total");
    }
    assert!(
        field(totals, "requests").is_some_and(|v| v > 0.0),
        "workers actually served requests"
    );
    for w in &workers {
        assert_eq!(field(w, "alive"), Some(1.0), "no worker died in this run");
    }

    // the report's worker table renders from the same events
    let md = trace::render_report(&events);
    assert!(md.contains("## Workers"), "{md}");

    std::fs::remove_file(&path).unwrap();
}
