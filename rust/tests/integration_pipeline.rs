//! Full-pipeline integration: corpus → streaming ingestion → jobs →
//! topic model → classification, plus the disk loader round-trip.

use esnmf::coordinator::ingest::{ingest_stream, IngestConfig, RawDoc};
use esnmf::coordinator::{JobManager, JobSpec, TopicModel};
use esnmf::corpus::{self, Scale};
use esnmf::nmf::{NmfOptions, SparsityMode};
use std::sync::Arc;

#[test]
fn stream_ingest_factorize_classify() {
    let spec = corpus::pubmed_sim(Scale::Tiny);
    let docs = corpus::generate(&spec, 21);
    let n = docs.len();
    let stream = docs.into_iter().map(|d| RawDoc {
        text: d.tokens.join(" "),
        label: Some(spec.topics[d.label as usize].name.clone()),
    });
    let (tdm, count) = ingest_stream(
        stream,
        &IngestConfig {
            workers: 3,
            capacity: 16,
        },
    );
    assert_eq!(count, n);

    let tdm = Arc::new(tdm);
    let mgr = JobManager::new(2);
    let id = mgr.submit(
        Arc::clone(&tdm),
        JobSpec::Als(
            NmfOptions::new(5)
                .with_iters(30)
                .with_seed(4)
                .with_sparsity(SparsityMode::both(150, 800))
                .with_track_error(false),
        ),
    );
    let r = mgr.wait_result(id).unwrap();
    let model = TopicModel::new(r.u.clone(), r.v.clone(), tdm.terms.clone());

    // classification should route domain vocabulary to distinct topics
    let neuro = model.classify(&["stroke", "seizure", "brain", "migraine"]);
    let edu = model.classify(&["students", "curriculum", "teaching", "learning"]);
    assert!(neuro[0].1 > 0.2, "no confident neuro topic: {neuro:?}");
    assert!(edu[0].1 > 0.2, "no confident edu topic: {edu:?}");
    assert_ne!(
        neuro[0].0, edu[0].0,
        "neurology and education mapped to the same topic"
    );
}

#[test]
fn disk_loader_roundtrip_matches_generator() {
    let dir = std::env::temp_dir().join("esnmf_it_corpus");
    let _ = std::fs::remove_dir_all(&dir);
    let spec = corpus::CorpusSpec {
        n_docs: 60,
        ..corpus::reuters_sim(Scale::Tiny)
    };
    let docs = corpus::generate(&spec, 23);
    for (i, d) in docs.iter().enumerate() {
        let label = &spec.topics[d.label as usize].name;
        let subdir = dir.join(label);
        std::fs::create_dir_all(&subdir).unwrap();
        std::fs::write(subdir.join(format!("d{i:04}.txt")), d.tokens.join(" ")).unwrap();
    }
    let tdm = corpus::loader::load_dir(&dir).unwrap();
    assert_eq!(tdm.n_docs(), 60);
    assert!(tdm.doc_labels.is_some());
    assert_eq!(tdm.label_names.len(), 5);
    // loaded corpus factorizes cleanly
    let r = esnmf::nmf::factorize(
        &tdm,
        &NmfOptions::new(3).with_iters(10).with_seed(1).with_track_error(false),
    );
    assert!(r.final_residual().is_finite());
}

#[test]
fn many_concurrent_jobs_on_shared_corpus() {
    let tdm = Arc::new(corpus::generate_tdm(
        &corpus::reuters_sim(Scale::Tiny),
        25,
    ));
    let mgr = JobManager::new(4);
    let ids: Vec<_> = (0..12)
        .map(|i| {
            mgr.submit(
                Arc::clone(&tdm),
                JobSpec::Als(
                    NmfOptions::new(3)
                        .with_iters(6)
                        .with_seed(i as u64)
                        .with_sparsity(SparsityMode::both(30 + i * 10, 100))
                        .with_track_error(false),
                ),
            )
        })
        .collect();
    for (i, id) in ids.iter().enumerate() {
        let r = mgr.wait_result(*id).unwrap();
        assert!(r.u.nnz() <= 30 + i * 10, "job {i} violated its budget");
    }
}
