//! Cross-module sparse-substrate integration: larger randomized matrices
//! through the full conversion/product/enforcement pipeline.

use esnmf::sparse::{ops, topk, Coo, Csr, RowBlock, TieMode};
use esnmf::util::prop;
use esnmf::util::rng::Rng;

fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
    let mut coo = Coo::new(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            if rng.f64() < density {
                coo.push(r, c, rng.abs_normal_f32() + 1e-4);
            }
        }
    }
    coo.to_csr()
}

#[test]
fn conversion_roundtrips_at_scale() {
    let mut rng = Rng::new(1);
    let m = random_csr(&mut rng, 500, 300, 0.02);
    assert_eq!(m.to_csc().to_csr(), m);
    assert_eq!(m.transpose().transpose(), m);
    let rb = RowBlock::from_csr(&m);
    assert_eq!(rb.to_csr(), m);
    m.validate().unwrap();
}

#[test]
fn product_associativity_with_identity() {
    let mut rng = Rng::new(2);
    let a = random_csr(&mut rng, 80, 60, 0.05);
    let eye = {
        let mut coo = Coo::new(60, 60);
        for i in 0..60 {
            coo.push(i, i, 1.0);
        }
        coo.to_csr()
    };
    let prod = ops::spmm(&a, &eye);
    assert_eq!(prod, a);
}

#[test]
fn atb_equals_spmm_of_transpose() {
    prop::check("atb-vs-spmm", 77, 24, |rng| {
        let n = rng.range(2, 40);
        let m = rng.range(2, 40);
        let k = rng.range(1, 6);
        let a = random_csr(rng, n, m, 0.1);
        let u = random_csr(rng, n, k, 0.4);
        let fast = ops::atb(&a.to_csc(), &u).to_csr();
        let slow = ops::spmm(&a.transpose(), &u);
        assert_eq!(fast.rows, slow.rows);
        for r in 0..fast.rows {
            let (fi, fv) = fast.row(r);
            let (si, sv) = slow.row(r);
            assert_eq!(fi, si, "row {r} pattern");
            for (a, b) in fv.iter().zip(sv) {
                assert!((a - b).abs() < 1e-4, "row {r}: {a} vs {b}");
            }
        }
    });
}

#[test]
fn gram_psd_at_scale() {
    let mut rng = Rng::new(3);
    let u = random_csr(&mut rng, 1000, 8, 0.1);
    let g = ops::gram(&u);
    // diagonal dominance of a Gram matrix: g[i][i] >= 0 and
    // |g[i][j]| <= sqrt(g[i][i] g[j][j]) (Cauchy-Schwarz)
    for i in 0..8 {
        assert!(g[i * 8 + i] >= 0.0);
        for j in 0..8 {
            let bound = (g[i * 8 + i] as f64 * g[j * 8 + j] as f64).sqrt() + 1e-4;
            assert!(
                (g[i * 8 + j] as f64).abs() <= bound,
                "CS violated at ({i},{j})"
            );
        }
    }
}

#[test]
fn enforcement_pipeline_preserves_invariants() {
    prop::check("enforce-pipeline", 99, 32, |rng| {
        let rows = rng.range(2, 60);
        let k = rng.range(1, 8);
        let m = random_csr(rng, rows, k, 0.5);
        let nnz0 = m.nnz();
        let t = rng.range(0, nnz0 + 5);

        let mut exact = m.clone();
        topk::enforce_top_t_csr(&mut exact, t, TieMode::Exact);
        assert_eq!(exact.nnz(), t.min(nnz0));
        exact.validate().unwrap();

        let mut ties = m.clone();
        topk::enforce_top_t_csr(&mut ties, t, TieMode::KeepTies);
        assert!(ties.nnz() >= exact.nnz());
        // keep-ties result is a superset of some exact-t result: every
        // kept value must be >= the smallest kept value of exact
        if exact.nnz() > 0 && t > 0 {
            let min_exact = exact.values.iter().copied().fold(f32::INFINITY, f32::min);
            assert!(ties.values.iter().all(|&v| v >= min_exact));
        }
    });
}

#[test]
fn per_column_and_global_agree_when_budget_is_loose() {
    let mut rng = Rng::new(5);
    let m = random_csr(&mut rng, 40, 4, 0.5);
    let mut a = m.clone();
    let mut b = m.clone();
    // budgets larger than any column/matrix nnz → both no-ops
    topk::enforce_top_t_csr(&mut a, m.nnz() + 10, TieMode::KeepTies);
    topk::enforce_top_t_per_column(&mut b, m.nnz() + 10, TieMode::KeepTies);
    assert_eq!(a, m);
    assert_eq!(b, m);
}

#[test]
fn fro_norms_consistent_across_formats() {
    let mut rng = Rng::new(6);
    let m = random_csr(&mut rng, 200, 100, 0.03);
    let dense = m.to_dense();
    let want: f64 = dense.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
    assert!((m.fro_norm() - want).abs() < 1e-6 * (1.0 + want));
    assert!((m.transpose().fro_norm() - want).abs() < 1e-6 * (1.0 + want));
}
