//! Property suite for the restructured hot kernels (chunked-accumulator
//! SpMM, dense-gather gram fast path, touched-index scratch clears):
//! every kernel is pinned bit-for-bit against its straight-line
//! reference in [`esnmf::sparse::ops::reference`], and the solver-level
//! determinism digest is pinned across every in-process execution mode
//! — threads × block heights × objectives, plus the sequential solver's
//! thread contract. (The distributed mode's digest equivalence lives in
//! `integration_distributed.rs` and the CI distributed-smoke job, which
//! diff the same [`NmfResult::digest`] across worker counts.)

use esnmf::corpus::words;
use esnmf::corpus::{generate_tdm, CorpusSpec, TopicSpec};
use esnmf::nmf::{
    factorize, factorize_sequential, NmfOptions, ObjectiveKind, SequentialOptions, SparsityMode,
};
use esnmf::sparse::ops::{self, reference};
use esnmf::sparse::{Csr, RowBlock, RowCursor};
use esnmf::util::prop;
use esnmf::util::rng::Rng;

/// Thread counts the contracts are pinned at: serial, even split,
/// typical small machine, and a prime that leaves ragged ranges.
const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 7];

/// Block heights the blocked streaming contract is pinned at: single
/// row, a prime (ragged final block), auto, and one-block/unblocked.
const BLOCK_ROWS: [usize; 4] = [1, 7, 0, usize::MAX];

/// A small random corpus — deliberately tiny so the full execution-mode
/// cross product stays fast.
fn tiny_corpus(rng: &mut Rng) -> esnmf::text::TermDocMatrix {
    let spec = CorpusSpec {
        name: "prop-kernels".into(),
        topics: vec![
            TopicSpec { name: "coffee".into(), seeds: words::COFFEE.to_vec() },
            TopicSpec { name: "science".into(), seeds: words::SCIENCE.to_vec() },
        ],
        n_docs: rng.range(20, 45),
        doc_len_mean: rng.range(12, 30),
        topic_tail: rng.range(10, 30),
        background_tail: rng.range(10, 25),
        background_frac: 0.2 + rng.f64() * 0.3,
        mixture: rng.f64() * 0.3,
        zipf_s: 1.0 + rng.f64() * 0.2,
    };
    generate_tdm(&spec, rng.next_u64())
}

fn bits(data: &[f32]) -> Vec<u32> {
    data.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn restructured_spmm_bit_matches_reference_at_every_thread_count() {
    // the chunked-accumulator / touched-clear SpMM, driven through the
    // public parallel entry point, against the pre-restructure loop:
    // both factor layouts (sparse scatter and dense gather), with and
    // without the fused sequential-ALS deflation, at every pinned
    // thread count — row ids and f32 bit patterns must agree exactly
    prop::check("prop-kernels-spmm", 0x9A01, 12, |rng: &mut Rng| {
        let n = rng.range(1, 40);
        let m = rng.range(1, 30);
        let k = rng.range(1, 2 * ops::ACC_LANES + 4);
        let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.3));
        let f = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.6));
        let fd = ops::dense_factor(&f);
        let d = Csr::from_dense(n, 2, &prop::gen_sparse_dense(rng, n, 2, 0.4));
        let mm: Vec<f32> = (0..2 * k).map(|_| rng.normal() as f32).collect();
        for dense in [None, fd.as_deref()] {
            for defl in [None, Some((&d, &mm[..]))] {
                let mut cur = RowCursor::new();
                let mut want = RowBlock::new(n, k);
                reference::stream_mul_into_ref(&a, &f, dense, defl, 0, n, &mut cur, &mut want);
                let case = (dense.is_some(), defl.is_some());
                for &threads in &THREAD_COUNTS {
                    let got = ops::stream_mul_par_with(&a, &f, dense, defl, threads);
                    assert_eq!(got.row_ids, want.row_ids, "rows {case:?} threads {threads}");
                    assert_eq!(
                        bits(&got.data),
                        bits(&want.data),
                        "data {case:?} threads {threads}"
                    );
                }
            }
        }
    });
}

#[test]
fn restructured_gram_and_error_trace_bit_match_reference() {
    // the gram dense-gather fast path (and its sparse fallback) against
    // the all-pairs reference at every thread count, and the
    // touched-clear error trace against the full-memset reference at
    // several chunkings — exact f32/f64 bit equality
    prop::check("prop-kernels-gram-trace", 0x9A02, 12, |rng: &mut Rng| {
        let n = rng.range(1, 35);
        let k = rng.range(1, 12);
        let density = [0.2, 0.5, 0.9][rng.range(0, 3)];
        let x = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, density));
        let want = bits(&reference::gram_ref(&x));
        for &threads in &THREAD_COUNTS {
            let got = bits(&ops::gram_par(&x, threads));
            assert_eq!(got, want, "gram density {density} threads {threads}");
        }

        let m = rng.range(1, 25);
        let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.4));
        let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.5));
        let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.5));
        for chunk_rows in [1, 3, n + 5] {
            let got = ops::tr_cross_source(&a, &u, &v, chunk_rows);
            let want = reference::tr_cross_source_ref(&a, &u, &v, chunk_rows);
            assert_eq!(got.to_bits(), want.to_bits(), "tr_cross chunk {chunk_rows}");
        }
    });
}

#[test]
fn digest_is_stable_across_every_execution_mode() {
    // the determinism contract at solver level: one digest per
    // (corpus, options) no matter how the work is scheduled — every
    // (threads, block_rows) pair, blocked and unblocked, under both
    // objectives. This is exactly the value the CI distributed-smoke
    // job diffs between a single process and an N-worker cluster.
    prop::check("prop-kernels-digest", 0x9A03, 3, |rng: &mut Rng| {
        let tdm = tiny_corpus(rng);
        let k = rng.range(2, 5);
        let seed = rng.next_u64();
        let t_u = rng.range(k, 120);
        let t_v = rng.range(k, 200);
        for objective in [ObjectiveKind::Frobenius, ObjectiveKind::Kl] {
            let base = NmfOptions::new(k)
                .with_iters(3)
                .with_seed(seed)
                .with_sparsity(SparsityMode::both(t_u, t_v))
                .with_objective(objective)
                .with_track_error(true)
                .with_threads(1)
                .with_block_rows(usize::MAX);
            let want = factorize(&tdm, &base).digest();
            for &threads in &THREAD_COUNTS[..3] {
                for &block_rows in &BLOCK_ROWS {
                    let r = factorize(
                        &tdm,
                        &base.clone().with_threads(threads).with_block_rows(block_rows),
                    );
                    assert_eq!(
                        r.digest(),
                        want,
                        "objective {objective:?} threads {threads} block_rows {block_rows}"
                    );
                }
            }
        }
    });
}

#[test]
fn sequential_solver_digest_is_stable_across_threads_and_blocks() {
    // the sequential (deflation) solver produces a *different* result
    // from standard ALS by design, but its own digest must not observe
    // the thread count or the streaming block height either — this is
    // the path whose fused deflation SpMM kept the historical loop
    prop::check("prop-kernels-seq-digest", 0x9A04, 3, |rng: &mut Rng| {
        let tdm = tiny_corpus(rng);
        let seed = rng.next_u64();
        let base = SequentialOptions::new(4, 2).with_budgets(8, 40).with_seed(seed);
        let want = factorize_sequential(&tdm, &base.clone().with_threads(1)).digest();
        for &threads in &THREAD_COUNTS[1..] {
            for &block_rows in &BLOCK_ROWS {
                let r = factorize_sequential(
                    &tdm,
                    &base.clone().with_threads(threads).with_block_rows(block_rows),
                );
                assert_eq!(r.digest(), want, "threads {threads} block_rows {block_rows}");
            }
        }
    });
}
