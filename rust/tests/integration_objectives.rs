//! Objective-seam acceptance tests, end-to-end through the real binary:
//! `factorize --objective kl` → `--save-model` → `serve --model`
//! (FOLDIN/CLASSIFY/STATS) → checkpoint + `--resume` → `--distributed`.
//!
//! The objective under test comes from `ESNMF_OBJECTIVE` (default `kl`,
//! which is what the CI `kl-tiny-blocks` matrix entry pins alongside
//! `ESNMF_BLOCK_ROWS=3`), so the same suite also proves the Frobenius
//! path end-to-end when pointed at it.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::process::Command;

fn objective() -> String {
    std::env::var("ESNMF_OBJECTIVE").unwrap_or_else(|_| "kl".into())
}

fn esnmf(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_esnmf"))
        .args(args)
        .env("ESNMF_LOG", "warn")
        .output()
        .expect("spawning esnmf")
}

fn digest_line(stdout: &str) -> String {
    stdout
        .lines()
        .find(|l| l.starts_with("factors digest:"))
        .unwrap_or_else(|| panic!("no digest line in:\n{stdout}"))
        .to_string()
}

#[test]
fn factorize_prints_the_objective_and_heldout_likelihood() {
    let obj = objective();
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "5", "--sparsity", "both", "--t-u", "60", "--t-v", "120",
        "--seed", "17", "--objective", &obj,
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        digest_line(&text).contains(&format!("objective={obj}")),
        "{text}"
    );
    assert!(text.contains("held-out mean log-likelihood:"), "{text}");
}

#[test]
fn unknown_objective_is_a_usage_error() {
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny",
        "--objective", "itakura",
    ]);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("objective"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn kl_requires_the_native_als_path() {
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny",
        "--objective", "kl", "--algorithm", "seq",
    ]);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("sequential"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn save_model_then_serve_answers_under_the_trained_objective() {
    let obj = objective();
    let snap = std::env::temp_dir().join(format!("esnmf_obj_serve_{obj}.esnmf"));
    let _ = std::fs::remove_file(&snap);
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "6", "--sparsity", "both", "--t-u", "60", "--t-v", "120",
        "--seed", "19", "--objective", &obj, "--save-model", snap.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(snap.exists());

    let mut child = Command::new(env!("CARGO_BIN_EXE_esnmf"))
        .args(["serve", "--model", snap.to_str().unwrap(), "--addr", "127.0.0.1:0"])
        .env("ESNMF_LOG", "warn")
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawning esnmf serve");
    let mut banner = String::new();
    BufReader::new(child.stdout.as_mut().unwrap())
        .read_line(&mut banner)
        .unwrap();
    let addr = banner
        .split_whitespace()
        .find(|t| t.contains(':') && t.starts_with("127.0.0.1"))
        .unwrap_or_else(|| panic!("no address in banner {banner:?}"))
        .to_string();
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut query = |cmd: &str| -> String {
        writeln!(writer, "{cmd}").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        line.trim_end().to_string()
    };
    // STATS leads with the serving objective
    let stats = query("STATS");
    assert!(
        stats.starts_with(&format!("OK objective={obj} ")),
        "{stats}"
    );
    // fold-in and classify answer (under the model's own objective)
    let folded = query("FOLDIN coffee:2 crop:1");
    assert!(folded.starts_with("OK"), "{folded}");
    let classified = query("CLASSIFY coffee crop");
    assert!(classified.starts_with("OK topic:"), "{classified}");
    query("QUIT");
    child.kill().unwrap();
    let _ = child.wait();
    std::fs::remove_file(&snap).unwrap();
}

#[test]
fn resumed_run_matches_the_uninterrupted_digest() {
    let obj = objective();
    let ck = std::env::temp_dir().join(format!("esnmf_obj_resume_{obj}.esnmf"));
    let _ = std::fs::remove_file(&ck);
    let common = [
        "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--sparsity", "both", "--t-u", "60", "--t-v", "120", "--seed", "23",
    ];
    // first half of the run, persisted as a checkpoint snapshot
    let mut args: Vec<&str> = vec!["factorize", "--objective", &obj, "--iters", "3"];
    args.extend_from_slice(&common);
    args.extend_from_slice(&["--save-model", ck.to_str().unwrap()]);
    assert!(esnmf(&args).status.success());
    // resume to the full length
    let mut args: Vec<&str> = vec!["factorize", "--objective", &obj, "--iters", "6"];
    args.extend_from_slice(&common);
    args.extend_from_slice(&["--resume", ck.to_str().unwrap()]);
    let resumed = esnmf(&args);
    assert!(
        resumed.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&resumed.stderr)
    );
    // the uninterrupted reference
    let mut args: Vec<&str> = vec!["factorize", "--objective", &obj, "--iters", "6"];
    args.extend_from_slice(&common);
    let full = esnmf(&args);
    assert!(full.status.success());
    assert_eq!(
        digest_line(&String::from_utf8_lossy(&resumed.stdout)),
        digest_line(&String::from_utf8_lossy(&full.stdout)),
        "resumed run diverged from the uninterrupted one"
    );
    std::fs::remove_file(&ck).unwrap();
}

#[test]
fn resume_refuses_an_objective_mismatch() {
    let ck = std::env::temp_dir().join("esnmf_obj_mismatch.esnmf");
    let _ = std::fs::remove_file(&ck);
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "3", "--seed", "29", "--objective", "kl",
        "--save-model", ck.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    // the snapshot was trained under KL; resuming it under Frobenius
    // would silently change the math mid-run — typed refusal instead
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--iters", "6", "--seed", "29", "--objective", "frobenius",
        "--resume", ck.to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("objective"), "{err}");
    assert_eq!(out.status.code(), Some(3), "snapshot mismatches exit 3");
    std::fs::remove_file(&ck).unwrap();
}

#[test]
fn distributed_matches_the_single_process_digest() {
    let obj = objective();
    let store_path = std::env::temp_dir().join(format!("esnmf_obj_dist_{obj}.estdm"));
    let _ = std::fs::remove_file(&store_path);
    let out = esnmf(&[
        "ingest", "--corpus", "reuters", "--scale", "tiny", "--seed", "31",
        "--shard-rows", "5", "--out", store_path.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "ingest stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let common = [
        "--k", "3", "--iters", "3", "--sparsity", "both", "--t-u", "50",
        "--t-v", "110", "--seed", "31", "--block-rows", "7",
    ];
    let mut local_args: Vec<&str> = vec![
        "factorize", "--objective", &obj,
        "--corpus-store", store_path.to_str().unwrap(),
    ];
    local_args.extend_from_slice(&common);
    let local_out = esnmf(&local_args);
    assert!(
        local_out.status.success(),
        "local stderr: {}",
        String::from_utf8_lossy(&local_out.stderr)
    );
    let local_digest = digest_line(&String::from_utf8_lossy(&local_out.stdout));

    let probe = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);
    let mut workers: Vec<_> = (0..2)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_esnmf"))
                .args([
                    "worker",
                    store_path.to_str().unwrap(),
                    "--coordinator",
                    addr.as_str(),
                    "--objective",
                    obj.as_str(),
                    "--threads",
                    "1",
                ])
                .env("ESNMF_LOG", "warn")
                .spawn()
                .expect("spawning worker")
        })
        .collect();
    let mut dist_args: Vec<&str> = vec![
        "factorize", "--objective", &obj,
        "--corpus-store", store_path.to_str().unwrap(),
        "--distributed", "--dist-workers", "2", "--dist-listen", addr.as_str(),
        "--dist-timeout", "30",
    ];
    dist_args.extend_from_slice(&common);
    let dist_out = esnmf(&dist_args);
    for w in &mut workers {
        let _ = w.kill();
        let _ = w.wait();
    }
    assert!(
        dist_out.status.success(),
        "distributed stderr: {}",
        String::from_utf8_lossy(&dist_out.stderr)
    );
    assert_eq!(
        digest_line(&String::from_utf8_lossy(&dist_out.stdout)),
        local_digest,
        "distributed run diverged under objective {obj}"
    );
    std::fs::remove_file(&store_path).unwrap();
}
