//! Out-of-core corpus store acceptance tests: `ingest` +
//! `factorize --corpus-store` must produce `NmfResult`s bit-identical to
//! the in-memory factorization at every `(block_rows, threads)`
//! combination, with resident corpus bytes bounded by the shards in
//! flight across workers — strictly below full-matrix residency.

use esnmf::corpus::{generate_tdm, reuters_sim, Scale};
use esnmf::io::{CorpusStore, Snapshot, SnapshotError};
use esnmf::nmf::{
    factorize, factorize_corpus, factorize_sequential, factorize_sequential_corpus,
    resume_corpus, NmfOptions, NmfResult, SequentialOptions, SparsityMode,
};
use esnmf::sparse::TieMode;
use esnmf::text::TermDocMatrix;
use std::path::PathBuf;
use std::process::Command;

fn corpus() -> TermDocMatrix {
    generate_tdm(&reuters_sim(Scale::Tiny), 0x0c0de)
}

fn temp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("esnmf_it_store_{name}"))
}

fn write_store(name: &str, tdm: &TermDocMatrix, shard_rows: usize) -> (PathBuf, CorpusStore) {
    let path = temp(&format!("{name}.estdm"));
    let _ = std::fs::remove_file(&path);
    CorpusStore::write(&path, tdm, shard_rows).unwrap();
    let store = CorpusStore::open(&path).unwrap();
    (path, store)
}

fn assert_same_result(a: &NmfResult, b: &NmfResult, tag: &str) {
    assert_eq!(a.u, b.u, "{tag}: U");
    assert_eq!(a.v, b.v, "{tag}: V");
    assert_eq!(a.iterations, b.iterations, "{tag}: iterations");
    assert_eq!(a.residuals, b.residuals, "{tag}: residuals");
    assert_eq!(a.errors, b.errors, "{tag}: errors");
    assert_eq!(a.memory, b.memory, "{tag}: memory telemetry");
}

#[test]
fn store_streamed_factorization_bit_identical_to_in_memory() {
    // the acceptance matrix: block_rows {1, 7, auto} × threads {1, 4},
    // for an enforced (two-pass global, Exact ties) and an unenforced
    // run, against a store whose shards the blocks constantly straddle
    let tdm = corpus();
    let (path, store) = write_store("accept", &tdm, 5);
    assert!(
        store.terms_major().n_shards() > 3 && store.docs_major().n_shards() > 3,
        "corpus must span several shards per orientation"
    );
    for (mode, tie) in [
        (SparsityMode::both(60, 140), TieMode::Exact),
        (SparsityMode::None, TieMode::KeepTies),
    ] {
        let mut base = NmfOptions::new(4)
            .with_iters(3)
            .with_seed(0x51de)
            .with_sparsity(mode);
        base.tie_mode = tie;
        for block_rows in [1usize, 7, 0] {
            for threads in [1usize, 4] {
                let opts = base
                    .clone()
                    .with_threads(threads)
                    .with_block_rows(block_rows);
                let mem = factorize(&tdm, &opts);
                let streamed = factorize_corpus(&store, &opts);
                assert_same_result(
                    &streamed,
                    &mem,
                    &format!("mode={mode:?} block_rows={block_rows} threads={threads}"),
                );
            }
        }
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn randomized_store_equivalence_property() {
    // random corpora × random shard heights × random sparsity modes:
    // the store-streamed NmfResult equals the in-memory one bit for bit
    use esnmf::util::prop;
    let dir = temp("prop");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    prop::check("store-vs-memory", 0xe57d, 4, |rng| {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), rng.next_u64());
        let shard_rows = rng.range(1, 40);
        let path = dir.join(format!("p{}.estdm", rng.below(1 << 30)));
        CorpusStore::write(&path, &tdm, shard_rows).unwrap();
        let store = CorpusStore::open(&path).unwrap();
        let k = rng.range(2, 5);
        let mode = match rng.below(3) {
            0 => SparsityMode::None,
            1 => SparsityMode::both(rng.range(k, 150), rng.range(k, 300)),
            _ => SparsityMode::PerColumn {
                t_u_col: Some(rng.range(1, 25)),
                t_v_col: Some(rng.range(1, 50)),
            },
        };
        let mut opts = NmfOptions::new(k)
            .with_iters(2)
            .with_seed(rng.next_u64())
            .with_sparsity(mode)
            .with_threads(rng.range(1, 5))
            .with_block_rows(rng.range(1, 50));
        opts.tie_mode = if rng.below(2) == 0 {
            TieMode::KeepTies
        } else {
            TieMode::Exact
        };
        let mem = factorize(&tdm, &opts);
        let streamed = factorize_corpus(&store, &opts);
        assert_same_result(&streamed, &mem, &format!("shard_rows={shard_rows}"));
        drop(store);
        std::fs::remove_file(&path).unwrap();
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sequential_from_store_matches_in_memory() {
    let tdm = corpus();
    let (path, store) = write_store("seq", &tdm, 6);
    for block_rows in [1usize, 16, 0] {
        let opts = SequentialOptions::new(3, 4)
            .with_budgets(30, 70)
            .with_seed(0x5e9)
            .with_block_rows(block_rows);
        let mem = factorize_sequential(&tdm, &opts);
        let streamed = factorize_sequential_corpus(&store, &opts);
        assert_same_result(&streamed, &mem, &format!("sequential block_rows={block_rows}"));
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn resident_corpus_stays_within_the_shard_flight_bound() {
    // during streamed half-steps, resident corpus bytes are the shards
    // cached by in-flight worker cursors: ≤ workers × max shard bytes,
    // and strictly below the full on-disk matrix
    let tdm = corpus();
    let shard_rows = 4;
    let (path, store) = write_store("resident", &tdm, shard_rows);
    let max_shard = store
        .terms_major()
        .max_shard_bytes()
        .max(store.docs_major().max_shard_bytes());
    for threads in [1usize, 4] {
        let opts = NmfOptions::new(4)
            .with_iters(2)
            .with_seed(0xbeef)
            .with_sparsity(SparsityMode::both(50, 120))
            .with_threads(threads)
            .with_block_rows(shard_rows); // blocks within (and straddling) shards
        let _ = factorize_corpus(&store, &opts);
        let peak = store.resident().peak();
        assert!(peak > 0, "threads {threads}: nothing was ever resident?");
        assert!(
            peak <= threads * max_shard,
            "threads {threads}: resident peak {peak} exceeds {threads} workers × {max_shard} shard bytes"
        );
        assert!(
            peak < store.payload_bytes(),
            "threads {threads}: resident peak {peak} not below full residency {}",
            store.payload_bytes()
        );
        assert_eq!(
            store.resident().current(),
            0,
            "threads {threads}: cursors must release their shards"
        );
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn checkpoint_resume_and_digest_refusals_work_against_a_store() {
    let tdm = corpus();
    let (path, store) = write_store("resume", &tdm, 5);
    let ck = temp("resume_ck.esnmf");
    let _ = std::fs::remove_file(&ck);

    let mut opts = NmfOptions::new(3)
        .with_iters(7)
        .with_seed(0xadd)
        .with_sparsity(SparsityMode::both(40, 90));
    opts.tie_mode = TieMode::Exact;
    // uninterrupted reference, fully in memory
    let uninterrupted = factorize(&tdm, &opts);

    // checkpointed run streamed from the store, "crashing" at 6
    let ck_opts = opts.clone().with_iters(6).with_checkpoint(&ck, 3);
    let _ = factorize_corpus(&store, &ck_opts);
    let snap = Snapshot::load(&ck).unwrap();
    assert_eq!(snap.progress.iterations, 6);
    // the store's metadata digest is the corpus digest the snapshot pins
    assert_eq!(snap.corpus_digest, store.digest());

    // resume against the store: bit-identical to never crashing
    let resumed = resume_corpus(&store, &opts, &snap).unwrap();
    assert_same_result(&resumed, &uninterrupted, "store resume");

    // a snapshot of a different corpus is refused by digest
    let other = generate_tdm(&reuters_sim(Scale::Tiny), 0xd1ff);
    let r = factorize(&other, &opts);
    let wrong = Snapshot::new(
        opts.clone(),
        r.u,
        r.v,
        &other,
        esnmf::io::Progress {
            iterations: r.iterations,
            residuals: r.residuals,
            errors: r.errors,
            memory: r.memory,
            elapsed_s: 0.0,
        },
    );
    match resume_corpus(&store, &opts, &wrong) {
        Err(e) => assert!(format!("{e:#}").contains("digest"), "{e:#}"),
        Ok(_) => panic!("resume against the wrong corpus store was accepted"),
    }
    // the typed layer agrees
    assert!(matches!(
        wrong.check_digest(store.digest(), store.n_terms(), store.n_docs()),
        Err(SnapshotError::Mismatch(_))
    ));
    std::fs::remove_file(&ck).unwrap();
    std::fs::remove_file(&path).unwrap();
}

// ---- CLI end-to-end ------------------------------------------------------

fn esnmf(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_esnmf"))
        .args(args)
        .env("ESNMF_LOG", "warn")
        .output()
        .expect("spawning esnmf")
}

/// The deterministic result lines of a factorize run: convergence
/// numbers (wall time stripped), factor stats, topic tables, accuracy —
/// everything except the store-only resident-corpus line, the
/// dataset-name header of the sparsity report, and the `UV^T` row
/// (deliberately absent from out-of-core reports — its support can be
/// dense).
fn comparable_lines(stdout: &str) -> Vec<String> {
    stdout
        .lines()
        .filter(|l| !l.starts_with("resident corpus peak"))
        .filter(|l| !l.contains(".estdm") && !l.starts_with("reuters"))
        .filter(|l| !l.starts_with("UV^T"))
        .map(|l| match (l.find(" in "), l.find("s  final residual")) {
            (Some(a), Some(b)) if a < b => format!("{}{}", &l[..a], &l[b + 1..]),
            _ => l.to_string(),
        })
        .collect()
}

#[test]
fn cli_ingest_then_factorize_from_store_matches_in_memory_output() {
    let store_path = temp("cli.estdm");
    let _ = std::fs::remove_file(&store_path);
    let out = esnmf(&[
        "ingest", "--corpus", "reuters", "--scale", "tiny", "--seed", "21",
        "--shard-rows", "5", "--out", store_path.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "ingest stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("shards"), "{text}");
    assert!(text.contains("digest"), "{text}");

    // --seed drives both the preset generator and the init guess, so the
    // in-memory run regenerates exactly the ingested corpus
    let common = [
        "--k", "4", "--iters", "5", "--sparsity", "both", "--t-u", "50",
        "--t-v", "110", "--seed", "21", "--threads", "2", "--block-rows", "7",
    ];
    let mut mem_args: Vec<&str> =
        vec!["factorize", "--corpus", "reuters", "--scale", "tiny"];
    mem_args.extend_from_slice(&common);
    let mem_out = esnmf(&mem_args);
    assert!(
        mem_out.status.success(),
        "in-memory stderr: {}",
        String::from_utf8_lossy(&mem_out.stderr)
    );

    let mut store_args: Vec<&str> = vec!["factorize", "--corpus-store"];
    let sp = store_path.to_str().unwrap();
    store_args.push(sp);
    store_args.extend_from_slice(&common);
    let store_out = esnmf(&store_args);
    assert!(
        store_out.status.success(),
        "store stderr: {}",
        String::from_utf8_lossy(&store_out.stderr)
    );
    let store_text = String::from_utf8_lossy(&store_out.stdout);
    assert!(
        store_text.contains("resident corpus peak"),
        "{store_text}"
    );

    let mem_lines = comparable_lines(&String::from_utf8_lossy(&mem_out.stdout));
    let store_lines = comparable_lines(&store_text);
    assert_eq!(mem_lines, store_lines, "store run diverged from in-memory");
    std::fs::remove_file(&store_path).unwrap();
}

#[test]
fn cli_store_errors_are_clear() {
    // missing store file
    let out = esnmf(&[
        "factorize", "--corpus-store", "/nonexistent/nope.estdm", "--k", "3",
    ]);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("nope.estdm"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    // junk --shard-rows
    let out = esnmf(&[
        "ingest", "--corpus", "reuters", "--scale", "tiny", "--shard-rows",
        "lots", "--out", "/tmp/esnmf_junk.estdm",
    ]);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("shard-rows"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    // the XLA backend cannot stream from a store
    let out = esnmf(&[
        "factorize", "--corpus-store", "/tmp/whatever.estdm", "--backend", "xla",
    ]);
    assert!(!out.status.success());
}

#[test]
fn cli_bench_check_gates_regressions() {
    let prev = temp("bench_prev.json");
    let cur = temp("bench_cur.json");
    std::fs::write(
        &prev,
        r#"{"schema":"esnmf-bench-smoke-v1","suites":{"fig6":{"metrics":{"blocked.max_intermediate_nnz":100}}}}"#,
    )
    .unwrap();
    std::fs::write(
        &cur,
        r#"{"schema":"esnmf-bench-smoke-v1","suites":{"fig6":{"metrics":{"blocked.max_intermediate_nnz":150}}}}"#,
    )
    .unwrap();
    // regression beyond tolerance fails with the metric named
    let out = esnmf(&[
        "bench-check", "--previous", prev.to_str().unwrap(), "--current",
        cur.to_str().unwrap(), "--tolerance", "1.10",
    ]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("max_intermediate_nnz"), "{err}");
    // a generous tolerance passes
    let out = esnmf(&[
        "bench-check", "--previous", prev.to_str().unwrap(), "--current",
        cur.to_str().unwrap(), "--tolerance", "2.0",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // no previous trajectory point: nothing to compare, pass
    let out = esnmf(&[
        "bench-check", "--previous", "/nonexistent/prev.json", "--current",
        cur.to_str().unwrap(),
    ]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("nothing to compare"));
    // a previous file that exists but is garbage must fail loudly, not
    // silently disable the gate
    let corrupt = temp("bench_corrupt.json");
    std::fs::write(&corrupt, "not json at all").unwrap();
    let out = esnmf(&[
        "bench-check", "--previous", corrupt.to_str().unwrap(), "--current",
        cur.to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("corrupt"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::remove_file(&corrupt).unwrap();
    std::fs::remove_file(&prev).unwrap();
    std::fs::remove_file(&cur).unwrap();
}

#[test]
fn cli_serve_model_verifies_against_a_store() {
    // save a model from the in-memory corpus, then ask serve to verify
    // it against the matching store (digest from metadata) and against a
    // mismatched one (refusal)
    let tdm = corpus();
    let (store_path, store) = write_store("serve", &tdm, 5);
    let opts = NmfOptions::new(3).with_iters(3).with_seed(0x5e4e);
    let r = factorize(&tdm, &opts);
    let snap = Snapshot::new(
        opts.clone(),
        r.u,
        r.v,
        &tdm,
        esnmf::io::Progress::default(),
    );
    // matching digest passes the explicit check
    snap.check_digest(store.digest(), store.n_terms(), store.n_docs())
        .unwrap();
    let model_path = temp("serve_model.esnmf");
    snap.save(&model_path).unwrap();

    // a store of a different corpus refuses at serve startup
    let other = generate_tdm(&reuters_sim(Scale::Tiny), 0xffee);
    let (other_path, _other_store) = write_store("serve_other", &other, 5);
    let out = esnmf(&[
        "serve", "--model", model_path.to_str().unwrap(), "--corpus-store",
        other_path.to_str().unwrap(), "--addr", "127.0.0.1:0",
    ]);
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("digest"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::remove_file(&model_path).unwrap();
    std::fs::remove_file(&store_path).unwrap();
    std::fs::remove_file(&other_path).unwrap();
}
