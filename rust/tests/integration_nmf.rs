//! NMF-engine integration: full factorizations on preset corpora,
//! validating the paper's qualitative claims at test scale.

use esnmf::corpus::{generate_tdm, pubmed_sim, reuters_sim, wikipedia_sim, Scale};
use esnmf::eval::topics::column_nnz_cv;
use esnmf::eval::{mean_topic_accuracy, SparsityReport};
use esnmf::nmf::{
    factorize, factorize_sequential, NmfOptions, SequentialOptions, SparsityMode,
};

#[test]
fn dense_als_densifies_factors_fig1_claim() {
    let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 42);
    let r = factorize(
        &tdm,
        &NmfOptions::new(5).with_iters(25).with_seed(42).with_track_error(false),
    );
    let report = SparsityReport::compute(&tdm.a, &r.u, &r.v);
    assert!(report.a_sparsity > 0.85, "A sparsity {}", report.a_sparsity);
    assert!(
        report.u_sparsity < report.a_sparsity,
        "dense ALS should densify U: {} vs {}",
        report.u_sparsity,
        report.a_sparsity
    );
    assert!(
        report.uvt_sparsity < report.a_sparsity,
        "UVᵀ should densify: {} vs {}",
        report.uvt_sparsity,
        report.a_sparsity
    );
}

#[test]
fn enforced_sparsity_converges_with_bounded_memory_fig6_claim() {
    let tdm = generate_tdm(&pubmed_sim(Scale::Tiny), 42);
    let k = 5;
    let t = 150;
    let sparse_init = factorize(
        &tdm,
        &NmfOptions::new(k)
            .with_iters(20)
            .with_seed(1)
            .with_sparsity(SparsityMode::both(t, t))
            .with_init_nnz(200)
            .with_track_error(false),
    );
    let dense_init = factorize(
        &tdm,
        &NmfOptions::new(k)
            .with_iters(20)
            .with_seed(1)
            .with_sparsity(SparsityMode::both(t, t))
            .with_track_error(false),
    );
    let dense_storage = (tdm.n_terms() + tdm.n_docs()) * k;
    assert!(
        sparse_init.memory.max_combined_nnz < dense_storage / 2,
        "peak {} should be far below dense {}",
        sparse_init.memory.max_combined_nnz,
        dense_storage
    );
    assert!(sparse_init.memory.max_combined_nnz <= dense_init.memory.max_combined_nnz);
    // both still converge to a usable model
    assert!(sparse_init.final_residual().is_finite());
}

#[test]
fn accuracy_improves_with_sparsity_fig4_claim() {
    let tdm = generate_tdm(&pubmed_sim(Scale::Tiny), 7);
    let labels = tdm.doc_labels.clone().unwrap();
    let nj = tdm.label_names.len();
    let dense = factorize(
        &tdm,
        &NmfOptions::new(5).with_iters(30).with_seed(3).with_track_error(false),
    );
    let sparse = factorize(
        &tdm,
        &NmfOptions::new(5)
            .with_iters(30)
            .with_seed(3)
            .with_sparsity(SparsityMode::v_only(tdm.n_docs()))
            .with_track_error(false),
    );
    let acc_dense = mean_topic_accuracy(&dense.v, &labels, nj);
    let acc_sparse = mean_topic_accuracy(&sparse.v, &labels, nj);
    assert!(
        acc_sparse >= acc_dense - 0.05,
        "sparse acc {acc_sparse} vs dense {acc_dense}"
    );
    // planted clusters should be findable at all
    assert!(acc_sparse > 0.2, "accuracy {acc_sparse} too low for planted data");
}

#[test]
fn global_enforcement_skews_columnwise_fixes_table1_fig7_claim() {
    let tdm = generate_tdm(&wikipedia_sim(Scale::Tiny), 11);
    let global = factorize(
        &tdm,
        &NmfOptions::new(5)
            .with_iters(30)
            .with_seed(5)
            .with_sparsity(SparsityMode::u_only(50))
            .with_track_error(false),
    );
    let colwise = factorize(
        &tdm,
        &NmfOptions::new(5)
            .with_iters(30)
            .with_seed(5)
            .with_sparsity(SparsityMode::PerColumn {
                t_u_col: Some(10),
                t_v_col: None,
            })
            .with_track_error(false),
    );
    let cv_global = column_nnz_cv(&global.u);
    let cv_col = column_nnz_cv(&colwise.u);
    assert!(
        cv_col <= cv_global + 1e-9,
        "column-wise cv {cv_col} vs global {cv_global}"
    );
    for &c in &colwise.u.col_nnz() {
        assert!(c <= 10);
    }
}

#[test]
fn sequential_matches_rank_and_is_fast_fig9_claim() {
    let tdm = generate_tdm(&pubmed_sim(Scale::Tiny), 13);
    let k = 5;
    let iters = 50;
    let normal = factorize(
        &tdm,
        &NmfOptions::new(k)
            .with_iters(iters)
            .with_seed(7)
            .with_sparsity(SparsityMode::both(50, 250))
            .with_track_error(false)
            // the sequential solver is serial; pin ALS to one thread so
            // the elapsed-time comparison below stays apples-to-apples
            .with_threads(1),
    );
    let seq = factorize_sequential(
        &tdm,
        &SequentialOptions::new(k, iters / k)
            .with_budgets(10, 50)
            .with_seed(7),
    );
    assert_eq!(seq.u.cols, k);
    assert_eq!(normal.u.cols, k);
    // same total iteration count; sequential should not be slower by much
    // (it is typically much faster; allow generous slack for CI noise)
    assert!(
        seq.elapsed_s <= normal.elapsed_s * 2.0,
        "sequential {:.3}s vs normal {:.3}s",
        seq.elapsed_s,
        normal.elapsed_s
    );
}

#[test]
fn full_pipeline_identical_at_one_and_many_threads() {
    // the whole NmfOptions path, config file included: a run configured
    // with threads = 1 and the same run at N threads must produce an
    // identical NmfResult — factors, iteration count, convergence trace,
    // error history and memory-tracker peaks
    use esnmf::config::{ConfigFile, RunConfig};

    let file = ConfigFile::parse(
        "corpus = pubmed\nscale = tiny\nseed = 31\n[nmf]\nk = 4\niters = 8\ntrack_error = true\ninit_nnz = 150\n[sparsity]\nmode = both\nt_u = 120\nt_v = 240\n",
    )
    .unwrap();
    let mut cfg = RunConfig::default();
    cfg.apply_file(&file).unwrap();
    let tdm = generate_tdm(&pubmed_sim(Scale::Tiny), cfg.seed);

    cfg.threads = 1;
    let serial = factorize(&tdm, &cfg.nmf_options().unwrap());
    for threads in [2usize, 4, 7] {
        cfg.threads = threads;
        let par = factorize(&tdm, &cfg.nmf_options().unwrap());
        assert_eq!(par.u, serial.u, "U differs at {threads} threads");
        assert_eq!(par.v, serial.v, "V differs at {threads} threads");
        assert_eq!(par.iterations, serial.iterations);
        assert_eq!(par.residuals, serial.residuals, "trace differs at {threads} threads");
        assert_eq!(par.errors, serial.errors, "errors differ at {threads} threads");
        assert_eq!(par.memory, serial.memory, "memory peaks differ at {threads} threads");
    }
}

#[test]
fn residual_definition_matches_history() {
    // residual at iteration i uses U_i and U_{i-1}: re-run two configs
    // differing only in max_iters and confirm the shared prefix agrees
    let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 17);
    let a = factorize(
        &tdm,
        &NmfOptions::new(3).with_iters(4).with_seed(9).with_track_error(false),
    );
    let b = factorize(
        &tdm,
        &NmfOptions::new(3).with_iters(8).with_seed(9).with_track_error(false),
    );
    for (x, y) in a.residuals.iter().zip(&b.residuals) {
        assert!((x - y).abs() < 1e-12, "{x} vs {y}");
    }
}

#[test]
fn error_history_monotone_for_dense_als() {
    let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 19);
    let r = factorize(&tdm, &NmfOptions::new(4).with_iters(15).with_seed(11));
    for w in r.errors.windows(2) {
        assert!(
            w[1] <= w[0] + 1e-3,
            "dense ALS error increased: {} -> {}",
            w[0],
            w[1]
        );
    }
}
