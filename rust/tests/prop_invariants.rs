//! System-level property tests (mini-prop harness): coordinator routing /
//! batching / state invariants and NMF solver invariants under random
//! configurations.

use esnmf::coordinator::{JobManager, JobSpec};
use esnmf::corpus::{generate_tdm, CorpusSpec, TopicSpec};
use esnmf::corpus::words;
use esnmf::nmf::{factorize, NmfOptions, SparsityMode};
use esnmf::sparse::{ops, topk, Coo, Csr, TieMode};
use esnmf::util::prop;
use esnmf::util::rng::Rng;
use std::sync::Arc;

/// Thread counts the serial≡parallel contract is pinned at: serial, even
/// split, typical small machine, and a prime that leaves ragged ranges.
const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 7];

/// A random COO matrix (duplicates included — freeze merges them) with a
/// mix of positive and negative values.
fn random_coo_csr(rng: &mut Rng, rows: usize, cols: usize, negatives: bool) -> Csr {
    let mut coo = Coo::new(rows, cols);
    let nnz = rng.below(rows * cols + 1);
    for _ in 0..nnz {
        let sign = if negatives && rng.below(3) == 0 { -1.0 } else { 1.0 };
        coo.push(
            rng.below(rows),
            rng.below(cols),
            sign * (rng.f32() + 1e-4),
        );
    }
    coo.to_csr()
}

fn random_corpus(rng: &mut Rng) -> esnmf::text::TermDocMatrix {
    let spec = CorpusSpec {
        name: "prop".into(),
        topics: vec![
            TopicSpec { name: "coffee".into(), seeds: words::COFFEE.to_vec() },
            TopicSpec { name: "science".into(), seeds: words::SCIENCE.to_vec() },
            TopicSpec { name: "music".into(), seeds: words::MUSIC.to_vec() },
        ],
        n_docs: rng.range(30, 120),
        doc_len_mean: rng.range(20, 60),
        topic_tail: rng.range(10, 60),
        background_tail: rng.range(10, 40),
        background_frac: 0.2 + rng.f64() * 0.4,
        mixture: rng.f64() * 0.3,
        zipf_s: 1.0 + rng.f64() * 0.2,
    };
    generate_tdm(&spec, rng.next_u64())
}

#[test]
fn solver_invariants_under_random_configs() {
    prop::check("solver-invariants", 0xA15, 12, |rng| {
        let tdm = random_corpus(rng);
        let k = rng.range(2, 7);
        let nnz_total = tdm.a.nnz();
        let t_u = rng.range(k, (tdm.n_terms() * k).max(k + 1));
        let t_v = rng.range(k, (tdm.n_docs() * k).max(k + 1));
        let mode = match rng.below(4) {
            0 => SparsityMode::None,
            1 => SparsityMode::u_only(t_u),
            2 => SparsityMode::v_only(t_v),
            _ => SparsityMode::both(t_u, t_v),
        };
        let mut opts = NmfOptions::new(k)
            .with_iters(rng.range(2, 8))
            .with_seed(rng.next_u64())
            .with_sparsity(mode)
            .with_track_error(true);
        opts.tie_mode = if rng.below(2) == 0 {
            TieMode::KeepTies
        } else {
            TieMode::Exact
        };
        if rng.below(2) == 0 {
            opts = opts.with_init_nnz(rng.range(k, t_u.max(k + 1)));
        }
        let r = factorize(&tdm, &opts);

        // invariant 1: non-negativity of both factors
        assert!(r.u.values.iter().all(|&x| x >= 0.0));
        assert!(r.v.values.iter().all(|&x| x >= 0.0));
        // invariant 2: structural validity
        r.u.validate().unwrap();
        r.v.validate().unwrap();
        // invariant 3: budgets honored strictly in Exact mode (KeepTies
        // may legitimately exceed the budget when weights tie — synthetic
        // corpora produce duplicate document profiles surprisingly often)
        if opts.tie_mode == TieMode::Exact {
            if let SparsityMode::Global { t_u: Some(t), .. } = opts.sparsity {
                assert!(r.u.nnz() <= t, "u {} > {t}", r.u.nnz());
            }
            if let SparsityMode::Global { t_v: Some(t), .. } = opts.sparsity {
                assert!(r.v.nnz() <= t, "v {} > {t}", r.v.nnz());
            }
        }
        // invariant 4: histories have full length
        assert_eq!(r.residuals.len(), r.iterations);
        assert_eq!(r.errors.len(), r.iterations);
        // invariant 5: errors are valid relative magnitudes
        for &e in &r.errors {
            assert!(e.is_finite() && e >= 0.0, "error {e}");
        }
        // invariant 6: memory peak ≥ final footprint
        assert!(r.memory.max_combined_nnz >= r.u.nnz() + r.v.nnz() || nnz_total == 0);
    });
}

#[test]
fn parallel_kernels_byte_identical_to_serial() {
    // the determinism contract of coordinator::pool, pinned kernel by
    // kernel: SpMM (both orientations), gram, solve, projection, and
    // top-t enforcement under each TieMode — serial output must be
    // byte-identical at thread counts {1, 2, 4, 7}
    prop::check("serial-vs-parallel-kernels", 0xF66, 24, |rng| {
        let n = rng.range(1, 50);
        let m = rng.range(1, 50);
        let k = rng.range(1, 7);
        let a = random_coo_csr(rng, n, m, true);
        let u = random_coo_csr(rng, n, k, false);
        let v = random_coo_csr(rng, m, k, false);
        let a_csc = a.to_csc();

        let atb_serial = ops::atb(&a_csc, &u);
        let ab_serial = ops::ab(&a, &v);
        let gram_serial = ops::gram(&u);
        for &threads in &THREAD_COUNTS {
            assert_eq!(ops::atb_par(&a_csc, &u, threads), atb_serial, "atb threads={threads}");
            assert_eq!(ops::ab_par(&a, &v, threads), ab_serial, "ab threads={threads}");
            assert_eq!(ops::gram_par(&u, threads), gram_serial, "gram threads={threads}");
        }

        // solve + projection on a half-step-shaped candidate (negatives
        // present so the projection actually bites)
        let cand = ops::atb(&a_csc, &u);
        let small: Vec<f32> = (0..k * k).map(|_| rng.normal() as f32).collect();
        let mut serial_rb = cand.clone();
        serial_rb.matmul_small(&small);
        serial_rb.project_nonneg();
        for &threads in &THREAD_COUNTS {
            let mut par = cand.clone();
            par.matmul_small_par(&small, threads);
            par.project_nonneg_par(threads);
            assert_eq!(par, serial_rb, "solve+project threads={threads}");
            assert_eq!(cand.gram_par(threads), cand.gram(), "rb gram threads={threads}");
        }

        // top-t enforcement: force duplicate magnitudes so tie-breaking
        // is exercised, then check both modes at every thread count
        let mut quantized = serial_rb.clone();
        for val in &mut quantized.data {
            *val = (*val * 4.0).round() / 4.0;
        }
        let t = rng.below(quantized.data.len() + 2);
        for mode in [TieMode::KeepTies, TieMode::Exact] {
            let mut want = quantized.clone();
            topk::enforce_top_t_rowblock(&mut want, t, mode);
            for &threads in &THREAD_COUNTS {
                let mut got = quantized.clone();
                topk::enforce_top_t_rowblock_par(&mut got, t, mode, threads);
                assert_eq!(got, want, "top-t t={t} mode={mode:?} threads={threads}");
            }
        }

        // per-column enforcement on a frozen positive factor
        let frozen = {
            let mut rb = serial_rb.clone();
            rb.project_nonneg();
            rb.to_csr()
        };
        let t_col = rng.range(1, 5);
        for mode in [TieMode::KeepTies, TieMode::Exact] {
            let mut want = frozen.clone();
            topk::enforce_top_t_per_column(&mut want, t_col, mode);
            for &threads in &THREAD_COUNTS {
                let mut got = frozen.clone();
                topk::enforce_top_t_per_column_par(&mut got, t_col, mode, threads);
                assert_eq!(got, want, "per-col t={t_col} mode={mode:?} threads={threads}");
            }
        }
    });
}

#[test]
fn factorization_byte_identical_across_thread_counts() {
    prop::check("serial-vs-parallel-solver", 0xF77, 6, |rng| {
        let tdm = random_corpus(rng);
        let k = rng.range(2, 6);
        let mode = match rng.below(3) {
            0 => SparsityMode::None,
            1 => SparsityMode::both(rng.range(k, 200), rng.range(k, 400)),
            _ => SparsityMode::PerColumn {
                t_u_col: Some(rng.range(1, 30)),
                t_v_col: Some(rng.range(1, 60)),
            },
        };
        let mut base = NmfOptions::new(k)
            .with_iters(rng.range(2, 5))
            .with_seed(rng.next_u64())
            .with_sparsity(mode)
            .with_threads(1);
        base.tie_mode = if rng.below(2) == 0 {
            TieMode::KeepTies
        } else {
            TieMode::Exact
        };
        let serial = factorize(&tdm, &base);
        for &threads in &THREAD_COUNTS[1..] {
            let r = factorize(&tdm, &base.clone().with_threads(threads));
            assert_eq!(r.u, serial.u, "threads {threads}");
            assert_eq!(r.v, serial.v, "threads {threads}");
            assert_eq!(r.iterations, serial.iterations);
            assert_eq!(r.residuals, serial.residuals);
            assert_eq!(r.errors, serial.errors);
            assert_eq!(r.memory, serial.memory);
        }
    });
}

/// Block heights the blocked ≡ unblocked contract is pinned at: single
/// row (every boundary possible), a prime (ragged final block), a
/// typical power of two, and auto.
const BLOCK_ROWS: [usize; 4] = [1, 7, 64, 0];

#[test]
fn factorization_byte_identical_across_block_heights() {
    // the blocked streaming pipeline's contract: factors, residuals and
    // errors are bit-identical at every (block_rows, threads) pair for
    // every SparsityMode and both TieModes; only max_intermediate_nnz
    // observes the block height (and never the thread count)
    prop::check("blocked-vs-unblocked-solver", 0xB10C, 3, |rng| {
        let tdm = random_corpus(rng);
        let k = rng.range(2, 5);
        let t_u = rng.range(k, 160);
        let t_v = rng.range(k, 320);
        let modes = [
            SparsityMode::None,
            SparsityMode::both(t_u, t_v),
            SparsityMode::PerColumn {
                t_u_col: Some(rng.range(1, 25)),
                t_v_col: Some(rng.range(1, 50)),
            },
            SparsityMode::Threshold {
                tau_u: Some((rng.f64() * 0.2) as f32),
                tau_v: Some((rng.f64() * 0.1) as f32),
            },
        ];
        let seed = rng.next_u64();
        for mode in modes {
            for tie in [TieMode::KeepTies, TieMode::Exact] {
                let mut base = NmfOptions::new(k)
                    .with_iters(2)
                    .with_seed(seed)
                    .with_sparsity(mode)
                    .with_threads(1)
                    .with_block_rows(usize::MAX); // one block = unblocked
                base.tie_mode = tie;
                let reference = factorize(&tdm, &base);
                for &block_rows in &BLOCK_ROWS {
                    let mut per_block_memory = None;
                    for threads in [1usize, 4] {
                        let opts = base
                            .clone()
                            .with_threads(threads)
                            .with_block_rows(block_rows);
                        let r = factorize(&tdm, &opts);
                        let tag = format!(
                            "mode={mode:?} tie={tie:?} block_rows={block_rows} threads={threads}"
                        );
                        assert_eq!(r.u, reference.u, "{tag}");
                        assert_eq!(r.v, reference.v, "{tag}");
                        assert_eq!(r.iterations, reference.iterations, "{tag}");
                        assert_eq!(r.residuals, reference.residuals, "{tag}");
                        assert_eq!(r.errors, reference.errors, "{tag}");
                        // memory telemetry may depend on block_rows but
                        // must not depend on the thread count
                        match per_block_memory {
                            None => per_block_memory = Some(r.memory),
                            Some(m) => assert_eq!(r.memory, m, "{tag}"),
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn intermediate_memory_is_bounded_by_one_block() {
    // a corpus spanning many blocks: the candidate scratch peak must be
    // block_rows · k, not active_rows · k — the whole point of the
    // blocked pipeline (and strictly below the unblocked peak)
    let spec = CorpusSpec {
        name: "blocky".into(),
        topics: vec![
            TopicSpec { name: "coffee".into(), seeds: words::COFFEE.to_vec() },
            TopicSpec { name: "science".into(), seeds: words::SCIENCE.to_vec() },
            TopicSpec { name: "music".into(), seeds: words::MUSIC.to_vec() },
        ],
        n_docs: 400,
        doc_len_mean: 30,
        topic_tail: 40,
        background_tail: 30,
        background_frac: 0.3,
        mixture: 0.1,
        zipf_s: 1.05,
    };
    let tdm = generate_tdm(&spec, 0xB10C2);
    let k = 5;
    let block_rows = 32;
    assert!(
        tdm.n_docs() > 4 * block_rows && tdm.n_terms() > 2 * block_rows,
        "corpus must span many blocks ({} docs, {} terms)",
        tdm.n_docs(),
        tdm.n_terms()
    );
    let base = NmfOptions::new(k)
        .with_iters(3)
        .with_seed(11)
        .with_sparsity(SparsityMode::both(300, 900))
        .with_track_error(false);
    let blocked = factorize(&tdm, &base.clone().with_block_rows(block_rows));
    assert!(
        blocked.memory.max_intermediate_nnz <= block_rows * k,
        "intermediate {} exceeds the {}-scalar block bound",
        blocked.memory.max_intermediate_nnz,
        block_rows * k
    );
    let unblocked = factorize(&tdm, &base.clone().with_block_rows(usize::MAX));
    assert!(
        blocked.memory.max_intermediate_nnz < unblocked.memory.max_intermediate_nnz,
        "blocked peak {} should undercut unblocked {}",
        blocked.memory.max_intermediate_nnz,
        unblocked.memory.max_intermediate_nnz
    );
    // same factorization either way
    assert_eq!(blocked.u, unblocked.u);
    assert_eq!(blocked.v, unblocked.v);
}

#[test]
fn job_manager_state_machine_invariants() {
    prop::check("job-state-machine", 0xB22, 6, |rng| {
        let tdm = Arc::new(random_corpus(rng));
        let workers = rng.range(1, 5);
        let mgr = JobManager::new(workers);
        let n_jobs = rng.range(1, 9);
        let ids: Vec<_> = (0..n_jobs)
            .map(|_| {
                mgr.submit(
                    Arc::clone(&tdm),
                    JobSpec::Als(
                        NmfOptions::new(rng.range(2, 5))
                            .with_iters(rng.range(1, 5))
                            .with_seed(rng.next_u64())
                            .with_track_error(false),
                    ),
                )
            })
            .collect();
        // ids are unique and dense
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len());
        // every job reaches a terminal state and stays there
        for &id in &ids {
            let s = mgr.wait(id);
            assert!(s.is_terminal());
            let again = mgr.status(id).unwrap();
            assert!(again.is_terminal(), "terminal state regressed");
        }
        assert_eq!(mgr.job_ids().len(), n_jobs);
    });
}

#[test]
fn server_command_handler_never_panics_on_garbage() {
    use esnmf::coordinator::server::handle_command;
    use esnmf::coordinator::{MetricsRegistry, TopicModel};
    use esnmf::sparse::Csr;

    let model = TopicModel::new(
        Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]),
        Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]),
        vec!["alpha".into(), "beta".into()],
    );
    let metrics = MetricsRegistry::new();
    prop::check("server-fuzz", 0xD44, 128, |rng| {
        // random printable garbage, random lengths, occasional real verbs
        let verbs = [
            "TOPICS", "TOPTERMS", "CLASSIFY", "FOLDIN", "DOCS", "STATS", "PING", "BATCH",
            "XYZZY",
        ];
        let mut line = String::new();
        if rng.below(2) == 0 {
            line.push_str(verbs[rng.below(verbs.len())]);
            line.push(' ');
        }
        let len = rng.below(40);
        for _ in 0..len {
            let c = match rng.below(5) {
                0 => ' ',
                1 => (b'0' + rng.below(10) as u8) as char,
                2 => (b'a' + rng.below(26) as u8) as char,
                3 => (b'A' + rng.below(26) as u8) as char,
                _ => ['-', '_', ':', '!', '\t', '\u{7f}', 'é'][rng.below(7)],
            };
            line.push(c);
        }
        let response = handle_command(&model, &metrics, &line);
        assert!(
            response.starts_with("OK") || response.starts_with("ERR"),
            "bad response {response:?} for {line:?}"
        );
        assert!(!response.contains('\n'), "multi-line response");
    });
}

#[test]
fn threshold_mode_never_violates_nonnegativity() {
    prop::check("threshold-mode", 0xE55, 8, |rng| {
        let tdm = random_corpus(rng);
        let tau = (rng.f64() * 0.2) as f32;
        let r = factorize(
            &tdm,
            &NmfOptions::new(3)
                .with_iters(4)
                .with_seed(rng.next_u64())
                .with_sparsity(SparsityMode::Threshold {
                    tau_u: Some(tau),
                    tau_v: Some(tau),
                })
                .with_track_error(false),
        );
        assert!(r.u.values.iter().all(|&x| x >= tau || x == 0.0));
        assert!(r.u.values.iter().all(|&x| x >= 0.0));
        r.u.validate().unwrap();
    });
}

#[test]
fn deterministic_end_to_end_given_seed() {
    prop::check("determinism", 0xC33, 6, |rng| {
        let seed = rng.next_u64();
        let spec = CorpusSpec {
            name: "det".into(),
            topics: vec![
                TopicSpec { name: "coffee".into(), seeds: words::COFFEE.to_vec() },
                TopicSpec { name: "sport".into(), seeds: words::SPORT.to_vec() },
            ],
            n_docs: 40,
            doc_len_mean: 30,
            topic_tail: 20,
            background_tail: 10,
            background_frac: 0.3,
            mixture: 0.1,
            zipf_s: 1.05,
        };
        let tdm1 = generate_tdm(&spec, seed);
        let tdm2 = generate_tdm(&spec, seed);
        assert_eq!(tdm1.a, tdm2.a);
        let opts = NmfOptions::new(2)
            .with_iters(4)
            .with_seed(seed)
            .with_sparsity(SparsityMode::both(30, 60));
        let r1 = factorize(&tdm1, &opts);
        let r2 = factorize(&tdm2, &opts);
        assert_eq!(r1.u, r2.u);
        assert_eq!(r1.v, r2.v);
        assert_eq!(r1.residuals, r2.residuals);
        assert_eq!(r1.memory, r2.memory);
    });
}
