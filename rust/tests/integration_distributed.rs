//! Distributed-plane acceptance tests: an N-worker run over a shared
//! `.estdm` must be bit-identical to the single-process blocked run at
//! every worker count — including when a worker is killed mid-iteration
//! — and every malformed or mismatched peer must get a typed refusal,
//! never a hang.
//!
//! Workers run in-process (threads calling [`run_worker`] over real
//! loopback sockets) except where a test needs to kill one, which uses
//! the actual `esnmf worker` binary as a subprocess.

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::Duration;

use esnmf::coordinator::{run_distributed_on, run_worker, DistOptions};
use esnmf::corpus::{generate_tdm, reuters_sim, Scale};
use esnmf::io::CorpusStore;
use esnmf::nmf::{factorize_corpus, NmfOptions, NmfResult, ObjectiveKind, SparsityMode};
use esnmf::sparse::TieMode;
use esnmf::EsnmfError;

fn temp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("esnmf_it_dist_{name}"))
}

fn write_store(name: &str, seed: u64) -> (PathBuf, CorpusStore) {
    let path = temp(&format!("{name}.estdm"));
    let _ = std::fs::remove_file(&path);
    let tdm = generate_tdm(&reuters_sim(Scale::Tiny), seed);
    CorpusStore::write(&path, &tdm, 5).unwrap();
    let store = CorpusStore::open(&path).unwrap();
    (path, store)
}

fn assert_same_result(a: &NmfResult, b: &NmfResult, tag: &str) {
    assert_eq!(a.u, b.u, "{tag}: U");
    assert_eq!(a.v, b.v, "{tag}: V");
    assert_eq!(a.iterations, b.iterations, "{tag}: iterations");
    assert_eq!(a.residuals, b.residuals, "{tag}: residuals");
    assert_eq!(a.errors, b.errors, "{tag}: errors");
    assert_eq!(a.memory, b.memory, "{tag}: memory telemetry");
    assert_eq!(a.digest(), b.digest(), "{tag}: digest");
}

/// Bind an ephemeral loopback port, spawn `workers` in-process workers
/// against it, run the coordinator, and join the workers after the
/// shutdown frame.
fn run_with_workers(
    store: &CorpusStore,
    store_path: &Path,
    opts: &NmfOptions,
    workers: usize,
) -> NmfResult {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let objective = opts.objective;
    let handles: Vec<_> = (0..workers)
        .map(|_| {
            let path = store_path.to_path_buf();
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&path, &addr, objective, 1))
        })
        .collect();
    let dopts = DistOptions {
        listen: addr,
        workers,
        timeout: Duration::from_secs(30),
    };
    let result = run_distributed_on(listener, store, opts, &dopts).expect("distributed run");
    for h in handles {
        h.join().unwrap().expect("worker exits cleanly");
    }
    result
}

fn enforced_opts() -> NmfOptions {
    // explicit block_rows well below the corpus height so every
    // half-step genuinely scatters multi-block spans
    let mut opts = NmfOptions::new(4)
        .with_iters(3)
        .with_seed(0xd157)
        .with_sparsity(SparsityMode::both(60, 140))
        .with_threads(2)
        .with_block_rows(3);
    opts.tie_mode = TieMode::Exact;
    opts
}

#[test]
fn distributed_is_bit_identical_at_every_worker_count() {
    let (path, store) = write_store("counts", 0x0c0de);
    let opts = enforced_opts();
    let baseline = factorize_corpus(&store, &opts);
    for workers in [1usize, 2, 3] {
        let dist = run_with_workers(&store, &path, &opts, workers);
        assert_same_result(&dist, &baseline, &format!("{workers} workers"));
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn distributed_matches_across_sparsity_modes() {
    let (path, store) = write_store("modes", 0x0c0de);
    for (mode, tie) in [
        (SparsityMode::None, TieMode::KeepTies),
        (SparsityMode::both(60, 140), TieMode::KeepTies),
        (
            SparsityMode::PerColumn {
                t_u_col: Some(12),
                t_v_col: Some(30),
            },
            TieMode::Exact,
        ),
    ] {
        let mut opts = enforced_opts().with_sparsity(mode);
        opts.tie_mode = tie;
        let baseline = factorize_corpus(&store, &opts);
        let dist = run_with_workers(&store, &path, &opts, 2);
        assert_same_result(&dist, &baseline, &format!("mode={mode:?}"));
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn worker_killed_mid_iteration_still_completes_bit_identically() {
    let (path, store) = write_store("kill", 0x0c0de);
    // enough iterations that the kill lands while spans are in flight
    // (and if the run happens to finish first, the invariant asserted —
    // bit-identity whatever the failure pattern — still holds)
    let opts = enforced_opts().with_iters(120);
    let baseline = factorize_corpus(&store, &opts);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let survivor = {
        let path = path.clone();
        let addr = addr.clone();
        std::thread::spawn(move || run_worker(&path, &addr, ObjectiveKind::Frobenius, 1))
    };
    let mut victim = Command::new(env!("CARGO_BIN_EXE_esnmf"))
        .args([
            "worker",
            path.to_str().unwrap(),
            "--coordinator",
            addr.as_str(),
            "--threads",
            "1",
        ])
        .env("ESNMF_LOG", "warn")
        .spawn()
        .expect("spawning worker subprocess");
    // late enough that spawn + store-open + handshake are done (so the
    // admission deadline is not left waiting on a corpse), early enough
    // to land inside the iteration loop
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(250));
        let _ = victim.kill();
        let _ = victim.wait();
    });

    let dopts = DistOptions {
        listen: addr,
        workers: 2,
        timeout: Duration::from_secs(30),
    };
    let dist = run_distributed_on(listener, &store, &opts, &dopts).expect("distributed run");
    assert_same_result(&dist, &baseline, "one worker killed mid-run");
    survivor.join().unwrap().expect("surviving worker exits cleanly");
    killer.join().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn garbage_peer_is_rejected_and_the_run_completes() {
    let (path, store) = write_store("garbage", 0x0c0de);
    let opts = enforced_opts();
    let baseline = factorize_corpus(&store, &opts);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // connect (and queue in the backlog) *before* the real worker so the
    // coordinator handshakes the garbage first: a corrupt frame must be
    // a typed rejection that keeps the admission loop going, not a hang
    let mut garbage = TcpStream::connect(&addr).unwrap();
    garbage.write_all(b"NOPE this is not a worker frame").unwrap();
    garbage.flush().unwrap();
    let worker = {
        let path = path.clone();
        let addr = addr.clone();
        std::thread::spawn(move || run_worker(&path, &addr, ObjectiveKind::Frobenius, 1))
    };

    let dopts = DistOptions {
        listen: addr,
        workers: 1,
        timeout: Duration::from_secs(30),
    };
    let dist = run_distributed_on(listener, &store, &opts, &dopts).expect("distributed run");
    assert_same_result(&dist, &baseline, "after rejecting a garbage peer");
    worker.join().unwrap().expect("real worker exits cleanly");
    drop(garbage);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn corpus_digest_mismatch_is_a_typed_refusal_on_both_sides() {
    let (path_a, store_a) = write_store("digest_a", 0x0c0de);
    let (path_b, _store_b) = write_store("digest_b", 0xd1ff);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // the worker opened a *different* corpus: the coordinator must
    // refuse it at handshake, and with no eligible worker left the run
    // must fail with a protocol error instead of waiting forever
    let worker = {
        let path = path_b.clone();
        let addr = addr.clone();
        std::thread::spawn(move || run_worker(&path, &addr, ObjectiveKind::Frobenius, 1))
    };
    let dopts = DistOptions {
        listen: addr,
        workers: 1,
        timeout: Duration::from_secs(2),
    };
    let opts = enforced_opts();
    match run_distributed_on(listener, &store_a, &opts, &dopts) {
        Err(EsnmfError::Protocol(msg)) => {
            assert!(msg.contains("no workers joined"), "{msg}")
        }
        other => panic!("expected a protocol error, got {other:?}"),
    }
    match worker.join().unwrap() {
        Err(EsnmfError::Protocol(msg)) => assert!(msg.contains("mismatch"), "{msg}"),
        other => panic!("worker should see the refusal, got {other:?}"),
    }
    std::fs::remove_file(&path_a).unwrap();
    std::fs::remove_file(&path_b).unwrap();
}

#[test]
fn distributed_kl_is_bit_identical_to_the_local_run() {
    let (path, store) = write_store("kl", 0x0c0de);
    let mut opts = enforced_opts().with_objective(ObjectiveKind::Kl);
    opts = opts.with_iters(4);
    let baseline = factorize_corpus(&store, &opts);
    for workers in [1usize, 2] {
        let dist = run_with_workers(&store, &path, &opts, workers);
        assert_same_result(&dist, &baseline, &format!("kl, {workers} workers"));
    }
    // the per-iteration KL history is monotone non-increasing
    for w in baseline.errors.windows(2) {
        assert!(w[1] <= w[0] + 1e-9, "KL went up: {:?}", baseline.errors);
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn objective_mismatch_is_a_typed_refusal_on_both_sides() {
    let (path, store) = write_store("objective", 0x0c0de);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // a KL coordinator must refuse a Frobenius worker at handshake —
    // mixed per-block math would corrupt the run, not just slow it
    let worker = {
        let path = path.clone();
        let addr = addr.clone();
        std::thread::spawn(move || run_worker(&path, &addr, ObjectiveKind::Frobenius, 1))
    };
    let dopts = DistOptions {
        listen: addr,
        workers: 1,
        timeout: Duration::from_secs(2),
    };
    let opts = enforced_opts().with_objective(ObjectiveKind::Kl);
    match run_distributed_on(listener, &store, &opts, &dopts) {
        Err(EsnmfError::Protocol(msg)) => {
            assert!(msg.contains("no workers joined"), "{msg}")
        }
        other => panic!("expected a protocol error, got {other:?}"),
    }
    match worker.join().unwrap() {
        Err(EsnmfError::Protocol(msg)) => {
            assert!(msg.contains("objective"), "{msg}");
            assert!(msg.contains("refused"), "{msg}");
        }
        other => panic!("worker should see the refusal, got {other:?}"),
    }
    std::fs::remove_file(&path).unwrap();
}

// ---- CLI end-to-end ------------------------------------------------------

fn esnmf(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_esnmf"))
        .args(args)
        .env("ESNMF_LOG", "warn")
        .output()
        .expect("spawning esnmf")
}

#[test]
fn cli_distributed_needs_a_corpus_store() {
    let out = esnmf(&[
        "factorize", "--corpus", "reuters", "--scale", "tiny", "--k", "3",
        "--distributed",
    ]);
    assert_eq!(out.status.code(), Some(2), "config mistakes exit 2");
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("--corpus-store"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn cli_distributed_run_prints_the_single_process_digest() {
    let store_path = temp("cli.estdm");
    let _ = std::fs::remove_file(&store_path);
    let out = esnmf(&[
        "ingest", "--corpus", "reuters", "--scale", "tiny", "--seed", "21",
        "--shard-rows", "5", "--out", store_path.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "ingest stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let digest_line = |stdout: &str| -> String {
        stdout
            .lines()
            .find(|l| l.starts_with("factors digest:"))
            .unwrap_or_else(|| panic!("no digest line in:\n{stdout}"))
            .to_string()
    };
    let common = [
        "--k", "4", "--iters", "4", "--sparsity", "both", "--t-u", "50",
        "--t-v", "110", "--seed", "21", "--block-rows", "7",
    ];
    let mut local_args: Vec<&str> =
        vec!["factorize", "--corpus-store", store_path.to_str().unwrap()];
    local_args.extend_from_slice(&common);
    let local_out = esnmf(&local_args);
    assert!(
        local_out.status.success(),
        "local stderr: {}",
        String::from_utf8_lossy(&local_out.stderr)
    );
    let local_digest = digest_line(&String::from_utf8_lossy(&local_out.stdout));

    // a port of our own: bind :0, note the address, release it for the
    // coordinator (workers retry connecting for 30s, so the brief gap
    // between drop and rebind is covered)
    let probe = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);
    let mut workers: Vec<_> = (0..2)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_esnmf"))
                .args([
                    "worker",
                    store_path.to_str().unwrap(),
                    "--coordinator",
                    addr.as_str(),
                    "--threads",
                    "1",
                ])
                .env("ESNMF_LOG", "warn")
                .spawn()
                .expect("spawning worker")
        })
        .collect();
    let mut dist_args: Vec<&str> = vec![
        "factorize", "--corpus-store", store_path.to_str().unwrap(),
        "--distributed", "--dist-workers", "2", "--dist-listen", addr.as_str(),
        "--dist-timeout", "30",
    ];
    dist_args.extend_from_slice(&common);
    let dist_out = esnmf(&dist_args);
    for w in &mut workers {
        let _ = w.kill();
        let _ = w.wait();
    }
    assert!(
        dist_out.status.success(),
        "distributed stderr: {}",
        String::from_utf8_lossy(&dist_out.stderr)
    );
    let dist_digest = digest_line(&String::from_utf8_lossy(&dist_out.stdout));
    assert_eq!(dist_digest, local_digest, "distributed CLI run diverged");
    std::fs::remove_file(&store_path).unwrap();
}
