//! End-to-end tests of the zero-downtime serving plane: atomic hot model
//! swap under concurrent load (every response attributable to exactly
//! one model, no dropped connections, no cross-generation cache hits),
//! failed reloads leaving the old model serving, and the loopback admin
//! listener (HEALTH / READY / METRICS / PROVENANCE / RELOAD) over real
//! TCP.

use esnmf::coordinator::{AdminServer, MetricsRegistry, ServerState, TopicModel, TopicServer};
use esnmf::io::{Progress, Snapshot};
use esnmf::nmf::NmfOptions;
use esnmf::sparse::Csr;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};

fn terms() -> Vec<String> {
    vec![
        "coffee".into(),
        "crop".into(),
        "electrons".into(),
        "atoms".into(),
    ]
}

/// Model A: coffee/crop load topic 0. `CLASSIFY coffee crop` answers
/// `OK topic:0:…` first.
fn model_a() -> Arc<TopicModel> {
    let u = Csr::from_dense(4, 2, &[
        0.9, 0.0, //
        0.5, 0.0, //
        0.0, 0.8, //
        0.0, 0.3,
    ]);
    let v = Csr::from_dense(3, 2, &[1.0, 0.0, 0.0, 0.9, 0.4, 0.0]);
    Arc::new(TopicModel::new(u, v, terms()))
}

/// Model B: the topic columns exchanged — the same query answers
/// `OK topic:1:…` first, so responses self-identify their model.
fn snapshot_b() -> Snapshot {
    let u = Csr::from_dense(4, 2, &[
        0.0, 0.9, //
        0.0, 0.5, //
        0.8, 0.0, //
        0.3, 0.0,
    ]);
    let v = Csr::from_dense(3, 2, &[0.0, 1.0, 0.9, 0.0, 0.0, 0.4]);
    Snapshot {
        options: NmfOptions::new(2),
        u,
        v,
        terms: terms(),
        doc_labels: None,
        label_names: vec![],
        corpus_digest: 0xD1CE,
        progress: Progress::default(),
    }
}

fn temp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("esnmf_plane_{}_{name}", std::process::id()))
}

fn connect(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
    let stream = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (reader, stream)
}

fn query(reader: &mut impl BufRead, writer: &mut impl Write, q: &str) -> String {
    writeln!(writer, "{q}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim_end().to_string()
}

#[test]
fn hot_swap_under_load_keeps_every_response_attributable() {
    let snap_path = temp("swap_load.esnmf");
    snapshot_b().save(&snap_path).unwrap();
    let state = Arc::new(ServerState::new(model_a(), MetricsRegistry::new(), 64));
    let server = TopicServer::serve_state("127.0.0.1:0", Arc::clone(&state), 8).unwrap();
    let addr = server.addr();

    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 40;
    // clients pause at the halfway barrier; the main thread swaps there,
    // so the second half of each session runs concurrently with (or
    // after) the swap while the first half strictly precedes it
    let halfway = Arc::new(Barrier::new(CLIENTS + 1));
    // …and every client's *final* request waits for the swap to have
    // completed, so "they all end on the new model" is deterministic
    let swapped = Arc::new(Barrier::new(CLIENTS + 1));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let halfway = Arc::clone(&halfway);
            let swapped = Arc::clone(&swapped);
            std::thread::spawn(move || {
                let (mut reader, mut writer) = connect(addr);
                let mut saw_new = false;
                for i in 0..PER_CLIENT {
                    if i == PER_CLIENT / 2 {
                        halfway.wait();
                    }
                    if i == PER_CLIENT - 1 {
                        swapped.wait();
                    }
                    // alternate a shared (cache-warming) and a per-client
                    // bag so both cache hits and misses cross the swap
                    let q = if i % 2 == 0 {
                        "CLASSIFY coffee crop".to_string()
                    } else {
                        format!("CLASSIFY coffee crop x{c}")
                    };
                    let r = query(&mut reader, &mut writer, &q);
                    // every response is attributable to exactly one model
                    let old = r.starts_with("OK topic:0:");
                    let new = r.starts_with("OK topic:1:");
                    assert!(old ^ new, "client {c} got unattributable {r:?}");
                    if i < PER_CLIENT / 2 {
                        assert!(old, "client {c} saw the new model before the swap: {r:?}");
                    }
                    // atomic swap + generation-tagged cache keys: once a
                    // client has seen the new model, a stale (old-model)
                    // response can never follow — a cross-generation
                    // cache hit would violate exactly this
                    if saw_new {
                        assert!(new, "client {c} flapped back to the old model: {r:?}");
                    }
                    saw_new = new;
                }
                // the connection survived the swap
                assert_eq!(query(&mut reader, &mut writer, "QUIT"), "OK bye");
                saw_new
            })
        })
        .collect();
    halfway.wait();
    let active = state.swap_model(&snap_path).expect("swap under load");
    assert_eq!(active.generation, 1);
    swapped.wait(); // release the final requests
    let clients_seeing_new = handles
        .into_iter()
        .map(|h| h.join().expect("client dropped"))
        .filter(|&saw| saw)
        .count();
    // the swap landed while traffic was live: the final request of every
    // client runs strictly after swap_model returned, so all of them
    // finished on the new model
    assert_eq!(clients_seeing_new, CLIENTS);
    assert_eq!(state.generation(), 1);
    server.stop();
    let _ = std::fs::remove_file(&snap_path);
}

#[test]
fn corrupt_reload_over_admin_leaves_the_old_model_serving() {
    let good = temp("good.esnmf");
    let bad = temp("bad.esnmf");
    snapshot_b().save(&good).unwrap();
    std::fs::write(&bad, b"not a snapshot at all").unwrap();

    let state = Arc::new(ServerState::new(model_a(), MetricsRegistry::new(), 16));
    let server = TopicServer::serve_state("127.0.0.1:0", Arc::clone(&state), 2).unwrap();
    let admin = AdminServer::start("127.0.0.1:0", Arc::clone(&state)).unwrap();
    let (mut areader, mut awriter) = connect(admin.addr());
    let (mut dreader, mut dwriter) = connect(server.addr());

    // a corrupt reload answers ERR and swaps nothing
    let r = query(&mut areader, &mut awriter, &format!("RELOAD {}", bad.display()));
    assert!(r.starts_with("ERR reload failed:"), "{r}");
    assert_eq!(state.generation(), 0);
    // READY stays true — the old model is intact and still serving
    assert_eq!(
        query(&mut areader, &mut awriter, "READY"),
        "OK ready generation=0"
    );
    let d = query(&mut dreader, &mut dwriter, "CLASSIFY coffee crop");
    assert!(d.starts_with("OK topic:0:"), "{d}");

    // a good reload then swaps live, no reconnect needed
    let r = query(&mut areader, &mut awriter, &format!("RELOAD {}", good.display()));
    assert_eq!(r, "OK swapped generation=1 k=2");
    let d = query(&mut dreader, &mut dwriter, "CLASSIFY coffee crop");
    assert!(d.starts_with("OK topic:1:"), "{d}");

    admin.stop();
    server.stop();
    let _ = std::fs::remove_file(&good);
    let _ = std::fs::remove_file(&bad);
}

#[test]
fn admin_listener_speaks_health_metrics_and_provenance() {
    let snap = temp("admin_swap.esnmf");
    snapshot_b().save(&snap).unwrap();
    let state = Arc::new(ServerState::new(model_a(), MetricsRegistry::new(), 16));
    let server = TopicServer::serve_state("127.0.0.1:0", Arc::clone(&state), 2).unwrap();
    let admin = AdminServer::start("127.0.0.1:0", Arc::clone(&state)).unwrap();
    let (mut areader, mut awriter) = connect(admin.addr());

    // drive one data-plane request so the counters are nonzero
    let (mut dreader, mut dwriter) = connect(server.addr());
    assert!(query(&mut dreader, &mut dwriter, "CLASSIFY coffee").starts_with("OK"));

    let health = query(&mut areader, &mut awriter, "HEALTH");
    assert!(health.starts_with("OK up generation=0 requests="), "{health}");
    assert_eq!(query(&mut areader, &mut awriter, "PING"), "OK pong");

    // METRICS: Prometheus text until the `# EOF` terminator
    writeln!(awriter, "METRICS").unwrap();
    let mut lines = Vec::new();
    loop {
        let mut line = String::new();
        areader.read_line(&mut line).unwrap();
        let line = line.trim_end().to_string();
        if line == "# EOF" {
            break;
        }
        lines.push(line);
    }
    assert!(
        lines.iter().any(|l| l.starts_with("esnmf_server_requests ")),
        "no request counter in {lines:?}"
    );
    assert!(
        lines
            .iter()
            .any(|l| l.contains("_us_bucket{le=\"+Inf\"}")),
        "no histogram buckets in {lines:?}"
    );
    // every non-comment line parses as `name[{labels}] value`
    for l in &lines {
        if l.starts_with('#') {
            continue;
        }
        let (name, value) = l.rsplit_once(' ').expect("name value");
        assert!(name.starts_with("esnmf_"), "{l}");
        assert!(value.parse::<f64>().is_ok(), "{l}");
    }

    // PROVENANCE before the swap: a from-memory model, no file facts
    let prov = query(&mut areader, &mut awriter, "PROVENANCE");
    assert!(prov.starts_with("OK path=- crc32=- "), "{prov}");
    assert!(prov.ends_with("generation=0"), "{prov}");

    // after a RELOAD it reports the snapshot's path, CRC and digest
    let r = query(&mut areader, &mut awriter, &format!("RELOAD {}", snap.display()));
    assert_eq!(r, "OK swapped generation=1 k=2");
    let prov = query(&mut areader, &mut awriter, "PROVENANCE");
    assert!(prov.contains(&format!("path={}", snap.display())), "{prov}");
    assert!(prov.contains("crc32=0x"), "{prov}");
    assert!(prov.contains(&format!("digest={:#018x}", 0xD1CEu64)), "{prov}");
    assert!(prov.ends_with("generation=1"), "{prov}");

    assert_eq!(query(&mut areader, &mut awriter, "QUIT"), "OK bye");
    admin.stop();
    server.stop();
    let _ = std::fs::remove_file(&snap);
}
