//! TCP round-trip tests of the concurrent topic-query server: protocol
//! correctness, BATCH framing, FOLDIN inference, cache/metrics
//! accounting, ≥8 simultaneous connections, and graceful shutdown.

use esnmf::coordinator::{MetricsRegistry, ServeOptions, TopicModel, TopicServer};
use esnmf::sparse::Csr;
use esnmf::text::TdmBuilder;
use esnmf::util::prop;
use esnmf::util::rng::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};

fn model() -> Arc<TopicModel> {
    let u = Csr::from_dense(4, 2, &[
        0.9, 0.0, //
        0.5, 0.0, //
        0.0, 0.8, //
        0.0, 0.3,
    ]);
    let v = Csr::from_dense(3, 2, &[1.0, 0.0, 0.0, 0.9, 0.4, 0.0]);
    Arc::new(TopicModel::new(
        u,
        v,
        vec![
            "coffee".into(),
            "crop".into(),
            "electrons".into(),
            "atoms".into(),
        ],
    ))
}

fn query(reader: &mut impl BufRead, writer: &mut impl Write, q: &str) -> String {
    writeln!(writer, "{q}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim_end().to_string()
}

fn connect(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
    let stream = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (reader, stream)
}

#[test]
fn tcp_protocol_roundtrip() {
    let metrics = MetricsRegistry::new();
    let server = TopicServer::start("127.0.0.1:0", model(), metrics.clone()).unwrap();
    let (mut reader, mut writer) = connect(server.addr());

    assert_eq!(query(&mut reader, &mut writer, "TOPICS"), "OK k=2");
    assert!(query(&mut reader, &mut writer, "TOPTERMS 0 2").contains("coffee"));
    assert!(query(&mut reader, &mut writer, "CLASSIFY electrons atoms").contains("topic:1"));
    assert!(query(&mut reader, &mut writer, "DOCS 1 5").starts_with("OK 1:0.9000"));
    assert!(query(&mut reader, &mut writer, "BOGUS").starts_with("ERR"));
    let stats = query(&mut reader, &mut writer, "STATS");
    assert!(stats.starts_with("OK objective=frobenius "), "{stats}");
    assert!(stats.contains("server.requests"), "{stats}");
    assert!(stats.contains("server.connections.active"), "{stats}");
    assert!(stats.contains("server.latency.topics.count"), "{stats}");
    assert_eq!(query(&mut reader, &mut writer, "QUIT"), "OK bye");
    assert!(metrics.counter("server.requests").get() >= 5);
    server.stop();
}

#[test]
fn malformed_lines_answer_err_and_blanks_are_ignored() {
    let server =
        TopicServer::start("127.0.0.1:0", model(), MetricsRegistry::new()).unwrap();
    let (mut reader, mut writer) = connect(server.addr());

    for bad in [
        "TOPTERMS 0 abc",
        "TOPTERMS 0 0",
        "DOCS 0 0",
        "DOCS xyz",
        "TOPTERMS 0 2 junk",
        "FOLDIN coffee",
        "FOLDIN coffee:-2",
    ] {
        let r = query(&mut reader, &mut writer, bad);
        assert!(r.starts_with("ERR"), "{bad:?} answered {r:?}");
    }
    // blank and whitespace-only lines get no response at all: the next
    // response on the wire belongs to the PING
    writer.write_all(b"\n   \nPING\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "OK pong");
    server.stop();
}

#[test]
fn batch_framing_answers_in_order() {
    let server =
        TopicServer::start("127.0.0.1:0", model(), MetricsRegistry::new()).unwrap();
    let (mut reader, mut writer) = connect(server.addr());

    // pipelined: header + three commands in a single write, one round trip
    writer
        .write_all(b"BATCH 3\nTOPICS\nCLASSIFY coffee\nPING\n")
        .unwrap();
    let mut lines = Vec::new();
    for _ in 0..4 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        lines.push(line.trim_end().to_string());
    }
    assert_eq!(lines[0], "OK batch=3");
    assert_eq!(lines[1], "OK k=2");
    assert!(lines[2].starts_with("OK topic:0"), "{}", lines[2]);
    assert_eq!(lines[3], "OK pong");

    // nested BATCH and QUIT are rejected per-line, keeping the count
    writer.write_all(b"BATCH 2\nBATCH 1\nQUIT\n").unwrap();
    let mut lines = Vec::new();
    for _ in 0..3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        lines.push(line.trim_end().to_string());
    }
    assert_eq!(lines[0], "OK batch=2");
    assert!(lines[1].starts_with("ERR"), "{}", lines[1]);
    assert!(lines[2].starts_with("ERR"), "{}", lines[2]);

    // blank lines inside a batch are answered (the count was promised)
    writer.write_all(b"BATCH 2\n\nTOPICS\n").unwrap();
    let mut lines = Vec::new();
    for _ in 0..3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        lines.push(line.trim_end().to_string());
    }
    assert_eq!(lines[0], "OK batch=2");
    assert!(lines[1].starts_with("ERR empty"), "{}", lines[1]);
    assert_eq!(lines[2], "OK k=2");

    // malformed headers answer exactly one ERR line
    for bad in ["BATCH", "BATCH 0", "BATCH zero", "BATCH 99999", "BATCH 1 x"] {
        let r = query(&mut reader, &mut writer, bad);
        assert!(r.starts_with("ERR"), "{bad:?} answered {r:?}");
    }
    assert_eq!(query(&mut reader, &mut writer, "QUIT"), "OK bye");
    server.stop();
}

#[test]
fn foldin_over_tcp_with_budget() {
    let m = Arc::new(
        TopicModel::new(
            Csr::from_dense(4, 2, &[
                0.9, 0.0, //
                0.5, 0.0, //
                0.0, 0.8, //
                0.0, 0.3,
            ]),
            Csr::from_dense(3, 2, &[1.0, 0.0, 0.0, 0.9, 0.4, 0.0]),
            vec![
                "coffee".into(),
                "crop".into(),
                "electrons".into(),
                "atoms".into(),
            ],
        )
        .with_foldin_budget(Some(1)),
    );
    let server = TopicServer::start("127.0.0.1:0", m, MetricsRegistry::new()).unwrap();
    let (mut reader, mut writer) = connect(server.addr());

    // a mixed bag touches both topics, but the budget keeps exactly one
    let r = query(&mut reader, &mut writer, "FOLDIN coffee:2 electrons:1");
    assert!(r.starts_with("OK nnz=1 topic:"), "{r}");
    // unknown words fold to the empty row
    assert_eq!(query(&mut reader, &mut writer, "FOLDIN zzz:4"), "OK nnz=0");
    server.stop();
}

/// Parse `OK nnz=<n> topic:<id>:<w> ...`, checking internal consistency.
fn parse_foldin_nnz(resp: &str) -> usize {
    let rest = resp.strip_prefix("OK nnz=").unwrap_or_else(|| {
        panic!("malformed FOLDIN response {resp:?}");
    });
    let mut toks = rest.split_whitespace();
    let nnz: usize = toks.next().unwrap().parse().unwrap();
    let pairs = toks.filter(|t| t.starts_with("topic:")).count();
    assert_eq!(pairs, nnz, "pair count disagrees with nnz in {resp:?}");
    nnz
}

#[test]
fn foldin_budget_property_over_random_bags() {
    // a larger random model, served with a hard per-document budget
    let mut rng = Rng::new(0xf01d);
    let rows = 30;
    let k = 5;
    let t = 2usize;
    let dense = prop::gen_sparse_dense(&mut rng, rows, k, 0.5);
    let u = Csr::from_dense(rows, k, &dense);
    let v = Csr::from_dense(1, k, &vec![1.0; k]);
    let terms: Vec<String> = (0..rows).map(|i| format!("w{i}")).collect();
    let m = Arc::new(TopicModel::new(u, v, terms).with_foldin_budget(Some(t)));
    let server = TopicServer::start("127.0.0.1:0", m, MetricsRegistry::new()).unwrap();
    let (mut reader, mut writer) = connect(server.addr());

    prop::check("foldin-budget-over-tcp", 0xbead, 64, |rng: &mut Rng| {
        let n_words = rng.range(1, 10);
        let bag: Vec<String> = (0..n_words)
            .map(|_| {
                // mostly known words, some unknown
                if rng.f64() < 0.85 {
                    format!("w{}:{}", rng.below(rows), rng.range(1, 6))
                } else {
                    format!("zzz{}:{}", rng.below(5), rng.range(1, 6))
                }
            })
            .collect();
        let resp = query(&mut reader, &mut writer, &format!("FOLDIN {}", bag.join(" ")));
        let nnz = parse_foldin_nnz(&resp);
        assert!(nnz <= t, "nnz {nnz} exceeds budget {t}: {resp:?}");
    });
    server.stop();
}

#[test]
fn foldin_of_training_doc_ranks_like_stored_v_row() {
    // train on a cleanly separable corpus, then fold each training
    // document's exact bag-of-words back in: the top topic must agree
    // with the stored V row
    let mut b = TdmBuilder::new();
    for _ in 0..6 {
        b.add_text("coffee crop quotas coffee brazil crop", Some("econ"));
        b.add_text("electrons atoms hydrogen electrons atoms", Some("sci"));
    }
    let tdm = b.freeze();
    let opts = esnmf::nmf::NmfOptions::new(2).with_iters(30).with_seed(7);
    let r = esnmf::nmf::factorize(&tdm, &opts);
    let model = TopicModel::new(r.u, r.v, tdm.terms.clone());
    let mut checked = 0;
    for d in 0..tdm.n_docs() {
        let (term_ids, counts) = tdm.a_csc.col(d);
        let doc: Vec<(String, f32)> = term_ids
            .iter()
            .zip(counts)
            .map(|(&t, &c)| (tdm.terms[t as usize].clone(), c))
            .collect();
        let (v_cols, v_vals) = model.v.row(d);
        if doc.is_empty() || v_cols.is_empty() {
            continue;
        }
        let stored_top = v_cols
            .iter()
            .zip(v_vals)
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(&c, _)| c as usize)
            .unwrap();
        let folded = model.fold_in(&doc);
        assert!(!folded.is_empty(), "training doc {d} folded to empty");
        assert_eq!(
            folded[0].0, stored_top,
            "doc {d}: fold-in top topic {} != stored V row top {stored_top}",
            folded[0].0
        );
        checked += 1;
    }
    assert!(checked >= 10, "only {checked} docs checked");
}

#[test]
fn eight_simultaneous_connections() {
    let metrics = MetricsRegistry::new();
    let server = TopicServer::start_with(
        "127.0.0.1:0",
        model(),
        metrics.clone(),
        ServeOptions {
            threads: 8,
            cache_size: 0,
        },
    )
    .unwrap();
    let addr = server.addr();
    const N: usize = 8;
    // all_connected: every client has been answered (so its handler is
    // live); release: main has inspected the gauge, clients may QUIT
    let all_connected = Arc::new(Barrier::new(N + 1));
    let release = Arc::new(Barrier::new(N + 1));
    let handles: Vec<_> = (0..N)
        .map(|_| {
            let all_connected = Arc::clone(&all_connected);
            let release = Arc::clone(&release);
            std::thread::spawn(move || {
                let (mut reader, mut writer) = connect(addr);
                assert_eq!(query(&mut reader, &mut writer, "PING"), "OK pong");
                all_connected.wait();
                release.wait();
                assert_eq!(query(&mut reader, &mut writer, "QUIT"), "OK bye");
            })
        })
        .collect();
    all_connected.wait();
    // every handler incremented the gauge before answering its PING and
    // none has exited: all 8 connections are being served right now
    assert_eq!(metrics.gauge("server.connections.active").get(), 8);
    assert_eq!(metrics.counter("server.connections.total").get(), 8);
    release.wait();
    for h in handles {
        h.join().unwrap();
    }
    server.stop();
}

#[test]
fn concurrent_clients_hammer_and_counters_add_up() {
    let metrics = MetricsRegistry::new();
    let server = TopicServer::start_with(
        "127.0.0.1:0",
        model(),
        metrics.clone(),
        ServeOptions {
            threads: 8,
            cache_size: 64,
        },
    )
    .unwrap();
    let addr = server.addr();
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 30;
    let cacheable_sent = Arc::new(AtomicUsize::new(0));
    let handles: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let cacheable_sent = Arc::clone(&cacheable_sent);
            std::thread::spawn(move || {
                let (mut reader, mut writer) = connect(addr);
                for j in 0..PER_CLIENT {
                    let (cmd, cacheable): (String, bool) = match j % 4 {
                        0 => ("TOPICS".into(), false),
                        // a shared bag (cache hits across clients) …
                        1 => ("CLASSIFY coffee crop".into(), true),
                        // … and per-client bags (mostly misses)
                        2 => (format!("CLASSIFY electrons atoms coffee{i}"), true),
                        _ => (format!("FOLDIN coffee:{} atoms:1", (j % 3) + 1), true),
                    };
                    if cacheable {
                        cacheable_sent.fetch_add(1, Ordering::Relaxed);
                    }
                    let r = query(&mut reader, &mut writer, &cmd);
                    assert!(r.starts_with("OK"), "{cmd:?} answered {r:?}");
                }
                assert_eq!(query(&mut reader, &mut writer, "QUIT"), "OK bye");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let total = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(metrics.counter("server.requests").get(), total);
    // every cacheable command is exactly one hit or one miss
    let hits = metrics.counter("server.cache.hits").get();
    let misses = metrics.counter("server.cache.misses").get();
    assert_eq!(
        hits + misses,
        cacheable_sent.load(Ordering::Relaxed) as u64
    );
    // the shared bag guarantees real hits once warmed
    assert!(hits > 0, "no cache hits at all");
    // latency histograms partition the requests by command
    let by_label: u64 = ["topics", "classify", "foldin"]
        .iter()
        .map(|l| metrics.histogram(&format!("server.latency.{l}")).count())
        .sum();
    assert_eq!(by_label, total);
    assert_eq!(
        metrics.counter("server.connections.total").get(),
        CLIENTS as u64
    );
    // fold-in scratch is pooled per in-flight request, never allocated
    // per request: creations are bounded by the worker count (8), far
    // below the 240 answered lines — zero per-request allocation growth
    let scratch_allocs = metrics.counter("server.foldin.scratch_allocs").get();
    assert!(
        scratch_allocs >= 1 && scratch_allocs <= 8,
        "scratch allocs {scratch_allocs} exceed the 8-worker concurrency bound"
    );
    assert!(
        scratch_allocs < total,
        "scratch allocs {scratch_allocs} grew with the {total} requests"
    );
    server.stop();
    assert_eq!(metrics.gauge("server.connections.active").get(), 0);
}

#[test]
fn identical_concurrent_misses_solve_once_across_connections() {
    // N clients fire the same never-seen FOLDIN at the same instant: the
    // single-flight slot must run exactly one solve and hand every other
    // client the computed response (as a hit), not N duplicate solves
    let metrics = MetricsRegistry::new();
    let server = TopicServer::start_with(
        "127.0.0.1:0",
        model(),
        metrics.clone(),
        ServeOptions {
            threads: 8,
            cache_size: 64,
        },
    )
    .unwrap();
    let addr = server.addr();
    const N: usize = 8;
    let aligned = Arc::new(Barrier::new(N));
    let handles: Vec<_> = (0..N)
        .map(|_| {
            let aligned = Arc::clone(&aligned);
            std::thread::spawn(move || {
                let (mut reader, mut writer) = connect(addr);
                // answered PING ⇒ this client's handler is live
                assert_eq!(query(&mut reader, &mut writer, "PING"), "OK pong");
                aligned.wait();
                let r = query(&mut reader, &mut writer, "FOLDIN coffee:3 electrons:1");
                assert!(r.starts_with("OK nnz="), "{r}");
                r
            })
        })
        .collect();
    let answers: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // exactly one solve; the other N-1 identical requests were either
    // single-flight waiters or post-publish cache hits — both are hits
    assert_eq!(metrics.counter("server.cache.misses").get(), 1);
    assert_eq!(metrics.counter("server.cache.hits").get(), (N - 1) as u64);
    let suppressed = metrics.counter("server.cache.stampede_suppressed").get();
    assert!(suppressed <= (N - 1) as u64, "suppressed {suppressed}");
    // every client saw the one computed response, byte for byte
    assert!(
        answers.windows(2).all(|w| w[0] == w[1]),
        "answers diverged: {answers:?}"
    );
    server.stop();
}

#[test]
fn graceful_shutdown_drains_open_connections() {
    let server =
        TopicServer::start("127.0.0.1:0", model(), MetricsRegistry::new()).unwrap();
    let (mut reader, mut writer) = connect(server.addr());
    assert_eq!(query(&mut reader, &mut writer, "PING"), "OK pong");

    // stop() must return even though a client connection is still open:
    // the handler notices the stop flag at its next read poll
    let start = std::time::Instant::now();
    server.stop();
    assert!(
        start.elapsed() < std::time::Duration::from_secs(5),
        "shutdown took {:?}",
        start.elapsed()
    );
    // the server closed our connection: the next read sees EOF
    reader
        .get_ref()
        .set_read_timeout(Some(std::time::Duration::from_secs(5)))
        .unwrap();
    let mut buf = [0u8; 16];
    assert_eq!(reader.get_mut().read(&mut buf).unwrap(), 0);
}

#[test]
fn queued_connections_are_served_when_workers_free() {
    // 2 workers, 4 sequential client sessions each holding then releasing
    // a worker: later connects queue on the pool and still get served
    let server = TopicServer::start_with(
        "127.0.0.1:0",
        model(),
        MetricsRegistry::new(),
        ServeOptions {
            threads: 2,
            cache_size: 0,
        },
    )
    .unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || {
                let (mut reader, mut writer) = connect(addr);
                for _ in 0..10 {
                    let r = query(&mut reader, &mut writer, "CLASSIFY coffee");
                    assert!(r.contains("topic:0"), "{r}");
                }
                assert_eq!(query(&mut reader, &mut writer, "QUIT"), "OK bye");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.stop();
}
