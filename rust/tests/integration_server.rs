//! TCP round-trip test of the topic-query server.

use esnmf::coordinator::{MetricsRegistry, TopicModel, TopicServer};
use esnmf::sparse::Csr;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn model() -> Arc<TopicModel> {
    let u = Csr::from_dense(4, 2, &[
        0.9, 0.0, //
        0.5, 0.0, //
        0.0, 0.8, //
        0.0, 0.3,
    ]);
    let v = Csr::from_dense(3, 2, &[1.0, 0.0, 0.0, 0.9, 0.4, 0.0]);
    Arc::new(TopicModel::new(
        u,
        v,
        vec![
            "coffee".into(),
            "crop".into(),
            "electrons".into(),
            "atoms".into(),
        ],
    ))
}

fn query(reader: &mut impl BufRead, writer: &mut impl Write, q: &str) -> String {
    writeln!(writer, "{q}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim_end().to_string()
}

#[test]
fn tcp_protocol_roundtrip() {
    let metrics = MetricsRegistry::new();
    let server = TopicServer::start("127.0.0.1:0", model(), metrics.clone()).unwrap();
    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    assert_eq!(query(&mut reader, &mut writer, "TOPICS"), "OK k=2");
    assert!(query(&mut reader, &mut writer, "TOPTERMS 0 2").contains("coffee"));
    assert!(query(&mut reader, &mut writer, "CLASSIFY electrons atoms").contains("topic:1"));
    assert!(query(&mut reader, &mut writer, "DOCS 1 5").starts_with("OK 1:0.9000"));
    assert!(query(&mut reader, &mut writer, "BOGUS").starts_with("ERR"));
    let stats = query(&mut reader, &mut writer, "STATS");
    assert!(stats.contains("server.requests"), "{stats}");
    assert_eq!(query(&mut reader, &mut writer, "QUIT"), "OK bye");
    assert!(metrics.counter("server.requests").get() >= 5);
    server.stop();
}

#[test]
fn multiple_concurrent_clients() {
    let server =
        TopicServer::start("127.0.0.1:0", model(), MetricsRegistry::new()).unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..6)
        .map(|_| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                for _ in 0..20 {
                    let r = query(&mut reader, &mut writer, "CLASSIFY coffee");
                    assert!(r.contains("topic:0"), "{r}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    server.stop();
}
