//! End-to-end tests of the `.esnmf` model-snapshot subsystem: property
//! tests of the save→load round trip, typed failures on truncated and
//! bit-flipped files, serve-from-snapshot answer identity over TCP, and
//! checkpoint→resume equivalence through the public API.

use esnmf::coordinator::{MetricsRegistry, ServerState, TopicModel, TopicServer};
use esnmf::io::{corpus_digest, Progress, Snapshot, SnapshotError};
use esnmf::nmf::{self, NmfOptions, SparsityMode};
use esnmf::sparse::TieMode;
use esnmf::text::TermDocMatrix;
use esnmf::util::prop;
use esnmf::util::rng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn labeled_tdm(seed: u64) -> TermDocMatrix {
    esnmf::corpus::generate_tdm(&esnmf::corpus::reuters_sim(esnmf::corpus::Scale::Tiny), seed)
}

fn snapshot_of(tdm: &TermDocMatrix, opts: &NmfOptions) -> (Snapshot, esnmf::nmf::NmfResult) {
    let r = nmf::factorize(tdm, opts);
    let snap = Snapshot::new(
        opts.clone(),
        r.u.clone(),
        r.v.clone(),
        tdm,
        Progress {
            iterations: r.iterations,
            residuals: r.residuals.clone(),
            errors: r.errors.clone(),
            memory: r.memory,
            elapsed_s: r.elapsed_s,
        },
    );
    (snap, r)
}

/// Property: save→load is the identity on factors, vocabulary, labels,
/// options and progress — across randomized ranks, sparsity budgets and
/// seeds.
#[test]
fn roundtrip_is_identity_property() {
    prop::check("snapshot roundtrip", 0xe5, 12, |rng: &mut Rng| {
        let tdm = labeled_tdm(rng.below(1000) as u64);
        let k = 2 + rng.below(4);
        let mut opts = NmfOptions::new(k)
            .with_iters(1 + rng.below(5))
            .with_seed(rng.below(10_000) as u64);
        if rng.below(2) == 1 {
            opts = opts.with_sparsity(SparsityMode::both(
                20 + rng.below(60),
                40 + rng.below(100),
            ));
            opts.tie_mode = TieMode::Exact;
        }
        if rng.below(2) == 1 {
            opts = opts.with_init_nnz(30 + rng.below(50));
        }
        let (snap, _) = snapshot_of(&tdm, &opts);
        let back = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(back.u, snap.u);
        assert_eq!(back.v, snap.v);
        assert_eq!(back.terms, snap.terms);
        assert_eq!(back.doc_labels, snap.doc_labels);
        assert_eq!(back.label_names, snap.label_names);
        assert_eq!(back.corpus_digest, snap.corpus_digest);
        assert_eq!(back.progress, snap.progress);
        assert_eq!(back.options.k, snap.options.k);
        assert_eq!(back.options.sparsity, snap.options.sparsity);
        assert_eq!(back.options.seed, snap.options.seed);
        assert_eq!(back.options.init_nnz, snap.options.init_nnz);
        assert_eq!(back.options.tie_mode, snap.options.tie_mode);
    });
}

/// Property: every strict prefix fails with a typed error (Truncated for
/// header/payload cuts — never a panic), and any single bit flip in the
/// payload is caught by the CRC.
#[test]
fn corruption_is_always_a_typed_error_property() {
    let tdm = labeled_tdm(7);
    let opts = NmfOptions::new(3).with_iters(3).with_seed(9);
    let (snap, _) = snapshot_of(&tdm, &opts);
    let bytes = snap.to_bytes();

    prop::check("snapshot corruption", 0xc0, 64, |rng: &mut Rng| {
        // random truncation point
        let cut = rng.below(bytes.len());
        match Snapshot::from_bytes(&bytes[..cut]) {
            Err(
                SnapshotError::Truncated { .. }
                | SnapshotError::BadMagic
                | SnapshotError::Corrupt(_),
            ) => {}
            other => panic!("truncation at {cut}: {other:?}"),
        }
        // random payload bit flip
        let pos = 20 + rng.below(bytes.len() - 20);
        let bit = 1u8 << rng.below(8);
        let mut bad = bytes.clone();
        bad[pos] ^= bit;
        match Snapshot::from_bytes(&bad) {
            Err(SnapshotError::CrcMismatch { .. }) => {}
            other => panic!("bit flip at {pos}: {other:?}"),
        }
    });
}

/// A server cold-started from a snapshot answers CLASSIFY/FOLDIN/TOPTERMS
/// byte-identically to the freshly-trained model it was saved from —
/// checked over a real TCP connection.
#[test]
fn serve_from_snapshot_answers_identically_over_tcp() {
    let tdm = labeled_tdm(23);
    let mut opts = NmfOptions::new(4)
        .with_iters(8)
        .with_seed(41)
        .with_sparsity(SparsityMode::both(60, 120));
    opts.tie_mode = TieMode::Exact;
    let (snap, r) = snapshot_of(&tdm, &opts);

    // the reference: the exact serving path over the fresh model
    let fresh = Arc::new(
        TopicModel::new(r.u, r.v, tdm.terms.clone()).with_foldin_budget(snap.t_v()),
    );
    let reference = ServerState::new(Arc::clone(&fresh), MetricsRegistry::new(), 0);

    // the system under test: a TCP server over the loaded snapshot
    let loaded = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
    let served = Arc::new(TopicModel::from_snapshot(loaded));
    assert_eq!(served.foldin_budget(), fresh.foldin_budget());
    let server = TopicServer::start("127.0.0.1:0", served, MetricsRegistry::new()).unwrap();
    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    let word_of = |i: usize| tdm.terms[i % tdm.terms.len()].clone();
    let mut queries = vec!["TOPICS".to_string()];
    for t in 0..4 {
        queries.push(format!("TOPTERMS {t} 8"));
        queries.push(format!("DOCS {t} 6"));
    }
    for i in 0..10 {
        queries.push(format!("CLASSIFY {} {}", word_of(i), word_of(i * 3 + 1)));
        queries.push(format!("FOLDIN {}:2 {}:1", word_of(i * 2), word_of(i * 5 + 3)));
    }
    for q in &queries {
        let want = esnmf::coordinator::server::respond(&reference, q);
        writeln!(writer, "{q}").unwrap();
        let mut got = String::new();
        reader.read_line(&mut got).unwrap();
        assert_eq!(got.trim_end(), want, "query {q:?}");
    }
    server.stop();
}

/// Checkpoint → crash → resume through the public API reaches the same
/// final factors, residual history and memory peaks as a run that never
/// crashed.
#[test]
fn checkpoint_resume_equals_uninterrupted() {
    let tdm = labeled_tdm(51);
    let ck = std::env::temp_dir().join("esnmf_integration_resume.esnmf");
    let _ = std::fs::remove_file(&ck);
    let mut opts = NmfOptions::new(3)
        .with_iters(10)
        .with_seed(13)
        .with_sparsity(SparsityMode::both(50, 110));
    opts.tie_mode = TieMode::Exact;

    let uninterrupted = nmf::factorize(&tdm, &opts);
    // crash after 7 iterations, checkpointing every 3 (last lands on 6)
    let _ = nmf::factorize(&tdm, &opts.clone().with_iters(7).with_checkpoint(&ck, 3));
    let snap = Snapshot::load(&ck).unwrap();
    assert_eq!(snap.progress.iterations, 6);
    let resumed = nmf::resume(&tdm, &opts, &snap).unwrap();
    assert_eq!(resumed.u, uninterrupted.u);
    assert_eq!(resumed.v, uninterrupted.v);
    assert_eq!(resumed.iterations, uninterrupted.iterations);
    assert_eq!(resumed.residuals, uninterrupted.residuals);
    assert_eq!(resumed.errors, uninterrupted.errors);
    assert_eq!(resumed.memory, uninterrupted.memory);
    std::fs::remove_file(&ck).unwrap();
}

/// The corpus digest distinguishes corpora and pins resumability.
#[test]
fn digest_distinguishes_corpora() {
    let a = labeled_tdm(1);
    let b = labeled_tdm(2);
    assert_eq!(corpus_digest(&a), corpus_digest(&a));
    assert_ne!(corpus_digest(&a), corpus_digest(&b));
    let opts = NmfOptions::new(2).with_iters(2).with_seed(1);
    let (snap, _) = snapshot_of(&a, &opts);
    assert!(snap.check_corpus(&a).is_ok());
    assert!(matches!(
        snap.check_corpus(&b),
        Err(SnapshotError::Mismatch(_))
    ));
}
