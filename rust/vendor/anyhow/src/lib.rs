//! Offline API-compatible subset of `anyhow`.
//!
//! The build environment has no crates.io mirror, so the workspace vendors
//! the few dozen lines of `anyhow` surface the crate actually uses:
//! [`Error`], [`Result`], [`Context`], and the `anyhow!` / `bail!` /
//! `ensure!` macros. Behaviour matches upstream where it matters:
//!
//! * `{}` displays the outermost message only; `{:#}` joins the context
//!   chain outermost-first with `": "` (what `eprintln!("error: {e:#}")`
//!   in `main` relies on).
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   capturing its `source()` chain as context frames.
//! * `Error` itself does *not* implement `std::error::Error`, mirroring
//!   upstream (which is what makes the blanket `From` impl coherent).

use std::fmt;

/// An error chain: `frames[0]` is the outermost (most recent) message.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (`anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            frames: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut frames = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            frames.push(s.to_string());
            source = s.source();
        }
        Error { frames }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err())
            .with_context(|| "reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().root_cause(), "gone");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero");
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative: -2");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
    }
}
