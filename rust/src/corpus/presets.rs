//! Corpus presets matched (structurally) to the paper's three datasets.
//!
//! Each preset accepts a [`Scale`] so tests run in milliseconds while the
//! `Paper` scale approaches the dataset sizes reported in §3:
//! Reuters-21578 (1,985 docs / 6,424 terms), Wikipedia (12,439 pages),
//! PubMed 5-journal abstracts (7,510 docs / 20,112 terms).

use super::generator::{CorpusSpec, TopicSpec};
use super::words;

/// How large to make a preset corpus.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// unit tests: hundreds of docs
    Tiny,
    /// benches/examples: ~1/4 of paper size
    Small,
    /// matches the paper's reported dataset sizes
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s.to_ascii_lowercase().as_str() {
            "tiny" => Some(Scale::Tiny),
            "small" => Some(Scale::Small),
            "paper" | "full" => Some(Scale::Paper),
            _ => None,
        }
    }

    fn docs(self, paper: usize) -> usize {
        match self {
            Scale::Tiny => (paper / 20).max(100),
            Scale::Small => paper / 4,
            Scale::Paper => paper,
        }
    }

    fn tail(self, paper: usize) -> usize {
        match self {
            Scale::Tiny => (paper / 10).max(40),
            Scale::Small => paper / 3,
            Scale::Paper => paper,
        }
    }
}

fn topics(specs: &[(&str, &'static [&'static str])]) -> Vec<TopicSpec> {
    specs
        .iter()
        .map(|(name, seeds)| TopicSpec {
            name: name.to_string(),
            seeds: seeds.to_vec(),
        })
        .collect()
}

/// Newswire-like corpus standing in for Reuters-21578: five financial /
/// commodity themes, short wire-story documents.
pub fn reuters_sim(scale: Scale) -> CorpusSpec {
    CorpusSpec {
        name: "reuters-sim".into(),
        topics: topics(&[
            ("transport", words::TRANSPORT),
            ("futures", words::FUTURES),
            ("coffee", words::COFFEE),
            ("buyback", words::BUYBACK),
            ("currency", words::CURRENCY),
        ]),
        n_docs: scale.docs(1985),
        doc_len_mean: 80,
        // tails kept well below the doc count so each tail word occurs in
        // many documents: the paper's row normalization (divide by row
        // nnz) would otherwise let topic-pure rare words displace the
        // seed vocabulary in every topic table (see DESIGN.md
        // §Substitutions)
        topic_tail: scale.tail(180),
        background_tail: scale.tail(120),
        background_frac: 0.35,
        mixture: 0.15,
        zipf_s: 1.05,
    }
}

/// Encyclopedia-like corpus standing in for the Wikipedia dump: five
/// broad themes with longer articles and a wide vocabulary tail.
pub fn wikipedia_sim(scale: Scale) -> CorpusSpec {
    CorpusSpec {
        name: "wikipedia-sim".into(),
        topics: topics(&[
            ("government", words::GOVERNMENT),
            ("science", words::SCIENCE),
            ("music", words::MUSIC),
            ("religion", words::RELIGION),
            ("geography", words::GEOGRAPHY),
        ]),
        n_docs: scale.docs(12_439),
        doc_len_mean: 160,
        topic_tail: scale.tail(500),
        background_tail: scale.tail(350),
        background_frac: 0.40,
        mixture: 0.20,
        zipf_s: 1.02,
    }
}

/// Abstract corpus standing in for the five PubMed journals; the topic
/// name doubles as the ground-truth journal label for Eq. 3.3 accuracy.
pub fn pubmed_sim(scale: Scale) -> CorpusSpec {
    CorpusSpec {
        name: "pubmed-sim".into(),
        topics: topics(&[
            ("bmc-bioinformatics", words::BIOINFORMATICS),
            ("bmc-genetics", words::GENETICS),
            ("bmc-medical-education", words::MEDICAL_EDUCATION),
            ("bmc-neurology", words::NEUROLOGY),
            ("bmc-psychiatry", words::PSYCHIATRY),
        ]),
        n_docs: scale.docs(7510),
        doc_len_mean: 120,
        topic_tail: scale.tail(380),
        background_tail: scale.tail(280),
        background_frac: 0.45,
        mixture: 0.10,
        zipf_s: 1.05,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generator::generate_tdm;

    #[test]
    fn scales_order() {
        let spec_t = reuters_sim(Scale::Tiny);
        let spec_s = reuters_sim(Scale::Small);
        let spec_p = reuters_sim(Scale::Paper);
        assert!(spec_t.n_docs < spec_s.n_docs && spec_s.n_docs < spec_p.n_docs);
        assert_eq!(spec_p.n_docs, 1985);
    }

    #[test]
    fn presets_have_five_topics() {
        for spec in [
            reuters_sim(Scale::Tiny),
            wikipedia_sim(Scale::Tiny),
            pubmed_sim(Scale::Tiny),
        ] {
            assert_eq!(spec.topics.len(), 5, "{}", spec.name);
        }
    }

    #[test]
    fn tiny_reuters_matrix_is_very_sparse() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 42);
        // the paper's data matrices are ~99.6% sparse; tiny scale is less
        // extreme but must still be clearly sparse
        assert!(tdm.a.sparsity() > 0.85, "sparsity {}", tdm.a.sparsity());
        assert!(tdm.n_terms() > 200);
        assert_eq!(tdm.label_names.len(), 5);
    }

    #[test]
    fn scale_parse() {
        assert_eq!(Scale::parse("tiny"), Some(Scale::Tiny));
        assert_eq!(Scale::parse("PAPER"), Some(Scale::Paper));
        assert_eq!(Scale::parse("bogus"), None);
    }
}
