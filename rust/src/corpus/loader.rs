//! Load a real corpus from disk: a directory of `.txt` files, optionally
//! nested one level where the subdirectory name is the ground-truth label
//! (`corpus/econ/doc1.txt` → label "econ").
//!
//! A directory mixing flat `.txt` files with labeled subdirectories is
//! well-defined: the flat documents get the
//! [`crate::text::tdm::UNLABELED`] sentinel label at freeze, so
//! `doc_labels` never carries out-of-range ids into the eval paths.

use crate::text::{TdmBuilder, TermDocMatrix};
use anyhow::{Context, Result};
use std::fs;
use std::path::Path;

/// Read every `*.txt` under `dir` (one level of label subdirectories
/// supported) into a term-document matrix.
pub fn load_dir(dir: &Path) -> Result<TermDocMatrix> {
    let mut builder = TdmBuilder::new();
    let mut entries: Vec<_> = fs::read_dir(dir)
        .with_context(|| format!("reading corpus dir {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            let label = path
                .file_name()
                .and_then(|n| n.to_str())
                .map(|s| s.to_string());
            let mut files: Vec<_> = fs::read_dir(&path)?
                .collect::<std::io::Result<Vec<_>>>()?;
            files.sort_by_key(|e| e.path());
            for f in files {
                let fp = f.path();
                if fp.extension().is_some_and(|e| e == "txt") {
                    let text = fs::read_to_string(&fp)
                        .with_context(|| format!("reading {}", fp.display()))?;
                    builder.add_text(&text, label.as_deref());
                }
            }
        } else if path.extension().is_some_and(|e| e == "txt") {
            let text = fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            builder.add_text(&text, None);
        }
    }
    anyhow::ensure!(builder.n_docs() > 0, "no .txt documents under {}", dir.display());
    Ok(builder.freeze())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write(path: &Path, content: &str) {
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(path, content).unwrap();
    }

    #[test]
    fn loads_flat_directory() {
        let dir = std::env::temp_dir().join("esnmf_loader_flat");
        let _ = fs::remove_dir_all(&dir);
        write(&dir.join("a.txt"), "coffee crop coffee");
        write(&dir.join("b.txt"), "coffee quotas market");
        write(&dir.join("ignored.md"), "not loaded");
        let tdm = load_dir(&dir).unwrap();
        assert_eq!(tdm.n_docs(), 2);
        assert!(tdm.doc_labels.is_none());
    }

    #[test]
    fn loads_labeled_subdirectories() {
        let dir = std::env::temp_dir().join("esnmf_loader_labeled");
        let _ = fs::remove_dir_all(&dir);
        write(&dir.join("econ/a.txt"), "coffee crop coffee market");
        write(&dir.join("econ/b.txt"), "coffee futures market");
        write(&dir.join("sci/c.txt"), "electrons atoms electrons");
        let tdm = load_dir(&dir).unwrap();
        assert_eq!(tdm.n_docs(), 3);
        let labels = tdm.doc_labels.as_ref().unwrap();
        assert_eq!(labels.len(), 3);
        assert_eq!(tdm.label_names.len(), 2);
    }

    #[test]
    fn mixed_flat_and_labeled_corpus_is_well_defined() {
        // regression: this layout used to yield doc_labels containing a
        // u32::MAX sentinel that downstream eval indexed out of bounds
        let dir = std::env::temp_dir().join("esnmf_loader_mixed");
        let _ = fs::remove_dir_all(&dir);
        write(&dir.join("stray.txt"), "coffee crop coffee crop");
        write(&dir.join("econ/a.txt"), "coffee crop coffee market");
        write(&dir.join("econ/b.txt"), "coffee futures market crop");
        write(&dir.join("sci/c.txt"), "electrons atoms electrons atoms");
        let tdm = load_dir(&dir).unwrap();
        assert_eq!(tdm.n_docs(), 4);
        let labels = tdm.doc_labels.as_ref().expect("mixed corpus keeps labels");
        assert_eq!(labels.len(), 4);
        for &l in labels {
            assert!(
                (l as usize) < tdm.label_names.len(),
                "label {l} out of range for {:?}",
                tdm.label_names
            );
        }
        assert!(tdm.label_names.iter().any(|n| n == crate::text::tdm::UNLABELED));
        // entries sort by path (econ/ < sci/ < stray.txt), so the flat
        // document is the last one added and must carry the sentinel
        assert_eq!(
            tdm.label_names[*labels.last().unwrap() as usize],
            crate::text::tdm::UNLABELED
        );
    }

    #[test]
    fn empty_dir_errors() {
        let dir = std::env::temp_dir().join("esnmf_loader_empty");
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        assert!(load_dir(&dir).is_err());
    }
}
