//! Planted-topic bag-of-words corpus generator.
//!
//! Each document draws a primary (and, with probability `mixture`, a
//! secondary) planted topic; tokens come from the topic's Zipf-distributed
//! vocabulary or a shared background vocabulary. Ground-truth topic labels
//! ride along for the Eq. 3.3 accuracy measure.

use super::words::{topic_vocab, BACKGROUND};
use crate::text::{TdmBuilder, TermDocMatrix};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TopicSpec {
    pub name: String,
    pub seeds: Vec<&'static str>,
}

#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub name: String,
    pub topics: Vec<TopicSpec>,
    pub n_docs: usize,
    /// mean document length in tokens (lognormal-ish spread)
    pub doc_len_mean: usize,
    /// synthetic tail words added to each topic vocabulary
    pub topic_tail: usize,
    /// synthetic tail words added to the background vocabulary
    pub background_tail: usize,
    /// probability a token is drawn from the background vocabulary
    pub background_frac: f64,
    /// probability a document mixes in a secondary topic
    pub mixture: f64,
    /// Zipf exponent for within-vocabulary rank weights
    pub zipf_s: f64,
}

#[derive(Clone, Debug)]
pub struct Document {
    pub tokens: Vec<String>,
    /// planted primary topic index
    pub label: u32,
}

/// Precomputed Zipf CDF over a vocabulary.
struct ZipfTable<'a> {
    vocab: &'a [String],
    cdf: Vec<f64>,
}

impl<'a> ZipfTable<'a> {
    fn new(vocab: &'a [String], s: f64) -> Self {
        let mut cdf = Vec::with_capacity(vocab.len());
        let mut acc = 0.0;
        for rank in 1..=vocab.len() {
            acc += 1.0 / (rank as f64).powf(s);
            cdf.push(acc);
        }
        ZipfTable { vocab, cdf }
    }

    fn sample(&self, rng: &mut Rng) -> &'a str {
        let total = *self.cdf.last().expect("empty vocabulary");
        let x = rng.f64() * total;
        let idx = self.cdf.partition_point(|&c| c < x);
        &self.vocab[idx.min(self.vocab.len() - 1)]
    }
}

/// Generate the documents of `spec` deterministically from `seed`.
pub fn generate(spec: &CorpusSpec, seed: u64) -> Vec<Document> {
    assert!(!spec.topics.is_empty(), "corpus needs at least one topic");
    let mut rng = Rng::new(seed ^ 0x00e5_0000_0000_0001);

    let topic_vocabs: Vec<Vec<String>> = spec
        .topics
        .iter()
        .map(|t| topic_vocab(&t.name, &t.seeds, spec.topic_tail))
        .collect();
    let background_vocab = topic_vocab("background", BACKGROUND, spec.background_tail);

    let topic_tables: Vec<ZipfTable> = topic_vocabs
        .iter()
        .map(|v| ZipfTable::new(v, spec.zipf_s))
        .collect();
    let background_table = ZipfTable::new(&background_vocab, spec.zipf_s);

    let mut docs = Vec::with_capacity(spec.n_docs);
    for _ in 0..spec.n_docs {
        let primary = rng.below(spec.topics.len());
        let secondary = if spec.topics.len() > 1 && rng.f64() < spec.mixture {
            let mut s = rng.below(spec.topics.len() - 1);
            if s >= primary {
                s += 1;
            }
            Some(s)
        } else {
            None
        };
        // lognormal-ish length, clamped to at least 8 tokens
        let len = ((spec.doc_len_mean as f64) * (0.35 * rng.normal()).exp())
            .round()
            .max(8.0) as usize;
        let mut tokens = Vec::with_capacity(len);
        for _ in 0..len {
            let word = if rng.f64() < spec.background_frac {
                background_table.sample(&mut rng)
            } else {
                let topic = match secondary {
                    Some(s) if rng.f64() < 0.4 => s,
                    _ => primary,
                };
                topic_tables[topic].sample(&mut rng)
            };
            tokens.push(word.to_string());
        }
        docs.push(Document {
            tokens,
            label: primary as u32,
        });
    }
    docs
}

/// Parse the numeric index out of a synthetic `<prefix><digits>` word
/// token (e.g. `"w13"` → 13), as produced by rank-indexed test
/// vocabularies. Returns a descriptive `Err` naming the offending token
/// instead of panicking on malformed input (the old
/// `token[1..].parse().unwrap()` crashed on any token without a valid
/// numeric tail — including multi-byte UTF-8 prefixes, where the `[1..]`
/// slice itself panicked).
pub fn synthetic_word_index(token: &str) -> Result<usize, String> {
    let start = token
        .char_indices()
        .find(|&(_, c)| c.is_ascii_digit())
        .map(|(i, _)| i)
        .ok_or_else(|| format!("synthetic word token {token:?} has no numeric index"))?;
    token[start..]
        .parse::<usize>()
        .map_err(|e| format!("synthetic word token {token:?}: bad index ({e})"))
}

/// Generate and freeze straight to a term-document matrix.
pub fn generate_tdm(spec: &CorpusSpec, seed: u64) -> TermDocMatrix {
    let docs = generate(spec, seed);
    let mut builder = TdmBuilder::new();
    for doc in &docs {
        let label = &spec.topics[doc.label as usize].name;
        builder.add_tokens(&doc.tokens, Some(label));
    }
    builder.freeze()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::words;

    fn tiny_spec() -> CorpusSpec {
        CorpusSpec {
            name: "tiny".into(),
            topics: vec![
                TopicSpec {
                    name: "coffee".into(),
                    seeds: words::COFFEE.to_vec(),
                },
                TopicSpec {
                    name: "science".into(),
                    seeds: words::SCIENCE.to_vec(),
                },
            ],
            n_docs: 60,
            doc_len_mean: 50,
            topic_tail: 30,
            background_tail: 30,
            background_frac: 0.3,
            mixture: 0.1,
            zipf_s: 1.05,
        }
    }

    #[test]
    fn deterministic_generation() {
        let spec = tiny_spec();
        let a = generate(&spec, 42);
        let b = generate(&spec, 42);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[0].tokens, b[0].tokens);
        let c = generate(&spec, 43);
        assert_ne!(a[0].tokens, c[0].tokens);
    }

    #[test]
    fn documents_have_plausible_lengths_and_labels() {
        let spec = tiny_spec();
        let docs = generate(&spec, 1);
        assert_eq!(docs.len(), 60);
        for d in &docs {
            assert!(d.tokens.len() >= 8);
            assert!((d.label as usize) < spec.topics.len());
        }
        // both topics appear
        let labels: std::collections::HashSet<u32> =
            docs.iter().map(|d| d.label).collect();
        assert_eq!(labels.len(), 2);
    }

    #[test]
    fn topic_words_dominate_their_topic() {
        let spec = tiny_spec();
        let docs = generate(&spec, 7);
        let mut coffee_in_coffee = 0usize;
        let mut coffee_in_science = 0usize;
        for d in &docs {
            let hits = d.tokens.iter().filter(|t| t.as_str() == "coffee").count();
            if d.label == 0 {
                coffee_in_coffee += hits;
            } else {
                coffee_in_science += hits;
            }
        }
        assert!(
            coffee_in_coffee > coffee_in_science * 3,
            "planted structure too weak: {coffee_in_coffee} vs {coffee_in_science}"
        );
    }

    #[test]
    fn tdm_pipeline_produces_sparse_labeled_matrix() {
        let tdm = generate_tdm(&tiny_spec(), 3);
        assert_eq!(tdm.n_docs(), 60);
        assert!(tdm.n_terms() > 40, "only {} terms", tdm.n_terms());
        assert!(tdm.a.sparsity() > 0.5, "sparsity {}", tdm.a.sparsity());
        let labels = tdm.doc_labels.as_ref().unwrap();
        assert_eq!(labels.len(), 60);
        assert_eq!(tdm.label_names.len(), 2);
    }

    #[test]
    fn zipf_head_is_most_frequent() {
        let vocab: Vec<String> = (0..50).map(|i| format!("w{i}")).collect();
        let table = ZipfTable::new(&vocab, 1.1);
        let mut rng = Rng::new(9);
        let mut counts = vec![0usize; 50];
        for _ in 0..20_000 {
            let w = table.sample(&mut rng);
            let idx = synthetic_word_index(w).expect("rank-indexed vocab");
            counts[idx] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[40]);
    }

    #[test]
    fn synthetic_word_index_parses_and_reports_bad_tokens() {
        assert_eq!(synthetic_word_index("w13"), Ok(13));
        assert_eq!(synthetic_word_index("word7"), Ok(7));
        assert_eq!(synthetic_word_index("w0"), Ok(0));
        // malformed tokens return Err naming the token instead of panicking
        for bad in ["w", "", "coffee", "übercrash"] {
            let err = synthetic_word_index(bad).unwrap_err();
            assert!(err.contains(&format!("{bad:?}")), "{err}");
        }
        // a digit tail longer than usize overflows into Err, not a panic
        let huge = format!("w{}", "9".repeat(40));
        assert!(synthetic_word_index(&huge).is_err());
    }
}
