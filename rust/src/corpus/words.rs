//! Seed vocabularies for the planted topics, plus a deterministic
//! pronounceable-word generator for the long Zipf tail.
//!
//! Seed words sit at the head of each topic's Zipf distribution so the
//! topic tables printed by the figure-7/table-1 experiments read like the
//! paper's (coffee/quotas/…, electrons/atoms/…), while the synthetic tail
//! provides realistic vocabulary breadth.

use crate::util::rng::Rng;

const ONSETS: &[&str] = &[
    "b", "c", "d", "f", "g", "h", "j", "k", "l", "m", "n", "p", "r", "s",
    "t", "v", "w", "z", "br", "cr", "dr", "fr", "gr", "pr", "tr", "st",
    "sp", "sl", "pl", "cl", "th", "sh", "ch",
];
const NUCLEI: &[&str] = &["a", "e", "i", "o", "u", "ai", "ea", "ou", "io"];
const CODAS: &[&str] = &["", "n", "r", "s", "t", "l", "m", "nd", "st", "rm", "ck"];

/// Deterministic pronounceable pseudo-word for (namespace, index).
pub fn synth_word(namespace: &str, index: usize) -> String {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in namespace.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x1000_0000_01b3);
    }
    h = (h ^ index as u64).wrapping_mul(0x1000_0000_01b3);
    let mut rng = Rng::new(h);
    let syllables = 2 + rng.below(2);
    let mut w = String::new();
    for _ in 0..syllables {
        w.push_str(ONSETS[rng.below(ONSETS.len())]);
        w.push_str(NUCLEI[rng.below(NUCLEI.len())]);
    }
    w.push_str(CODAS[rng.below(CODAS.len())]);
    // disambiguate rare collisions across namespaces deterministically
    if index % 7 == 3 {
        w.push_str(match index % 3 {
            0 => "ia",
            1 => "or",
            _ => "um",
        });
    }
    w
}

/// Build a topic vocabulary: seeds first (Zipf head), then synthetic tail.
pub fn topic_vocab(name: &str, seeds: &[&str], tail: usize) -> Vec<String> {
    let mut v: Vec<String> = seeds.iter().map(|s| s.to_string()).collect();
    let mut i = 0usize;
    while v.len() < seeds.len() + tail {
        let w = synth_word(name, i);
        i += 1;
        if !v.contains(&w) {
            v.push(w);
        }
    }
    v
}

// --- seed word lists per planted theme -------------------------------------

pub const TRANSPORT: &[&str] = &[
    "miles", "load", "factor", "revenue", "passenger", "airline", "traffic",
    "cargo", "flights", "carriers", "fleet", "routes", "freight", "aviation",
    "airports", "travel", "fares", "jet", "fuel", "capacity",
];

pub const FUTURES: &[&str] = &[
    "risk", "contracts", "paper", "proposals", "futures", "exchange",
    "trading", "options", "hedge", "margin", "settlement", "clearing",
    "commodity", "speculators", "volume", "delivery", "positions", "brokers",
    "regulators", "volatility",
];

pub const COFFEE: &[&str] = &[
    "coffee", "quotas", "ico", "crop", "colombia", "producer", "brazil",
    "export", "bags", "harvest", "beans", "prices", "growers", "roasters",
    "stocks", "quota", "agreement", "market", "season", "output",
];

pub const BUYBACK: &[&str] = &[
    "repurchase", "motors", "class", "spending", "buyback", "shares",
    "shareholders", "dividend", "stock", "board", "equity", "outstanding",
    "capital", "treasury", "common", "authorized", "program", "earnings",
    "quarter", "split",
];

pub const CURRENCY: &[&str] = &[
    "yen", "firms", "plaza", "currencies", "movements", "dollar", "exchange",
    "intervention", "monetary", "rates", "central", "banks", "trade",
    "deficit", "surplus", "accord", "stability", "depreciation", "mark",
    "treasury",
];

pub const GOVERNMENT: &[&str] = &[
    "government", "party", "war", "elections", "president", "election",
    "parliament", "minister", "military", "soviet", "policy", "state",
    "congress", "senate", "legislation", "vote", "coalition", "treaty",
    "constitution", "democracy",
];

pub const SCIENCE: &[&str] = &[
    "electrons", "electron", "atoms", "hydrogen", "isotopes", "atom",
    "nucleus", "protons", "neutrons", "energy", "quantum", "particles",
    "elements", "chemistry", "physics", "orbital", "molecules", "charge",
    "mass", "radiation",
];

pub const MUSIC: &[&str] = &[
    "album", "band", "albums", "music", "songs", "song", "guitar", "rock",
    "released", "tour", "singer", "vocals", "records", "chart", "studio",
    "label", "drums", "bass", "recording", "single",
];

pub const RELIGION: &[&str] = &[
    "jewish", "jews", "judaism", "israel", "hebrew", "torah", "rabbi",
    "synagogue", "holiday", "tradition", "community", "religious", "temple",
    "faith", "scripture", "prayer", "covenant", "festival", "diaspora",
    "kosher",
];

pub const SPORT: &[&str] = &[
    "league", "game", "games", "players", "team", "season", "teams",
    "championship", "coach", "football", "played", "club", "cup", "match",
    "tournament", "stadium", "scored", "goals", "defense", "victory",
];

pub const GEOGRAPHY: &[&str] = &[
    "city", "population", "airport", "census", "county", "region", "river",
    "capital", "district", "area", "north", "south", "municipality", "town",
    "border", "province", "coast", "climate", "settlement", "highway",
];

pub const FILM: &[&str] = &[
    "film", "church", "empire", "country", "united", "movie", "director",
    "actor", "cinema", "scene", "screen", "producer", "script", "awards",
    "drama", "cast", "premiere", "studio", "role", "audience",
];

pub const BIOINFORMATICS: &[&str] = &[
    "algorithm", "sequence", "genome", "protein", "alignment", "database",
    "software", "annotation", "expression", "microarray", "clustering",
    "prediction", "sequences", "computational", "gene", "analysis", "tool",
    "dataset", "classifier", "pipeline",
];

pub const GENETICS: &[&str] = &[
    "allele", "polymorphism", "linkage", "locus", "genotype", "inheritance",
    "mutation", "chromosome", "marker", "snp", "haplotype", "pedigree",
    "heritability", "phenotype", "variant", "recombination", "association",
    "loci", "genomic", "alleles",
];

pub const MEDICAL_EDUCATION: &[&str] = &[
    "students", "curriculum", "teaching", "education", "learning",
    "training", "skills", "assessment", "medical", "faculty", "course",
    "examination", "competence", "residents", "clinical", "feedback",
    "simulation", "undergraduate", "lecture", "mentoring",
];

pub const NEUROLOGY: &[&str] = &[
    "stroke", "seizure", "epilepsy", "migraine", "neurological", "brain",
    "lesion", "cognitive", "dementia", "parkinson", "sclerosis", "motor",
    "neuropathy", "cortex", "imaging", "mri", "symptoms", "headache",
    "cerebral", "neurons",
];

pub const PSYCHIATRY: &[&str] = &[
    "depression", "anxiety", "schizophrenia", "psychiatric", "disorder",
    "symptoms", "mental", "therapy", "antidepressant", "mood", "bipolar",
    "psychosis", "treatment", "suicide", "cognitive", "behavioral",
    "diagnosis", "patients", "intervention", "stress",
];

pub const BACKGROUND: &[&str] = &[
    "time", "people", "year", "years", "new", "first", "last", "world",
    "report", "group", "number", "part", "case", "high", "long", "early",
    "later", "major", "small", "large", "found", "called", "known", "used",
    "made", "based", "including", "according", "results", "study", "work",
    "system", "form", "three", "several", "important", "general", "common",
    "recent", "total", "level", "order", "way", "end", "day", "week",
    "month", "points", "data", "change",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_word_deterministic() {
        assert_eq!(synth_word("coffee", 7), synth_word("coffee", 7));
        assert_ne!(synth_word("coffee", 7), synth_word("coffee", 8));
        assert_ne!(synth_word("coffee", 7), synth_word("music", 7));
    }

    #[test]
    fn synth_words_are_tokenizable() {
        for i in 0..50 {
            let w = synth_word("test", i);
            assert!(w.len() >= 2, "{w}");
            assert!(w.chars().all(|c| c.is_ascii_lowercase()), "{w}");
        }
    }

    #[test]
    fn topic_vocab_has_requested_size_and_seeds_first() {
        let v = topic_vocab("coffee", COFFEE, 100);
        assert_eq!(v.len(), COFFEE.len() + 100);
        assert_eq!(v[0], "coffee");
        let mut dedup = v.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), v.len(), "vocabulary has duplicates");
    }
}
