//! Synthetic corpora standing in for the paper's datasets.
//!
//! The paper evaluates on Reuters-21578, a Wikipedia dump, and abstracts
//! from five PubMed journals — none redistributable here. Per DESIGN.md
//! §Substitutions we generate planted-topic bag-of-words corpora whose
//! *structure* (document/term counts, Zipfian term use, distinct topical
//! clusters, ground-truth labels) matches what the algorithms actually
//! exercise; the convergence / sparsity / accuracy behaviour of ALS
//! depends on that structure, not on the specific English words.

pub mod generator;
pub mod loader;
pub mod presets;
pub mod words;

pub use generator::{CorpusSpec, Document, TopicSpec, generate, generate_tdm, synthetic_word_index};
pub use presets::{pubmed_sim, reuters_sim, wikipedia_sim, Scale};
