//! A dedicated executor thread owning the PJRT engine.
//!
//! `xla::PjRtClient` wraps raw pointers without `Send`/`Sync`, so the
//! engine is confined to one thread; the coordinator talks to it through
//! a channel. Requests carry a reply sender — the calling thread blocks
//! only on its own reply, and independent callers interleave naturally.

use super::engine::{AlsIterOut, Engine};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

enum Request {
    AlsIter {
        n: usize,
        m: usize,
        k: usize,
        a: Vec<f32>,
        u: Vec<f32>,
        t_u: i32,
        t_v: i32,
        reply: mpsc::Sender<Result<AlsIterOut>>,
    },
    RelError {
        n: usize,
        m: usize,
        k: usize,
        a: Vec<f32>,
        u: Vec<f32>,
        v: Vec<f32>,
        reply: mpsc::Sender<Result<f32>>,
    },
    Warmup {
        reply: mpsc::Sender<Result<usize>>,
    },
    Platform {
        reply: mpsc::Sender<String>,
    },
    Shutdown,
}

/// Cloneable handle to the executor thread.
#[derive(Clone)]
pub struct XlaExecutor {
    tx: mpsc::Sender<Request>,
}

pub struct XlaExecutorGuard {
    pub handle: XlaExecutor,
    join: Option<JoinHandle<()>>,
}

impl XlaExecutor {
    /// Spawn the executor thread; fails fast if the manifest is missing.
    pub fn spawn(artifact_dir: PathBuf) -> Result<XlaExecutorGuard> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("xla-executor".into())
            .spawn(move || {
                let mut engine = match Engine::load(&artifact_dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::AlsIter {
                            n,
                            m,
                            k,
                            a,
                            u,
                            t_u,
                            t_v,
                            reply,
                        } => {
                            let _ = reply.send(engine.als_iter(n, m, k, &a, &u, t_u, t_v));
                        }
                        Request::RelError {
                            n,
                            m,
                            k,
                            a,
                            u,
                            v,
                            reply,
                        } => {
                            let _ = reply.send(engine.rel_error(n, m, k, &a, &u, &v));
                        }
                        Request::Warmup { reply } => {
                            let _ = reply.send(engine.warmup());
                        }
                        Request::Platform { reply } => {
                            let _ = reply.send(engine.platform());
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))??;
        Ok(XlaExecutorGuard {
            handle: XlaExecutor { tx },
            join: Some(join),
        })
    }

    pub fn als_iter(
        &self,
        n: usize,
        m: usize,
        k: usize,
        a: Vec<f32>,
        u: Vec<f32>,
        t_u: i32,
        t_v: i32,
    ) -> Result<AlsIterOut> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::AlsIter {
                n,
                m,
                k,
                a,
                u,
                t_u,
                t_v,
                reply,
            })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    pub fn rel_error(
        &self,
        n: usize,
        m: usize,
        k: usize,
        a: Vec<f32>,
        u: Vec<f32>,
        v: Vec<f32>,
    ) -> Result<f32> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::RelError {
                n,
                m,
                k,
                a,
                u,
                v,
                reply,
            })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    pub fn warmup(&self) -> Result<usize> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Warmup { reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))?
    }

    pub fn platform(&self) -> Result<String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Platform { reply })
            .map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped reply"))
    }
}

impl Drop for XlaExecutorGuard {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}
