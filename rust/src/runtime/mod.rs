//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from rust. Python is never on this path — the HLO text
//! files plus `manifest.json` are the entire interface.
//!
//! * [`manifest`] — parse the artifact manifest (shapes, dtypes, kinds).
//! * [`engine`] — `PjRtClient` wrapper: compile once, execute many.
//! * [`executor`] — a dedicated thread owning the engine, exposed through
//!   a channel API so the multithreaded coordinator can share it.

pub mod engine;
pub mod executor;
pub mod manifest;

pub use engine::Engine;
pub use executor::XlaExecutor;
pub use manifest::{Manifest, ProgramKind, ProgramSpec};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$ESNMF_ARTIFACTS`, else `artifacts/`
/// relative to the current dir, else relative to the crate manifest dir
/// (so `cargo test` works from anywhere in the tree).
pub fn artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("ESNMF_ARTIFACTS") {
        return dir.into();
    }
    let cwd = std::path::Path::new(DEFAULT_ARTIFACT_DIR);
    if cwd.join("manifest.json").exists() {
        return cwd.to_path_buf();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACT_DIR)
}

/// Are compiled artifacts available? (Tests skip XLA paths when not.)
pub fn artifacts_available() -> bool {
    artifact_dir().join("manifest.json").exists()
}
