//! The PJRT engine: compile artifacts once, execute many times.
//!
//! Mirrors `/opt/xla-example/load_hlo`: HLO *text* → `HloModuleProto` →
//! `XlaComputation` → `PjRtClient::compile` → `execute`. Executables are
//! cached by program name; inputs/outputs are flat `f32`/`i32` slices so
//! callers never touch `xla::Literal` directly.

use super::manifest::{Manifest, ProgramKind, ProgramSpec};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Outputs of one fused ALS iteration on the device.
#[derive(Clone, Debug)]
pub struct AlsIterOut {
    pub u_new: Vec<f32>,
    pub v: Vec<f32>,
}

impl Engine {
    /// Create a CPU PJRT client and load the manifest (compilation is
    /// lazy per program; call [`Engine::warmup`] to pre-compile).
    pub fn load(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            executables: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&mut self, spec: &ProgramSpec) -> Result<()> {
        if self.executables.contains_key(&spec.name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow!("parsing {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;
        self.executables.insert(spec.name.clone(), exe);
        Ok(())
    }

    /// Pre-compile every program in the manifest.
    pub fn warmup(&mut self) -> Result<usize> {
        let specs: Vec<ProgramSpec> = self.manifest.programs.clone();
        for spec in &specs {
            self.compile(spec)
                .with_context(|| format!("warmup {}", spec.name))?;
        }
        Ok(specs.len())
    }

    fn find(&self, kind: ProgramKind, n: usize, m: usize, k: usize) -> Result<ProgramSpec> {
        self.manifest
            .exact(kind, n, m, k)
            .cloned()
            .ok_or_else(|| anyhow!("no artifact for {kind:?} ({n}, {m}, {k}); re-run `make artifacts` with a matching config"))
    }

    /// Run one fused enforced-sparsity ALS iteration (Algorithm 2) on the
    /// device. `a` is row-major (n, m); `u` row-major (n, k); `t ≤ 0`
    /// disables enforcement for that side.
    pub fn als_iter(
        &mut self,
        n: usize,
        m: usize,
        k: usize,
        a: &[f32],
        u: &[f32],
        t_u: i32,
        t_v: i32,
    ) -> Result<AlsIterOut> {
        if a.len() != n * m {
            bail!("a has {} elements, want {}", a.len(), n * m);
        }
        if u.len() != n * k {
            bail!("u has {} elements, want {}", u.len(), n * k);
        }
        let spec = self.find(ProgramKind::AlsIter, n, m, k)?;
        self.compile(&spec)?;
        let exe = &self.executables[&spec.name];
        let a_lit = xla::Literal::vec1(a).reshape(&[n as i64, m as i64])?;
        let u_lit = xla::Literal::vec1(u).reshape(&[n as i64, k as i64])?;
        let tu_lit = xla::Literal::scalar(t_u);
        let tv_lit = xla::Literal::scalar(t_v);
        let result = exe.execute::<xla::Literal>(&[a_lit, u_lit, tu_lit, tv_lit])?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 2 {
            bail!("als_iter returned {} outputs, want 2", outs.len());
        }
        Ok(AlsIterOut {
            u_new: outs[0].to_vec::<f32>()?,
            v: outs[1].to_vec::<f32>()?,
        })
    }

    /// Relative Frobenius error ‖A − U Vᵀ‖/‖A‖ on the device.
    pub fn rel_error(
        &mut self,
        n: usize,
        m: usize,
        k: usize,
        a: &[f32],
        u: &[f32],
        v: &[f32],
    ) -> Result<f32> {
        let spec = self.find(ProgramKind::RelError, n, m, k)?;
        self.compile(&spec)?;
        let exe = &self.executables[&spec.name];
        let a_lit = xla::Literal::vec1(a).reshape(&[n as i64, m as i64])?;
        let u_lit = xla::Literal::vec1(u).reshape(&[n as i64, k as i64])?;
        let v_lit = xla::Literal::vec1(v).reshape(&[m as i64, k as i64])?;
        let result = exe.execute::<xla::Literal>(&[a_lit, u_lit, v_lit])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.get_first_element::<f32>()?)
    }
}

// Engine owns raw PJRT pointers; it is confined to one thread by the
// executor wrapper (see executor.rs), never shared.

#[cfg(test)]
mod tests {
    // Engine tests live in rust/tests/integration_runtime.rs because they
    // need compiled artifacts; unit scope here covers only error paths
    // that don't require a client. (Creating a client is cheap but loads
    // the PJRT plugin; keep that to the integration suite.)
}
