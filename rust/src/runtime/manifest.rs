//! Artifact manifest parsing (`artifacts/manifest.json`).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

pub const SUPPORTED_VERSION: usize = 2;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProgramKind {
    AlsIter,
    RelError,
}

impl ProgramKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "als_iter" => Ok(ProgramKind::AlsIter),
            "rel_error" => Ok(ProgramKind::RelError),
            other => bail!("unknown program kind {other:?}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ProgramSpec {
    pub name: String,
    pub kind: ProgramKind,
    pub n: usize,
    pub m: usize,
    pub k: usize,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub programs: Vec<ProgramSpec>,
}

fn tensor_specs(v: &Json, what: &str) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("{what} is not an array"))?
        .iter()
        .map(|t| {
            let t = t.as_arr().ok_or_else(|| anyhow!("{what} entry not an array"))?;
            if t.len() != 3 {
                bail!("{what} entry should be [name, dims, dtype]");
            }
            Ok(TensorSpec {
                name: t[0].as_str().ok_or_else(|| anyhow!("tensor name"))?.to_string(),
                dims: t[1]
                    .as_arr()
                    .ok_or_else(|| anyhow!("tensor dims"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("tensor dim")))
                    .collect::<Result<_>>()?,
                dtype: t[2].as_str().ok_or_else(|| anyhow!("tensor dtype"))?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str, base_dir: &Path) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let version = root
            .get("version")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != SUPPORTED_VERSION {
            bail!("manifest version {version} != supported {SUPPORTED_VERSION}; re-run `make artifacts`");
        }
        let progs = root
            .get("programs")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow!("manifest missing programs"))?;
        let mut programs = Vec::with_capacity(progs.len());
        for p in progs {
            let get_usize = |key: &str| {
                p.get(key)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| anyhow!("program missing {key}"))
            };
            programs.push(ProgramSpec {
                name: p
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("program missing name"))?
                    .to_string(),
                kind: ProgramKind::parse(
                    p.get("kind").and_then(|v| v.as_str()).unwrap_or(""),
                )?,
                n: get_usize("n")?,
                m: get_usize("m")?,
                k: get_usize("k")?,
                file: base_dir.join(
                    p.get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("program missing file"))?,
                ),
                inputs: tensor_specs(
                    p.get("inputs").ok_or_else(|| anyhow!("missing inputs"))?,
                    "inputs",
                )?,
                outputs: tensor_specs(
                    p.get("outputs").ok_or_else(|| anyhow!("missing outputs"))?,
                    "outputs",
                )?,
            });
        }
        Ok(Manifest { programs })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text, dir)
    }

    /// Smallest program of `kind` whose (n, m, k) can contain the request.
    pub fn best_fit(&self, kind: ProgramKind, n: usize, m: usize, k: usize) -> Option<&ProgramSpec> {
        self.programs
            .iter()
            .filter(|p| p.kind == kind && p.n >= n && p.m >= m && p.k == k)
            .min_by_key(|p| p.n * p.m)
    }

    /// Exact-shape lookup.
    pub fn exact(&self, kind: ProgramKind, n: usize, m: usize, k: usize) -> Option<&ProgramSpec> {
        self.programs
            .iter()
            .find(|p| p.kind == kind && p.n == n && p.m == m && p.k == k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 2,
      "programs": [
        {"name": "als_iter_8x12x2", "kind": "als_iter", "n": 8, "m": 12, "k": 2,
         "file": "als_iter_8x12x2.hlo.txt",
         "inputs": [["a", [8, 12], "f32"], ["u", [8, 2], "f32"],
                    ["t_u", [], "i32"], ["t_v", [], "i32"]],
         "outputs": [["u_new", [8, 2], "f32"], ["v", [12, 2], "f32"]]},
        {"name": "als_iter_64x96x2", "kind": "als_iter", "n": 64, "m": 96, "k": 2,
         "file": "als_iter_64x96x2.hlo.txt",
         "inputs": [["a", [64, 96], "f32"]],
         "outputs": [["u_new", [64, 2], "f32"]]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.programs.len(), 2);
        let p = &m.programs[0];
        assert_eq!(p.kind, ProgramKind::AlsIter);
        assert_eq!((p.n, p.m, p.k), (8, 12, 2));
        assert_eq!(p.inputs[2].dims, Vec::<usize>::new());
        assert_eq!(p.inputs[0].element_count(), 96);
        assert!(p.file.ends_with("als_iter_8x12x2.hlo.txt"));
    }

    #[test]
    fn best_fit_prefers_smallest_containing() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        let p = m.best_fit(ProgramKind::AlsIter, 8, 10, 2).unwrap();
        assert_eq!(p.n, 8);
        let p = m.best_fit(ProgramKind::AlsIter, 20, 20, 2).unwrap();
        assert_eq!(p.n, 64);
        assert!(m.best_fit(ProgramKind::AlsIter, 100, 10, 2).is_none());
        assert!(m.best_fit(ProgramKind::AlsIter, 8, 10, 3).is_none());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 2", "\"version\": 1");
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err());
    }

    #[test]
    fn rejects_unknown_kind() {
        let bad = SAMPLE.replace("als_iter\"", "mystery\"");
        assert!(Manifest::parse(&bad, Path::new("/x")).is_err());
    }
}
