//! Text-processing substrate: tokenizer → stop-word filter → vocabulary →
//! term-document matrix, with the paper's exact preprocessing (§3):
//! discard stop words, discard terms that occur only once in the corpus,
//! and divide each row of the data matrix by its nonzero count so common
//! terms do not dominate.

pub mod stopwords;
pub mod tdm;
pub mod tokenizer;
pub mod vocab;

pub use tdm::{TdmBuilder, TermDocMatrix, UNLABELED};
pub use tokenizer::{normalize_term, tokenize};
pub use vocab::Vocab;
