//! English stop-word list (the paper discards stop words before building
//! the term-document matrix). Derived from the classic SMART/Glasgow lists,
//! trimmed to common function words.

use std::collections::HashSet;
use std::sync::OnceLock;

const STOPWORDS: &[&str] = &[
    "about", "above", "after", "again", "against", "all", "also", "am", "an",
    "and", "any", "are", "aren't", "as", "at", "be", "because", "been",
    "before", "being", "below", "between", "both", "but", "by", "can",
    "cannot", "could", "couldn't", "did", "didn't", "do", "does", "doesn't",
    "doing", "don't", "down", "during", "each", "few", "for", "from",
    "further", "had", "hadn't", "has", "hasn't", "have", "haven't", "having",
    "he", "her", "here", "hers", "herself", "him", "himself", "his", "how",
    "if", "in", "into", "is", "isn't", "it", "its", "itself", "just", "me",
    "more", "most", "my", "myself", "no", "nor", "not", "now", "of", "off",
    "on", "once", "only", "or", "other", "ought", "our", "ours", "ourselves",
    "out", "over", "own", "said", "same", "she", "should", "shouldn't", "so",
    "some", "such", "than", "that", "the", "their", "theirs", "them",
    "themselves", "then", "there", "these", "they", "this", "those",
    "through", "to", "too", "under", "until", "up", "upon", "very", "was",
    "wasn't", "we", "were", "weren't", "what", "when", "where", "which",
    "while", "who", "whom", "why", "will", "with", "won't", "would",
    "wouldn't", "you", "your", "yours", "yourself", "yourselves", "mr",
    "mrs", "ms", "one", "two", "may", "many", "much", "us", "however",
    "since", "within", "without", "among", "between", "per", "via",
];

fn set() -> &'static HashSet<&'static str> {
    static SET: OnceLock<HashSet<&'static str>> = OnceLock::new();
    SET.get_or_init(|| STOPWORDS.iter().copied().collect())
}

/// Is this (already lowercased) term a stop word?
pub fn is_stopword(term: &str) -> bool {
    set().contains(term)
}

/// Remove stop words in place.
pub fn filter_stopwords(terms: &mut Vec<String>) {
    terms.retain(|t| !is_stopword(t));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn common_words_stopped() {
        for w in ["the", "and", "of", "is", "wouldn't"] {
            assert!(is_stopword(w), "{w} should be a stop word");
        }
    }

    #[test]
    fn content_words_pass() {
        for w in ["coffee", "electrons", "government", "yen"] {
            assert!(!is_stopword(w), "{w} should pass");
        }
    }

    #[test]
    fn filter_in_place() {
        let mut v = vec!["the".to_string(), "coffee".to_string(), "of".to_string()];
        filter_stopwords(&mut v);
        assert_eq!(v, vec!["coffee"]);
    }

    #[test]
    fn list_is_deduplicated_enough() {
        // the OnceLock set drops duplicates; sanity-check size is plausible
        assert!(set().len() > 100);
    }
}
