//! Term-document matrix builder — the paper's §3 preprocessing.
//!
//! Rows are terms, columns are documents, `a_ij` = occurrences of term i
//! in document j. Stop words are dropped at ingest; terms occurring only
//! once in the whole corpus are dropped at freeze; each surviving row is
//! divided by its nonzero count so common terms don't dominate topics.

use super::stopwords::is_stopword;
use super::tokenizer::tokenize;
use super::vocab::Vocab;
use crate::sparse::{Coo, Csc, Csr};
use std::collections::HashMap;

/// Label assigned at freeze to documents added without a label when the
/// corpus is *partially* labeled (e.g. a directory mixing flat `.txt`
/// files with labeled subdirectories). Guarantees the invariant
/// downstream eval relies on: whenever `doc_labels` is `Some`, every
/// entry is a valid index into `label_names` — previously such corpora
/// carried a `u32::MAX` sentinel that panicked or indexed out of bounds
/// in the accuracy/eval paths.
pub const UNLABELED: &str = "_unlabeled";

/// The frozen corpus matrix plus the metadata evaluation needs.
#[derive(Clone, Debug)]
pub struct TermDocMatrix {
    /// (terms × docs), row-normalized counts, CSR.
    pub a: Csr,
    /// CSC twin of `a` (built once; the Aᵀ·U product streams columns).
    pub a_csc: Csc,
    /// Term strings, indexed by row id.
    pub terms: Vec<String>,
    /// Ground-truth label per document (e.g. journal id), if known.
    pub doc_labels: Option<Vec<u32>>,
    /// Human names for label ids.
    pub label_names: Vec<String>,
}

impl TermDocMatrix {
    pub fn n_terms(&self) -> usize {
        self.a.rows
    }

    pub fn n_docs(&self) -> usize {
        self.a.cols
    }
}

/// Streaming builder: feed documents one at a time (possibly from the
/// coordinator's ingestion pipeline), then freeze.
#[derive(Debug, Default)]
pub struct TdmBuilder {
    vocab: Vocab,
    /// per-document sparse term counts: (term_id, count)
    docs: Vec<Vec<(u32, u32)>>,
    labels: Vec<u32>,
    label_names: Vec<String>,
    label_ids: HashMap<String, u32>,
    any_label: bool,
}

impl TdmBuilder {
    pub fn new() -> Self {
        TdmBuilder::default()
    }

    pub fn n_docs(&self) -> usize {
        self.docs.len()
    }

    pub fn n_terms_seen(&self) -> usize {
        self.vocab.len()
    }

    /// Add a raw-text document. `label` is the optional ground-truth
    /// cluster (journal) used by the accuracy measure.
    pub fn add_text(&mut self, text: &str, label: Option<&str>) {
        let tokens = tokenize(text);
        self.add_tokens(&tokens, label);
    }

    /// Add a pre-tokenized document.
    pub fn add_tokens<S: AsRef<str>>(&mut self, tokens: &[S], label: Option<&str>) {
        let mut counts: HashMap<u32, u32> = HashMap::new();
        for tok in tokens {
            let t = tok.as_ref();
            if is_stopword(t) {
                continue;
            }
            let id = self.vocab.intern(t);
            *counts.entry(id).or_insert(0) += 1;
        }
        let mut doc: Vec<(u32, u32)> = counts.into_iter().collect();
        doc.sort_unstable_by_key(|&(id, _)| id);
        for &(id, c) in &doc {
            self.vocab.bump(id, c as u64);
        }
        self.docs.push(doc);
        let label_id = match label {
            Some(name) => {
                self.any_label = true;
                match self.label_ids.get(name) {
                    Some(&id) => id,
                    None => {
                        let id = self.label_names.len() as u32;
                        self.label_ids.insert(name.to_string(), id);
                        self.label_names.push(name.to_string());
                        id
                    }
                }
            }
            None => u32::MAX,
        };
        self.labels.push(label_id);
    }

    /// Freeze: drop singleton terms, remap ids, build the CSR/CSC pair,
    /// row-normalize by nonzero count.
    pub fn freeze(self) -> TermDocMatrix {
        let keep = self.vocab.non_singleton_ids();
        let mut remap = vec![u32::MAX; self.vocab.len()];
        for (new_id, &old_id) in keep.iter().enumerate() {
            remap[old_id as usize] = new_id as u32;
        }
        let n_terms = keep.len();
        let n_docs = self.docs.len();

        let mut coo = Coo::new(n_terms, n_docs);
        for (j, doc) in self.docs.iter().enumerate() {
            for &(old_id, count) in doc {
                let new_id = remap[old_id as usize];
                if new_id != u32::MAX {
                    coo.push(new_id as usize, j, count as f32);
                }
            }
        }
        let mut a = coo.to_csr();

        // row normalization: divide each row by its nonzero count
        for r in 0..a.rows {
            let lo = a.indptr[r];
            let hi = a.indptr[r + 1];
            let nnz_row = (hi - lo) as f32;
            if nnz_row > 0.0 {
                for v in &mut a.values[lo..hi] {
                    *v /= nnz_row;
                }
            }
        }

        let terms: Vec<String> = keep.iter().map(|&id| self.vocab.term(id).to_string()).collect();
        let a_csc = a.to_csc();

        // a partially-labeled corpus (some docs added with a label, some
        // without) gets the UNLABELED sentinel for the gaps, so Some(labels)
        // always means "every entry indexes label_names"
        let mut labels = self.labels;
        let mut label_names = self.label_names;
        if self.any_label && labels.iter().any(|&l| l == u32::MAX) {
            let id = match label_names.iter().position(|n| n == UNLABELED) {
                Some(i) => i as u32,
                None => {
                    label_names.push(UNLABELED.to_string());
                    (label_names.len() - 1) as u32
                }
            };
            for l in &mut labels {
                if *l == u32::MAX {
                    *l = id;
                }
            }
        }
        TermDocMatrix {
            a,
            a_csc,
            terms,
            doc_labels: if self.any_label { Some(labels) } else { None },
            label_names,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_corpus() -> TermDocMatrix {
        let mut b = TdmBuilder::new();
        b.add_text("coffee crop coffee quotas", Some("econ"));
        b.add_text("the coffee market and crop reports", Some("econ"));
        b.add_text("electrons atoms electrons", Some("sci"));
        b.freeze()
    }

    #[test]
    fn shapes_and_labels() {
        let tdm = tiny_corpus();
        assert_eq!(tdm.n_docs(), 3);
        // singletons dropped: quotas, market, reports, atoms occur once
        assert!(tdm.terms.contains(&"coffee".to_string()));
        assert!(tdm.terms.contains(&"crop".to_string()));
        assert!(tdm.terms.contains(&"electrons".to_string()));
        assert!(!tdm.terms.contains(&"quotas".to_string()));
        assert!(!tdm.terms.contains(&"atoms".to_string()));
        assert!(!tdm.terms.contains(&"the".to_string())); // stop word
        assert_eq!(tdm.n_terms(), 3);
        assert_eq!(tdm.doc_labels.as_ref().unwrap().len(), 3);
        assert_eq!(tdm.label_names, vec!["econ", "sci"]);
    }

    #[test]
    fn row_normalization() {
        let tdm = tiny_corpus();
        let coffee = tdm.terms.iter().position(|t| t == "coffee").unwrap();
        // coffee appears in docs 0 (×2) and 1 (×1): nnz=2 → values 1.0, 0.5
        let (_, vals) = tdm.a.row(coffee);
        assert_eq!(vals.len(), 2);
        assert!((vals[0] - 1.0).abs() < 1e-6);
        assert!((vals[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn csc_twin_matches() {
        let tdm = tiny_corpus();
        assert_eq!(tdm.a_csc.to_csr(), tdm.a);
    }

    #[test]
    fn partially_labeled_corpus_gets_the_sentinel() {
        let mut b = TdmBuilder::new();
        b.add_text("coffee crop coffee crop", Some("econ"));
        b.add_text("coffee crop coffee", None); // unlabeled rider
        b.add_text("electrons atoms electrons atoms", Some("sci"));
        let tdm = b.freeze();
        let labels = tdm.doc_labels.as_ref().unwrap();
        assert_eq!(labels.len(), 3);
        // every label is a valid index into label_names (no u32::MAX leak)
        for &l in labels {
            assert!((l as usize) < tdm.label_names.len(), "label {l} out of range");
        }
        assert_eq!(tdm.label_names, vec!["econ", "sci", UNLABELED]);
        assert_eq!(labels[1] as usize, 2);
        // eval over such labels no longer panics/misindexes
        let v = Csr::from_dense(3, 2, &[1.0, 0.0, 1.0, 0.0, 0.0, 1.0]);
        let acc = crate::eval::mean_topic_accuracy(&v, labels, tdm.label_names.len());
        assert!(acc.is_finite());
    }

    #[test]
    fn unlabeled_corpus_has_no_labels() {
        let mut b = TdmBuilder::new();
        b.add_text("alpha beta alpha beta", None);
        b.add_text("beta gamma beta", None);
        let tdm = b.freeze();
        assert!(tdm.doc_labels.is_none());
    }

    #[test]
    fn empty_corpus() {
        let tdm = TdmBuilder::new().freeze();
        assert_eq!(tdm.n_docs(), 0);
        assert_eq!(tdm.n_terms(), 0);
    }

    #[test]
    fn tokens_api() {
        let mut b = TdmBuilder::new();
        b.add_tokens(&["alpha", "beta", "alpha"], Some("x"));
        b.add_tokens(&["alpha"], Some("x"));
        let tdm = b.freeze();
        assert_eq!(tdm.n_terms(), 1); // beta is a singleton
        assert_eq!(tdm.terms[0], "alpha");
    }
}
