//! Unicode-ish tokenizer: lowercase, split on non-alphanumerics, keep
//! alphabetic tokens of length ≥ 2 (single characters and pure numbers
//! carry no topical signal and the paper filters singletons anyway).

/// The canonical case normalization of this stack — char-wise Unicode
/// lowercasing, exactly what [`tokenize`] applies while building the
/// vocabulary. Every term lookup against that vocabulary (the model's
/// CLASSIFY/FOLDIN paths) and every case-folding cache key MUST use this
/// function rather than `str::to_lowercase`: the two differ on
/// context-sensitive mappings (e.g. Greek final sigma — `"ΟΔΟΣ"`
/// lowercases to `"οδος"` as a string but to `"οδοσ"` char-wise), and a
/// lookup normalized differently from the stored vocabulary silently
/// misses, serving wrong answers.
pub fn normalize_term(term: &str) -> String {
    let mut out = String::with_capacity(term.len());
    for ch in term.chars() {
        for lc in ch.to_lowercase() {
            out.push(lc);
        }
    }
    out
}

/// Tokenize one document into lowercase terms.
pub fn tokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for ch in text.chars() {
        if ch.is_alphanumeric() || ch == '\'' {
            for lc in ch.to_lowercase() {
                cur.push(lc);
            }
        } else if !cur.is_empty() {
            push_token(&mut out, &mut cur);
        }
    }
    if !cur.is_empty() {
        push_token(&mut out, &mut cur);
    }
    out
}

fn push_token(out: &mut Vec<String>, cur: &mut String) {
    // strip possessives: "market's" -> "market"
    let stripped = cur.trim_end_matches("'s").trim_matches('\'');
    if stripped.len() >= 2 && stripped.chars().any(|c| c.is_alphabetic()) {
        out.push(stripped.to_string());
    }
    cur.clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_split_and_lowercase() {
        assert_eq!(
            tokenize("The quick, Brown FOX!"),
            vec!["the", "quick", "brown", "fox"]
        );
    }

    #[test]
    fn drops_single_chars_and_numbers() {
        assert_eq!(tokenize("a 1 22 b2 xy"), vec!["b2", "xy"]);
    }

    #[test]
    fn strips_possessives() {
        assert_eq!(tokenize("market's"), vec!["market"]);
        assert_eq!(tokenize("'quoted'"), vec!["quoted"]);
    }

    #[test]
    fn empty_and_punctuation_only() {
        assert!(tokenize("").is_empty());
        assert!(tokenize("... !!! ---").is_empty());
    }

    #[test]
    fn unicode_lowercases() {
        assert_eq!(tokenize("Zürich Ärzte"), vec!["zürich", "ärzte"]);
    }

    #[test]
    fn normalize_term_matches_tokenizer_exactly() {
        // including the context-sensitive cases where str::to_lowercase
        // diverges (Greek capital sigma in final position)
        for word in ["Coffee", "ΟΔΟΣ", "İstanbul", "ÄRZTE", "mixedCASE'"] {
            let toks = tokenize(word);
            if let Some(tok) = toks.first() {
                // the tokenizer also strips quotes/possessives, so compare
                // against the normalized-then-stripped form
                let mut norm = normalize_term(word);
                norm = norm.trim_end_matches("'s").trim_matches('\'').to_string();
                assert_eq!(tok, &norm, "word {word:?}");
            }
        }
        // the regression this function exists for: final sigma
        assert_eq!(normalize_term("ΟΔΟΣ"), "οδοσ");
        assert_ne!(normalize_term("ΟΔΟΣ"), "ΟΔΟΣ".to_lowercase());
        assert_eq!(tokenize("ΟΔΟΣ")[0], normalize_term("ΟΔΟΣ"));
    }
}
