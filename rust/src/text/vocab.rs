//! Vocabulary: bidirectional term ↔ id mapping with corpus frequencies.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Vocab {
    term_to_id: HashMap<String, u32>,
    id_to_term: Vec<String>,
    /// total corpus occurrences per term id
    counts: Vec<u64>,
    /// number of documents containing the term
    doc_counts: Vec<u64>,
}

impl Vocab {
    pub fn new() -> Self {
        Vocab::default()
    }

    pub fn len(&self) -> usize {
        self.id_to_term.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id_to_term.is_empty()
    }

    /// Intern a term, returning its id.
    pub fn intern(&mut self, term: &str) -> u32 {
        if let Some(&id) = self.term_to_id.get(term) {
            return id;
        }
        let id = self.id_to_term.len() as u32;
        self.term_to_id.insert(term.to_string(), id);
        self.id_to_term.push(term.to_string());
        self.counts.push(0);
        self.doc_counts.push(0);
        id
    }

    pub fn id(&self, term: &str) -> Option<u32> {
        self.term_to_id.get(term).copied()
    }

    pub fn term(&self, id: u32) -> &str {
        &self.id_to_term[id as usize]
    }

    pub fn count(&self, id: u32) -> u64 {
        self.counts[id as usize]
    }

    pub fn doc_count(&self, id: u32) -> u64 {
        self.doc_counts[id as usize]
    }

    pub(crate) fn bump(&mut self, id: u32, occurrences: u64) {
        self.counts[id as usize] += occurrences;
        self.doc_counts[id as usize] += 1;
    }

    /// Ids of terms occurring more than once in the corpus (the paper
    /// discards singletons), in id order.
    pub fn non_singleton_ids(&self) -> Vec<u32> {
        (0..self.len() as u32)
            .filter(|&id| self.counts[id as usize] > 1)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let mut v = Vocab::new();
        let a = v.intern("coffee");
        let b = v.intern("coffee");
        assert_eq!(a, b);
        assert_eq!(v.len(), 1);
        assert_eq!(v.term(a), "coffee");
        assert_eq!(v.id("coffee"), Some(a));
        assert_eq!(v.id("tea"), None);
    }

    #[test]
    fn counts_accumulate() {
        let mut v = Vocab::new();
        let id = v.intern("yen");
        v.bump(id, 3);
        v.bump(id, 2);
        assert_eq!(v.count(id), 5);
        assert_eq!(v.doc_count(id), 2);
    }

    #[test]
    fn singleton_filter() {
        let mut v = Vocab::new();
        let a = v.intern("rare");
        let b = v.intern("common");
        v.bump(a, 1);
        v.bump(b, 4);
        assert_eq!(v.non_singleton_ids(), vec![b]);
    }
}
