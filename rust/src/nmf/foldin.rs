//! Fold-in of unseen documents — Algorithm 2's V half-step specialized to
//! one document at inference time.
//!
//! Given the frozen term factor `U`, projecting a new document `a`
//! (a sparse bag-of-words column) onto topic space is the same
//! one-factor-fixed non-negative least-squares step the training loop
//! runs for every document row:
//!
//! ```text
//! x = enforce_top_t( proj₊( aᵀ U (UᵀU + εI)⁻¹ ) )
//! ```
//!
//! The (k, k) ridged Gram inverse depends only on `U`, so [`FoldIn`]
//! computes it once at construction; each document then costs
//! O(nnz(a)·k + k²), which is what makes fold-in servable at request
//! rates. The enforcement operator is the same single-column top-t
//! primitive the training loop uses ([`topk::enforce_top_t_vec`]), so a
//! served model's fold-in rows obey the identical nonzero budget
//! discipline as its stored `V` rows.
//!
//! A model trained under KL divergence folds in under KL too
//! ([`FoldIn::with_objective`]): a fixed budget of multiplicative
//! updates against the frozen `U` (the trait's per-objective
//! [`Objective::foldin_solve`](crate::nmf::objective::Objective)), so
//! served answers minimize the same divergence the training loop did.
//! The per-`U` auxiliary is the objective's `step_aux` — the Gram
//! inverse for Frobenius, the per-topic column sums for KL.

use crate::sparse::{topk, Csr, TieMode};

use super::objective::ObjectiveKind;

/// A reusable single-document solver over a frozen `U`.
#[derive(Clone, Debug)]
pub struct FoldIn {
    k: usize,
    /// the objective the model was trained under (and solves under here)
    objective: ObjectiveKind,
    /// the objective's per-`U` solve auxiliary: `(UᵀU + εI)⁻¹` row-major
    /// (k, k) for Frobenius, per-topic column sums (k) for KL
    aux: Vec<f32>,
    /// per-document nonzero budget (None = unenforced)
    pub t: Option<usize>,
    pub tie: TieMode,
}

/// Per-request buffers of one fold-in solve, poolable by the serving
/// layer so a warm pool answers requests with zero allocation growth —
/// the same reuse discipline the solver applies to its per-worker
/// `RowBlock`s. Plain [`FoldIn::solve`] creates one transparently.
#[derive(Debug, Default)]
pub struct FoldInScratch {
    /// k-wide solve accumulator (`b = aᵀU` for Frobenius, the
    /// multiplicative-update numerator for KL)
    b: Vec<f32>,
    /// the solved row (k-wide; borrowed out by [`FoldIn::solve_into`])
    x: Vec<f32>,
    /// positive-value gather buffer of the enforcement pass
    positives: Vec<f32>,
    /// resolved (term row id, count) pairs of the model-level lookup
    pub pairs: Vec<(usize, f32)>,
}

impl FoldIn {
    /// The Frobenius solver (the historical constructor): precompute the
    /// ridged Gram inverse of `u`. `t` caps the nonzeros of every
    /// folded-in row (None leaves rows unenforced).
    pub fn new(u: &Csr, t: Option<usize>, tie: TieMode) -> FoldIn {
        FoldIn::with_objective(u, ObjectiveKind::Frobenius, t, tie)
    }

    /// A solver under an explicit objective — what the serving plane
    /// builds from a snapshot, so FOLDIN/CLASSIFY answers are consistent
    /// with how the model was trained.
    pub fn with_objective(
        u: &Csr,
        objective: ObjectiveKind,
        t: Option<usize>,
        tie: TieMode,
    ) -> FoldIn {
        // step_aux at threads = 1 is bit-identical to the historical
        // serial `gram` + `inverse_spd` (gram is gram_par(·, 1))
        let aux = objective.implementation().step_aux(u, 1);
        FoldIn {
            k: u.cols,
            objective,
            aux,
            t,
            tie,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// The objective this solver minimizes.
    pub fn objective(&self) -> ObjectiveKind {
        self.objective
    }

    /// One enforced-sparse half-step for a single document. `doc` is the
    /// sparse bag-of-words as (term row id, count) pairs; out-of-range
    /// term ids and non-positive counts are ignored. Returns the dense
    /// length-k topic row (nonnegative, at most `t` nonzeros when
    /// enforced).
    pub fn solve(&self, u: &Csr, doc: &[(usize, f32)]) -> Vec<f32> {
        let mut scratch = FoldInScratch::default();
        self.solve_into(u, doc, &mut scratch);
        scratch.x
    }

    /// As [`FoldIn::solve`] but through caller-pooled buffers: the solved
    /// row is left in (and returned as a view of) `scratch.x`, and no
    /// allocation happens once the scratch has warmed to size k. Results
    /// are identical to `solve` — the accumulator keeps an all-zero
    /// invariant between solves (the objective un-scatters exactly the
    /// indices it touched, O(nnz) per solve instead of a k-wide memset —
    /// see [`Objective::foldin_solve`](crate::nmf::objective::Objective)),
    /// so a pooled solve reads the same state a fresh allocation would.
    pub fn solve_into<'s>(
        &self,
        u: &Csr,
        doc: &[(usize, f32)],
        scratch: &'s mut FoldInScratch,
    ) -> &'s [f32] {
        let k = self.k;
        debug_assert_eq!(u.cols, k, "U changed shape under the solver");
        // the objective's per-document solve (non-negative, unenforced):
        // the Frobenius implementation is the exact historical
        // b = aᵀU → x = b·G⁻¹ → clamp sequence; KL runs a fixed budget
        // of multiplicative updates
        self.objective.implementation().foldin_solve(
            u,
            &self.aux,
            doc,
            &mut scratch.x,
            &mut scratch.b,
        );
        if let Some(t) = self.t {
            // the gather holds at most k positives: reserving up front
            // makes the no-allocation-once-warm property deterministic
            scratch.positives.clear();
            scratch.positives.reserve(k);
            topk::enforce_top_t_vec_with(&mut scratch.x, t, self.tie, &mut scratch.positives);
        }
        &scratch.x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmf::{factorize, half_step_v, MemoryTracker, NmfOptions};
    use crate::text::TdmBuilder;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn tiny_tdm() -> crate::text::TermDocMatrix {
        let mut b = TdmBuilder::new();
        for _ in 0..6 {
            b.add_text("coffee crop quotas coffee brazil crop", Some("econ"));
            b.add_text("electrons atoms hydrogen electrons atoms", Some("sci"));
        }
        b.freeze()
    }

    #[test]
    fn foldin_matches_unenforced_half_step_rows() {
        // fold-in of every training column must reproduce the same
        // algebra half_step_v runs over the whole matrix
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(10).with_seed(3).with_threads(1);
        let r = factorize(&tdm, &opts);
        let mut mem = MemoryTracker::new();
        let v_full = half_step_v(&tdm.a_csc, &r.u, &opts, &mut mem);
        let solver = FoldIn::new(&r.u, None, TieMode::KeepTies);
        for d in 0..tdm.n_docs() {
            let (idx, val) = tdm.a_csc.col(d);
            let doc: Vec<(usize, f32)> = idx
                .iter()
                .zip(val)
                .map(|(&t, &c)| (t as usize, c))
                .collect();
            let x = solver.solve(&r.u, &doc);
            for (c, &xc) in x.iter().enumerate() {
                let want = v_full.get(d, c);
                assert!(
                    (xc - want).abs() <= 1e-5 * want.abs().max(1.0),
                    "doc {d} topic {c}: fold-in {xc} vs half-step {want}"
                );
            }
        }
    }

    #[test]
    fn budget_respected_on_random_bags() {
        prop::check("foldin-topt-budget", 1400, 96, |rng: &mut Rng| {
            let rows = rng.range(4, 40);
            let k = rng.range(1, 8);
            let dense = prop::gen_sparse_dense(rng, rows, k, 0.5);
            let u = Csr::from_dense(rows, k, &dense);
            let t = rng.range(0, k + 2);
            let solver = FoldIn::new(&u, Some(t), TieMode::Exact);
            let n_words = rng.range(1, 12);
            let doc: Vec<(usize, f32)> = (0..n_words)
                .map(|_| (rng.below(rows + 2), rng.below(5) as f32))
                .collect();
            let x = solver.solve(&u, &doc);
            assert_eq!(x.len(), k);
            let nnz = x.iter().filter(|&&v| v > 0.0).count();
            assert!(nnz <= t, "nnz {nnz} > budget {t}");
            assert!(x.iter().all(|v| v.is_finite() && *v >= 0.0));
        });
    }

    #[test]
    fn pooled_scratch_solves_identically_and_stops_allocating() {
        // the serving layer reuses one FoldInScratch across requests;
        // reused solves must match fresh ones bit for bit, and once the
        // buffers are warm, further solves must not grow them
        let mut rng = Rng::new(0x5c7a);
        let rows = 20;
        let k = 6;
        let dense = prop::gen_sparse_dense(&mut rng, rows, k, 0.5);
        let u = Csr::from_dense(rows, k, &dense);
        let solver = FoldIn::new(&u, Some(3), TieMode::Exact);
        let mut scratch = FoldInScratch::default();
        // warm the buffers with a maximal document (every term present)
        let full: Vec<(usize, f32)> = (0..rows).map(|r| (r, 1.0)).collect();
        let _ = solver.solve_into(&u, &full, &mut scratch);
        let caps = (
            scratch.b.capacity(),
            scratch.x.capacity(),
            scratch.positives.capacity(),
        );
        for round in 0..30 {
            let n_words = rng.range(1, 10);
            let doc: Vec<(usize, f32)> = (0..n_words)
                .map(|_| (rng.below(rows), rng.below(5) as f32 + 1.0))
                .collect();
            let fresh = solver.solve(&u, &doc);
            let pooled = solver.solve_into(&u, &doc, &mut scratch).to_vec();
            assert_eq!(
                fresh.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                pooled.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "round {round}"
            );
            // warm buffers never grow: a request costs zero allocation
            assert_eq!(
                (
                    scratch.b.capacity(),
                    scratch.x.capacity(),
                    scratch.positives.capacity(),
                ),
                caps,
                "scratch grew on round {round}"
            );
        }
    }

    #[test]
    fn empty_and_unknown_docs_fold_to_zero() {
        let u = Csr::from_dense(3, 2, &[1.0, 0.0, 0.5, 0.5, 0.0, 1.0]);
        for objective in [ObjectiveKind::Frobenius, ObjectiveKind::Kl] {
            let solver = FoldIn::with_objective(&u, objective, Some(1), TieMode::Exact);
            assert!(solver.solve(&u, &[]).iter().all(|&v| v == 0.0), "{objective:?}");
            // out-of-range ids and non-positive counts are ignored
            let x = solver.solve(&u, &[(99, 1.0), (0, 0.0), (1, -3.0), (0, f32::NAN)]);
            assert!(x.iter().all(|&v| v == 0.0), "{objective:?}");
        }
    }

    #[test]
    fn kl_foldin_respects_the_budget_and_pools_scratch() {
        // same budget + zero-allocation contract as Frobenius, under KL
        let mut rng = Rng::new(0x6b1);
        let rows = 20;
        let k = 6;
        let u = Csr::from_dense(rows, k, &prop::gen_sparse_dense(&mut rng, rows, k, 0.5));
        let solver = FoldIn::with_objective(&u, ObjectiveKind::Kl, Some(3), TieMode::Exact);
        assert_eq!(solver.objective(), ObjectiveKind::Kl);
        let mut scratch = FoldInScratch::default();
        let full: Vec<(usize, f32)> = (0..rows).map(|r| (r, 1.0)).collect();
        let _ = solver.solve_into(&u, &full, &mut scratch);
        let caps = (
            scratch.b.capacity(),
            scratch.x.capacity(),
            scratch.positives.capacity(),
        );
        for round in 0..20 {
            let n_words = rng.range(1, 10);
            let doc: Vec<(usize, f32)> = (0..n_words)
                .map(|_| (rng.below(rows), rng.below(5) as f32 + 1.0))
                .collect();
            let fresh = solver.solve(&u, &doc);
            let pooled = solver.solve_into(&u, &doc, &mut scratch).to_vec();
            assert_eq!(
                fresh.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                pooled.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "round {round}"
            );
            let nnz = pooled.iter().filter(|&&v| v > 0.0).count();
            assert!(nnz <= 3, "round {round}: nnz {nnz}");
            assert!(pooled.iter().all(|v| v.is_finite() && *v >= 0.0));
            assert_eq!(
                (
                    scratch.b.capacity(),
                    scratch.x.capacity(),
                    scratch.positives.capacity(),
                ),
                caps,
                "scratch grew on round {round}"
            );
        }
    }
}
