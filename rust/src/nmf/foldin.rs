//! Fold-in of unseen documents — Algorithm 2's V half-step specialized to
//! one document at inference time.
//!
//! Given the frozen term factor `U`, projecting a new document `a`
//! (a sparse bag-of-words column) onto topic space is the same
//! one-factor-fixed non-negative least-squares step the training loop
//! runs for every document row:
//!
//! ```text
//! x = enforce_top_t( proj₊( aᵀ U (UᵀU + εI)⁻¹ ) )
//! ```
//!
//! The (k, k) ridged Gram inverse depends only on `U`, so [`FoldIn`]
//! computes it once at construction; each document then costs
//! O(nnz(a)·k + k²), which is what makes fold-in servable at request
//! rates. The enforcement operator is the same single-column top-t
//! primitive the training loop uses ([`topk::enforce_top_t_vec`]), so a
//! served model's fold-in rows obey the identical nonzero budget
//! discipline as its stored `V` rows.

use crate::dense::inverse_spd;
use crate::sparse::{ops, topk, Csr, TieMode};

/// A reusable single-document solver over a frozen `U`.
#[derive(Clone, Debug)]
pub struct FoldIn {
    k: usize,
    /// (UᵀU + εI)⁻¹, row-major (k, k)
    g_inv: Vec<f32>,
    /// per-document nonzero budget (None = unenforced)
    pub t: Option<usize>,
    pub tie: TieMode,
}

/// Per-request buffers of one fold-in solve, poolable by the serving
/// layer so a warm pool answers requests with zero allocation growth —
/// the same reuse discipline the solver applies to its per-worker
/// `RowBlock`s. Plain [`FoldIn::solve`] creates one transparently.
#[derive(Debug, Default)]
pub struct FoldInScratch {
    /// `b = aᵀU` accumulator (k-wide)
    b: Vec<f32>,
    /// the solved row (k-wide; borrowed out by [`FoldIn::solve_into`])
    x: Vec<f32>,
    /// positive-value gather buffer of the enforcement pass
    positives: Vec<f32>,
    /// resolved (term row id, count) pairs of the model-level lookup
    pub pairs: Vec<(usize, f32)>,
}

impl FoldIn {
    /// Precompute the ridged Gram inverse of `u`. `t` caps the nonzeros
    /// of every folded-in row (None leaves rows unenforced).
    pub fn new(u: &Csr, t: Option<usize>, tie: TieMode) -> FoldIn {
        let g = ops::gram(u);
        let g_inv = inverse_spd(&g, u.cols);
        FoldIn {
            k: u.cols,
            g_inv,
            t,
            tie,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// One enforced-sparse half-step for a single document. `doc` is the
    /// sparse bag-of-words as (term row id, count) pairs; out-of-range
    /// term ids and non-positive counts are ignored. Returns the dense
    /// length-k topic row (nonnegative, at most `t` nonzeros when
    /// enforced).
    pub fn solve(&self, u: &Csr, doc: &[(usize, f32)]) -> Vec<f32> {
        let mut scratch = FoldInScratch::default();
        self.solve_into(u, doc, &mut scratch);
        scratch.x
    }

    /// As [`FoldIn::solve`] but through caller-pooled buffers: the solved
    /// row is left in (and returned as a view of) `scratch.x`, and no
    /// allocation happens once the scratch has warmed to size k. Results
    /// are identical to `solve` — the buffers are cleared and refilled
    /// exactly as the fresh allocations were.
    pub fn solve_into<'s>(
        &self,
        u: &Csr,
        doc: &[(usize, f32)],
        scratch: &'s mut FoldInScratch,
    ) -> &'s [f32] {
        let k = self.k;
        debug_assert_eq!(u.cols, k, "U changed shape under the solver");
        // b = aᵀ U — same accumulation order as ops::atb's sparse path
        scratch.b.clear();
        scratch.b.resize(k, 0.0);
        for &(term, count) in doc {
            if term >= u.rows || !count.is_finite() || count <= 0.0 {
                continue;
            }
            let (idx, val) = u.row(term);
            for (&c, &uv) in idx.iter().zip(val) {
                scratch.b[c as usize] += count * uv;
            }
        }
        // x = b · G⁻¹ (the 1-row form of RowBlock::matmul_small)
        scratch.x.clear();
        scratch.x.resize(k, 0.0);
        for (i, &bi) in scratch.b.iter().enumerate() {
            if bi != 0.0 {
                let g_row = &self.g_inv[i * k..(i + 1) * k];
                for (xj, &gij) in scratch.x.iter_mut().zip(g_row) {
                    *xj += bi * gij;
                }
            }
        }
        for v in &mut scratch.x {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        if let Some(t) = self.t {
            // the gather holds at most k positives: reserving up front
            // makes the no-allocation-once-warm property deterministic
            scratch.positives.clear();
            scratch.positives.reserve(k);
            topk::enforce_top_t_vec_with(&mut scratch.x, t, self.tie, &mut scratch.positives);
        }
        &scratch.x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmf::{factorize, half_step_v, MemoryTracker, NmfOptions};
    use crate::text::TdmBuilder;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn tiny_tdm() -> crate::text::TermDocMatrix {
        let mut b = TdmBuilder::new();
        for _ in 0..6 {
            b.add_text("coffee crop quotas coffee brazil crop", Some("econ"));
            b.add_text("electrons atoms hydrogen electrons atoms", Some("sci"));
        }
        b.freeze()
    }

    #[test]
    fn foldin_matches_unenforced_half_step_rows() {
        // fold-in of every training column must reproduce the same
        // algebra half_step_v runs over the whole matrix
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(10).with_seed(3).with_threads(1);
        let r = factorize(&tdm, &opts);
        let mut mem = MemoryTracker::new();
        let v_full = half_step_v(&tdm.a_csc, &r.u, &opts, &mut mem);
        let solver = FoldIn::new(&r.u, None, TieMode::KeepTies);
        for d in 0..tdm.n_docs() {
            let (idx, val) = tdm.a_csc.col(d);
            let doc: Vec<(usize, f32)> = idx
                .iter()
                .zip(val)
                .map(|(&t, &c)| (t as usize, c))
                .collect();
            let x = solver.solve(&r.u, &doc);
            for (c, &xc) in x.iter().enumerate() {
                let want = v_full.get(d, c);
                assert!(
                    (xc - want).abs() <= 1e-5 * want.abs().max(1.0),
                    "doc {d} topic {c}: fold-in {xc} vs half-step {want}"
                );
            }
        }
    }

    #[test]
    fn budget_respected_on_random_bags() {
        prop::check("foldin-topt-budget", 1400, 96, |rng: &mut Rng| {
            let rows = rng.range(4, 40);
            let k = rng.range(1, 8);
            let dense = prop::gen_sparse_dense(rng, rows, k, 0.5);
            let u = Csr::from_dense(rows, k, &dense);
            let t = rng.range(0, k + 2);
            let solver = FoldIn::new(&u, Some(t), TieMode::Exact);
            let n_words = rng.range(1, 12);
            let doc: Vec<(usize, f32)> = (0..n_words)
                .map(|_| (rng.below(rows + 2), rng.below(5) as f32))
                .collect();
            let x = solver.solve(&u, &doc);
            assert_eq!(x.len(), k);
            let nnz = x.iter().filter(|&&v| v > 0.0).count();
            assert!(nnz <= t, "nnz {nnz} > budget {t}");
            assert!(x.iter().all(|v| v.is_finite() && *v >= 0.0));
        });
    }

    #[test]
    fn pooled_scratch_solves_identically_and_stops_allocating() {
        // the serving layer reuses one FoldInScratch across requests;
        // reused solves must match fresh ones bit for bit, and once the
        // buffers are warm, further solves must not grow them
        let mut rng = Rng::new(0x5c7a);
        let rows = 20;
        let k = 6;
        let dense = prop::gen_sparse_dense(&mut rng, rows, k, 0.5);
        let u = Csr::from_dense(rows, k, &dense);
        let solver = FoldIn::new(&u, Some(3), TieMode::Exact);
        let mut scratch = FoldInScratch::default();
        // warm the buffers with a maximal document (every term present)
        let full: Vec<(usize, f32)> = (0..rows).map(|r| (r, 1.0)).collect();
        let _ = solver.solve_into(&u, &full, &mut scratch);
        let caps = (
            scratch.b.capacity(),
            scratch.x.capacity(),
            scratch.positives.capacity(),
        );
        for round in 0..30 {
            let n_words = rng.range(1, 10);
            let doc: Vec<(usize, f32)> = (0..n_words)
                .map(|_| (rng.below(rows), rng.below(5) as f32 + 1.0))
                .collect();
            let fresh = solver.solve(&u, &doc);
            let pooled = solver.solve_into(&u, &doc, &mut scratch).to_vec();
            assert_eq!(
                fresh.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                pooled.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "round {round}"
            );
            // warm buffers never grow: a request costs zero allocation
            assert_eq!(
                (
                    scratch.b.capacity(),
                    scratch.x.capacity(),
                    scratch.positives.capacity(),
                ),
                caps,
                "scratch grew on round {round}"
            );
        }
    }

    #[test]
    fn empty_and_unknown_docs_fold_to_zero() {
        let u = Csr::from_dense(3, 2, &[1.0, 0.0, 0.5, 0.5, 0.0, 1.0]);
        let solver = FoldIn::new(&u, Some(1), TieMode::Exact);
        assert!(solver.solve(&u, &[]).iter().all(|&v| v == 0.0));
        // out-of-range ids and non-positive counts are ignored
        let x = solver.solve(&u, &[(99, 1.0), (0, 0.0), (1, -3.0), (0, f32::NAN)]);
        assert!(x.iter().all(|&v| v == 0.0));
    }
}
