//! Factor initialization.
//!
//! The paper seeds ALS with a random nonnegative `U₀`; Figure 6 varies the
//! *sparsity* of that guess, so the sparse initializer takes an explicit
//! nonzero budget placed uniformly at random.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Rng;

/// Fully dense random nonnegative (n, k) factor: |N(0,1)| entries.
pub fn dense_random(n: usize, k: usize, rng: &mut Rng) -> Csr {
    let data: Vec<f32> = (0..n * k).map(|_| rng.abs_normal_f32() + 1e-6).collect();
    Csr::from_dense(n, k, &data)
}

/// Sparse random nonnegative (n, k) factor with exactly
/// `min(nnz, n·k)` nonzeros at distinct uniform positions.
pub fn sparse_random(n: usize, k: usize, nnz: usize, rng: &mut Rng) -> Csr {
    let total = n * k;
    let nnz = nnz.min(total);
    let positions = rng.sample_distinct(total, nnz);
    let mut coo = Coo::new(n, k);
    for pos in positions {
        coo.push(pos / k, pos % k, rng.abs_normal_f32() + 1e-6);
    }
    coo.to_csr()
}

/// The initializer used by the solvers: dense unless a budget is given.
pub fn initial_u(n: usize, k: usize, init_nnz: Option<usize>, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    match init_nnz {
        None => dense_random(n, k, &mut rng),
        Some(nnz) => sparse_random(n, k, nnz, &mut rng),
    }
}

/// Positive random `V₀` for multiplicative-update objectives (KL), which
/// cannot leave zero: always fully dense, under a seed derived from the
/// run seed so `U₀` and `V₀` draw independent streams but both stay
/// deterministic in `seed`. (Least-squares ALS re-solves `V` from scratch
/// each half-iteration and starts from `V₀ = 0` instead; the `init_nnz`
/// Fig. 6 budget applies only to `U₀` — a sparse `V₀` under KL would
/// permanently lock the missing entries at zero before the first
/// enforcement pass ever ran.)
pub fn initial_v(m: usize, k: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed ^ 0x9e37_79b9_7f4a_7c15);
    dense_random(m, k, &mut rng)
}

/// Warm-start `U₀` from a previously-trained factor over a (possibly
/// different) vocabulary: rows whose term survives into `new_terms` carry
/// their trained topic weights over verbatim; terms the old model never
/// saw get one seeded-random nonzero of typical magnitude so ALS can pull
/// them into a topic without swamping the converged structure. The result
/// is deterministic in (`old_u`, the term lists, `seed`).
///
/// This is what makes incremental corpus updates cheap: re-factorizing
/// the grown corpus from a warm start converges in a fraction of the
/// iterations a cold random start needs (the fig-8 sequential workload).
pub fn warm_start_u(
    old_u: &Csr,
    old_terms: &[String],
    new_terms: &[String],
    k: usize,
    seed: u64,
) -> Csr {
    assert_eq!(old_u.cols, k, "warm-start factor width != k");
    assert_eq!(old_u.rows, old_terms.len(), "warm-start factor/vocab mismatch");
    let old_ids: std::collections::HashMap<&str, usize> = old_terms
        .iter()
        .enumerate()
        .map(|(i, t)| (t.as_str(), i))
        .collect();
    // typical trained magnitude for seeding unseen terms
    let mean: f32 = if old_u.nnz() > 0 {
        (old_u.values.iter().map(|&v| v as f64).sum::<f64>() / old_u.nnz() as f64) as f32
    } else {
        0.1
    };
    let mut rng = Rng::new(seed ^ 0x3a5f_0000_77a3_a901);
    let mut coo = Coo::new(new_terms.len(), k);
    for (new_row, term) in new_terms.iter().enumerate() {
        match old_ids.get(term.as_str()) {
            Some(&old_row) => {
                let (idx, val) = old_u.row(old_row);
                for (&c, &v) in idx.iter().zip(val) {
                    coo.push(new_row, c as usize, v);
                }
            }
            None => {
                // one small nonzero at a seeded-random topic
                coo.push(new_row, rng.below(k), mean * (0.5 + 0.5 * rng.abs_normal_f32()));
            }
        }
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_has_all_entries() {
        let mut rng = Rng::new(1);
        let u = dense_random(10, 4, &mut rng);
        assert_eq!(u.nnz(), 40);
        assert!(u.values.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn sparse_has_exact_budget() {
        let mut rng = Rng::new(2);
        let u = sparse_random(20, 5, 17, &mut rng);
        assert_eq!(u.nnz(), 17);
        assert!(u.values.iter().all(|&v| v > 0.0));
        u.validate().unwrap();
    }

    #[test]
    fn sparse_budget_clamped() {
        let mut rng = Rng::new(3);
        let u = sparse_random(3, 3, 100, &mut rng);
        assert_eq!(u.nnz(), 9);
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(initial_u(8, 3, Some(10), 7), initial_u(8, 3, Some(10), 7));
        assert_ne!(initial_u(8, 3, Some(10), 7), initial_u(8, 3, Some(10), 8));
    }

    #[test]
    fn initial_v_is_dense_positive_and_independent_of_u() {
        let v = initial_v(6, 3, 7);
        assert_eq!(v.nnz(), 18, "KL V₀ is always fully dense");
        assert!(v.values.iter().all(|&x| x > 0.0));
        assert_eq!(v, initial_v(6, 3, 7), "deterministic in the seed");
        assert_ne!(v, initial_v(6, 3, 8));
        // a different stream than U₀ at the same seed and shape
        assert_ne!(v, initial_u(6, 3, None, 7));
    }

    #[test]
    fn warm_start_carries_known_terms_and_seeds_new_ones() {
        let old_terms: Vec<String> = ["coffee", "crop", "atoms"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let old_u = Csr::from_dense(3, 2, &[0.9, 0.0, 0.4, 0.1, 0.0, 0.7]);
        // new vocab: "crop" dropped, "quotas"/"brazil" appear, order shuffled
        let new_terms: Vec<String> = ["atoms", "quotas", "coffee", "brazil"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let u0 = warm_start_u(&old_u, &old_terms, &new_terms, 2, 5);
        assert_eq!(u0.rows, 4);
        assert_eq!(u0.cols, 2);
        u0.validate().unwrap();
        // surviving terms keep their trained rows bit-for-bit
        assert_eq!(u0.get(0, 1), 0.7); // atoms
        assert_eq!(u0.get(2, 0), 0.9); // coffee
        // unseen terms get exactly one small positive nonzero
        for row in [1usize, 3] {
            let (idx, val) = u0.row(row);
            assert_eq!(idx.len(), 1, "row {row}");
            assert!(val[0] > 0.0);
        }
        // deterministic in the seed
        assert_eq!(u0, warm_start_u(&old_u, &old_terms, &new_terms, 2, 5));
        assert_ne!(u0, warm_start_u(&old_u, &old_terms, &new_terms, 2, 6));
    }
}
