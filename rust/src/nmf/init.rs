//! Factor initialization.
//!
//! The paper seeds ALS with a random nonnegative `U₀`; Figure 6 varies the
//! *sparsity* of that guess, so the sparse initializer takes an explicit
//! nonzero budget placed uniformly at random.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Rng;

/// Fully dense random nonnegative (n, k) factor: |N(0,1)| entries.
pub fn dense_random(n: usize, k: usize, rng: &mut Rng) -> Csr {
    let data: Vec<f32> = (0..n * k).map(|_| rng.abs_normal_f32() + 1e-6).collect();
    Csr::from_dense(n, k, &data)
}

/// Sparse random nonnegative (n, k) factor with exactly
/// `min(nnz, n·k)` nonzeros at distinct uniform positions.
pub fn sparse_random(n: usize, k: usize, nnz: usize, rng: &mut Rng) -> Csr {
    let total = n * k;
    let nnz = nnz.min(total);
    let positions = rng.sample_distinct(total, nnz);
    let mut coo = Coo::new(n, k);
    for pos in positions {
        coo.push(pos / k, pos % k, rng.abs_normal_f32() + 1e-6);
    }
    coo.to_csr()
}

/// The initializer used by the solvers: dense unless a budget is given.
pub fn initial_u(n: usize, k: usize, init_nnz: Option<usize>, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    match init_nnz {
        None => dense_random(n, k, &mut rng),
        Some(nnz) => sparse_random(n, k, nnz, &mut rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_has_all_entries() {
        let mut rng = Rng::new(1);
        let u = dense_random(10, 4, &mut rng);
        assert_eq!(u.nnz(), 40);
        assert!(u.values.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn sparse_has_exact_budget() {
        let mut rng = Rng::new(2);
        let u = sparse_random(20, 5, 17, &mut rng);
        assert_eq!(u.nnz(), 17);
        assert!(u.values.iter().all(|&v| v > 0.0));
        u.validate().unwrap();
    }

    #[test]
    fn sparse_budget_clamped() {
        let mut rng = Rng::new(3);
        let u = sparse_random(3, 3, 100, &mut rng);
        assert_eq!(u.nnz(), 9);
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(initial_u(8, 3, Some(10), 7), initial_u(8, 3, Some(10), 7));
        assert_ne!(initial_u(8, 3, Some(10), 7), initial_u(8, 3, Some(10), 8));
    }
}
