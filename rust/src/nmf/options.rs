//! Solver configuration and result types.

use crate::sparse::{Csr, TieMode};

use super::memory::MemoryStats;
use super::objective::ObjectiveKind;

/// How (and whether) sparsity is enforced each iteration.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum SparsityMode {
    /// Algorithm 1: projection only, factors may densify.
    #[default]
    None,
    /// Algorithm 2: keep the `t` largest entries of the whole matrix.
    /// `None` for a side leaves that factor unenforced (the Fig. 3
    /// "U only" / "V only" variants).
    Global {
        t_u: Option<usize>,
        t_v: Option<usize>,
    },
    /// §4 column-wise: keep the `t` largest entries of *each column*.
    PerColumn {
        t_u_col: Option<usize>,
        t_v_col: Option<usize>,
    },
    /// The "simpler method" the paper §2 contrasts against: zero every
    /// entry below a fixed magnitude. Cheaper than top-t (no selection)
    /// but gives no control over the resulting NNZ — kept as an ablation
    /// (see `benches/ablation_enforcement.rs`).
    Threshold {
        tau_u: Option<f32>,
        tau_v: Option<f32>,
    },
}

impl SparsityMode {
    /// Convenience: enforce both factors globally.
    pub fn both(t_u: usize, t_v: usize) -> Self {
        SparsityMode::Global {
            t_u: Some(t_u),
            t_v: Some(t_v),
        }
    }

    pub fn u_only(t_u: usize) -> Self {
        SparsityMode::Global {
            t_u: Some(t_u),
            t_v: None,
        }
    }

    pub fn v_only(t_v: usize) -> Self {
        SparsityMode::Global {
            t_u: None,
            t_v: Some(t_v),
        }
    }
}

#[derive(Clone, Debug)]
pub struct NmfOptions {
    /// factorization rank (number of topics)
    pub k: usize,
    pub max_iters: usize,
    /// stop when the relative residual drops below this (0.0 = never)
    pub tol: f64,
    /// the training objective the half-steps minimize (Frobenius least
    /// squares or KL divergence — see [`crate::nmf::objective`]).
    /// Persisted in `.esnmf` snapshots and announced on the worker wire:
    /// resume and distributed runs refuse a mismatch with typed errors.
    pub objective: ObjectiveKind,
    pub sparsity: SparsityMode,
    pub tie_mode: TieMode,
    /// RNG seed for the initial guess
    pub seed: u64,
    /// nonzeros in the initial guess U₀ (None = fully dense random)
    pub init_nnz: Option<usize>,
    /// compute the relative error every iteration (costs O(nnz(A)·k))
    pub track_error: bool,
    /// row-parallelism for the ALS hot path — the SpMM products, gram
    /// accumulations, projection and top-t enforcement all partition
    /// across this many workers. Defaults to the machine's available
    /// cores; results are bit-identical at any setting (see the
    /// determinism contract in `crate::coordinator::pool`), so this is
    /// purely a speed knob.
    pub threads: usize,
    /// rows per streamed half-step block (0 = auto): each half-step
    /// computes, solves, projects and enforces its candidate one
    /// contiguous `block_rows`-row block at a time, so peak intermediate
    /// memory is O(`block_rows` · k) per worker instead of
    /// O(active rows · k).
    /// Factors, residuals and errors are bit-identical at every setting
    /// (only `MemoryStats::max_intermediate_nnz` observes the block
    /// size), so this — like `threads` — is a machine-local memory/speed
    /// knob and is deliberately not persisted in `.esnmf` snapshots.
    pub block_rows: usize,
    /// write a `.esnmf` checkpoint to `checkpoint_path` every N completed
    /// iterations (0 = never). The driver skips the write on the final
    /// iteration's tol-break so resuming a checkpoint never overshoots an
    /// uninterrupted run.
    pub checkpoint_every: usize,
    /// where periodic checkpoints go (required when `checkpoint_every > 0`)
    pub checkpoint_path: Option<std::path::PathBuf>,
}

impl NmfOptions {
    pub fn new(k: usize) -> Self {
        NmfOptions {
            k,
            max_iters: 75,
            tol: 0.0,
            objective: ObjectiveKind::Frobenius,
            sparsity: SparsityMode::None,
            tie_mode: TieMode::KeepTies,
            seed: 0x5eed,
            init_nnz: None,
            track_error: true,
            threads: crate::coordinator::pool::default_threads(),
            block_rows: 0,
            checkpoint_every: 0,
            checkpoint_path: None,
        }
    }

    pub fn with_sparsity(mut self, s: SparsityMode) -> Self {
        self.sparsity = s;
        self
    }

    pub fn with_objective(mut self, objective: ObjectiveKind) -> Self {
        self.objective = objective;
        self
    }

    pub fn with_iters(mut self, it: usize) -> Self {
        self.max_iters = it;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_init_nnz(mut self, nnz: usize) -> Self {
        self.init_nnz = Some(nnz);
        self
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_track_error(mut self, track: bool) -> Self {
        self.track_error = track;
        self
    }

    /// Checkpoint to `path` every `every` completed iterations
    /// (`every = 0` disables).
    pub fn with_checkpoint(mut self, path: impl Into<std::path::PathBuf>, every: usize) -> Self {
        self.checkpoint_path = Some(path.into());
        self.checkpoint_every = every;
        self
    }

    /// Set the worker count; `0` means "auto" (all available cores).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = if threads == 0 {
            crate::coordinator::pool::default_threads()
        } else {
            threads
        };
        self
    }

    /// Set the streamed half-step block height; `0` means "auto" (the
    /// `ESNMF_BLOCK_ROWS` environment override if set, else a fixed
    /// [`AUTO_BLOCK_SCALARS`]-scalar scratch budget divided by `k`).
    pub fn with_block_rows(mut self, block_rows: usize) -> Self {
        self.block_rows = block_rows;
        self
    }

    /// The block height the solver actually streams with. Deliberately
    /// independent of `threads` and of the corpus, so `MemoryStats` —
    /// which observes per-block scratch — stays bit-identical across
    /// thread counts and machines.
    pub fn resolved_block_rows(&self) -> usize {
        resolve_block_rows(self.block_rows, self.k)
    }
}

/// Resolve a `block_rows` knob (0 = auto) against a rank: the
/// `ESNMF_BLOCK_ROWS` env override, else the fixed
/// [`AUTO_BLOCK_SCALARS`]-scalar scratch budget divided by `k`. Shared
/// by [`NmfOptions`] and [`SequentialOptions`](super::SequentialOptions)
/// (whose blocks solve at rank `block_topics`, not `k`).
pub fn resolve_block_rows(block_rows: usize, k: usize) -> usize {
    if block_rows != 0 {
        return block_rows;
    }
    if let Ok(v) = std::env::var("ESNMF_BLOCK_ROWS") {
        // a malformed override must fail loudly: the CI tiny-blocks
        // job exists solely to exercise block boundaries, and a typo
        // silently falling back to auto would turn it into a no-op
        // that still reports green
        match v.trim().parse::<usize>() {
            Ok(0) => {} // 0 = auto, same as the flag and config knob
            Ok(n) => return n,
            Err(_) => panic!(
                "ESNMF_BLOCK_ROWS must be a non-negative integer (0 = auto), got {v:?}"
            ),
        }
    }
    (AUTO_BLOCK_SCALARS / k.max(1)).max(1)
}

/// Candidate-scratch scalar budget behind `block_rows = auto`: one block
/// holds at most this many f32s (16 KiB), so `auto` block height is
/// `AUTO_BLOCK_SCALARS / k`. Deliberately equal to
/// [`crate::coordinator::pool::MIN_ITEMS_PER_WORKER`]: the streamed
/// pipeline parallelizes *across* blocks, so `auto` produces at least as
/// many blocks as the pre-blocking row partitioning had workers — the
/// memory bound never costs parallelism at the default setting. (A
/// block height ≥ the output rows serializes into the single-block
/// in-memory path instead.)
pub const AUTO_BLOCK_SCALARS: usize = crate::coordinator::pool::MIN_ITEMS_PER_WORKER;

/// A completed factorization with its convergence telemetry.
#[derive(Clone, Debug)]
pub struct NmfResult {
    /// term/topic factor (n × k)
    pub u: Csr,
    /// document/topic factor (m × k)
    pub v: Csr,
    pub iterations: usize,
    /// relative residual ‖Uᵢ−Uᵢ₋₁‖/‖Uᵢ‖ per iteration
    pub residuals: Vec<f64>,
    /// relative error ‖A−UVᵀ‖/‖A‖ per iteration (empty if untracked)
    pub errors: Vec<f64>,
    pub memory: MemoryStats,
    pub elapsed_s: f64,
}

impl NmfResult {
    pub fn final_residual(&self) -> f64 {
        self.residuals.last().copied().unwrap_or(f64::NAN)
    }

    pub fn final_error(&self) -> f64 {
        self.errors.last().copied().unwrap_or(f64::NAN)
    }

    /// FNV-1a digest over everything the determinism contract pins: the
    /// exact factor bytes (CSR structure and f32 bit patterns), the
    /// iteration count, and the per-iteration residual/error f64 bits.
    /// Two runs print the same digest iff they converged bit-identically,
    /// so the CI distributed-smoke job compares exactly this value
    /// between a single-process and an N-worker run. Wall time and
    /// memory telemetry are deliberately excluded — they are allowed to
    /// differ between runs that computed the same factors.
    pub fn digest(&self) -> u64 {
        let mut bytes = Vec::new();
        self.u.write_bytes(&mut bytes);
        self.v.write_bytes(&mut bytes);
        bytes.extend_from_slice(&(self.iterations as u64).to_le_bytes());
        bytes.extend_from_slice(&(self.residuals.len() as u64).to_le_bytes());
        for r in &self.residuals {
            bytes.extend_from_slice(&r.to_bits().to_le_bytes());
        }
        for e in &self.errors {
            bytes.extend_from_slice(&e.to_bits().to_le_bytes());
        }
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        for &b in &bytes {
            h ^= u64::from(b);
            h = h.wrapping_mul(FNV_PRIME);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders() {
        let o = NmfOptions::new(5)
            .with_iters(10)
            .with_seed(1)
            .with_init_nnz(50)
            .with_tol(1e-9)
            .with_sparsity(SparsityMode::both(40, 60));
        assert_eq!(o.objective, ObjectiveKind::Frobenius, "default objective");
        assert_eq!(
            o.clone().with_objective(ObjectiveKind::Kl).objective,
            ObjectiveKind::Kl
        );
        assert_eq!(o.k, 5);
        assert_eq!(o.max_iters, 10);
        assert_eq!(o.init_nnz, Some(50));
        assert_eq!(
            o.sparsity,
            SparsityMode::Global {
                t_u: Some(40),
                t_v: Some(60)
            }
        );
    }

    #[test]
    fn threads_default_to_available_cores_and_zero_means_auto() {
        let auto = crate::coordinator::pool::default_threads();
        assert_eq!(NmfOptions::new(2).threads, auto);
        assert_eq!(NmfOptions::new(2).with_threads(0).threads, auto);
        assert_eq!(NmfOptions::new(2).with_threads(3).threads, 3);
    }

    #[test]
    fn block_rows_default_auto_and_explicit_values_win() {
        let o = NmfOptions::new(4);
        assert_eq!(o.block_rows, 0);
        // auto: the fixed scalar budget divided by k (no env override in
        // the test environment unless CI sets one — then any positive
        // value is acceptable, it only moves memory telemetry)
        let auto = o.resolved_block_rows();
        assert!(auto >= 1);
        if std::env::var("ESNMF_BLOCK_ROWS").is_err() {
            assert_eq!(auto, AUTO_BLOCK_SCALARS / 4);
        }
        // explicit values resolve to themselves, env or not
        assert_eq!(NmfOptions::new(4).with_block_rows(7).resolved_block_rows(), 7);
        assert_eq!(
            NmfOptions::new(4).with_block_rows(usize::MAX).resolved_block_rows(),
            usize::MAX
        );
        // a rank above the scalar budget still yields a positive height
        if std::env::var("ESNMF_BLOCK_ROWS").is_err() {
            assert_eq!(
                NmfOptions::new(AUTO_BLOCK_SCALARS * 2).resolved_block_rows(),
                1
            );
        }
    }

    #[test]
    fn result_digest_tracks_factor_bits() {
        let base = NmfResult {
            u: Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 2.0]),
            v: Csr::from_dense(2, 2, &[0.5, 0.0, 0.0, 0.25]),
            iterations: 3,
            residuals: vec![0.1, 0.01],
            errors: vec![0.9],
            memory: MemoryStats::default(),
            elapsed_s: 1.0,
        };
        let d = base.digest();
        assert_eq!(d, base.digest(), "digest must be a pure function");
        let mut slower = base.clone();
        slower.elapsed_s = 99.0;
        assert_eq!(d, slower.digest(), "wall time must not move the digest");
        let mut other = base.clone();
        other.u = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 2.5]);
        assert_ne!(d, other.digest());
        let mut more_iters = base.clone();
        more_iters.iterations = 4;
        assert_ne!(d, more_iters.digest());
    }

    #[test]
    fn sparsity_helpers() {
        assert_eq!(
            SparsityMode::u_only(9),
            SparsityMode::Global {
                t_u: Some(9),
                t_v: None
            }
        );
        assert_eq!(
            SparsityMode::v_only(9),
            SparsityMode::Global {
                t_u: None,
                t_v: Some(9)
            }
        );
    }
}
