//! Convergence measures exactly as the paper defines them.
//!
//! * relative residual `R = ‖Uᵢ − Uᵢ₋₁‖_F / ‖Uᵢ‖_F`
//! * relative error `E = ‖A − U Vᵀ‖_F / ‖A‖_F`, computed sparse-safely via
//!   `‖A‖² − 2·tr(UᵀAV) + tr((UᵀU)(VᵀV))` so `U Vᵀ` is never materialized
//!   (on the PubMed-sized corpus that product would be 20k × 7.5k dense).

use crate::sparse::{ops, Csr, RowSource};

/// `‖u_new − u_old‖_F / ‖u_new‖_F` (0/0 → 0: two empty factors agree).
pub fn rel_residual(u_new: &Csr, u_old: &Csr) -> f64 {
    let num = u_new.fro_diff(u_old);
    let den = u_new.fro_norm();
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        num / den
    }
}

/// Sparse-safe relative Frobenius error. `norm_a_sq` = ‖A‖²_F may be
/// precomputed once per run; float cancellation is clamped at zero.
pub fn rel_error_sparse(a: &Csr, u: &Csr, v: &Csr, norm_a_sq: f64) -> f64 {
    rel_error_source(a, u, v, norm_a_sq, a.rows.max(1))
}

/// [`rel_error_sparse`] with `A` streamed through a [`RowSource`] in
/// `chunk_rows`-row runs — the out-of-core error pass. The cross trace
/// walks rows in order into one f64 accumulator, so the chunking (and
/// the backing storage) cannot change the result bits.
pub fn rel_error_source(
    a: &dyn RowSource,
    u: &Csr,
    v: &Csr,
    norm_a_sq: f64,
    chunk_rows: usize,
) -> f64 {
    if norm_a_sq == 0.0 {
        return 0.0;
    }
    let cross = ops::tr_cross_source(a, u, v, chunk_rows);
    let gu = ops::gram(u);
    let gv = ops::gram(v);
    let gg = ops::tr_gram_product(&gu, &gv, u.cols);
    let err_sq = (norm_a_sq - 2.0 * cross + gg).max(0.0);
    err_sq.sqrt() / norm_a_sq.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::ops::spmm;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn residual_identical_is_zero() {
        let u = Csr::from_dense(3, 2, &[1.0, 0.0, 2.0, 0.0, 0.0, 3.0]);
        assert_eq!(rel_residual(&u, &u), 0.0);
    }

    #[test]
    fn residual_from_zero_is_one() {
        let u = Csr::from_dense(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let z = Csr::zeros(2, 2);
        assert!((rel_residual(&u, &z) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn residual_both_empty() {
        let z = Csr::zeros(2, 2);
        assert_eq!(rel_residual(&z, &z), 0.0);
    }

    #[test]
    fn error_exact_factorization_is_zero() {
        prop::check("error-exact-zero", 1300, 24, |rng: &mut Rng| {
            let n = rng.range(1, 10);
            let m = rng.range(1, 10);
            let k = rng.range(1, 4);
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.7));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.7));
            let a = spmm(&u, &v.transpose());
            let e = rel_error_sparse(&a, &u, &v, a.fro_norm_sq());
            assert!(e < 1e-3, "exact factorization error {e}");
        });
    }

    #[test]
    fn error_matches_dense_computation() {
        prop::check("error-vs-dense", 1400, 24, |rng: &mut Rng| {
            let n = rng.range(1, 10);
            let m = rng.range(1, 10);
            let k = rng.range(1, 4);
            let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.5));
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.6));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.6));
            if a.nnz() == 0 {
                return; // E = ‖A−UVᵀ‖/‖A‖ is undefined for A = 0
            }
            let got = rel_error_sparse(&a, &u, &v, a.fro_norm_sq());
            // dense reference
            let uvt = spmm(&u, &v.transpose());
            let want = a.fro_diff(&uvt) / a.fro_norm().max(1e-30);
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want),
                "sparse {got} vs dense {want}"
            );
        });
    }

    #[test]
    fn error_zero_matrix() {
        let z = Csr::zeros(3, 3);
        let u = Csr::zeros(3, 2);
        assert_eq!(rel_error_sparse(&z, &u, &Csr::zeros(3, 2), 0.0), 0.0);
    }
}
