//! Convergence measures exactly as the paper defines them.
//!
//! * relative residual `R = ‖Uᵢ − Uᵢ₋₁‖_F / ‖Uᵢ‖_F`
//! * relative error `E = ‖A − U Vᵀ‖_F / ‖A‖_F`, computed sparse-safely via
//!   `‖A‖² − 2·tr(UᵀAV) + tr((UᵀU)(VᵀV))` so `U Vᵀ` is never materialized
//!   (on the PubMed-sized corpus that product would be 20k × 7.5k dense).
//! * mean per-token KL divergence `D(A ‖ U Vᵀ) / Σa`, streamed the same
//!   way — the nonzero terms walk `A` in row order, the total predicted
//!   mass collapses to `⟨colsums(U), colsums(V)⟩`.

use crate::coordinator::pool;
use crate::sparse::{ops, Csr, RowCursor, RowSource};

use super::objective::KL_EPS;

/// `‖u_new − u_old‖_F / ‖u_new‖_F` (0/0 → 0: two empty factors agree).
pub fn rel_residual(u_new: &Csr, u_old: &Csr) -> f64 {
    let num = u_new.fro_diff(u_old);
    let den = u_new.fro_norm();
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        num / den
    }
}

/// Sparse-safe relative Frobenius error. `norm_a_sq` = ‖A‖²_F may be
/// precomputed once per run; float cancellation is clamped at zero.
pub fn rel_error_sparse(a: &Csr, u: &Csr, v: &Csr, norm_a_sq: f64) -> f64 {
    rel_error_source(a, u, v, norm_a_sq, a.rows.max(1))
}

/// [`rel_error_sparse`] with `A` streamed through a [`RowSource`] in
/// `chunk_rows`-row runs — the out-of-core error pass. The cross trace
/// walks rows in order into one f64 accumulator, so the chunking (and
/// the backing storage) cannot change the result bits.
pub fn rel_error_source(
    a: &dyn RowSource,
    u: &Csr,
    v: &Csr,
    norm_a_sq: f64,
    chunk_rows: usize,
) -> f64 {
    if norm_a_sq == 0.0 {
        return 0.0;
    }
    let cross = ops::tr_cross_source(a, u, v, chunk_rows);
    let gu = ops::gram(u);
    let gv = ops::gram(v);
    let gg = ops::tr_gram_product(&gu, &gv, u.cols);
    let err_sq = (norm_a_sq - 2.0 * cross + gg).max(0.0);
    err_sq.sqrt() / norm_a_sq.sqrt()
}

/// Mean per-token generalized KL divergence
/// `D(A ‖ U Vᵀ) = Σ_cells [a·ln(a/p) − a + p]` divided by the total token
/// mass `Σ a`, with `A` streamed through a [`RowSource`] in
/// `chunk_rows`-row runs (the KL analogue of [`rel_error_source`]).
///
/// The sum splits sparse-safely: only `A`'s nonzeros contribute
/// `a·(ln a − ln p) − a`, and the all-cells `Σ p` term collapses to
/// `⟨colsums(U), colsums(V)⟩` without materializing `U Vᵀ`. Predicted
/// counts are floored at [`KL_EPS`] inside the logarithm only, so a model
/// assigning zero mass to an observed token yields a large finite value
/// instead of poisoning the history with infinities. Accumulation is a
/// single f64 walk in row order — chunking and backing storage cannot
/// change the result bits.
pub fn kl_divergence_source(a: &dyn RowSource, u: &Csr, v: &Csr, chunk_rows: usize) -> f64 {
    assert_eq!(a.rows(), u.rows, "A rows != U rows");
    assert_eq!(a.cols(), v.rows, "A cols != V rows");
    assert_eq!(u.cols, v.cols, "rank mismatch");
    let k = u.cols;
    let mut scratch = vec![0.0f32; k];
    let mut acc = 0.0f64; // Σ over nnz(A) of a·(ln a − ln p)
    let mut mass = 0.0f64; // Σ a
    let mut cur = RowCursor::new();
    for (lo, hi) in pool::fixed_chunks(a.rows(), chunk_rows.max(1)) {
        let view = a.load(lo, hi, &mut cur);
        for i in lo..hi {
            let (acols, avals) = view.row(i - lo);
            if acols.is_empty() {
                continue;
            }
            scratch.iter_mut().for_each(|x| *x = 0.0);
            let (uidx, uval) = u.row(i);
            for (&c, &uv) in uidx.iter().zip(uval) {
                scratch[c as usize] = uv;
            }
            for (&j, &aij) in acols.iter().zip(avals) {
                let (vidx, vval) = v.row(j as usize);
                let mut p = 0.0f64;
                for (&c, &vv) in vidx.iter().zip(vval) {
                    p += scratch[c as usize] as f64 * vv as f64;
                }
                let aij = aij as f64;
                mass += aij;
                acc += aij * (aij.ln() - p.max(KL_EPS).ln());
            }
        }
    }
    if mass == 0.0 {
        return 0.0;
    }
    let total_pred: f64 = col_sums_f64(u)
        .iter()
        .zip(&col_sums_f64(v))
        .map(|(cu, cv)| cu * cv)
        .sum();
    (acc - mass + total_pred) / mass
}

/// f64 per-column sums of a factor, serial row walk.
fn col_sums_f64(x: &Csr) -> Vec<f64> {
    let mut sums = vec![0.0f64; x.cols];
    for r in 0..x.rows {
        let (idx, val) = x.row(r);
        for (&c, &v) in idx.iter().zip(val) {
            sums[c as usize] += v as f64;
        }
    }
    sums
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::ops::spmm;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn residual_identical_is_zero() {
        let u = Csr::from_dense(3, 2, &[1.0, 0.0, 2.0, 0.0, 0.0, 3.0]);
        assert_eq!(rel_residual(&u, &u), 0.0);
    }

    #[test]
    fn residual_from_zero_is_one() {
        let u = Csr::from_dense(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let z = Csr::zeros(2, 2);
        assert!((rel_residual(&u, &z) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn residual_both_empty() {
        let z = Csr::zeros(2, 2);
        assert_eq!(rel_residual(&z, &z), 0.0);
    }

    #[test]
    fn error_exact_factorization_is_zero() {
        prop::check("error-exact-zero", 1300, 24, |rng: &mut Rng| {
            let n = rng.range(1, 10);
            let m = rng.range(1, 10);
            let k = rng.range(1, 4);
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.7));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.7));
            let a = spmm(&u, &v.transpose());
            let e = rel_error_sparse(&a, &u, &v, a.fro_norm_sq());
            assert!(e < 1e-3, "exact factorization error {e}");
        });
    }

    #[test]
    fn error_matches_dense_computation() {
        prop::check("error-vs-dense", 1400, 24, |rng: &mut Rng| {
            let n = rng.range(1, 10);
            let m = rng.range(1, 10);
            let k = rng.range(1, 4);
            let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.5));
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.6));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.6));
            if a.nnz() == 0 {
                return; // E = ‖A−UVᵀ‖/‖A‖ is undefined for A = 0
            }
            let got = rel_error_sparse(&a, &u, &v, a.fro_norm_sq());
            // dense reference
            let uvt = spmm(&u, &v.transpose());
            let want = a.fro_diff(&uvt) / a.fro_norm().max(1e-30);
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want),
                "sparse {got} vs dense {want}"
            );
        });
    }

    #[test]
    fn error_zero_matrix() {
        let z = Csr::zeros(3, 3);
        let u = Csr::zeros(3, 2);
        assert_eq!(rel_error_sparse(&z, &u, &Csr::zeros(3, 2), 0.0), 0.0);
    }

    #[test]
    fn kl_divergence_of_an_exact_factorization_is_near_zero() {
        prop::check("kl-exact-zero", 1500, 24, |rng: &mut Rng| {
            let n = rng.range(1, 10);
            let m = rng.range(1, 10);
            let k = rng.range(1, 4);
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.7));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.7));
            let a = spmm(&u, &v.transpose());
            if a.nnz() == 0 {
                return;
            }
            let d = kl_divergence_source(&a, &u, &v, a.rows.max(1));
            assert!(d.abs() < 1e-3, "exact factorization divergence {d}");
        });
    }

    #[test]
    fn kl_divergence_matches_the_dense_cellwise_sum() {
        prop::check("kl-vs-dense", 1600, 24, |rng: &mut Rng| {
            let n = rng.range(1, 10);
            let m = rng.range(1, 10);
            let k = rng.range(1, 4);
            let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.5));
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.6));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.6));
            if a.nnz() == 0 {
                return;
            }
            let got = kl_divergence_source(&a, &u, &v, n);
            // dense reference: walk every cell of UVᵀ
            let pred = spmm(&u, &v.transpose()).to_dense();
            let ad = a.to_dense();
            let mut want = 0.0f64;
            let mut mass = 0.0f64;
            for (&aij, &pij) in ad.iter().zip(&pred) {
                let p = (pij as f64).max(super::KL_EPS);
                if aij > 0.0 {
                    let aij = aij as f64;
                    mass += aij;
                    want += aij * (aij.ln() - p.ln()) - aij + p;
                } else {
                    want += pij as f64;
                }
            }
            want /= mass;
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "streamed {got} vs dense {want}"
            );
        });
    }

    #[test]
    fn kl_divergence_is_chunk_invariant_bit_for_bit() {
        prop::check("kl-chunk-invariant", 1700, 16, |rng: &mut Rng| {
            let n = rng.range(2, 15);
            let m = rng.range(1, 15);
            let k = rng.range(1, 4);
            let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.4));
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.6));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.6));
            let want = kl_divergence_source(&a, &u, &v, n);
            for chunk in [1usize, 2, 7, usize::MAX] {
                let got = kl_divergence_source(&a, &u, &v, chunk);
                assert_eq!(got.to_bits(), want.to_bits(), "chunk {chunk}");
            }
        });
    }

    #[test]
    fn kl_divergence_of_an_empty_matrix_is_zero() {
        let z = Csr::zeros(3, 4);
        let u = Csr::zeros(3, 2);
        let v = Csr::zeros(4, 2);
        assert_eq!(kl_divergence_source(&z, &u, &v, 2), 0.0);
    }
}
