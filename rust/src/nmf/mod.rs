//! The NMF engine: every algorithm of the paper over the sparse substrate.
//!
//! * [`als`] — Algorithm 1 (projected ALS) and Algorithm 2 (enforced
//!   sparsity ALS, global top-t for U / V / both) plus the §4 column-wise
//!   enforcement variant; all share one driver.
//! * [`sequential`] — Algorithm 3 (sequential ALS: topics converged one
//!   block at a time with deflation, rank-1 fast path).
//! * [`init`] — factor initialization (dense random / sparse random with a
//!   chosen nonzero budget, the Fig. 6 knob).
//! * [`objective`] — the objective seam: Frobenius least squares and KL
//!   divergence behind one [`objective::Objective`] trait, so the blocked
//!   streaming machinery, enforcement, snapshots and the wire protocol
//!   stay objective-agnostic.
//! * [`convergence`] — relative residual, sparse-safe relative error, and
//!   the streamed KL divergence.
//! * [`memory`] — max-stored-nonzeros tracking (Fig. 6).
//! * [`foldin`] — inference-time projection of unseen documents (one
//!   enforced-sparse half-step against the frozen `U`, used by the topic
//!   server's FOLDIN command).

pub mod als;
pub mod convergence;
pub mod foldin;
pub mod init;
pub mod memory;
pub mod objective;
pub mod options;
pub mod sequential;

pub use als::{
    factorize, factorize_corpus, factorize_from, factorize_from_corpus, half_step_u,
    half_step_u_src, half_step_v, half_step_v_src, resume, resume_corpus, resume_options,
    AlsCorpus,
};
pub use convergence::{kl_divergence_source, rel_error_source, rel_error_sparse, rel_residual};
pub use foldin::{FoldIn, FoldInScratch};
pub use memory::MemoryTracker;
pub use objective::{Objective, ObjectiveKind};
pub use options::{NmfOptions, NmfResult, SparsityMode};
pub use sequential::{factorize_sequential, factorize_sequential_corpus, SequentialOptions};
