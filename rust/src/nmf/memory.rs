//! Max-stored-nonzeros tracking — the paper's memory-footprint metric.
//!
//! Figure 6 reports "the maximum number of nonzeros that need to be stored
//! for the U and V matrices combined" during the computation
//! (`max_combined_nnz`: the stored factors at step boundaries).
//!
//! `max_intermediate_nnz` tracks the half-step candidate scratch. Since
//! the blocked pipeline (PR 4), multi-block half-steps stream over
//! `block_rows`-row blocks reusing one scratch RowBlock per worker, so
//! for the streamed global/threshold/unenforced modes this peak is the
//! largest *single block* — bounded by `block_rows · k` whatever the
//! corpus size — rather than the whole active-rows × k candidate.
//! Deliberate exceptions, because those shapes genuinely exist in
//! memory: a half-step whose output fits one block records the full
//! candidate (the single-block in-memory path), and per-column
//! enforcement additionally records the gathered unenforced CSR (the §4
//! column gather needs every candidate column at once — the paper's
//! point about column-wise enforcement's cost). Auxiliary fixed-size
//! state (the k×k Gram/inverse, the per-worker O(t) top-t selectors) is
//! not counted, exactly as the Gram never was.

/// Frozen summary attached to an [`super::options::NmfResult`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemoryStats {
    /// peak of (stored U scalars + stored V scalars) at step boundaries
    pub max_combined_nnz: usize,
    /// peak half-step candidate scratch (for streamed
    /// global/threshold/unenforced half-steps: one block,
    /// ≤ `block_rows · k` — see the module docs for the exceptions)
    pub max_intermediate_nnz: usize,
    /// final factor nonzeros
    pub final_u_nnz: usize,
    pub final_v_nnz: usize,
}

/// Live tracker threaded through the solvers.
#[derive(Clone, Debug, Default)]
pub struct MemoryTracker {
    stats: MemoryStats,
}

impl MemoryTracker {
    pub fn new() -> Self {
        MemoryTracker::default()
    }

    /// Resume tracking from previously-recorded peaks (checkpoint
    /// restore): the resumed run's peaks continue from the checkpointed
    /// ones, so the final [`MemoryStats`] matches an uninterrupted run.
    pub fn from_stats(stats: MemoryStats) -> Self {
        MemoryTracker { stats }
    }

    /// Record a snapshot of the two live factor-side objects (stored
    /// scalar counts; for a frozen CSR that is its nnz, for a RowBlock
    /// candidate its active_rows × k).
    pub fn observe_pair(&mut self, side_a: usize, side_b: usize) {
        let combined = side_a + side_b;
        if combined > self.stats.max_combined_nnz {
            self.stats.max_combined_nnz = combined;
        }
    }

    /// Record the stored size of a half-step intermediate.
    pub fn observe_intermediate(&mut self, stored: usize) {
        if stored > self.stats.max_intermediate_nnz {
            self.stats.max_intermediate_nnz = stored;
        }
    }

    pub fn finish(mut self, u_nnz: usize, v_nnz: usize) -> MemoryStats {
        self.stats.final_u_nnz = u_nnz;
        self.stats.final_v_nnz = v_nnz;
        self.stats
    }

    pub fn peek(&self) -> &MemoryStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peaks() {
        let mut t = MemoryTracker::new();
        t.observe_pair(10, 5);
        t.observe_pair(3, 4);
        t.observe_pair(8, 20);
        t.observe_intermediate(50);
        t.observe_intermediate(30);
        let s = t.finish(7, 9);
        assert_eq!(s.max_combined_nnz, 28);
        assert_eq!(s.max_intermediate_nnz, 50);
        assert_eq!(s.final_u_nnz, 7);
        assert_eq!(s.final_v_nnz, 9);
    }
}
