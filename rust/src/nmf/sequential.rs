//! Algorithm 3 — sequential ALS NMF: converge topics one block at a time.
//!
//! Block deflation (Eq. 4.5): with previously converged topics `U₁, V₁`,
//! the new block `U₂, V₂` solves
//!
//! ```text
//! V₂ = (Aᵀ U₂ − V₁ (U₁ᵀ U₂)) (U₂ᵀ U₂)⁻¹       (Eq. 4.7)
//! U₂ = (A V₂ − U₁ (V₁ᵀ V₂)) (V₂ᵀ V₂)⁻¹        (Eq. 4.8)
//! ```
//!
//! with projection and per-block top-t enforcement exactly as Algorithm 2.
//! For `k₂ = 1` (the paper's configuration) the normal matrix is a scalar,
//! so "inverse" is a floating-point division — the source of the Fig. 9
//! speedup.

use crate::dense::inverse_spd;
use crate::sparse::{ops, topk, Csr, RowBlock, TieMode};
use crate::text::TermDocMatrix;
use crate::util::timer::Timer;

use super::init::initial_u;
use super::memory::MemoryTracker;
use super::options::NmfResult;

#[derive(Clone, Debug)]
pub struct SequentialOptions {
    /// topics per block (k₂ in the paper; 1 enables the scalar fast path)
    pub block_topics: usize,
    /// number of blocks (η); total rank k = η · block_topics
    pub blocks: usize,
    /// ALS iterations per block
    pub iters_per_block: usize,
    /// per-block nonzero budgets (applied to U₂ / V₂)
    pub t_u: Option<usize>,
    pub t_v: Option<usize>,
    pub tie_mode: TieMode,
    pub seed: u64,
    /// nnz of each block's initial guess (None = dense random)
    pub init_nnz: Option<usize>,
}

impl SequentialOptions {
    pub fn new(blocks: usize, iters_per_block: usize) -> Self {
        SequentialOptions {
            block_topics: 1,
            blocks,
            iters_per_block,
            t_u: None,
            t_v: None,
            tie_mode: TieMode::KeepTies,
            seed: 0x5eed,
            init_nnz: None,
        }
    }

    pub fn with_budgets(mut self, t_u: usize, t_v: usize) -> Self {
        self.t_u = Some(t_u);
        self.t_v = Some(t_v);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn total_k(&self) -> usize {
        self.block_topics * self.blocks
    }
}

/// Append the columns of `block` (rows × k₂) to `acc` (rows × k_cur),
/// producing rows × (k_cur + k₂).
fn append_columns(acc: &Csr, block: &Csr) -> Csr {
    assert_eq!(acc.rows, block.rows);
    let k0 = acc.cols;
    let mut indptr = vec![0usize; acc.rows + 1];
    let mut indices = Vec::with_capacity(acc.nnz() + block.nnz());
    let mut values = Vec::with_capacity(acc.nnz() + block.nnz());
    for r in 0..acc.rows {
        let (ia, va) = acc.row(r);
        indices.extend_from_slice(ia);
        values.extend_from_slice(va);
        let (ib, vb) = block.row(r);
        indices.extend(ib.iter().map(|&c| c + k0 as u32));
        values.extend_from_slice(vb);
        indptr[r + 1] = indices.len();
    }
    Csr {
        rows: acc.rows,
        cols: k0 + block.cols,
        indptr,
        indices,
        values,
    }
}

/// Solve `cand · G⁻¹` with the k₂=1 scalar fast path.
fn solve_block(cand: &mut RowBlock, g: &[f32], k2: usize) {
    if k2 == 1 {
        // scalar "inverse": one floating-point division (ridged like
        // inverse_spd so the k₂=1 and k₂>1 paths agree)
        let s = g[0] as f64;
        let eps = crate::dense::RIDGE_SCALE * s + 1e-10;
        let inv = (1.0 / (s + eps)) as f32;
        for v in &mut cand.data {
            *v *= inv;
        }
    } else {
        let g_inv = inverse_spd(g, k2);
        cand.matmul_small(&g_inv);
    }
}

fn enforce_block(cand: &mut RowBlock, t: Option<usize>, tie: TieMode) {
    cand.project_nonneg();
    if let Some(t) = t {
        topk::enforce_top_t_rowblock(cand, t, tie);
    }
}

/// Run sequential ALS (Algorithm 3).
pub fn factorize_sequential(tdm: &TermDocMatrix, opts: &SequentialOptions) -> NmfResult {
    let timer = Timer::start();
    let n = tdm.n_terms();
    let m = tdm.n_docs();
    let k2 = opts.block_topics;
    assert!(k2 >= 1 && opts.blocks >= 1);

    let mut mem = MemoryTracker::new();
    let mut u1 = Csr::zeros(n, 0);
    let mut v1 = Csr::zeros(m, 0);
    let mut residuals = Vec::new();

    for block in 0..opts.blocks {
        let seed = opts.seed.wrapping_add(block as u64 * 0x9E37_79B9);
        let mut u2 = initial_u(n, k2, opts.init_nnz, seed);
        let mut v2 = Csr::zeros(m, k2);
        let mut prev_u2 = u2.clone();

        for _ in 0..opts.iters_per_block {
            // --- V₂ update (Eq. 4.7) ---
            let mut cand_v = ops::atb(&tdm.a_csc, &u2);
            if u1.cols > 0 {
                let u1tu2 = ops::cross_gram(&u1, &u2); // (k_cur, k₂)
                let defl = ops::csr_times_small(&v1, &u1tu2, k2);
                cand_v = ops::rowblock_sub(&cand_v, &defl);
            }
            mem.observe_intermediate(cand_v.stored_len());
            let gu = ops::gram(&u2);
            solve_block(&mut cand_v, &gu, k2);
            enforce_block(&mut cand_v, opts.t_v, opts.tie_mode);
            v2 = cand_v.to_csr();
            mem.observe_pair(u1.nnz() + u2.nnz(), v1.nnz() + v2.nnz());

            // --- U₂ update (Eq. 4.8) ---
            let mut cand_u = ops::ab(&tdm.a, &v2);
            if v1.cols > 0 {
                let v1tv2 = ops::cross_gram(&v1, &v2);
                let defl = ops::csr_times_small(&u1, &v1tv2, k2);
                cand_u = ops::rowblock_sub(&cand_u, &defl);
            }
            mem.observe_intermediate(cand_u.stored_len());
            let gv = ops::gram(&v2);
            solve_block(&mut cand_u, &gv, k2);
            enforce_block(&mut cand_u, opts.t_u, opts.tie_mode);
            u2 = cand_u.to_csr();
            mem.observe_pair(u1.nnz() + u2.nnz(), v1.nnz() + v2.nnz());

            residuals.push(super::convergence::rel_residual(&u2, &prev_u2));
            prev_u2 = u2.clone();
        }

        u1 = append_columns(&u1, &u2);
        v1 = append_columns(&v1, &v2);
    }

    let norm_a_sq = tdm.a.fro_norm_sq();
    let final_error =
        super::convergence::rel_error_sparse(&tdm.a, &u1, &v1, norm_a_sq);
    let iterations = opts.blocks * opts.iters_per_block;
    let memory = mem.finish(u1.nnz(), v1.nnz());
    NmfResult {
        u: u1,
        v: v1,
        iterations,
        residuals,
        errors: vec![final_error],
        memory,
        elapsed_s: timer.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_tdm, reuters_sim, Scale};
    use crate::text::TdmBuilder;

    fn tiny_tdm() -> TermDocMatrix {
        let mut b = TdmBuilder::new();
        for _ in 0..6 {
            b.add_text("coffee crop quotas coffee brazil crop", Some("econ"));
            b.add_text("electrons atoms hydrogen electrons atoms", Some("sci"));
        }
        b.freeze()
    }

    #[test]
    fn sequential_produces_requested_rank() {
        let tdm = tiny_tdm();
        let opts = SequentialOptions::new(3, 10).with_seed(1);
        let r = factorize_sequential(&tdm, &opts);
        assert_eq!(r.u.cols, 3);
        assert_eq!(r.v.cols, 3);
        assert_eq!(r.iterations, 30);
        r.u.validate().unwrap();
        r.v.validate().unwrap();
        assert!(r.u.values.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn sequential_reduces_error_on_clusterable_data() {
        let tdm = tiny_tdm();
        let opts = SequentialOptions::new(2, 20).with_seed(3);
        let r = factorize_sequential(&tdm, &opts);
        assert!(
            r.final_error() < 0.6,
            "sequential error {} too high",
            r.final_error()
        );
    }

    #[test]
    fn per_block_budgets_yield_even_topics() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 23);
        let mut opts = SequentialOptions::new(5, 8)
            .with_budgets(10, 40)
            .with_seed(5);
        opts.tie_mode = TieMode::Exact; // strict caps (ties on tiny corpora)
        let r = factorize_sequential(&tdm, &opts);
        // every topic column individually obeys its block budget
        for &c in &r.u.col_nnz() {
            assert!(c <= 10, "topic got {c} > 10 terms");
        }
        for &c in &r.v.col_nnz() {
            assert!(c <= 40);
        }
    }

    #[test]
    fn block_topics_greater_than_one() {
        let tdm = tiny_tdm();
        let opts = SequentialOptions {
            block_topics: 2,
            blocks: 2,
            iters_per_block: 8,
            t_u: Some(20),
            t_v: Some(20),
            tie_mode: TieMode::KeepTies,
            seed: 7,
            init_nnz: None,
        };
        let r = factorize_sequential(&tdm, &opts);
        assert_eq!(r.u.cols, 4);
        assert!(r.final_error().is_finite());
    }

    #[test]
    fn append_columns_concatenates() {
        let a = Csr::from_dense(2, 1, &[1.0, 0.0]);
        let b = Csr::from_dense(2, 2, &[0.0, 2.0, 3.0, 0.0]);
        let c = append_columns(&a, &b);
        assert_eq!(c.cols, 3);
        assert_eq!(c.get(0, 0), 1.0);
        assert_eq!(c.get(0, 2), 2.0);
        assert_eq!(c.get(1, 1), 3.0);
        c.validate().unwrap();
    }

    #[test]
    fn scalar_fast_path_matches_general_path() {
        // same data, same seeds: k₂=1 scalar path vs forcing the general
        // path by calling inverse_spd on a 1×1 matrix gives nearly equal
        // results because the ridge matches
        let g = [4.2f32];
        let mut rb1 = RowBlock::new(3, 1);
        rb1.push_row(0, &[2.0]);
        rb1.push_row(2, &[-1.0]);
        let mut rb2 = rb1.clone();
        solve_block(&mut rb1, &g, 1);
        let inv = inverse_spd(&g, 1);
        rb2.matmul_small(&inv);
        for (a, b) in rb1.data.iter().zip(&rb2.data) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }
}
