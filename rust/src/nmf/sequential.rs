//! Algorithm 3 — sequential ALS NMF: converge topics one block at a time.
//!
//! Block deflation (Eq. 4.5): with previously converged topics `U₁, V₁`,
//! the new block `U₂, V₂` solves
//!
//! ```text
//! V₂ = (Aᵀ U₂ − V₁ (U₁ᵀ U₂)) (U₂ᵀ U₂)⁻¹       (Eq. 4.7)
//! U₂ = (A V₂ − U₁ (V₁ᵀ V₂)) (V₂ᵀ V₂)⁻¹        (Eq. 4.8)
//! ```
//!
//! with projection and per-block top-t enforcement exactly as Algorithm 2.
//! For `k₂ = 1` (the paper's configuration) the normal matrix is a scalar,
//! so "inverse" is a floating-point division — the source of the Fig. 9
//! speedup.
//!
//! Since the out-of-core PR, each half-step runs on the same streamed
//! blocked engine as Algorithm 2 ([`crate::nmf::als`]): the candidate is
//! computed one `block_rows`-row block at a time with the deflation term
//! fused into the streaming kernel, so peak intermediate memory is
//! O(block_rows · k₂) per worker instead of O(active rows · k₂) — and
//! `A` itself may be streamed from an on-disk corpus store through the
//! same [`AlsCorpus`] contract. Factors, residuals and errors are
//! bit-identical at every `(block_rows, threads)` combination, matching
//! the pre-port serial pipeline exactly (the fused-deflation kernel is
//! property-pinned against `csr_times_small` + `rowblock_sub`).

use crate::coordinator::pool;
use crate::dense::inverse_spd;
use crate::sparse::source::RowSource;
use crate::sparse::{ops, Csr, TieMode};
use crate::text::TermDocMatrix;
use crate::util::timer::Timer;
use crate::util::trace;

use super::als::{stream_half_step, AlsCorpus, CandSource, Enforce, Solve, StreamCtx};
use super::convergence::rel_error_source;
use super::init::initial_u;
use super::memory::MemoryTracker;
use super::options::{resolve_block_rows, NmfResult};

#[derive(Clone, Debug)]
pub struct SequentialOptions {
    /// topics per block (k₂ in the paper; 1 enables the scalar fast path)
    pub block_topics: usize,
    /// number of blocks (η); total rank k = η · block_topics
    pub blocks: usize,
    /// ALS iterations per block
    pub iters_per_block: usize,
    /// per-block nonzero budgets (applied to U₂ / V₂)
    pub t_u: Option<usize>,
    pub t_v: Option<usize>,
    pub tie_mode: TieMode,
    pub seed: u64,
    /// nnz of each block's initial guess (None = dense random)
    pub init_nnz: Option<usize>,
    /// worker threads for the streamed half-steps (0 = auto, all cores);
    /// results are bit-identical at any setting
    pub threads: usize,
    /// rows per streamed half-step block (0 = auto, resolved against
    /// `block_topics`); bounds peak intermediate memory at
    /// `block_rows · block_topics` per worker without changing results
    pub block_rows: usize,
}

impl SequentialOptions {
    pub fn new(blocks: usize, iters_per_block: usize) -> Self {
        SequentialOptions {
            block_topics: 1,
            blocks,
            iters_per_block,
            t_u: None,
            t_v: None,
            tie_mode: TieMode::KeepTies,
            seed: 0x5eed,
            init_nnz: None,
            threads: 0,
            block_rows: 0,
        }
    }

    pub fn with_budgets(mut self, t_u: usize, t_v: usize) -> Self {
        self.t_u = Some(t_u);
        self.t_v = Some(t_v);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the worker count; `0` means "auto" (all available cores).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Set the streamed half-step block height; `0` means "auto".
    pub fn with_block_rows(mut self, block_rows: usize) -> Self {
        self.block_rows = block_rows;
        self
    }

    pub fn total_k(&self) -> usize {
        self.block_topics * self.blocks
    }
}

/// Append the columns of `block` (rows × k₂) to `acc` (rows × k_cur),
/// producing rows × (k_cur + k₂).
fn append_columns(acc: &Csr, block: &Csr) -> Csr {
    assert_eq!(acc.rows, block.rows);
    let k0 = acc.cols;
    let mut indptr = vec![0usize; acc.rows + 1];
    let mut indices = Vec::with_capacity(acc.nnz() + block.nnz());
    let mut values = Vec::with_capacity(acc.nnz() + block.nnz());
    for r in 0..acc.rows {
        let (ia, va) = acc.row(r);
        indices.extend_from_slice(ia);
        values.extend_from_slice(va);
        let (ib, vb) = block.row(r);
        indices.extend(ib.iter().map(|&c| c + k0 as u32));
        values.extend_from_slice(vb);
        indptr[r + 1] = indices.len();
    }
    Csr {
        rows: acc.rows,
        cols: k0 + block.cols,
        indptr,
        indices,
        values,
    }
}

/// The ridged scalar "inverse" of the k₂ = 1 fast path — one division,
/// ridged like [`inverse_spd`] so the k₂ = 1 and k₂ > 1 paths agree.
fn scalar_inverse(g: f32) -> f32 {
    let s = g as f64;
    let eps = crate::dense::RIDGE_SCALE * s + 1e-10;
    (1.0 / (s + eps)) as f32
}

/// One streamed sequential half-step: candidate = `src·factor − defl`,
/// solved (scalar fast path at k₂ = 1), projected, globally enforced —
/// all on the Algorithm-2 blocked engine.
#[allow(clippy::too_many_arguments)]
fn seq_half_step(
    src: &dyn RowSource,
    factor: &Csr,
    defl: Option<(&Csr, Vec<f32>)>,
    t: Option<usize>,
    tie: TieMode,
    threads: usize,
    block_rows: usize,
    mem: &mut MemoryTracker,
) -> Csr {
    let k2 = factor.cols;
    let g = ops::gram_par(factor, threads);
    let solve = if k2 == 1 {
        Solve::Scalar(scalar_inverse(g[0]))
    } else {
        Solve::Gram(inverse_spd(&g, k2))
    };
    let cand = CandSource {
        src,
        factor,
        dense: ops::dense_factor(factor),
        defl,
    };
    let ctx = StreamCtx::new(cand, solve, k2, threads, block_rows);
    let enforce = match t {
        Some(t) => Enforce::Global(t),
        None => Enforce::No,
    };
    stream_half_step(&ctx, enforce, tie, threads, mem)
}

/// Run sequential ALS (Algorithm 3).
pub fn factorize_sequential(tdm: &TermDocMatrix, opts: &SequentialOptions) -> NmfResult {
    factorize_sequential_corpus(tdm, opts)
}

/// [`factorize_sequential`] over any [`AlsCorpus`] — resident or
/// streamed from an on-disk corpus store. Bit-identical either way.
pub fn factorize_sequential_corpus(
    corpus: &dyn AlsCorpus,
    opts: &SequentialOptions,
) -> NmfResult {
    let timer = Timer::start();
    let n = corpus.n_terms();
    let m = corpus.n_docs();
    let k2 = opts.block_topics;
    assert!(k2 >= 1 && opts.blocks >= 1);
    let threads = if opts.threads == 0 {
        pool::default_threads()
    } else {
        opts.threads
    };
    let block_rows = resolve_block_rows(opts.block_rows, k2);

    let mut mem = MemoryTracker::new();
    let mut u1 = Csr::zeros(n, 0);
    let mut v1 = Csr::zeros(m, 0);
    let mut residuals = Vec::new();

    trace::progress::begin(0, opts.blocks * opts.iters_per_block);
    for block in 0..opts.blocks {
        let seed = opts.seed.wrapping_add(block as u64 * 0x9E37_79B9);
        let mut u2 = initial_u(n, k2, opts.init_nnz, seed);
        let mut v2 = Csr::zeros(m, k2);
        let mut prev_u2 = u2.clone();

        for inner in 0..opts.iters_per_block {
            // the sequential solver drives its own loop (block × inner,
            // deflation fused), so it records its own iteration spans —
            // the enforcement spans come from the shared streamed
            // machinery under seq_half_step
            let mut iter_span = trace::span("iteration");
            let global_iter = block * opts.iters_per_block + inner + 1;
            iter_span.field("iter", global_iter as f64);
            iter_span.field("block", block as f64);

            // --- V₂ update (Eq. 4.7), deflation fused into the stream ---
            let defl_v = (u1.cols > 0).then(|| (&v1, ops::cross_gram(&u1, &u2)));
            v2 = {
                let mut span = trace::span("half_step_v");
                let v2 = seq_half_step(
                    corpus.a_cols(),
                    &u2,
                    defl_v,
                    opts.t_v,
                    opts.tie_mode,
                    threads,
                    block_rows,
                    &mut mem,
                );
                span.field("nnz", v2.nnz() as f64);
                v2
            };
            mem.observe_pair(u1.nnz() + u2.nnz(), v1.nnz() + v2.nnz());

            // --- U₂ update (Eq. 4.8) ---
            let defl_u = (v1.cols > 0).then(|| (&u1, ops::cross_gram(&v1, &v2)));
            u2 = {
                let mut span = trace::span("half_step_u");
                let u2 = seq_half_step(
                    corpus.a_rows(),
                    &v2,
                    defl_u,
                    opts.t_u,
                    opts.tie_mode,
                    threads,
                    block_rows,
                    &mut mem,
                );
                span.field("nnz", u2.nnz() as f64);
                u2
            };
            mem.observe_pair(u1.nnz() + u2.nnz(), v1.nnz() + v2.nnz());

            let r = super::convergence::rel_residual(&u2, &prev_u2);
            residuals.push(r);
            iter_span.field("residual", r);
            trace::progress::update(global_iter, r, None);
            prev_u2 = u2.clone();
        }

        u1 = append_columns(&u1, &u2);
        v1 = append_columns(&v1, &v2);
    }
    trace::progress::finish();

    let norm_a_sq = corpus.norm_a_sq();
    let final_error = {
        let mut span = trace::span("error_pass");
        let e = rel_error_source(corpus.a_rows(), &u1, &v1, norm_a_sq, block_rows);
        span.field("error", e);
        e
    };
    let iterations = opts.blocks * opts.iters_per_block;
    let memory = mem.finish(u1.nnz(), v1.nnz());
    NmfResult {
        u: u1,
        v: v1,
        iterations,
        residuals,
        errors: vec![final_error],
        memory,
        elapsed_s: timer.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_tdm, reuters_sim, Scale};
    use crate::sparse::RowBlock;
    use crate::text::TdmBuilder;

    fn tiny_tdm() -> TermDocMatrix {
        let mut b = TdmBuilder::new();
        for _ in 0..6 {
            b.add_text("coffee crop quotas coffee brazil crop", Some("econ"));
            b.add_text("electrons atoms hydrogen electrons atoms", Some("sci"));
        }
        b.freeze()
    }

    #[test]
    fn sequential_produces_requested_rank() {
        let tdm = tiny_tdm();
        let opts = SequentialOptions::new(3, 10).with_seed(1);
        let r = factorize_sequential(&tdm, &opts);
        assert_eq!(r.u.cols, 3);
        assert_eq!(r.v.cols, 3);
        assert_eq!(r.iterations, 30);
        r.u.validate().unwrap();
        r.v.validate().unwrap();
        assert!(r.u.values.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn sequential_reduces_error_on_clusterable_data() {
        let tdm = tiny_tdm();
        let opts = SequentialOptions::new(2, 20).with_seed(3);
        let r = factorize_sequential(&tdm, &opts);
        assert!(
            r.final_error() < 0.6,
            "sequential error {} too high",
            r.final_error()
        );
    }

    #[test]
    fn per_block_budgets_yield_even_topics() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 23);
        let mut opts = SequentialOptions::new(5, 8)
            .with_budgets(10, 40)
            .with_seed(5);
        opts.tie_mode = TieMode::Exact; // strict caps (ties on tiny corpora)
        let r = factorize_sequential(&tdm, &opts);
        // every topic column individually obeys its block budget
        for &c in &r.u.col_nnz() {
            assert!(c <= 10, "topic got {c} > 10 terms");
        }
        for &c in &r.v.col_nnz() {
            assert!(c <= 40);
        }
    }

    #[test]
    fn block_topics_greater_than_one() {
        let tdm = tiny_tdm();
        let opts = SequentialOptions {
            block_topics: 2,
            blocks: 2,
            iters_per_block: 8,
            t_u: Some(20),
            t_v: Some(20),
            tie_mode: TieMode::KeepTies,
            seed: 7,
            init_nnz: None,
            threads: 0,
            block_rows: 0,
        };
        let r = factorize_sequential(&tdm, &opts);
        assert_eq!(r.u.cols, 4);
        assert!(r.final_error().is_finite());
    }

    #[test]
    fn append_columns_concatenates() {
        let a = Csr::from_dense(2, 1, &[1.0, 0.0]);
        let b = Csr::from_dense(2, 2, &[0.0, 2.0, 3.0, 0.0]);
        let c = append_columns(&a, &b);
        assert_eq!(c.cols, 3);
        assert_eq!(c.get(0, 0), 1.0);
        assert_eq!(c.get(0, 2), 2.0);
        assert_eq!(c.get(1, 1), 3.0);
        c.validate().unwrap();
    }

    #[test]
    fn scalar_fast_path_matches_general_path() {
        // the k₂=1 scalar division and the general 1×1 inverse_spd solve
        // agree because the ridge matches
        let g = [4.2f32];
        let mut rb1 = RowBlock::new(3, 1);
        rb1.push_row(0, &[2.0]);
        rb1.push_row(2, &[-1.0]);
        let mut rb2 = rb1.clone();
        let inv = scalar_inverse(g[0]);
        for v in &mut rb1.data {
            *v *= inv;
        }
        rb2.matmul_small(&inverse_spd(&g, 1));
        for (a, b) in rb1.data.iter().zip(&rb2.data) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    fn assert_same_result(a: &NmfResult, b: &NmfResult, tag: &str) {
        assert_eq!(a.u, b.u, "{tag}");
        assert_eq!(a.v, b.v, "{tag}");
        assert_eq!(a.iterations, b.iterations, "{tag}");
        assert_eq!(a.residuals, b.residuals, "{tag}");
        assert_eq!(a.errors, b.errors, "{tag}");
    }

    #[test]
    fn blocked_sequential_bit_identical_across_block_rows_and_threads() {
        // the regression pin for the streamed port: the in-memory
        // single-block path (block_rows = ∞, threads = 1) reproduces the
        // pre-port serial pipeline, and every (block_rows, threads)
        // combination must match it bit for bit — including ragged final
        // blocks and the k₂ > 1 general solve
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 29);
        for (block_topics, blocks) in [(1usize, 3usize), (2, 2)] {
            let mut base = SequentialOptions::new(blocks, 4)
                .with_budgets(25, 60)
                .with_seed(31)
                .with_threads(1)
                .with_block_rows(usize::MAX);
            base.block_topics = block_topics;
            for tie in [TieMode::KeepTies, TieMode::Exact] {
                base.tie_mode = tie;
                let reference = factorize_sequential(&tdm, &base);
                for block_rows in [1usize, 7, 64] {
                    for threads in [1usize, 4] {
                        let opts = base
                            .clone()
                            .with_threads(threads)
                            .with_block_rows(block_rows);
                        let r = factorize_sequential(&tdm, &opts);
                        assert_same_result(
                            &r,
                            &reference,
                            &format!(
                                "k2={block_topics} tie={tie:?} block_rows={block_rows} threads={threads}"
                            ),
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn blocked_sequential_bounds_the_intermediate() {
        // a corpus spanning many streamed blocks: the candidate scratch
        // peak obeys the block_rows · k₂ bound — the ROADMAP item this
        // port exists for
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 41);
        let block_rows = 16;
        let opts = SequentialOptions::new(2, 3)
            .with_budgets(40, 80)
            .with_seed(43)
            .with_block_rows(block_rows);
        assert!(tdm.n_docs() > 3 * block_rows, "corpus must span many blocks");
        let r = factorize_sequential(&tdm, &opts);
        assert!(
            r.memory.max_intermediate_nnz <= block_rows,
            "intermediate {} exceeds the {}-scalar bound (k₂ = 1)",
            r.memory.max_intermediate_nnz,
            block_rows
        );
        let unblocked =
            factorize_sequential(&tdm, &opts.clone().with_block_rows(usize::MAX));
        assert!(
            r.memory.max_intermediate_nnz < unblocked.memory.max_intermediate_nnz,
            "blocked peak {} should undercut unblocked {}",
            r.memory.max_intermediate_nnz,
            unblocked.memory.max_intermediate_nnz
        );
        assert_same_result(&r, &unblocked, "blocked vs unblocked");
    }
}
