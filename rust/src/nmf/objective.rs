//! The objective seam: everything half-step math that depends on *what*
//! is being minimized lives behind [`Objective`], so the streamed block
//! machinery ([`crate::nmf::als::StreamCtx`]), the enforcement passes,
//! the snapshot/wire formats and the serving plane are objective-agnostic.
//!
//! Two implementations:
//!
//! * **Frobenius** — the paper's least-squares objective
//!   `‖A − U Vᵀ‖²_F`. Per half-step the auxiliary is the ridged Gram
//!   inverse `(FᵀF + εI)⁻¹` of the fixed factor; each candidate block is
//!   the SpMM row run solved against it and projected non-negative. This
//!   path is **bit-identical** to the pre-seam solver: the instruction
//!   sequence (gram → inverse → per-block fill/solve/project) is
//!   unchanged, pinned by `NmfResult::digest()` equality in the property
//!   and integration suites.
//! * **KL divergence** — the count-data objective
//!   `D(A ‖ U Vᵀ) = Σ a·ln(a/p) − a + p` (Nguyen & Ho,
//!   arXiv:1604.04026). Per half-step the auxiliary is the fixed
//!   factor's per-topic column sums; each output row gets one
//!   multiplicative update computed per block by [`kl_update_rows`]
//!   inside the same `StreamCtx`, then rides the unchanged `topk`
//!   enforcement. Rows update independently, so the result is
//!   bit-identical at every `(block_rows, threads)` pair by
//!   construction.
//!
//! # The KL multiplicative update, per row
//!
//! Updating row `x` of one factor with the other factor `F` fixed
//! (documents stream for the V half, terms for the U half):
//!
//! ```text
//! x[c] ← x[c] · ( Σ_w (a_w / ⟨F_w, x⟩) · F[w, c] ) / ( Σ_w F[w, c] )
//! ```
//!
//! summed over the nonzeros `a_w` of the streamed `A` row. Zeros are
//! **absorbing** (`x[c] = 0` stays 0) — exactly the behavior enforced
//! sparsity wants: a top-t pass zeroing an entry prunes it permanently,
//! like the paper's during-iteration enforcement. A predicted count of 0
//! needs no epsilon: `⟨F_w, x⟩ = 0` means every topic `F_w` touches has
//! `x[c] = 0`, so that term's contributions are multiplied away by
//! `x[c]` regardless — skipping it is exact.

use crate::dense::inverse_spd;
use crate::sparse::{ops, Csr, RowBlock, RowCursor, RowSource};
use crate::util::trace;

use super::convergence::{kl_divergence_source, rel_error_source};

/// Floor applied to predicted counts inside logarithms (the KL
/// divergence metric and the held-out log-likelihood): a model that
/// assigns zero mass to an observed token has genuinely infinite
/// divergence, but the reported history must stay finite and comparable
/// across iterations.
pub const KL_EPS: f64 = 1e-30;

/// Multiplicative-update rounds of a KL fold-in solve (one unseen
/// document against the frozen `U`). Fixed so served answers are
/// deterministic; k ≤ 64 converges well within this budget.
pub const KL_FOLDIN_ROUNDS: usize = 25;

/// The training objective — the serializable identity that travels
/// through options, config, CLI, snapshots and the worker wire protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ObjectiveKind {
    /// least squares `‖A − U Vᵀ‖²_F` (the paper's objective)
    #[default]
    Frobenius,
    /// generalized KL divergence `D(A ‖ U Vᵀ)` (count data)
    Kl,
}

impl ObjectiveKind {
    /// Parse the CLI / config spelling.
    pub fn parse(s: &str) -> Option<ObjectiveKind> {
        match s {
            "frobenius" | "fro" => Some(ObjectiveKind::Frobenius),
            "kl" | "kl-divergence" => Some(ObjectiveKind::Kl),
            _ => None,
        }
    }

    /// Canonical spelling (round-trips through [`ObjectiveKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            ObjectiveKind::Frobenius => "frobenius",
            ObjectiveKind::Kl => "kl",
        }
    }

    /// Stable one-byte tag persisted in `.esnmf` snapshots (format v2+)
    /// and the worker wire protocol. Never renumber.
    pub fn tag(self) -> u8 {
        match self {
            ObjectiveKind::Frobenius => 0,
            ObjectiveKind::Kl => 1,
        }
    }

    /// Decode a persisted tag; `None` for tags from a future format
    /// (callers surface a typed error — never a silent Frobenius
    /// default).
    pub fn from_tag(tag: u8) -> Option<ObjectiveKind> {
        match tag {
            0 => Some(ObjectiveKind::Frobenius),
            1 => Some(ObjectiveKind::Kl),
            _ => None,
        }
    }

    /// The (stateless) implementation behind this kind.
    pub fn implementation(self) -> &'static dyn Objective {
        match self {
            ObjectiveKind::Frobenius => &Frobenius,
            ObjectiveKind::Kl => &KlDivergence,
        }
    }
}

/// The per-half-step math of one training objective. Implementations are
/// stateless units; dispatch happens through
/// [`ObjectiveKind::implementation`].
///
/// The contract mirrors what the streamed driver needs around its block
/// loop: one auxiliary vector computed from the fixed factor before the
/// blocks stream (`step_aux`), the per-iteration fit statistic
/// (`error_source`), and the per-document fold-in solve the serving
/// plane runs (`foldin_solve`). The per-block candidate computation
/// itself is dispatched inside `nmf::als` (it works over crate-private
/// scratch types), keyed by [`ObjectiveKind`].
pub trait Objective: Sync {
    fn kind(&self) -> ObjectiveKind;

    /// The half-step auxiliary computed once from the fixed factor
    /// before the blocks stream: Frobenius returns the dense (k, k)
    /// ridged Gram inverse (row-major); KL returns the k per-topic
    /// column sums. This is exactly what the distributed coordinator
    /// ships to workers in `ComputeReq.aux`.
    fn step_aux(&self, fixed: &Csr, threads: usize) -> Vec<f32>;

    /// Expected `step_aux` length at rank `k` — the worker plane's
    /// shape validation.
    fn aux_len(&self, k: usize) -> usize;

    /// Whether half-steps consume the previous iterate of the factor
    /// being updated (multiplicative objectives do; least squares
    /// re-solves from scratch). Governs whether `ComputeReq` carries
    /// the `prev` factor.
    fn needs_prev(&self) -> bool;

    /// The per-iteration fit statistic of the error history: relative
    /// Frobenius error, or mean per-token KL divergence. `norm_a_sq` is
    /// `‖A‖²_F` (precomputed once per run; KL ignores it).
    fn error_source(
        &self,
        a: &dyn RowSource,
        u: &Csr,
        v: &Csr,
        norm_a_sq: f64,
        chunk_rows: usize,
    ) -> f64;

    /// Solve one document row against the frozen `u` using a
    /// precomputed `aux` (= `step_aux(u, 1)`): the serving plane's
    /// fold-in. `doc` is (term row, count) pairs — out-of-range ids and
    /// non-positive counts must be ignored; the dense length-k result
    /// is left in `x` (non-negative, unenforced — the caller applies
    /// the top-t budget).
    ///
    /// `b` is a reusable k-wide accumulator with an **all-zero
    /// invariant**: pass it fresh (empty) or only ever through this
    /// method. Implementations scatter the doc's term rows into it and
    /// un-scatter the same indices before returning — O(nnz) per solve
    /// instead of a k-wide memset — so a pooled `b` must not be mutated
    /// elsewhere between solves (a length mismatch, e.g. after a hot
    /// model swap to a different rank, resets it wholesale).
    fn foldin_solve(
        &self,
        u: &Csr,
        aux: &[f32],
        doc: &[(usize, f32)],
        x: &mut Vec<f32>,
        b: &mut Vec<f32>,
    );
}

/// The paper's least-squares objective — see the module docs for the
/// bit-identity contract.
pub struct Frobenius;

impl Objective for Frobenius {
    fn kind(&self) -> ObjectiveKind {
        ObjectiveKind::Frobenius
    }

    fn step_aux(&self, fixed: &Csr, threads: usize) -> Vec<f32> {
        // the exact pre-seam instruction sequence of the half-steps and
        // the distributed coordinator: parallel gram, then the ridged
        // SPD inverse — the bits of every downstream factor depend on it
        let g = ops::gram_par(fixed, threads);
        inverse_spd(&g, fixed.cols)
    }

    fn aux_len(&self, k: usize) -> usize {
        k * k
    }

    fn needs_prev(&self) -> bool {
        false
    }

    fn error_source(
        &self,
        a: &dyn RowSource,
        u: &Csr,
        v: &Csr,
        norm_a_sq: f64,
        chunk_rows: usize,
    ) -> f64 {
        let mut span = trace::span("error_pass");
        let e = rel_error_source(a, u, v, norm_a_sq, chunk_rows);
        span.field("error", e);
        e
    }

    fn foldin_solve(
        &self,
        u: &Csr,
        aux: &[f32],
        doc: &[(usize, f32)],
        x: &mut Vec<f32>,
        b: &mut Vec<f32>,
    ) {
        let k = u.cols;
        debug_assert_eq!(aux.len(), k * k, "fold-in aux is the (k,k) Gram inverse");
        if b.len() != k {
            b.clear();
            b.resize(k, 0.0);
        }
        debug_assert!(
            b.iter().all(|&z| z == 0.0),
            "pooled fold-in accumulator must keep its all-zero invariant"
        );
        // b = aᵀ U — same accumulation order as ops::atb's sparse path
        for &(term, count) in doc {
            if term >= u.rows || !count.is_finite() || count <= 0.0 {
                continue;
            }
            let (idx, val) = u.row(term);
            for (&c, &uv) in idx.iter().zip(val) {
                b[c as usize] += count * uv;
            }
        }
        // x = b · G⁻¹ (the 1-row form of RowBlock::matmul_small)
        x.clear();
        x.resize(k, 0.0);
        for (i, &bi) in b.iter().enumerate() {
            if bi != 0.0 {
                let g_row = &aux[i * k..(i + 1) * k];
                for (xj, &gij) in x.iter_mut().zip(g_row) {
                    *xj += bi * gij;
                }
            }
        }
        for v in x.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        // restore b's all-zero invariant at O(nnz): un-scatter exactly
        // the term rows the accumulation pass touched
        for &(term, count) in doc {
            if term >= u.rows || !count.is_finite() || count <= 0.0 {
                continue;
            }
            for &c in u.row(term).0 {
                b[c as usize] = 0.0;
            }
        }
    }
}

/// The generalized KL-divergence objective (count data) — multiplicative
/// per-row updates, see the module docs for the update rule.
pub struct KlDivergence;

impl Objective for KlDivergence {
    fn kind(&self) -> ObjectiveKind {
        ObjectiveKind::Kl
    }

    fn step_aux(&self, fixed: &Csr, _threads: usize) -> Vec<f32> {
        kl_col_sums(fixed)
    }

    fn aux_len(&self, k: usize) -> usize {
        k
    }

    fn needs_prev(&self) -> bool {
        true
    }

    fn error_source(
        &self,
        a: &dyn RowSource,
        u: &Csr,
        v: &Csr,
        _norm_a_sq: f64,
        chunk_rows: usize,
    ) -> f64 {
        let mut span = trace::span("error_pass");
        let e = kl_divergence_source(a, u, v, chunk_rows);
        span.field("error", e);
        e
    }

    fn foldin_solve(
        &self,
        u: &Csr,
        aux: &[f32],
        doc: &[(usize, f32)],
        x: &mut Vec<f32>,
        b: &mut Vec<f32>,
    ) {
        let k = u.cols;
        debug_assert_eq!(aux.len(), k, "fold-in aux is the per-topic column sums");
        // multiplicative updates from a uniform positive start (they
        // cannot leave zero); a fixed round budget keeps served answers
        // deterministic. `b` is the numerator accumulator, holding the
        // all-zero invariant between rounds and between solves (cleared
        // by un-scattering the doc's term rows, never a k-wide memset).
        if b.len() != k {
            b.clear();
            b.resize(k, 0.0);
        }
        debug_assert!(
            b.iter().all(|&z| z == 0.0),
            "pooled fold-in accumulator must keep its all-zero invariant"
        );
        x.clear();
        x.resize(k, 1.0);
        for _ in 0..KL_FOLDIN_ROUNDS {
            for &(term, count) in doc {
                if term >= u.rows || !count.is_finite() || count <= 0.0 {
                    continue;
                }
                let (idx, val) = u.row(term);
                let mut pred = 0.0f64;
                for (&c, &uv) in idx.iter().zip(val) {
                    pred += uv as f64 * x[c as usize] as f64;
                }
                if pred <= 0.0 {
                    // no support overlap: the contribution would be
                    // multiplied away by x[c] = 0 anyway (module docs)
                    continue;
                }
                let ratio = count as f64 / pred;
                for (&c, &uv) in idx.iter().zip(val) {
                    b[c as usize] += (ratio * uv as f64) as f32;
                }
            }
            for (c, xc) in x.iter_mut().enumerate() {
                *xc = if *xc > 0.0 && aux[c] > 0.0 {
                    (*xc as f64 * b[c] as f64 / aux[c] as f64) as f32
                } else {
                    0.0
                };
            }
            // un-scatter this round's numerator (a superset of what the
            // pred > 0 gate actually wrote — clearing zeros is free)
            for &(term, count) in doc {
                if term >= u.rows || !count.is_finite() || count <= 0.0 {
                    continue;
                }
                for &c in u.row(term).0 {
                    b[c as usize] = 0.0;
                }
            }
        }
    }
}

/// Per-topic column sums `Σ_rows F[r, c]` of the fixed factor — the KL
/// half-step auxiliary (the multiplicative update's denominator).
/// Accumulated serially in row order in f64, so the result is
/// independent of the thread count by construction.
pub(crate) fn kl_col_sums(fixed: &Csr) -> Vec<f32> {
    let k = fixed.cols;
    let mut sums = vec![0.0f64; k];
    for r in 0..fixed.rows {
        let (idx, val) = fixed.row(r);
        for (&c, &v) in idx.iter().zip(val) {
            sums[c as usize] += v as f64;
        }
    }
    sums.into_iter().map(|s| s as f32).collect()
}

/// One block of KL multiplicative row updates — the KL analogue of
/// [`ops::stream_mul_into`]: compute updated rows `lo..hi` of the factor
/// whose `A` orientation streams through `a`, appending the surviving
/// (non-zero) rows into `out` (cleared first; `cur` is the worker's
/// streaming cursor).
///
/// `fixed` is the other factor `F` (contraction dim × k), `prev` the
/// previous iterate of the factor being updated (full logical row space
/// — row `j` of the output reads row `j` of `prev`), `col_sums` the
/// precomputed per-topic sums of `fixed` ([`kl_col_sums`]).
///
/// Each row's update touches only that row of `prev` and of `a`, with
/// all accumulation in f64 over the `A` row's stored order — so the
/// emitted bits are independent of block boundaries and worker
/// scheduling, which is what lets this kernel ride the same blocked
/// two-pass enforcement machinery as Frobenius, bit-identically at
/// every `(block_rows, threads)` pair.
#[allow(clippy::too_many_arguments)]
pub(crate) fn kl_update_rows(
    a: &dyn RowSource,
    fixed: &Csr,
    prev: &Csr,
    col_sums: &[f32],
    lo: usize,
    hi: usize,
    cur: &mut RowCursor,
    out: &mut RowBlock,
) {
    assert_eq!(a.cols(), fixed.rows, "KL contraction mismatch");
    assert_eq!(a.rows(), prev.rows, "KL previous-iterate row mismatch");
    assert_eq!(fixed.cols, prev.cols, "KL rank mismatch");
    assert_eq!(col_sums.len(), fixed.cols, "KL column-sum length");
    out.clear();
    let k = fixed.cols;
    let view = a.load(lo, hi, cur);
    let mut x = vec![0.0f32; k];
    let mut num = vec![0.0f64; k];
    for j in lo..hi {
        let (pidx, pval) = prev.row(j);
        if pidx.is_empty() {
            // an all-zero row is a fixed point of the multiplicative
            // update; like stream_mul_into, inactive rows are not pushed
            continue;
        }
        x.iter_mut().for_each(|s| *s = 0.0);
        for (&c, &v) in pidx.iter().zip(pval) {
            x[c as usize] = v;
        }
        num.iter_mut().for_each(|s| *s = 0.0);
        let (acols, avals) = view.row(j - lo);
        for (&w, &aij) in acols.iter().zip(avals) {
            let (fidx, fval) = fixed.row(w as usize);
            // predicted count ⟨F_w, x⟩ for this (term, doc) cell
            let mut pred = 0.0f64;
            for (&c, &fv) in fidx.iter().zip(fval) {
                pred += fv as f64 * x[c as usize] as f64;
            }
            if pred <= 0.0 {
                // exact skip, no epsilon — see the module docs
                continue;
            }
            let ratio = aij as f64 / pred;
            for (&c, &fv) in fidx.iter().zip(fval) {
                num[c as usize] += ratio * fv as f64;
            }
        }
        let mut any = false;
        for (c, xc) in x.iter_mut().enumerate() {
            let v = if *xc > 0.0 && col_sums[c] > 0.0 {
                (*xc as f64 * num[c] / col_sums[c] as f64) as f32
            } else {
                0.0
            };
            *xc = v;
            any |= v != 0.0;
        }
        if any {
            out.push_row(j, &x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn kind_spellings_and_tags_round_trip() {
        for kind in [ObjectiveKind::Frobenius, ObjectiveKind::Kl] {
            assert_eq!(ObjectiveKind::parse(kind.name()), Some(kind));
            assert_eq!(ObjectiveKind::from_tag(kind.tag()), Some(kind));
            assert_eq!(kind.implementation().kind(), kind);
        }
        assert_eq!(ObjectiveKind::parse("fro"), Some(ObjectiveKind::Frobenius));
        assert_eq!(ObjectiveKind::parse("kl-divergence"), Some(ObjectiveKind::Kl));
        assert_eq!(ObjectiveKind::parse("l2"), None);
        // unknown future tags decode to None, never a silent default
        assert_eq!(ObjectiveKind::from_tag(2), None);
        assert_eq!(ObjectiveKind::from_tag(255), None);
        assert_eq!(ObjectiveKind::default(), ObjectiveKind::Frobenius);
    }

    #[test]
    fn frobenius_aux_is_the_ridged_gram_inverse() {
        let mut rng = Rng::new(0x0b1);
        let u = Csr::from_dense(12, 3, &prop::gen_sparse_dense(&mut rng, 12, 3, 0.6));
        let want = inverse_spd(&ops::gram_par(&u, 2), 3);
        let got = ObjectiveKind::Frobenius.implementation().step_aux(&u, 2);
        assert_eq!(
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
        assert_eq!(ObjectiveKind::Frobenius.implementation().aux_len(3), 9);
        assert!(!ObjectiveKind::Frobenius.implementation().needs_prev());
    }

    #[test]
    fn kl_aux_is_the_column_sums_at_any_thread_count() {
        let mut rng = Rng::new(0x0b2);
        let u = Csr::from_dense(20, 4, &prop::gen_sparse_dense(&mut rng, 20, 4, 0.5));
        let obj = ObjectiveKind::Kl.implementation();
        let want = obj.step_aux(&u, 1);
        for threads in [2usize, 7] {
            assert_eq!(obj.step_aux(&u, threads), want);
        }
        // reference: dense column sums
        let dense = u.to_dense();
        for c in 0..4 {
            let s: f64 = (0..20).map(|r| dense[r * 4 + c] as f64).sum();
            assert!((want[c] as f64 - s).abs() < 1e-4, "col {c}");
        }
        assert_eq!(obj.aux_len(4), 4);
        assert!(obj.needs_prev());
    }

    /// Dense reference of the per-row multiplicative update, same f64
    /// accumulation order as the kernel.
    fn kl_reference_row(
        a_row: (&[u32], &[f32]),
        fixed: &Csr,
        x: &[f32],
        col_sums: &[f32],
    ) -> Vec<f32> {
        let k = x.len();
        let mut num = vec![0.0f64; k];
        for (&w, &aij) in a_row.0.iter().zip(a_row.1) {
            let (fidx, fval) = fixed.row(w as usize);
            let mut pred = 0.0f64;
            for (&c, &fv) in fidx.iter().zip(fval) {
                pred += fv as f64 * x[c as usize] as f64;
            }
            if pred <= 0.0 {
                continue;
            }
            for (&c, &fv) in fidx.iter().zip(fval) {
                num[c as usize] += aij as f64 / pred * fv as f64;
            }
        }
        (0..k)
            .map(|c| {
                if x[c] > 0.0 && col_sums[c] > 0.0 {
                    (x[c] as f64 * num[c] / col_sums[c] as f64) as f32
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn kl_update_matches_the_rowwise_reference() {
        prop::check("kl-update-vs-reference", 3100, 48, |rng: &mut Rng| {
            let n = rng.range(1, 15);
            let m = rng.range(1, 15);
            let k = rng.range(1, 5);
            // a: the streamed orientation (output rows × contraction)
            let a = Csr::from_dense(m, n, &prop::gen_sparse_dense(rng, m, n, 0.4));
            let fixed = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.6));
            let prev = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.7));
            let sums = kl_col_sums(&fixed);
            let mut cur = RowCursor::new();
            let mut out = RowBlock::new(m, k);
            kl_update_rows(&a, &fixed, &prev, &sums, 0, m, &mut cur, &mut out);
            let got = out.to_csr();
            let mut x = vec![0.0f32; k];
            for j in 0..m {
                x.iter_mut().for_each(|v| *v = 0.0);
                let (pidx, pval) = prev.row(j);
                for (&c, &v) in pidx.iter().zip(pval) {
                    x[c as usize] = v;
                }
                let want = kl_reference_row(a.row(j), &fixed, &x, &sums);
                for (c, &w) in want.iter().enumerate() {
                    let g = got.get(j, c);
                    assert!(
                        (g - w).abs() <= 1e-6 * w.abs().max(1.0),
                        "row {j} col {c}: {g} vs {w}"
                    );
                }
            }
        });
    }

    #[test]
    fn kl_update_is_block_invariant_bit_for_bit() {
        prop::check("kl-update-block-invariant", 3200, 48, |rng: &mut Rng| {
            let n = rng.range(1, 20);
            let m = rng.range(2, 20);
            let k = rng.range(1, 5);
            let a = Csr::from_dense(m, n, &prop::gen_sparse_dense(rng, m, n, 0.3));
            let fixed = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.6));
            let prev = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.7));
            let sums = kl_col_sums(&fixed);
            let mut cur = RowCursor::new();
            let mut full = RowBlock::new(m, k);
            kl_update_rows(&a, &fixed, &prev, &sums, 0, m, &mut cur, &mut full);
            let want = full.to_csr();
            for block in [1usize, 3, 7] {
                let mut scratch = RowBlock::new(m, k);
                let mut assembled = RowBlock::new(m, k);
                for (lo, hi) in crate::coordinator::pool::fixed_chunks(m, block) {
                    kl_update_rows(&a, &fixed, &prev, &sums, lo, hi, &mut cur, &mut scratch);
                    for (slot, &rid) in scratch.row_ids.iter().enumerate() {
                        assembled.push_row(rid as usize, scratch.row_data(slot));
                    }
                }
                let got = assembled.to_csr();
                assert_eq!(got.indptr, want.indptr, "block {block}");
                assert_eq!(got.indices, want.indices, "block {block}");
                assert_eq!(
                    got.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "block {block}"
                );
            }
        });
    }

    #[test]
    fn kl_zeros_are_absorbing_and_dead_topics_stay_dead() {
        // prev has a zero entry and topic 1 of `fixed` is empty: both
        // must stay exactly zero in the update
        let a = Csr::from_dense(2, 2, &[3.0, 1.0, 0.0, 2.0]);
        let fixed = Csr::from_dense(2, 2, &[1.0, 0.0, 2.0, 0.0]);
        let prev = Csr::from_dense(2, 2, &[0.5, 0.0, 0.25, 4.0]);
        let sums = kl_col_sums(&fixed);
        assert_eq!(sums, vec![3.0, 0.0]);
        let mut cur = RowCursor::new();
        let mut out = RowBlock::new(2, 2);
        kl_update_rows(&a, &fixed, &prev, &sums, 0, 2, &mut cur, &mut out);
        let got = out.to_csr();
        assert_eq!(got.get(0, 1), 0.0, "zero prev entry is absorbing");
        assert_eq!(got.get(1, 1), 0.0, "dead topic stays dead");
        assert!(got.get(0, 0) > 0.0);
        assert!(got.get(1, 0) > 0.0);
    }

    #[test]
    fn kl_all_zero_prev_rows_are_skipped_like_inactive_spmm_rows() {
        let a = Csr::from_dense(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let fixed = Csr::from_dense(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let prev = Csr::from_dense(2, 2, &[0.0, 0.0, 1.0, 1.0]);
        let sums = kl_col_sums(&fixed);
        let mut cur = RowCursor::new();
        let mut out = RowBlock::new(2, 2);
        kl_update_rows(&a, &fixed, &prev, &sums, 0, 2, &mut cur, &mut out);
        assert_eq!(out.row_ids, vec![1]);
    }

    #[test]
    fn kl_foldin_solve_fits_a_training_column() {
        // fold a document whose counts are exactly k·U's column 0 mass:
        // the solve must put (almost) all weight on topic 0
        let u = Csr::from_dense(3, 2, &[4.0, 0.1, 2.0, 0.0, 0.0, 3.0]);
        let obj = ObjectiveKind::Kl.implementation();
        let aux = obj.step_aux(&u, 1);
        let (mut x, mut b) = (Vec::new(), Vec::new());
        obj.foldin_solve(&u, &aux, &[(0, 8.0), (1, 4.0)], &mut x, &mut b);
        assert_eq!(x.len(), 2);
        assert!(x.iter().all(|v| v.is_finite() && *v >= 0.0));
        assert!(x[0] > 10.0 * x[1].max(1e-6), "topic 0 dominates: {x:?}");
        // invalid entries are ignored; an empty doc folds to zero
        obj.foldin_solve(
            &u,
            &aux,
            &[(99, 1.0), (0, 0.0), (1, -3.0), (0, f32::NAN)],
            &mut x,
            &mut b,
        );
        assert!(x.iter().all(|&v| v == 0.0), "{x:?}");
    }

    #[test]
    fn foldin_scratch_invariant_survives_pooling_across_objectives() {
        // one pooled (x, b) pair alternating between both solvers must
        // produce bit-identical results to fresh scratch every time —
        // the O(nnz) un-scatter contract of foldin_solve, including the
        // skip paths (out-of-range terms, non-positive counts) that must
        // skip identically in the scatter and un-scatter passes
        let mut rng = Rng::new(0x0b3);
        let u = Csr::from_dense(15, 4, &prop::gen_sparse_dense(&mut rng, 15, 4, 0.5));
        let docs: Vec<Vec<(usize, f32)>> = (0..12)
            .map(|_| {
                (0..rng.range(0, 6))
                    .map(|_| (rng.below(18), rng.normal() as f32))
                    .collect()
            })
            .collect();
        let (mut x, mut b) = (Vec::new(), Vec::new());
        for (d, doc) in docs.iter().enumerate() {
            let kind = if d % 2 == 0 {
                ObjectiveKind::Frobenius
            } else {
                ObjectiveKind::Kl
            };
            let obj = kind.implementation();
            let aux = obj.step_aux(&u, 1);
            obj.foldin_solve(&u, &aux, doc, &mut x, &mut b);
            let (mut xf, mut bf) = (Vec::new(), Vec::new());
            obj.foldin_solve(&u, &aux, doc, &mut xf, &mut bf);
            assert_eq!(
                x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                xf.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "doc {d} {kind:?}"
            );
        }
    }
}
