//! The projected / enforced-sparsity ALS driver (Algorithms 1 and 2, plus
//! §4 column-wise enforcement).
//!
//! One driver serves all three because they differ only in the
//! enforcement applied after each half-step:
//!
//! ```text
//! repeat:
//!   V ← enforce( proj₊( Aᵀ U (UᵀU+εI)⁻¹ ) )        (steps 1–2)
//!   U ← enforce( proj₊( A V (VᵀV+εI)⁻¹ ) )          (steps 3–4)
//! until ‖Uᵢ−Uᵢ₋₁‖/‖Uᵢ‖ < tol or max_iters
//! ```
//!
//! # Blocked, memory-bounded half-steps
//!
//! Each half-step streams over contiguous `block_rows`-row blocks of its
//! output: for every block it computes the candidate rows
//! ([`ops::stream_mul_into`]), multiplies by the precomputed
//! Gram inverse, projects non-negative, enforces sparsity, and appends
//! the survivors straight into the output CSR. One scratch [`RowBlock`]
//! per worker is reused across blocks
//! ([`pool::scoped_map_ranges_with`]), so peak intermediate memory is
//! **O(block_rows · k) per worker** (threads × block_rows × k resident
//! in total) instead of O(active rows · k) — the limited-internal-memory
//! direction of Nguyen & Ho (arXiv:1506.08938) applied to the paper's
//! Algorithm 2. The [`MemoryTracker`] observes the per-block scratch
//! peak (`max_intermediate_nnz`).
//!
//! The data matrix itself reaches the kernels through the [`RowSource`]
//! streaming contract, gathered behind the [`AlsCorpus`] trait: a
//! resident [`TermDocMatrix`] serves borrowed row views, and the on-disk
//! [`CorpusStore`] (`.estdm`) pages row-range shards through per-worker
//! cursors — so corpora that do not fit in RAM factorize with resident
//! `A` bounded by the shards in flight, bit-identical to in-memory.
//!
//! Global top-t enforcement is a **two-pass streaming selection**: pass 1
//! streams the blocks through per-worker O(t) [`topk::TopTSelector`]s
//! (merged afterwards — the cutoff is an order statistic, so worker
//! interleaving cannot change it) to find the cutoff `tau` and the
//! `Exact` tie budget; pass 2 re-streams (compute is traded for memory)
//! and emits. Per-column, threshold, and unenforced
//! half-steps stream in a single pass; per-column enforcement then runs
//! on the assembled CSR, keeping the §4 column-gather cost the paper
//! measures. A half-step whose output fits one block (`block_rows ≥
//! rows`) falls back to the pre-blocking in-memory pipeline
//! ([`unblocked_half_step`]): the candidate exists in full either way,
//! so the row-partitioned parallel kernels and single-sweep enforcement
//! are strictly better there — and bit-identical.
//!
//! # Determinism contract
//!
//! The factors, residuals and errors are **bit-for-bit identical at
//! every `(block_rows, threads)` combination** — both knobs are purely
//! speed/memory knobs (only `MemoryStats::max_intermediate_nnz` observes
//! the block size; nothing observes the thread count):
//!
//! * every candidate row is computed by the same instruction sequence
//!   whatever block it lands in, and blocks concatenate in row order;
//! * the gram reduction accumulates per fixed-width row chunk
//!   ([`crate::sparse::ops::GRAM_CHUNK_ROWS`]) merged in ascending chunk
//!   order, independent of the thread count;
//! * the global cutoff `tau` is an order statistic of the candidate
//!   multiset — independent of block and worker interleaving — and the
//!   `Exact` tie budget is consumed during in-order assembly,
//!   reproducing the serial left-to-right scan;
//! * the dense-factor fast-path decision is made once per half-step
//!   ([`ops::dense_factor`]), never per block.
//!
//! `tests/prop_invariants.rs` and `tests/integration_nmf.rs` pin this
//! for thread counts {1, 2, 4, 7} × block heights {1, 7, 64, auto, ∞}.

use crate::coordinator::pool;
use crate::dense::inverse_spd;
use crate::io::CorpusStore;
use crate::sparse::source::{RowCursor, RowSource};
use crate::sparse::{ops, topk, Csc, Csr, RowBlock, TieMode};
use crate::text::TermDocMatrix;
use crate::util::timer::Timer;
use crate::util::trace;

use super::convergence::rel_residual;
use super::init::{initial_u, initial_v};
use super::memory::MemoryTracker;
use super::objective::{self, ObjectiveKind};
use super::options::{NmfOptions, NmfResult, SparsityMode};

/// The solver's whole view of a corpus: each orientation of `A` readable
/// as contiguous row runs ([`RowSource`]), plus the scalars and metadata
/// the driver needs around the half-steps. Implemented by the resident
/// [`TermDocMatrix`] and by the on-disk [`CorpusStore`], so one driver
/// factorizes both — bit-identically, since the half-step kernels see
/// the same rows either way.
pub trait AlsCorpus: Sync {
    /// Terms-major orientation: rows of `A` (terms × docs), streamed by
    /// the update-U half-step (`A·V`) and the error pass.
    fn a_rows(&self) -> &dyn RowSource;

    /// Docs-major orientation: rows of `Aᵀ` (docs × terms), streamed by
    /// the update-V half-step (`Aᵀ·U`).
    fn a_cols(&self) -> &dyn RowSource;

    /// `‖A‖²_F`, summed in [`Csr::fro_norm_sq`]'s order (the error
    /// history depends on these bits).
    fn norm_a_sq(&self) -> f64;

    /// The [`corpus_digest`](crate::io::corpus_digest) of this corpus.
    /// May cost O(nnz) for resident corpora; the store answers from
    /// metadata. Called only where a snapshot is written or checked.
    fn digest(&self) -> u64;

    fn terms(&self) -> &[String];
    fn doc_labels(&self) -> Option<&[u32]>;
    fn label_names(&self) -> &[String];

    fn n_terms(&self) -> usize {
        self.a_rows().rows()
    }

    fn n_docs(&self) -> usize {
        self.a_cols().rows()
    }

    /// The corpus's latched mid-run read fault, if any — see
    /// [`crate::io::store`]'s failure model. [`RowSource::load`] is
    /// total (unreadable ranges come back as empty rows), so the run
    /// loop checks this after every half-step to avoid training on
    /// partial data. Resident corpora can never fault.
    fn store_error(&self) -> Option<String> {
        None
    }
}

impl AlsCorpus for TermDocMatrix {
    fn a_rows(&self) -> &dyn RowSource {
        &self.a
    }

    fn a_cols(&self) -> &dyn RowSource {
        // the CSC twin is, byte for byte, the CSR of Aᵀ
        &self.a_csc
    }

    fn norm_a_sq(&self) -> f64 {
        self.a.fro_norm_sq()
    }

    fn digest(&self) -> u64 {
        crate::io::corpus_digest(self)
    }

    fn terms(&self) -> &[String] {
        &self.terms
    }

    fn doc_labels(&self) -> Option<&[u32]> {
        self.doc_labels.as_deref()
    }

    fn label_names(&self) -> &[String] {
        &self.label_names
    }
}

impl AlsCorpus for CorpusStore {
    fn a_rows(&self) -> &dyn RowSource {
        self.terms_major()
    }

    fn a_cols(&self) -> &dyn RowSource {
        self.docs_major()
    }

    fn norm_a_sq(&self) -> f64 {
        CorpusStore::norm_a_sq(self)
    }

    fn digest(&self) -> u64 {
        CorpusStore::digest(self)
    }

    fn terms(&self) -> &[String] {
        &self.terms
    }

    fn doc_labels(&self) -> Option<&[u32]> {
        self.doc_labels.as_deref()
    }

    fn label_names(&self) -> &[String] {
        &self.label_names
    }

    fn store_error(&self) -> Option<String> {
        CorpusStore::error(self)
    }
}

/// Enforcement applied to one side's candidate.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Enforce {
    No,
    Global(usize),
    PerColumn(usize),
    Threshold(f32),
}

pub(crate) fn enforcement_for(mode: SparsityMode, is_u: bool) -> Enforce {
    match mode {
        SparsityMode::None => Enforce::No,
        SparsityMode::Global { t_u, t_v } => {
            match if is_u { t_u } else { t_v } {
                Some(t) => Enforce::Global(t),
                None => Enforce::No,
            }
        }
        SparsityMode::PerColumn { t_u_col, t_v_col } => {
            match if is_u { t_u_col } else { t_v_col } {
                Some(t) => Enforce::PerColumn(t),
                None => Enforce::No,
            }
        }
        SparsityMode::Threshold { tau_u, tau_v } => {
            match if is_u { tau_u } else { tau_v } {
                Some(tau) => Enforce::Threshold(tau),
                None => Enforce::No,
            }
        }
    }
}

/// The candidate-row source of one half-step: the streamed left operand
/// (rows of `A` or of `Aᵀ` — one [`RowSource`], whatever the backing
/// storage), the fixed factor, the half-step-wide dense fast-path copy
/// (decided once, see [`ops::dense_factor`], so the result bits cannot
/// vary with `block_rows`), and the optional sequential-ALS deflation
/// term fused into the streaming kernel.
pub(crate) struct CandSource<'a> {
    pub src: &'a dyn RowSource,
    pub factor: &'a Csr,
    pub dense: Option<Vec<f32>>,
    /// `(D, M)`: subtract `D[row]·M` from every candidate row
    /// (Eqs. 4.7/4.8; `None` outside sequential ALS)
    pub defl: Option<(&'a Csr, Vec<f32>)>,
}

impl CandSource<'_> {
    fn out_rows(&self) -> usize {
        self.src.rows()
    }

    fn defl_ref(&self) -> Option<(&Csr, &[f32])> {
        self.defl.as_ref().map(|(d, m)| (*d, m.as_slice()))
    }

    /// Compute candidate rows `lo..hi` into the scratch block (cleared
    /// by the kernels first — scratch and cursor are reused across the
    /// blocks one worker claims).
    fn fill(&self, lo: usize, hi: usize, cur: &mut RowCursor, out: &mut RowBlock) {
        ops::stream_mul_into(
            self.src,
            self.factor,
            self.dense.as_deref(),
            self.defl_ref(),
            lo,
            hi,
            cur,
            out,
        );
    }

    /// Materialize the whole candidate at once, row-partitioned across
    /// `threads` workers — the single-block fast path.
    fn fill_all_par(&self, threads: usize) -> RowBlock {
        ops::stream_mul_par_with(
            self.src,
            self.factor,
            self.dense.as_deref(),
            self.defl_ref(),
            threads,
        )
    }
}

/// The per-row solve applied after the candidate SpMM.
pub(crate) enum Solve {
    /// right-multiply by the dense (k, k) ridged Gram inverse
    Gram(Vec<f32>),
    /// k = 1 scalar fast path (sequential ALS's rank-1 blocks): one
    /// multiply per element, bit-identical at any partitioning
    Scalar(f32),
}

impl Solve {
    fn apply(&self, rb: &mut RowBlock) {
        self.apply_par(rb, 1);
    }

    fn apply_par(&self, rb: &mut RowBlock, threads: usize) {
        match self {
            Solve::Gram(g_inv) => rb.matmul_small_par(g_inv, threads),
            Solve::Scalar(inv) => {
                let inv = *inv;
                pool::scoped_partition_map_mut(threads, &mut rb.data, 1, |_, piece| {
                    for v in piece {
                        *v *= inv;
                    }
                });
            }
        }
    }
}

/// The per-block candidate computation of one half-step — the
/// objective-specific heart of the streamed pipeline. Everything around
/// it (block geometry, worker scheduling, two-pass selection, emission,
/// assembly, the memory tracker) is objective-agnostic and shared.
///
/// The variants are keyed by [`ObjectiveKind`]; they live as an enum
/// rather than a trait object because each needs different borrowed
/// state (the Frobenius solve owns its Gram inverse, the KL update
/// borrows the previous iterate) and the dispatch sits inside the
/// hottest loop.
pub(crate) enum BlockCompute<'a> {
    /// Frobenius least squares: SpMM candidate rows, right-multiply by
    /// the fixed factor's ridged Gram inverse, project non-negative —
    /// the exact pre-seam instruction sequence (bit-identity contract).
    Solve(Solve),
    /// KL divergence: one multiplicative update per row from the
    /// previous iterate ([`objective::kl_update_rows`]); results are
    /// non-negative by construction.
    Kl {
        /// previous iterate of the factor being updated (full row space)
        prev: &'a Csr,
        /// per-topic column sums of the fixed factor
        /// ([`objective::kl_col_sums`] — the KL `step_aux`)
        col_sums: Vec<f32>,
    },
}

/// Which solved + projected candidate values a block emits into the
/// output CSR. The predicates replicate the pre-blocking operators
/// exactly — down to their NaN edge cases — so the streamed pipeline is
/// bit-identical to the full-matrix one.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Keep {
    /// unenforced freeze: every stored nonzero (`RowBlock::to_csr`)
    All,
    /// threshold mode: `v ≥ tau` and finite. Dropping non-finite values
    /// is deliberate: a candidate solved against a degenerate Gram
    /// inverse can go NaN/∞, and the old in-place `*v < tau` zeroing
    /// silently kept NaN.
    FiniteAtLeast(f32),
    /// global top-t, KeepTies: everything not strictly below `tau` (NaN
    /// included — matching the in-place zeroing pass this replaces)
    AtLeast(f32),
    /// global top-t, Exact: `v ≥ tau`; the `== tau` ties beyond the
    /// budget are dropped during in-order assembly
    AboveOrTie(f32),
}

impl Keep {
    #[inline]
    fn keeps(self, v: f32) -> bool {
        match self {
            Keep::All => v != 0.0,
            Keep::FiniteAtLeast(tau) => v.is_finite() && v >= tau && v != 0.0,
            // `!(v < tau)` spelled out NaN-explicitly
            Keep::AtLeast(tau) => (v >= tau || v.is_nan()) && v != 0.0,
            Keep::AboveOrTie(tau) => v >= tau,
        }
    }

    /// Encode as the worker-plane `(keep_tag, tau)` pair (see
    /// [`crate::io::wire::PassReq::Emit`]). `tau` for [`Keep::All`] is
    /// NaN — there is no cutoff, and the bits round-trip exactly.
    pub(crate) fn to_wire(self) -> (u8, f32) {
        match self {
            Keep::All => (0, f32::NAN),
            Keep::FiniteAtLeast(tau) => (1, tau),
            Keep::AtLeast(tau) => (2, tau),
            Keep::AboveOrTie(tau) => (3, tau),
        }
    }

    /// Decode the worker-plane pair; `None` for an unknown tag (the
    /// frame decoder already rejects those, this is the worker's own
    /// defense-in-depth).
    pub(crate) fn from_wire(tag: u8, tau: f32) -> Option<Keep> {
        match tag {
            0 => Some(Keep::All),
            1 => Some(Keep::FiniteAtLeast(tau)),
            2 => Some(Keep::AtLeast(tau)),
            3 => Some(Keep::AboveOrTie(tau)),
            _ => None,
        }
    }
}

/// One block's emitted output: the surviving nonzeros in CSR-fragment
/// form, plus the candidate scratch size the block materialized (the
/// bounded Fig. 6 intermediate).
pub(crate) struct BlockEmit {
    /// surviving nonzeros per output row of the block
    pub(crate) row_nnz: Vec<u32>,
    pub(crate) indices: Vec<u32>,
    pub(crate) values: Vec<f32>,
    pub(crate) scratch_len: usize,
}

impl BlockEmit {
    /// Move into the worker-plane fragment form.
    pub(crate) fn into_wire(self) -> crate::io::wire::WireEmit {
        crate::io::wire::WireEmit {
            row_nnz: self.row_nnz,
            indices: self.indices,
            values: self.values,
            scratch_len: self.scratch_len as u64,
        }
    }

    /// Move a received worker-plane fragment back into assembly form.
    pub(crate) fn from_wire(w: crate::io::wire::WireEmit) -> Self {
        BlockEmit {
            row_nnz: w.row_nnz,
            indices: w.indices,
            values: w.values,
            scratch_len: w.scratch_len as usize,
        }
    }
}

/// Everything one streamed half-step needs: the candidate source, the
/// per-block objective computation, and the block/worker geometry.
pub(crate) struct StreamCtx<'a> {
    src: CandSource<'a>,
    compute: BlockCompute<'a>,
    blocks: Vec<(usize, usize)>,
    workers: usize,
    rows: usize,
    k: usize,
}

impl<'a> StreamCtx<'a> {
    /// A Frobenius context (the historical constructor — every
    /// least-squares call site, including the sequential solver and the
    /// worker plane, builds through here unchanged).
    pub(crate) fn new(
        src: CandSource<'a>,
        solve: Solve,
        k: usize,
        threads: usize,
        block_rows: usize,
    ) -> Self {
        StreamCtx::with_compute(src, BlockCompute::Solve(solve), k, threads, block_rows)
    }

    /// A context with an explicit per-block computation — the
    /// objective seam's entry point.
    pub(crate) fn with_compute(
        src: CandSource<'a>,
        compute: BlockCompute<'a>,
        k: usize,
        threads: usize,
        block_rows: usize,
    ) -> Self {
        let rows = src.out_rows();
        StreamCtx {
            compute,
            blocks: pool::fixed_chunks(rows, block_rows),
            // below the per-worker floor, spawn overhead beats the work;
            // the clamp changes nothing but speed
            workers: pool::effective_workers(rows.saturating_mul(k), threads),
            rows,
            k,
            src,
        }
    }

    /// One block of the objective's candidate rows into the worker's
    /// scratch: the single place both streaming passes compute.
    fn compute_block(&self, lo: usize, hi: usize, cur: &mut RowCursor, scratch: &mut RowBlock) {
        match &self.compute {
            BlockCompute::Solve(solve) => {
                self.src.fill(lo, hi, cur, scratch);
                solve.apply(scratch);
                scratch.project_nonneg();
            }
            BlockCompute::Kl { prev, col_sums } => {
                objective::kl_update_rows(
                    self.src.src,
                    self.src.factor,
                    prev,
                    col_sums,
                    lo,
                    hi,
                    cur,
                    scratch,
                );
            }
        }
    }

    /// [`StreamCtx::new`] with the usual ALS solve: the ridged inverse
    /// of the other factor's Gram matrix.
    fn with_gram(
        src: CandSource<'a>,
        gram_other: &[f32],
        k: usize,
        threads: usize,
        block_rows: usize,
    ) -> Self {
        StreamCtx::new(src, Solve::Gram(inverse_spd(gram_other, k)), k, threads, block_rows)
    }

    /// Number of fixed-geometry blocks this half-step streams over. The
    /// distributed coordinator partitions *blocks* (never raw rows)
    /// across workers so every participant agrees on the block list
    /// [`pool::fixed_chunks`] produces.
    pub(crate) fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Row bounds `[lo, hi)` of block `i` — the coordinator validates
    /// received fragments against this before trusting their shape.
    pub(crate) fn block_bounds(&self, i: usize) -> (usize, usize) {
        self.blocks[i]
    }

    /// Output column count (the factorization rank).
    pub(crate) fn k(&self) -> usize {
        self.k
    }

    /// Run `per_block` over every solved + projected candidate block.
    /// Blocks are claimed dynamically across the workers, each worker
    /// reusing one scratch RowBlock and one streaming cursor; results
    /// come back in block order.
    fn map_blocks<R: Send>(
        &self,
        per_block: impl Fn(&RowBlock, usize, usize) -> R + Sync,
    ) -> Vec<R> {
        self.map_blocks_in(&self.blocks, per_block)
    }

    /// [`Self::map_blocks`] over an explicit block subset (a worker's
    /// assigned span).
    fn map_blocks_in<R: Send>(
        &self,
        blocks: &[(usize, usize)],
        per_block: impl Fn(&RowBlock, usize, usize) -> R + Sync,
    ) -> Vec<R> {
        pool::scoped_map_ranges_with(
            self.workers,
            blocks,
            || (RowBlock::new(self.rows, self.k), RowCursor::new()),
            |(scratch, cur), lo, hi| {
                self.compute_block(lo, hi, cur, scratch);
                per_block(scratch, lo, hi)
            },
        )
    }

    /// Pass 1 of global enforcement: stream every block, folding each
    /// worker's solved + projected candidate values into that worker's
    /// *own* O(t) selector — pass-1 memory is one selector per worker,
    /// never one per block. Returns the per-block scratch sizes (block
    /// order, for the memory tracker) and the ≤ workers selectors
    /// (worker order is scheduling-dependent, which is fine: the cutoff
    /// they merge into is an order statistic).
    fn select_pass(&self, t: usize) -> (Vec<usize>, Vec<topk::TopTSelector>) {
        self.select_in(&self.blocks, t)
    }

    /// Select pass restricted to blocks `b_lo..b_hi` of the global block
    /// list, merged to a single selector — the worker-plane unit of
    /// pass-1 work. Scratch sizes come back in block order within the
    /// span; the merged selector is safe to absorb in any order (the
    /// cutoff is an order statistic).
    pub(crate) fn select_span(
        &self,
        b_lo: usize,
        b_hi: usize,
        t: usize,
    ) -> (Vec<usize>, topk::TopTSelector) {
        let (lens, sels) = self.select_in(&self.blocks[b_lo..b_hi], t);
        let mut sel = topk::TopTSelector::new(t);
        for part in sels {
            sel.absorb(part);
        }
        (lens, sel)
    }

    fn select_in(
        &self,
        blocks: &[(usize, usize)],
        t: usize,
    ) -> (Vec<usize>, Vec<topk::TopTSelector>) {
        let (lens, states) = pool::scoped_map_ranges_with_states(
            self.workers,
            blocks,
            || {
                (
                    RowBlock::new(self.rows, self.k),
                    RowCursor::new(),
                    topk::TopTSelector::new(t),
                )
            },
            |state, lo, hi| {
                let (scratch, cur, sel) = state;
                self.compute_block(lo, hi, cur, scratch);
                sel.offer_all(&scratch.data);
                scratch.stored_len()
            },
        );
        (lens, states.into_iter().map(|(_, _, sel)| sel).collect())
    }

    /// Emission pass: stream the blocks once, filter with `keep`, append
    /// straight into the output CSR in block order. `trim` is the
    /// `Exact`-mode global tie budget `(tau, budget)`, consumed during
    /// assembly — which walks blocks, rows and columns in ascending
    /// order — reproducing the serial left-to-right budget scan.
    fn emit(&self, keep: Keep, trim: Option<(f32, usize)>, mem: &mut MemoryTracker) -> Csr {
        let emits = self.map_blocks(|scratch, lo, hi| emit_block(scratch, lo, hi, keep));
        self.assemble(emits, trim, mem)
    }

    /// Emission pass restricted to blocks `b_lo..b_hi` of the global
    /// block list, returning the raw fragments instead of assembling —
    /// the worker-plane unit of pass-2 work. The coordinator concatenates
    /// every span's fragments in global block order and runs
    /// [`Self::assemble`] itself, so the `Exact` tie budget is consumed
    /// by one serial left-to-right scan exactly as in-process.
    pub(crate) fn emit_span(&self, b_lo: usize, b_hi: usize, keep: Keep) -> Vec<BlockEmit> {
        self.map_blocks_in(&self.blocks[b_lo..b_hi], |scratch, lo, hi| {
            emit_block(scratch, lo, hi, keep)
        })
    }

    /// Concatenate the per-block fragments (contiguous, ascending) into
    /// the output CSR, dropping `== tau` ties once the global `Exact`
    /// budget runs out. With `trim == None` the tie test never fires
    /// (`tau` is NaN) and every fragment value is kept verbatim.
    pub(crate) fn assemble(
        &self,
        emits: Vec<BlockEmit>,
        trim: Option<(f32, usize)>,
        mem: &mut MemoryTracker,
    ) -> Csr {
        let total: usize = emits.iter().map(|e| e.values.len()).sum();
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(total);
        let mut values = Vec::with_capacity(total);
        let mut row = 0usize;
        let (tau, mut budget) = trim.unwrap_or((f32::NAN, 0));
        for e in emits {
            mem.observe_intermediate(e.scratch_len);
            let mut off = 0usize;
            for &n in &e.row_nnz {
                for p in off..off + n as usize {
                    let v = e.values[p];
                    if v == tau {
                        if budget == 0 {
                            continue;
                        }
                        budget -= 1;
                    }
                    indices.push(e.indices[p]);
                    values.push(v);
                }
                off += n as usize;
                row += 1;
                indptr[row] = values.len();
            }
        }
        debug_assert_eq!(row, self.rows, "fragments must cover every output row");
        Csr {
            rows: self.rows,
            cols: self.k,
            indptr,
            indices,
            values,
        }
    }
}

/// One block's emission: filter the solved + projected scratch with
/// `keep`, producing a CSR fragment. Shared verbatim by the in-process
/// emission pass and the worker-plane `emit_span`, so a fragment's bits
/// cannot depend on who computed it.
fn emit_block(scratch: &RowBlock, lo: usize, hi: usize, keep: Keep) -> BlockEmit {
    let mut out = BlockEmit {
        row_nnz: vec![0u32; hi - lo],
        indices: Vec::new(),
        values: Vec::new(),
        scratch_len: scratch.stored_len(),
    };
    for (slot, &rid) in scratch.row_ids.iter().enumerate() {
        let mut n = 0u32;
        for (c, &v) in scratch.row_data(slot).iter().enumerate() {
            if keep.keeps(v) {
                out.indices.push(c as u32);
                out.values.push(v);
                n += 1;
            }
        }
        out.row_nnz[rid as usize - lo] = n;
    }
    out
}

/// Stream one half-step over contiguous row blocks: per block, compute
/// the candidate rows, solve against the Gram inverse, project, enforce,
/// and append into the output CSR. Peak intermediate memory is one
/// scratch RowBlock per worker — O(block_rows · k) — and the result is
/// bit-identical to the unblocked pipeline at every `(block_rows,
/// threads)` pair (module docs).
pub(crate) fn stream_half_step(
    ctx: &StreamCtx<'_>,
    enforce: Enforce,
    tie: TieMode,
    threads: usize,
    mem: &mut MemoryTracker,
) -> Csr {
    if ctx.blocks.len() <= 1 && matches!(ctx.compute, BlockCompute::Solve(_)) {
        // the whole output fits one block, so the candidate is
        // materialized in full anyway: the pre-blocking in-memory
        // pipeline is strictly better here (row-partitioned parallel
        // kernels, and global enforcement in a single sweep instead of
        // the two-pass selection). KL has no separate in-memory
        // pipeline and needs none — a single block IS the in-memory
        // shape, and the blocked machinery handles it unchanged.
        return unblocked_half_step(ctx, enforce, tie, threads, mem);
    }
    let emit_traced = |keep: Keep, trim: Option<(f32, usize)>, mem: &mut MemoryTracker| {
        let mut span = trace::span("emit_pass");
        span.field("n_blocks", ctx.blocks.len() as f64);
        let csr = ctx.emit(keep, trim, mem);
        span.field("nnz", csr.nnz() as f64);
        csr
    };
    match enforce {
        Enforce::No => emit_traced(Keep::All, None, mem),
        Enforce::Threshold(tau) => emit_traced(Keep::FiniteAtLeast(tau), None, mem),
        Enforce::PerColumn(t) => {
            // assemble unenforced, then deliberately go through the CSR
            // column gather — the access-pattern cost the paper
            // attributes to column-wise enforcement
            let mut csr = emit_traced(Keep::All, None, mem);
            // the gather needs every candidate column at once, so the
            // unenforced CSR is itself a transient intermediate:
            // per-column mode cannot honor the block_rows bound (the
            // paper's point about column-wise enforcement) and the
            // telemetry must say so
            mem.observe_intermediate(csr.nnz());
            let mut span = trace::span("enforce_percol");
            span.field("cand_nnz", csr.nnz() as f64);
            topk::enforce_top_t_per_column_par(&mut csr, t, tie, threads);
            span.field("nnz", csr.nnz() as f64);
            drop(span);
            csr
        }
        Enforce::Global(t) => {
            // pass 1: stream the blocks through per-worker O(t)
            // selectors to find the cutoff — an order statistic of the
            // candidate multiset, independent of block and worker
            // interleaving
            let mut select_span = trace::span("select_pass");
            select_span.field("n_blocks", ctx.blocks.len() as f64);
            let (scratch_lens, selectors) = ctx.select_pass(t);
            select_span.field("cand_nnz", scratch_lens.iter().sum::<usize>() as f64);
            for len in scratch_lens {
                mem.observe_intermediate(len);
            }
            let mut sel = topk::TopTSelector::new(t);
            for part in selectors {
                sel.absorb(part);
            }
            let cutoff = sel.cutoff();
            if let Some((tau, _)) = cutoff {
                select_span.field("tau", f64::from(tau));
            }
            drop(select_span);
            // pass 2: re-stream (compute traded for memory) and emit
            match cutoff {
                None => emit_traced(Keep::All, None, mem),
                Some((tau, above)) => match tie {
                    TieMode::KeepTies => emit_traced(Keep::AtLeast(tau), None, mem),
                    // above ≤ t-1 (see TopTSelector::cutoff), so the
                    // budget cannot underflow
                    TieMode::Exact => {
                        emit_traced(Keep::AboveOrTie(tau), Some((tau, t - above)), mem)
                    }
                },
            }
        }
    }
}

/// The pre-blocking in-memory pipeline, used when the output fits one
/// block (`block_rows ≥ rows`): materialize the whole candidate with the
/// row-partitioned parallel kernels, solve, project and enforce in place,
/// in a single sweep. Bit-identical to the streamed path — the
/// blocked-vs-unblocked property tests literally pin the two against
/// each other. The memory tracker records the full candidate, which is
/// what actually exists (and still satisfies the `block_rows · k` bound).
fn unblocked_half_step(
    ctx: &StreamCtx<'_>,
    enforce: Enforce,
    tie: TieMode,
    threads: usize,
    mem: &mut MemoryTracker,
) -> Csr {
    let BlockCompute::Solve(solve) = &ctx.compute else {
        unreachable!("the unblocked fast path is Frobenius-only (see stream_half_step)");
    };
    // one "emit_pass" span covers the whole single-block pipeline, so a
    // trace reads uniformly whether or not the run was blocked
    let mut span = trace::span("emit_pass");
    span.field("n_blocks", 1.0);
    let mut cand = ctx.src.fill_all_par(threads);
    mem.observe_intermediate(cand.stored_len());
    span.field("cand_nnz", cand.stored_len() as f64);
    // below the per-worker floor, spawn overhead beats the work; the
    // clamp changes nothing but speed
    let threads = pool::effective_workers(cand.stored_len(), threads);
    solve.apply_par(&mut cand, threads);
    cand.project_nonneg_par(threads);
    let csr = match enforce {
        Enforce::No => cand.to_csr(),
        Enforce::Global(t) => {
            topk::enforce_top_t_rowblock_par(&mut cand, t, tie, threads);
            cand.to_csr()
        }
        Enforce::PerColumn(t) => {
            // via the CSR column gather, as in the streamed path
            let mut csr = cand.to_csr();
            topk::enforce_top_t_per_column_par(&mut csr, t, tie, threads);
            csr
        }
        Enforce::Threshold(tau) => {
            // same predicate as the streamed emission (non-finite
            // candidates are dropped, the satellite bugfix)
            for v in &mut cand.data {
                if !Keep::FiniteAtLeast(tau).keeps(*v) {
                    *v = 0.0;
                }
            }
            cand.to_csr()
        }
    };
    span.field("nnz", csr.nnz() as f64);
    drop(span);
    csr
}

/// Steps 1–2 of Algorithm 2: `V = proj₊(Aᵀ U (UᵀU)⁻¹)`, enforced,
/// streamed over `block_rows`-row blocks.
pub fn half_step_v(
    a_csc: &Csc,
    u: &Csr,
    opts: &NmfOptions,
    mem: &mut MemoryTracker,
) -> Csr {
    half_step_v_src(a_csc, u, opts, mem)
}

/// [`half_step_v`] with `Aᵀ` streamed through any [`RowSource`] (the
/// out-of-core entry point; a [`Csc`] streams as its transpose's rows).
pub fn half_step_v_src(
    a_cols: &dyn RowSource,
    u: &Csr,
    opts: &NmfOptions,
    mem: &mut MemoryTracker,
) -> Csr {
    assert_eq!(a_cols.cols(), u.rows, "Aᵀ·U contraction mismatch");
    let g = ops::gram_par(u, opts.threads);
    let src = CandSource {
        src: a_cols,
        factor: u,
        dense: ops::dense_factor(u),
        defl: None,
    };
    let ctx = StreamCtx::with_gram(src, &g, opts.k, opts.threads, opts.resolved_block_rows());
    stream_half_step(
        &ctx,
        enforcement_for(opts.sparsity, false),
        opts.tie_mode,
        opts.threads,
        mem,
    )
}

/// Steps 3–4 of Algorithm 2: `U = proj₊(A V (VᵀV)⁻¹)`, enforced,
/// streamed over `block_rows`-row blocks.
pub fn half_step_u(
    a: &Csr,
    v: &Csr,
    opts: &NmfOptions,
    mem: &mut MemoryTracker,
) -> Csr {
    half_step_u_src(a, v, opts, mem)
}

/// [`half_step_u`] with `A` streamed through any [`RowSource`].
pub fn half_step_u_src(
    a_rows: &dyn RowSource,
    v: &Csr,
    opts: &NmfOptions,
    mem: &mut MemoryTracker,
) -> Csr {
    assert_eq!(a_rows.cols(), v.rows, "A·V contraction mismatch");
    let g = ops::gram_par(v, opts.threads);
    let src = CandSource {
        src: a_rows,
        factor: v,
        dense: ops::dense_factor(v),
        defl: None,
    };
    let ctx = StreamCtx::with_gram(src, &g, opts.k, opts.threads, opts.resolved_block_rows());
    stream_half_step(
        &ctx,
        enforcement_for(opts.sparsity, true),
        opts.tie_mode,
        opts.threads,
        mem,
    )
}

/// One KL multiplicative half-step: update the factor whose rows stream
/// through `a` (docs-major for V, terms-major for U) from its previous
/// iterate `prev`, with the other factor `fixed`. The update rides the
/// same streamed block machinery — and the same unchanged `topk`
/// enforcement — as Frobenius; only the per-block computation differs
/// ([`BlockCompute::Kl`]).
fn kl_half_step(
    a: &dyn RowSource,
    fixed: &Csr,
    prev: &Csr,
    is_u: bool,
    opts: &NmfOptions,
    mem: &mut MemoryTracker,
) -> Csr {
    assert_eq!(a.cols(), fixed.rows, "KL contraction mismatch");
    assert_eq!(prev.rows, a.rows(), "KL previous-iterate row mismatch");
    let col_sums = objective::kl_col_sums(fixed);
    let src = CandSource {
        src: a,
        factor: fixed,
        dense: None, // the dense fast path belongs to the SpMM fill, unused by KL
        defl: None,
    };
    let ctx = StreamCtx::with_compute(
        src,
        BlockCompute::Kl { prev, col_sums },
        opts.k,
        opts.threads,
        opts.resolved_block_rows(),
    );
    stream_half_step(
        &ctx,
        enforcement_for(opts.sparsity, is_u),
        opts.tie_mode,
        opts.threads,
        mem,
    )
}

/// The half-step engine the iteration loop drives. [`run_loop_with`]
/// owns everything *around* the half-steps — residual tracking, error
/// sampling, checkpoint cadence, store-fault latching — and delegates
/// the two factor updates here, so the distributed coordinator replaces
/// only the compute placement and reuses the loop verbatim (one code
/// path to keep the trajectories bit-identical).
///
/// Each update also receives the previous iterate of the factor being
/// updated (`v_prev` / `u_prev`): multiplicative objectives start from
/// it; least squares re-solves from scratch and ignores it.
pub(crate) trait HalfSteps {
    /// Steps 1–2: the V update given the current U.
    fn v(
        &mut self,
        corpus: &dyn AlsCorpus,
        u: &Csr,
        v_prev: &Csr,
        opts: &NmfOptions,
        mem: &mut MemoryTracker,
    ) -> Csr;

    /// Steps 3–4: the U update given the fresh V.
    fn u(
        &mut self,
        corpus: &dyn AlsCorpus,
        v: &Csr,
        u_prev: &Csr,
        opts: &NmfOptions,
        mem: &mut MemoryTracker,
    ) -> Csr;
}

/// The in-process engine: both half-steps stream on this machine,
/// dispatched on the configured objective.
pub(crate) struct LocalHalfSteps;

impl HalfSteps for LocalHalfSteps {
    fn v(
        &mut self,
        corpus: &dyn AlsCorpus,
        u: &Csr,
        v_prev: &Csr,
        opts: &NmfOptions,
        mem: &mut MemoryTracker,
    ) -> Csr {
        match opts.objective {
            ObjectiveKind::Frobenius => half_step_v_src(corpus.a_cols(), u, opts, mem),
            ObjectiveKind::Kl => kl_half_step(corpus.a_cols(), u, v_prev, false, opts, mem),
        }
    }

    fn u(
        &mut self,
        corpus: &dyn AlsCorpus,
        v: &Csr,
        u_prev: &Csr,
        opts: &NmfOptions,
        mem: &mut MemoryTracker,
    ) -> Csr {
        match opts.objective {
            ObjectiveKind::Frobenius => half_step_u_src(corpus.a_rows(), v, opts, mem),
            ObjectiveKind::Kl => kl_half_step(corpus.a_rows(), v, u_prev, true, opts, mem),
        }
    }
}

/// Run projected / enforced-sparsity ALS on a term-document matrix.
pub fn factorize(tdm: &TermDocMatrix, opts: &NmfOptions) -> NmfResult {
    factorize_corpus(tdm, opts)
}

/// [`factorize`] over any [`AlsCorpus`] — resident or streamed from an
/// on-disk [`CorpusStore`]. Bit-identical either way.
pub fn factorize_corpus(corpus: &dyn AlsCorpus, opts: &NmfOptions) -> NmfResult {
    factorize_from_corpus(
        corpus,
        opts,
        initial_u(corpus.n_terms(), opts.k, opts.init_nnz, opts.seed),
    )
}

/// As [`factorize`] but with an explicit initial guess (used by the
/// backend-agreement tests and by warm starts, see
/// [`crate::nmf::init::warm_start_u`]).
pub fn factorize_from(tdm: &TermDocMatrix, opts: &NmfOptions, u0: Csr) -> NmfResult {
    factorize_from_corpus(tdm, opts, u0)
}

/// [`factorize_from`] over any [`AlsCorpus`].
pub fn factorize_from_corpus(corpus: &dyn AlsCorpus, opts: &NmfOptions, u0: Csr) -> NmfResult {
    factorize_with(corpus, opts, u0, &mut LocalHalfSteps)
}

/// [`factorize_corpus`] driven by an explicit half-step engine (the
/// distributed coordinator's entry point — same initial guess, same
/// loop, different compute placement).
pub(crate) fn factorize_corpus_with(
    corpus: &dyn AlsCorpus,
    opts: &NmfOptions,
    engine: &mut dyn HalfSteps,
) -> NmfResult {
    factorize_with(
        corpus,
        opts,
        initial_u(corpus.n_terms(), opts.k, opts.init_nnz, opts.seed),
        engine,
    )
}

fn factorize_with(
    corpus: &dyn AlsCorpus,
    opts: &NmfOptions,
    u0: Csr,
    engine: &mut dyn HalfSteps,
) -> NmfResult {
    assert_eq!(u0.rows, corpus.n_terms(), "U₀ row count != vocabulary size");
    assert_eq!(u0.cols, opts.k, "U₀ column count != k");
    // least-squares ALS re-solves V from scratch, so V₀ = 0 (and the
    // initial-guess telemetry counts only U₀ — unchanged bits). KL's
    // multiplicative updates cannot leave zero: V₀ is a dense positive
    // random factor under a seed-derived stream (see `init::initial_v`).
    let v0 = match opts.objective {
        ObjectiveKind::Frobenius => Csr::zeros(corpus.n_docs(), opts.k),
        ObjectiveKind::Kl => initial_v(corpus.n_docs(), opts.k, opts.seed),
    };
    let mut mem = MemoryTracker::new();
    mem.observe_pair(u0.nnz(), v0.nnz()); // the initial guess is stored too
    let state = LoopState {
        u: u0,
        v: v0,
        start_iter: 0,
        residuals: Vec::with_capacity(opts.max_iters),
        errors: Vec::new(),
        mem,
        elapsed_base_s: 0.0,
    };
    run_loop_with(corpus, opts, state, engine)
}

/// Continue a checkpointed run. The solver math (k, sparsity, tie mode,
/// tolerance, error tracking) comes from the *snapshot's* recorded
/// options so the continued trajectory is exactly the uninterrupted one;
/// only `max_iters`, `threads` and the checkpoint knobs are taken from
/// `opts` (a resumed run may extend the iteration budget, use a
/// different machine, and keep checkpointing). Refuses with a typed
/// [`SnapshotError`](crate::io::SnapshotError) when the corpus digest or
/// the requested `k` do not match the snapshot.
pub fn resume(
    tdm: &TermDocMatrix,
    opts: &NmfOptions,
    snap: &crate::io::Snapshot,
) -> crate::Result<NmfResult> {
    resume_corpus(tdm, opts, snap)
}

/// [`resume`] over any [`AlsCorpus`]. The digest refusal works for the
/// on-disk store too — its metadata carries the same
/// [`corpus_digest`](crate::io::corpus_digest) the snapshot pinned.
pub fn resume_corpus(
    corpus: &dyn AlsCorpus,
    opts: &NmfOptions,
    snap: &crate::io::Snapshot,
) -> crate::Result<NmfResult> {
    snap.check_k(opts.k)?;
    snap.check_objective(opts.objective)?;
    snap.check_digest(corpus.digest(), corpus.n_terms(), corpus.n_docs())?;
    snap.check_resumable()?;
    let effective = resume_options(opts, snap);

    let p = &snap.progress;
    let state = LoopState {
        u: snap.u.clone(),
        v: snap.v.clone(),
        start_iter: p.iterations,
        residuals: p.residuals.clone(),
        errors: p.errors.clone(),
        mem: MemoryTracker::from_stats(p.memory),
        elapsed_base_s: sanitize_elapsed_base(p.elapsed_s),
    };
    // already converged (or the budget is already spent): the stored
    // result IS the final result — do not run an extra iteration the
    // uninterrupted run would not have run
    let done_by_tol = effective.tol > 0.0
        && p.residuals.last().is_some_and(|&r| r < effective.tol);
    if done_by_tol || p.iterations >= effective.max_iters {
        let memory = state.mem.finish(state.u.nnz(), state.v.nnz());
        return Ok(NmfResult {
            u: state.u,
            v: state.v,
            iterations: state.start_iter,
            residuals: state.residuals,
            errors: state.errors,
            memory,
            elapsed_s: state.elapsed_base_s,
        });
    }
    Ok(run_loop(corpus, &effective, state))
}

/// The options a resumed run actually trains with: the snapshot's
/// recorded solver math, with only the iteration budget, the
/// machine-local knobs (`threads`, `block_rows` — neither is persisted)
/// and the checkpoint knobs taken from the caller. Public so a
/// `--save-model` after `--resume` records the options the run really
/// used instead of the CLI defaults.
pub fn resume_options(opts: &NmfOptions, snap: &crate::io::Snapshot) -> NmfOptions {
    let mut effective = snap.options.clone();
    effective.max_iters = opts.max_iters;
    effective.threads = opts.threads;
    effective.block_rows = opts.block_rows;
    effective.checkpoint_every = opts.checkpoint_every;
    effective.checkpoint_path = opts.checkpoint_path.clone();
    effective
}

/// Clamp a wall-time base spliced in from a snapshot file.
/// `Progress.elapsed_s` is raw f64 bits read from disk, measured by an
/// earlier process — a corrupt or hand-edited snapshot could splice a
/// negative or non-finite base into the accumulation. Within a segment
/// elapsed time is a monotonic [`Timer`] delta added to this base, so
/// clamping the spliced value keeps the accumulated wall time finite
/// and monotone non-decreasing across checkpoint/resume segments.
fn sanitize_elapsed_base(s: f64) -> f64 {
    if s.is_finite() && s > 0.0 {
        s
    } else {
        0.0
    }
}

/// Mid-run solver state — everything an iteration boundary carries.
struct LoopState {
    u: Csr,
    v: Csr,
    /// completed iterations before this (re)start
    start_iter: usize,
    residuals: Vec<f64>,
    errors: Vec<f64>,
    mem: MemoryTracker,
    /// wall time accumulated by previous (checkpointed) segments
    elapsed_base_s: f64,
}

/// Write one checkpoint snapshot of the loop state at an iteration
/// boundary. A failing checkpoint disk must not abort hours of training:
/// errors warn and the run continues.
#[allow(clippy::too_many_arguments)]
fn write_checkpoint(
    corpus: &dyn AlsCorpus,
    opts: &NmfOptions,
    u: &Csr,
    v: &Csr,
    iterations: usize,
    residuals: &[f64],
    errors: &[f64],
    memory: super::memory::MemoryStats,
    elapsed_s: f64,
    digest: u64,
) {
    let Some(path) = &opts.checkpoint_path else {
        return;
    };
    let snap = crate::io::Snapshot {
        options: opts.clone(),
        u: u.clone(),
        v: v.clone(),
        terms: corpus.terms().to_vec(),
        doc_labels: corpus.doc_labels().map(|l| l.to_vec()),
        label_names: corpus.label_names().to_vec(),
        corpus_digest: digest,
        progress: crate::io::Progress {
            iterations,
            residuals: residuals.to_vec(),
            errors: errors.to_vec(),
            memory,
            elapsed_s,
        },
    };
    if let Err(e) = snap.save(path) {
        crate::log_warn!("als", "checkpoint at iteration {iterations} failed: {e}");
    } else {
        crate::log_debug!(
            "als",
            "checkpointed iteration {iterations} to {}",
            path.display()
        );
    }
}

fn run_loop(corpus: &dyn AlsCorpus, opts: &NmfOptions, state: LoopState) -> NmfResult {
    run_loop_with(corpus, opts, state, &mut LocalHalfSteps)
}

fn run_loop_with(
    corpus: &dyn AlsCorpus,
    opts: &NmfOptions,
    state: LoopState,
    engine: &mut dyn HalfSteps,
) -> NmfResult {
    let timer = Timer::start();
    let norm_a_sq = corpus.norm_a_sq();
    // the corpus is immutable for the whole run, so hash it once up
    // front instead of once per checkpoint (O(nnz) for resident corpora;
    // the store answers from metadata)
    let checkpoint_digest = (opts.checkpoint_every > 0 && opts.checkpoint_path.is_some())
        .then(|| corpus.digest());

    let LoopState {
        mut u,
        mut v,
        start_iter,
        mut residuals,
        mut errors,
        mut mem,
        elapsed_base_s,
    } = state;
    let mut iterations = start_iter;
    // a latched corpus-store read fault: the half-step that hit it was
    // computed on partial data, so its output is discarded and the loop
    // stops with the last consistent state (see io::store's failure
    // model — load() serves empty rows instead of panicking)
    let mut store_fault: Option<String> = None;

    trace::progress::begin(start_iter, opts.max_iters);
    for it in start_iter..opts.max_iters {
        let mut iter_span = trace::span("iteration");
        iter_span.field("iter", (it + 1) as f64);
        let v_new = {
            let mut span = trace::span("half_step_v");
            let v_new = engine.v(corpus, &u, &v, opts, &mut mem);
            span.field("nnz", v_new.nnz() as f64);
            v_new
        };
        if let Some(fault) = corpus.store_error() {
            store_fault = Some(fault);
            break;
        }
        v = v_new;
        mem.observe_pair(u.nnz(), v.nnz());
        let u_new = {
            let mut span = trace::span("half_step_u");
            let u_new = engine.u(corpus, &v, &u, opts, &mut mem);
            span.field("nnz", u_new.nnz() as f64);
            u_new
        };
        if let Some(fault) = corpus.store_error() {
            store_fault = Some(fault);
            break;
        }
        mem.observe_pair(u_new.nnz(), v.nnz());

        let r = rel_residual(&u_new, &u);
        residuals.push(r);
        u = u_new;
        iterations = it + 1;
        iter_span.field("residual", r);

        if opts.track_error {
            // the objective's own fit statistic (relative Frobenius
            // error, or mean per-token KL divergence), streamed in
            // block_rows-row runs so the error pass honors the same
            // resident-corpus bound as the half-steps
            let e = opts.objective.implementation().error_source(
                corpus.a_rows(),
                &u,
                &v,
                norm_a_sq,
                opts.resolved_block_rows(),
            );
            if let Some(fault) = corpus.store_error() {
                // the factors are consistent (both half-steps completed)
                // but this error sample saw partial data — drop it
                store_fault = Some(fault);
                break;
            }
            errors.push(e);
            iter_span.field("objective", e);
        }
        trace::progress::update(iterations, r, errors.last().copied());
        let stopping = opts.tol > 0.0 && r < opts.tol;
        // checkpoint cadence counts absolute iterations so a resumed run
        // checkpoints at the same boundaries the uninterrupted one did;
        // nothing is written on the stopping iteration (the final model
        // is the caller's --save-model, not a checkpoint)
        if !stopping && opts.checkpoint_every > 0 && iterations % opts.checkpoint_every == 0 {
            let mut span = trace::span("checkpoint");
            span.field("iter", iterations as f64);
            write_checkpoint(
                corpus,
                opts,
                &u,
                &v,
                iterations,
                &residuals,
                &errors,
                *mem.peek(),
                elapsed_base_s + timer.elapsed_s(),
                checkpoint_digest.unwrap_or_default(),
            );
        }
        if stopping {
            break;
        }
    }
    trace::progress::finish();

    if let Some(fault) = &store_fault {
        crate::log_warn!(
            "als",
            "corpus store fault after {iterations} completed iterations: {fault} — \
             saving last-good state and stopping"
        );
        // force a checkpoint of the surviving consistent state even off
        // the regular cadence: the completed iterations are hours of
        // compute, and the fault is exactly when they must not be lost
        if opts.checkpoint_every > 0 {
            write_checkpoint(
                corpus,
                opts,
                &u,
                &v,
                iterations,
                &residuals,
                &errors,
                *mem.peek(),
                elapsed_base_s + timer.elapsed_s(),
                checkpoint_digest.unwrap_or_default(),
            );
        }
        // the fault stays latched on the corpus: drivers check
        // store_error() after this returns and surface a typed error
        // instead of reporting the partial result as clean
    }

    let memory = mem.finish(u.nnz(), v.nnz());
    NmfResult {
        u,
        v,
        iterations,
        residuals,
        errors,
        memory,
        elapsed_s: elapsed_base_s + timer.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_tdm, reuters_sim, Scale};
    use crate::sparse::ops::spmm;
    use crate::text::TdmBuilder;

    fn tiny_tdm() -> TermDocMatrix {
        // deterministic 2-cluster corpus
        let mut b = TdmBuilder::new();
        for _ in 0..6 {
            b.add_text("coffee crop quotas coffee brazil crop", Some("econ"));
            b.add_text("electrons atoms hydrogen electrons atoms", Some("sci"));
        }
        b.freeze()
    }

    #[test]
    fn projected_als_reduces_error() {
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(20).with_seed(1);
        let r = factorize(&tdm, &opts);
        assert_eq!(r.iterations, 20);
        // the tiny corpus is exactly rank 2, so the fit is near-exact from
        // iteration 1 and the history just jitters at float-noise level
        assert!(r.final_error() < 0.01, "error {}", r.final_error());
        assert!(r.errors[0] >= r.final_error() - 1e-3);
        // factors are nonnegative
        assert!(r.u.values.iter().all(|&x| x >= 0.0));
        assert!(r.v.values.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn rank2_structure_recovered_exactly_for_rank2_data() {
        // A = U* V*ᵀ with clean rank-2 structure → error should reach ~0
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(50).with_seed(3);
        let r = factorize(&tdm, &opts);
        assert!(
            r.final_error() < 0.35,
            "final error {} too high",
            r.final_error()
        );
        // reconstruction actually close: ‖A−UVᵀ‖ via dense check
        let uvt = spmm(&r.u, &r.v.transpose());
        let rel = tdm.a.fro_diff(&uvt) / tdm.a.fro_norm();
        assert!((rel - r.final_error()).abs() < 1e-3);
    }

    #[test]
    fn enforced_sparsity_caps_nnz() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 11);
        let mut opts = NmfOptions::new(5)
            .with_iters(8)
            .with_sparsity(SparsityMode::both(55, 120))
            .with_seed(5);
        opts.tie_mode = crate::sparse::TieMode::Exact; // strict caps
        let r = factorize(&tdm, &opts);
        assert!(r.u.nnz() <= 55, "u nnz {}", r.u.nnz());
        assert!(r.v.nnz() <= 120, "v nnz {}", r.v.nnz());
        r.u.validate().unwrap();
        r.v.validate().unwrap();
    }

    #[test]
    fn u_only_enforcement_leaves_v_free() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 13);
        let opts = NmfOptions::new(5)
            .with_iters(6)
            .with_sparsity(SparsityMode::u_only(50))
            .with_seed(7);
        let r = factorize(&tdm, &opts);
        assert!(r.u.nnz() <= 50);
        // V is unenforced: it keeps every doc reachable from U's support,
        // far above U's budget (it need not be fully dense on a tiny corpus)
        assert!(
            r.v.nnz() > r.u.nnz() * 2,
            "v should stay much denser than u, nnz {}",
            r.v.nnz()
        );
    }

    #[test]
    fn per_column_enforcement_bounds_columns() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 17);
        let mut opts = NmfOptions::new(5)
            .with_iters(6)
            .with_sparsity(SparsityMode::PerColumn {
                t_u_col: Some(10),
                t_v_col: Some(30),
            })
            .with_seed(9);
        // Exact mode for a strict bound; KeepTies may exceed it when two
        // documents produce identical weights (observed on tiny corpora)
        opts.tie_mode = crate::sparse::TieMode::Exact;
        let r = factorize(&tdm, &opts);
        for &c in &r.u.col_nnz() {
            assert!(c <= 10);
        }
        for &c in &r.v.col_nnz() {
            assert!(c <= 30);
        }
        // per-column budget → even distribution by construction
        let counts = r.u.col_nnz();
        assert!(counts.iter().all(|&c| c > 0), "some topic starved: {counts:?}");
    }

    #[test]
    fn memory_tracking_reports_peak() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 19);
        let opts = NmfOptions::new(5)
            .with_iters(5)
            .with_sparsity(SparsityMode::both(50, 50))
            .with_init_nnz(60)
            .with_seed(11);
        let r = factorize(&tdm, &opts);
        assert!(r.memory.max_combined_nnz >= r.memory.final_u_nnz + r.memory.final_v_nnz);
        assert!(r.memory.max_intermediate_nnz > 0);
        // sparse init + enforcement ⇒ far below dense storage
        let dense_total = tdm.n_terms() * 5 + tdm.n_docs() * 5;
        assert!(
            r.memory.max_combined_nnz < dense_total,
            "peak {} vs dense {}",
            r.memory.max_combined_nnz,
            dense_total
        );
    }

    #[test]
    fn tol_stops_early() {
        let tdm = tiny_tdm();
        // projected ALS can cycle near the optimum, so use a tolerance
        // comfortably above float-noise level
        let opts = NmfOptions::new(2).with_iters(500).with_tol(1e-4).with_seed(13);
        let r = factorize(&tdm, &opts);
        assert!(r.iterations < 500, "never converged");
        assert!(r.final_residual() < 1e-4);
    }

    #[test]
    fn threshold_enforcement_drops_nonfinite_candidates() {
        // A degenerate candidate (NaN from a broken Gram inverse, or a
        // NaN slipped into the corpus) must not survive thresholding —
        // the old `*v < tau` comparison silently kept NaN. The NaN in
        // A's row 0 contaminates that whole candidate row through the
        // SpMM accumulator, so only row 1's value can survive.
        let a = Csr::from_dense(2, 2, &[f32::NAN, 1.0, 0.0, 2.0]);
        let v = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let opts = NmfOptions::new(2).with_sparsity(SparsityMode::Threshold {
            tau_u: Some(0.5),
            tau_v: None,
        });
        // both pipelines: single-block in-memory and streamed (1-row
        // blocks) must agree on dropping the non-finite values
        for block_rows in [usize::MAX, 1] {
            let opts = opts.clone().with_block_rows(block_rows);
            let mut mem = MemoryTracker::new();
            // candidate ≈ A·V·(VᵀV+εI)⁻¹ ≈ A with row 0 fully NaN
            let u = half_step_u(&a, &v, &opts, &mut mem);
            assert!(
                u.values.iter().all(|x| x.is_finite()),
                "block_rows {block_rows}: {:?}",
                u.values
            );
            assert_eq!(u.nnz(), 1, "only row 1's finite 2.0 survives");
            assert!(u.get(1, 1) > 1.5, "block_rows {block_rows}");
        }
    }

    #[test]
    fn keep_predicates_replicate_the_in_place_operators() {
        // the emission predicates are the single source of truth for
        // what each enforcement mode keeps — pin their edge cases
        let nan = f32::NAN;
        assert!(Keep::All.keeps(0.5) && Keep::All.keeps(nan));
        assert!(!Keep::All.keeps(0.0) && !Keep::All.keeps(-0.0));
        // threshold drops non-finite (the bugfix)
        assert!(Keep::FiniteAtLeast(0.5).keeps(0.5));
        assert!(!Keep::FiniteAtLeast(0.5).keeps(0.4));
        assert!(!Keep::FiniteAtLeast(0.5).keeps(nan));
        assert!(!Keep::FiniteAtLeast(0.5).keeps(f32::INFINITY));
        // global KeepTies replicates `!(v < tau)` zeroing, NaN and all
        assert!(Keep::AtLeast(2.0).keeps(2.0) && Keep::AtLeast(2.0).keeps(nan));
        assert!(!Keep::AtLeast(2.0).keeps(1.0) && !Keep::AtLeast(2.0).keeps(0.0));
        // global Exact drops NaN like the old budget scan did
        assert!(Keep::AboveOrTie(2.0).keeps(2.0) && Keep::AboveOrTie(2.0).keeps(3.0));
        assert!(!Keep::AboveOrTie(2.0).keeps(1.0) && !Keep::AboveOrTie(2.0).keeps(nan));
    }

    #[test]
    fn block_rows_change_memory_but_not_the_factors() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 41);
        let k = 4;
        for (mode, tie) in [
            (SparsityMode::None, crate::sparse::TieMode::KeepTies),
            (SparsityMode::both(60, 120), crate::sparse::TieMode::Exact),
            (SparsityMode::both(60, 120), crate::sparse::TieMode::KeepTies),
        ] {
            let mut base = NmfOptions::new(k)
                .with_iters(4)
                .with_seed(43)
                .with_sparsity(mode)
                .with_threads(2)
                .with_block_rows(usize::MAX); // one block = unblocked shape
            base.tie_mode = tie;
            let unblocked = factorize(&tdm, &base);
            for block_rows in [1usize, 7, 64] {
                let r = factorize(&tdm, &base.clone().with_block_rows(block_rows));
                assert_eq!(r.u, unblocked.u, "block_rows {block_rows}");
                assert_eq!(r.v, unblocked.v, "block_rows {block_rows}");
                assert_eq!(r.residuals, unblocked.residuals, "block_rows {block_rows}");
                assert_eq!(r.errors, unblocked.errors, "block_rows {block_rows}");
                // the bounded-scratch guarantee of the streamed pipeline
                assert!(
                    r.memory.max_intermediate_nnz <= block_rows.saturating_mul(k),
                    "block_rows {block_rows}: intermediate {} > {}",
                    r.memory.max_intermediate_nnz,
                    block_rows * k
                );
                assert_eq!(
                    r.memory.max_combined_nnz, unblocked.memory.max_combined_nnz,
                    "combined peak counts stored factors, not scratch"
                );
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_the_result() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 23);
        let mut base = NmfOptions::new(3)
            .with_iters(6)
            .with_seed(29)
            .with_sparsity(SparsityMode::both(40, 80))
            .with_threads(1);
        base.tie_mode = crate::sparse::TieMode::Exact;
        let serial = factorize(&tdm, &base);
        for threads in [2usize, 4, 7] {
            let r = factorize(&tdm, &base.clone().with_threads(threads));
            assert_eq!(r.u, serial.u, "threads {threads}");
            assert_eq!(r.v, serial.v, "threads {threads}");
            assert_eq!(r.residuals, serial.residuals, "threads {threads}");
            assert_eq!(r.memory, serial.memory, "threads {threads}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(5).with_seed(99);
        let r1 = factorize(&tdm, &opts);
        let r2 = factorize(&tdm, &opts);
        assert_eq!(r1.u, r2.u);
        assert_eq!(r1.v, r2.v);
        assert_eq!(r1.residuals, r2.residuals);
    }

    #[test]
    #[should_panic(expected = "row count")]
    fn mismatched_initial_guess_panics() {
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2);
        let bad = Csr::zeros(3, 2);
        factorize_from(&tdm, &opts, bad);
    }

    fn assert_same_result(a: &NmfResult, b: &NmfResult) {
        assert_eq!(a.u, b.u);
        assert_eq!(a.v, b.v);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.residuals, b.residuals);
        assert_eq!(a.errors, b.errors);
        assert_eq!(a.memory, b.memory);
    }

    #[test]
    fn resume_from_checkpoint_matches_uninterrupted_run() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 31);
        let ck = std::env::temp_dir().join("esnmf_als_resume_test.esnmf");
        let _ = std::fs::remove_file(&ck);

        let mut opts = NmfOptions::new(3)
            .with_iters(9)
            .with_seed(17)
            .with_sparsity(SparsityMode::both(40, 90));
        opts.tie_mode = crate::sparse::TieMode::Exact;
        let uninterrupted = factorize(&tdm, &opts);

        // same run, checkpointing every 4 iterations, "crashing" at 8
        let ck_opts = opts.clone().with_iters(8).with_checkpoint(&ck, 4);
        let _partial = factorize(&tdm, &ck_opts);
        let snap = crate::io::Snapshot::load(&ck).unwrap();
        assert_eq!(snap.progress.iterations, 8);

        // resume to the full budget: bit-identical to never crashing
        let resumed = super::resume(&tdm, &opts, &snap).unwrap();
        assert_same_result(&resumed, &uninterrupted);
        std::fs::remove_file(&ck).unwrap();
    }

    #[test]
    fn resumed_wall_time_accumulates_monotonically_across_segments() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 53);
        let ck = std::env::temp_dir().join("esnmf_als_walltime_test.esnmf");
        let _ = std::fs::remove_file(&ck);
        let mut opts = NmfOptions::new(3)
            .with_iters(4)
            .with_seed(11)
            .with_checkpoint(&ck, 2);
        opts.tie_mode = crate::sparse::TieMode::Exact;
        let seg1 = factorize(&tdm, &opts);
        assert!(seg1.elapsed_s.is_finite() && seg1.elapsed_s >= 0.0);
        let snap = crate::io::Snapshot::load(&ck).unwrap();
        assert_eq!(snap.progress.iterations, 4);
        let e1 = snap.progress.elapsed_s;
        assert!(e1.is_finite() && e1 >= 0.0, "{e1}");
        // resume across a (simulated) process boundary with a larger
        // budget: the new segment's monotonic clock delta is added to the
        // spliced base, never rebased to zero
        let more = opts.clone().with_iters(8);
        let resumed = super::resume(&tdm, &more, &snap).unwrap();
        assert!(
            resumed.elapsed_s.is_finite() && resumed.elapsed_s >= e1,
            "accumulated wall time went backwards: {} < {e1}",
            resumed.elapsed_s
        );
        // the resumed segment kept checkpointing; each checkpoint's
        // accumulated wall time stays within [e1, final]
        let snap2 = crate::io::Snapshot::load(&ck).unwrap();
        assert_eq!(snap2.progress.iterations, 8);
        assert!(snap2.progress.elapsed_s >= e1, "{}", snap2.progress.elapsed_s);
        assert!(
            snap2.progress.elapsed_s <= resumed.elapsed_s,
            "checkpoint wall time {} beyond the final {}",
            snap2.progress.elapsed_s,
            resumed.elapsed_s
        );
        std::fs::remove_file(&ck).unwrap();
    }

    #[test]
    fn poisoned_snapshot_elapsed_is_clamped_not_propagated() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -3.0] {
            assert_eq!(super::sanitize_elapsed_base(bad), 0.0, "{bad}");
        }
        assert_eq!(super::sanitize_elapsed_base(2.5), 2.5);
        // end-to-end: a hand-edited snapshot carrying a poisoned elapsed
        // resumes with finite, non-negative accumulated wall time
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(3).with_seed(3);
        let r = factorize(&tdm, &opts);
        let snap = crate::io::Snapshot::new(
            opts.clone(),
            r.u,
            r.v,
            &tdm,
            crate::io::Progress {
                iterations: r.iterations,
                residuals: r.residuals,
                errors: r.errors,
                memory: r.memory,
                elapsed_s: f64::NAN,
            },
        );
        let more = opts.clone().with_iters(6);
        let resumed = super::resume(&tdm, &more, &snap).unwrap();
        assert!(
            resumed.elapsed_s.is_finite() && resumed.elapsed_s >= 0.0,
            "{}",
            resumed.elapsed_s
        );
    }

    #[test]
    fn store_fault_mid_run_stops_cleanly_with_last_good_state() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 41);
        let path = std::env::temp_dir().join("esnmf_als_fault_test.estdm");
        let _ = std::fs::remove_file(&path);
        crate::io::CorpusStore::write(&path, &tdm, 2).unwrap();
        let store = crate::io::CorpusStore::open(&path).unwrap();
        // corrupt a docs-major shard AFTER open (mid-run bit rot): the
        // very first v half-step streams it and latches the fault — this
        // used to be a panic that killed the whole process
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let opts = NmfOptions::new(2).with_iters(3).with_seed(7);
        let r = factorize_corpus(&store, &opts);
        // the faulted half-step's output is discarded: no iteration
        // completed, the state returned is the consistent initial one
        assert_eq!(r.iterations, 0, "faulted half-step must not count");
        assert!(r.residuals.is_empty());
        // the fault stays latched for the driver to surface as an error
        let msg = store.error().expect("fault latched");
        assert!(msg.contains("checksum mismatch"), "{msg}");
        // a resident corpus can never fault
        assert!(AlsCorpus::store_error(&tdm).is_none());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn resume_refuses_wrong_corpus_and_wrong_k() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 37);
        let other = generate_tdm(&reuters_sim(Scale::Tiny), 38);
        let opts = NmfOptions::new(3).with_iters(4).with_seed(5);
        let r = factorize(&tdm, &opts);
        let snap = crate::io::Snapshot::new(
            opts.clone(),
            r.u,
            r.v,
            &tdm,
            crate::io::Progress {
                iterations: r.iterations,
                residuals: r.residuals,
                errors: r.errors,
                memory: r.memory,
                elapsed_s: 0.0,
            },
        );
        // wrong corpus → digest refusal
        let err = super::resume(&other, &opts, &snap).unwrap_err();
        assert!(format!("{err:#}").contains("digest"), "{err:#}");
        // wrong k → typed refusal
        let bad_k = NmfOptions::new(7).with_iters(8);
        let err = super::resume(&tdm, &bad_k, &snap).unwrap_err();
        assert!(format!("{err:#}").contains("k="), "{err:#}");
    }

    #[test]
    fn resume_past_budget_or_tolerance_returns_the_stored_result() {
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(6).with_seed(3);
        let r = factorize(&tdm, &opts);
        let snap = crate::io::Snapshot::new(
            opts.clone(),
            r.u.clone(),
            r.v.clone(),
            &tdm,
            crate::io::Progress {
                iterations: r.iterations,
                residuals: r.residuals.clone(),
                errors: r.errors.clone(),
                memory: r.memory,
                elapsed_s: r.elapsed_s,
            },
        );
        // same budget: nothing left to do, stored result comes back
        let same = super::resume(&tdm, &opts, &snap).unwrap();
        assert_same_result(&same, &r);
        // extended budget: runs exactly the extra iterations
        let more = super::resume(&tdm, &opts.clone().with_iters(9), &snap).unwrap();
        assert_eq!(more.iterations, 9);
        assert_eq!(more.residuals[..6], r.residuals[..]);
        let full = factorize(&tdm, &opts.clone().with_iters(9));
        assert_same_result(&more, &full);
    }

    #[test]
    fn kl_objective_history_is_monotone_non_increasing() {
        // the multiplicative update is monotone in D(A ‖ UVᵀ) for the
        // unenforced problem (Lee & Seung); enforcement truncation can
        // break the guarantee, so this pins SparsityMode::None
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 47);
        let opts = NmfOptions::new(4)
            .with_objective(ObjectiveKind::Kl)
            .with_iters(12)
            .with_seed(3);
        let r = factorize(&tdm, &opts);
        assert_eq!(r.errors.len(), 12);
        assert!(r.errors.iter().all(|e| e.is_finite()), "{:?}", r.errors);
        for w in r.errors.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-6) + 1e-9,
                "KL history increased: {} -> {} ({:?})",
                w[0],
                w[1],
                r.errors
            );
        }
        // it actually fits: the divergence drops materially from start
        assert!(r.final_error() < r.errors[0] * 0.99, "{:?}", r.errors);
        assert!(r.u.values.iter().all(|&x| x >= 0.0));
        assert!(r.v.values.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn kl_factors_are_invariant_to_block_rows_and_threads() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 53);
        for (mode, tie) in [
            (SparsityMode::None, crate::sparse::TieMode::KeepTies),
            (SparsityMode::both(60, 120), crate::sparse::TieMode::Exact),
        ] {
            let mut base = NmfOptions::new(4)
                .with_objective(ObjectiveKind::Kl)
                .with_iters(5)
                .with_seed(59)
                .with_sparsity(mode)
                .with_threads(1)
                .with_block_rows(usize::MAX);
            base.tie_mode = tie;
            let reference = factorize(&tdm, &base);
            for block_rows in [1usize, 7, 64] {
                for threads in [1usize, 4] {
                    let opts = base
                        .clone()
                        .with_block_rows(block_rows)
                        .with_threads(threads);
                    let r = factorize(&tdm, &opts);
                    assert_eq!(r.u, reference.u, "block_rows {block_rows} threads {threads}");
                    assert_eq!(r.v, reference.v, "block_rows {block_rows} threads {threads}");
                    assert_eq!(
                        r.digest(),
                        reference.digest(),
                        "block_rows {block_rows} threads {threads}"
                    );
                }
            }
        }
    }

    #[test]
    fn kl_enforced_sparsity_caps_nnz() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 61);
        let mut opts = NmfOptions::new(5)
            .with_objective(ObjectiveKind::Kl)
            .with_iters(8)
            .with_sparsity(SparsityMode::both(55, 120))
            .with_seed(5);
        opts.tie_mode = crate::sparse::TieMode::Exact;
        let r = factorize(&tdm, &opts);
        assert!(r.u.nnz() <= 55, "u nnz {}", r.u.nnz());
        assert!(r.v.nnz() <= 120, "v nnz {}", r.v.nnz());
        r.u.validate().unwrap();
        r.v.validate().unwrap();
        assert!(r.errors.iter().all(|e| e.is_finite()));
    }

    #[test]
    fn kl_resume_from_checkpoint_matches_uninterrupted_run() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 67);
        let ck = std::env::temp_dir().join("esnmf_als_kl_resume_test.esnmf");
        let _ = std::fs::remove_file(&ck);
        let opts = NmfOptions::new(3)
            .with_objective(ObjectiveKind::Kl)
            .with_iters(9)
            .with_seed(17)
            .with_sparsity(SparsityMode::both(40, 90));
        let uninterrupted = factorize(&tdm, &opts);
        let ck_opts = opts.clone().with_iters(8).with_checkpoint(&ck, 4);
        let _partial = factorize(&tdm, &ck_opts);
        let snap = crate::io::Snapshot::load(&ck).unwrap();
        assert_eq!(snap.options.objective, ObjectiveKind::Kl);
        let resumed = super::resume(&tdm, &opts, &snap).unwrap();
        assert_same_result(&resumed, &uninterrupted);
        std::fs::remove_file(&ck).unwrap();
    }

    #[test]
    fn resume_refuses_an_objective_mismatch() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 71);
        let opts = NmfOptions::new(3)
            .with_objective(ObjectiveKind::Kl)
            .with_iters(3)
            .with_seed(5);
        let r = factorize(&tdm, &opts);
        let snap = crate::io::Snapshot::new(
            opts.clone(),
            r.u,
            r.v,
            &tdm,
            crate::io::Progress {
                iterations: r.iterations,
                residuals: r.residuals,
                errors: r.errors,
                memory: r.memory,
                elapsed_s: 0.0,
            },
        );
        let fro = opts.clone().with_objective(ObjectiveKind::Frobenius);
        let err = super::resume(&tdm, &fro, &snap).unwrap_err();
        assert!(format!("{err:#}").contains("objective"), "{err:#}");
    }
}
