//! The projected / enforced-sparsity ALS driver (Algorithms 1 and 2, plus
//! §4 column-wise enforcement).
//!
//! One driver serves all three because they differ only in the
//! enforcement applied after each half-step:
//!
//! ```text
//! repeat:
//!   V ← enforce( proj₊( Aᵀ U (UᵀU+εI)⁻¹ ) )        (steps 1–2)
//!   U ← enforce( proj₊( A V (VᵀV+εI)⁻¹ ) )          (steps 3–4)
//! until ‖Uᵢ−Uᵢ₋₁‖/‖Uᵢ‖ < tol or max_iters
//! ```
//!
//! The half-step intermediates are [`RowBlock`]s: only rows reachable from
//! the current factor's support are ever materialized, which is the
//! paper's memory claim; the [`MemoryTracker`] records the peak.
//!
//! # Parallel execution
//!
//! Every stage of a half-step is row-partitioned across
//! `NmfOptions::threads` scoped workers (see
//! [`crate::coordinator::pool`] for the primitives): the SpMM product
//! (`Aᵀ·U` / `A·V`), the gram accumulation, the small solve
//! (`B · G⁻¹`), the non-negative projection, and the top-t enforcement.
//!
//! # Determinism contract
//!
//! The result is **bit-for-bit identical at every thread count**,
//! so `threads` is purely a speed knob:
//!
//! * row-local stages concatenate per-range outputs in range order;
//! * the gram reduction accumulates per fixed-width row chunk
//!   ([`crate::sparse::ops::GRAM_CHUNK_ROWS`]) and merges partials in
//!   ascending chunk order, independent of the thread count;
//! * top-t tie-breaking splits the `Exact`-mode budget by prefix-counted
//!   ties per range, reproducing the serial left-to-right scan;
//! * the memory tracker observes logical stored sizes (identical by the
//!   above), so `MemoryStats` peaks match exactly too.
//!
//! `tests/prop_invariants.rs` and `tests/integration_nmf.rs` pin this
//! for thread counts {1, 2, 4, 7}.

use crate::dense::inverse_spd;
use crate::sparse::{ops, topk, Csc, Csr, RowBlock, TieMode};
use crate::text::TermDocMatrix;
use crate::util::timer::Timer;

use super::convergence::{rel_error_sparse, rel_residual};
use super::init::initial_u;
use super::memory::MemoryTracker;
use super::options::{NmfOptions, NmfResult, SparsityMode};

/// Enforcement applied to one side's candidate.
#[derive(Clone, Copy, Debug)]
enum Enforce {
    No,
    Global(usize),
    PerColumn(usize),
    Threshold(f32),
}

fn enforcement_for(mode: SparsityMode, is_u: bool) -> Enforce {
    match mode {
        SparsityMode::None => Enforce::No,
        SparsityMode::Global { t_u, t_v } => {
            match if is_u { t_u } else { t_v } {
                Some(t) => Enforce::Global(t),
                None => Enforce::No,
            }
        }
        SparsityMode::PerColumn { t_u_col, t_v_col } => {
            match if is_u { t_u_col } else { t_v_col } {
                Some(t) => Enforce::PerColumn(t),
                None => Enforce::No,
            }
        }
        SparsityMode::Threshold { tau_u, tau_v } => {
            match if is_u { tau_u } else { tau_v } {
                Some(tau) => Enforce::Threshold(tau),
                None => Enforce::No,
            }
        }
    }
}

/// Solve + project + enforce one candidate RowBlock into a CSR factor.
/// Every stage is row-partitioned across `threads` workers.
fn finish_half_step(
    mut cand: RowBlock,
    gram_other: &[f32],
    k: usize,
    enforce: Enforce,
    tie: TieMode,
    threads: usize,
    mem: &mut MemoryTracker,
) -> Csr {
    // candidates are tracked separately (max_intermediate_nnz); the
    // paper's Fig. 6 metric (max_combined_nnz) counts the stored factor
    // matrices at step boundaries, matching the MATLAB implementation
    mem.observe_intermediate(cand.stored_len());
    // below the per-worker floor, spawn overhead beats the work; the
    // clamp changes nothing but speed (results are thread-count
    // independent)
    let threads = crate::coordinator::pool::effective_workers(cand.stored_len(), threads);
    let g_inv = inverse_spd(gram_other, k);
    cand.matmul_small_par(&g_inv, threads);
    cand.project_nonneg_par(threads);
    match enforce {
        Enforce::No => cand.to_csr(),
        Enforce::Global(t) => {
            topk::enforce_top_t_rowblock_par(&mut cand, t, tie, threads);
            cand.to_csr()
        }
        Enforce::PerColumn(t) => {
            // deliberately via the CSR column gather — the access-pattern
            // cost the paper attributes to column-wise enforcement
            let mut csr = cand.to_csr();
            topk::enforce_top_t_per_column_par(&mut csr, t, tie, threads);
            csr
        }
        Enforce::Threshold(tau) => {
            for v in &mut cand.data {
                if *v < tau {
                    *v = 0.0;
                }
            }
            cand.to_csr()
        }
    }
}

/// Steps 1–2 of Algorithm 2: `V = proj₊(Aᵀ U (UᵀU)⁻¹)`, enforced.
pub fn half_step_v(
    a_csc: &Csc,
    u: &Csr,
    opts: &NmfOptions,
    mem: &mut MemoryTracker,
) -> Csr {
    let g = ops::gram_par(u, opts.threads);
    let cand = ops::atb_par(a_csc, u, opts.threads);
    finish_half_step(
        cand,
        &g,
        opts.k,
        enforcement_for(opts.sparsity, false),
        opts.tie_mode,
        opts.threads,
        mem,
    )
}

/// Steps 3–4 of Algorithm 2: `U = proj₊(A V (VᵀV)⁻¹)`, enforced.
pub fn half_step_u(
    a: &Csr,
    v: &Csr,
    opts: &NmfOptions,
    mem: &mut MemoryTracker,
) -> Csr {
    let g = ops::gram_par(v, opts.threads);
    let cand = ops::ab_par(a, v, opts.threads);
    finish_half_step(
        cand,
        &g,
        opts.k,
        enforcement_for(opts.sparsity, true),
        opts.tie_mode,
        opts.threads,
        mem,
    )
}

/// Run projected / enforced-sparsity ALS on a term-document matrix.
pub fn factorize(tdm: &TermDocMatrix, opts: &NmfOptions) -> NmfResult {
    factorize_from(tdm, opts, initial_u(tdm.n_terms(), opts.k, opts.init_nnz, opts.seed))
}

/// As [`factorize`] but with an explicit initial guess (used by the
/// backend-agreement tests and by warm starts, see
/// [`crate::nmf::init::warm_start_u`]).
pub fn factorize_from(tdm: &TermDocMatrix, opts: &NmfOptions, u0: Csr) -> NmfResult {
    assert_eq!(u0.rows, tdm.n_terms(), "U₀ row count != vocabulary size");
    assert_eq!(u0.cols, opts.k, "U₀ column count != k");
    let mut mem = MemoryTracker::new();
    mem.observe_pair(u0.nnz(), 0); // the initial guess is stored too
    let state = LoopState {
        u: u0,
        v: Csr::zeros(tdm.n_docs(), opts.k),
        start_iter: 0,
        residuals: Vec::with_capacity(opts.max_iters),
        errors: Vec::new(),
        mem,
        elapsed_base_s: 0.0,
    };
    run_loop(tdm, opts, state)
}

/// Continue a checkpointed run. The solver math (k, sparsity, tie mode,
/// tolerance, error tracking) comes from the *snapshot's* recorded
/// options so the continued trajectory is exactly the uninterrupted one;
/// only `max_iters`, `threads` and the checkpoint knobs are taken from
/// `opts` (a resumed run may extend the iteration budget, use a
/// different machine, and keep checkpointing). Refuses with a typed
/// [`SnapshotError`](crate::io::SnapshotError) when the corpus digest or
/// the requested `k` do not match the snapshot.
pub fn resume(
    tdm: &TermDocMatrix,
    opts: &NmfOptions,
    snap: &crate::io::Snapshot,
) -> crate::Result<NmfResult> {
    snap.check_k(opts.k)?;
    snap.check_corpus(tdm)?;
    snap.check_resumable()?;
    let effective = resume_options(opts, snap);

    let p = &snap.progress;
    let state = LoopState {
        u: snap.u.clone(),
        v: snap.v.clone(),
        start_iter: p.iterations,
        residuals: p.residuals.clone(),
        errors: p.errors.clone(),
        mem: MemoryTracker::from_stats(p.memory),
        elapsed_base_s: p.elapsed_s,
    };
    // already converged (or the budget is already spent): the stored
    // result IS the final result — do not run an extra iteration the
    // uninterrupted run would not have run
    let done_by_tol = effective.tol > 0.0
        && p.residuals.last().is_some_and(|&r| r < effective.tol);
    if done_by_tol || p.iterations >= effective.max_iters {
        let memory = state.mem.finish(state.u.nnz(), state.v.nnz());
        return Ok(NmfResult {
            u: state.u,
            v: state.v,
            iterations: state.start_iter,
            residuals: state.residuals,
            errors: state.errors,
            memory,
            elapsed_s: state.elapsed_base_s,
        });
    }
    Ok(run_loop(tdm, &effective, state))
}

/// The options a resumed run actually trains with: the snapshot's
/// recorded solver math, with only the iteration budget, thread count
/// and checkpoint knobs taken from the caller. Public so a
/// `--save-model` after `--resume` records the options the run really
/// used instead of the CLI defaults.
pub fn resume_options(opts: &NmfOptions, snap: &crate::io::Snapshot) -> NmfOptions {
    let mut effective = snap.options.clone();
    effective.max_iters = opts.max_iters;
    effective.threads = opts.threads;
    effective.checkpoint_every = opts.checkpoint_every;
    effective.checkpoint_path = opts.checkpoint_path.clone();
    effective
}

/// Mid-run solver state — everything an iteration boundary carries.
struct LoopState {
    u: Csr,
    v: Csr,
    /// completed iterations before this (re)start
    start_iter: usize,
    residuals: Vec<f64>,
    errors: Vec<f64>,
    mem: MemoryTracker,
    /// wall time accumulated by previous (checkpointed) segments
    elapsed_base_s: f64,
}

fn run_loop(tdm: &TermDocMatrix, opts: &NmfOptions, state: LoopState) -> NmfResult {
    let timer = Timer::start();
    let a = &tdm.a;
    let a_csc = &tdm.a_csc;
    let norm_a_sq = a.fro_norm_sq();
    // the corpus is immutable for the whole run, so hash it once up
    // front instead of once per checkpoint (it is O(nnz))
    let checkpoint_digest = (opts.checkpoint_every > 0 && opts.checkpoint_path.is_some())
        .then(|| crate::io::corpus_digest(tdm));

    let LoopState {
        mut u,
        mut v,
        start_iter,
        mut residuals,
        mut errors,
        mut mem,
        elapsed_base_s,
    } = state;
    let mut iterations = start_iter;

    for it in start_iter..opts.max_iters {
        v = half_step_v(a_csc, &u, opts, &mut mem);
        mem.observe_pair(u.nnz(), v.nnz());
        let u_new = half_step_u(a, &v, opts, &mut mem);
        mem.observe_pair(u_new.nnz(), v.nnz());

        let r = rel_residual(&u_new, &u);
        residuals.push(r);
        u = u_new;
        iterations = it + 1;

        if opts.track_error {
            errors.push(rel_error_sparse(a, &u, &v, norm_a_sq));
        }
        let stopping = opts.tol > 0.0 && r < opts.tol;
        // checkpoint cadence counts absolute iterations so a resumed run
        // checkpoints at the same boundaries the uninterrupted one did;
        // nothing is written on the stopping iteration (the final model
        // is the caller's --save-model, not a checkpoint)
        if !stopping && opts.checkpoint_every > 0 && iterations % opts.checkpoint_every == 0 {
            if let Some(path) = &opts.checkpoint_path {
                let progress = crate::io::Progress {
                    iterations,
                    residuals: residuals.clone(),
                    errors: errors.clone(),
                    memory: *mem.peek(),
                    elapsed_s: elapsed_base_s + timer.elapsed_s(),
                };
                let snap = crate::io::Snapshot {
                    options: opts.clone(),
                    u: u.clone(),
                    v: v.clone(),
                    terms: tdm.terms.clone(),
                    doc_labels: tdm.doc_labels.clone(),
                    label_names: tdm.label_names.clone(),
                    corpus_digest: checkpoint_digest.unwrap_or_default(),
                    progress,
                };
                if let Err(e) = snap.save(path) {
                    // a failing checkpoint disk must not abort hours of
                    // training — warn and keep iterating
                    crate::log_warn!(
                        "als",
                        "checkpoint at iteration {iterations} failed: {e}"
                    );
                } else {
                    crate::log_debug!(
                        "als",
                        "checkpointed iteration {iterations} to {}",
                        path.display()
                    );
                }
            }
        }
        if stopping {
            break;
        }
    }

    let memory = mem.finish(u.nnz(), v.nnz());
    NmfResult {
        u,
        v,
        iterations,
        residuals,
        errors,
        memory,
        elapsed_s: elapsed_base_s + timer.elapsed_s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate_tdm, reuters_sim, Scale};
    use crate::sparse::ops::spmm;
    use crate::text::TdmBuilder;

    fn tiny_tdm() -> TermDocMatrix {
        // deterministic 2-cluster corpus
        let mut b = TdmBuilder::new();
        for _ in 0..6 {
            b.add_text("coffee crop quotas coffee brazil crop", Some("econ"));
            b.add_text("electrons atoms hydrogen electrons atoms", Some("sci"));
        }
        b.freeze()
    }

    #[test]
    fn projected_als_reduces_error() {
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(20).with_seed(1);
        let r = factorize(&tdm, &opts);
        assert_eq!(r.iterations, 20);
        // the tiny corpus is exactly rank 2, so the fit is near-exact from
        // iteration 1 and the history just jitters at float-noise level
        assert!(r.final_error() < 0.01, "error {}", r.final_error());
        assert!(r.errors[0] >= r.final_error() - 1e-3);
        // factors are nonnegative
        assert!(r.u.values.iter().all(|&x| x >= 0.0));
        assert!(r.v.values.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn rank2_structure_recovered_exactly_for_rank2_data() {
        // A = U* V*ᵀ with clean rank-2 structure → error should reach ~0
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(50).with_seed(3);
        let r = factorize(&tdm, &opts);
        assert!(
            r.final_error() < 0.35,
            "final error {} too high",
            r.final_error()
        );
        // reconstruction actually close: ‖A−UVᵀ‖ via dense check
        let uvt = spmm(&r.u, &r.v.transpose());
        let rel = tdm.a.fro_diff(&uvt) / tdm.a.fro_norm();
        assert!((rel - r.final_error()).abs() < 1e-3);
    }

    #[test]
    fn enforced_sparsity_caps_nnz() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 11);
        let mut opts = NmfOptions::new(5)
            .with_iters(8)
            .with_sparsity(SparsityMode::both(55, 120))
            .with_seed(5);
        opts.tie_mode = crate::sparse::TieMode::Exact; // strict caps
        let r = factorize(&tdm, &opts);
        assert!(r.u.nnz() <= 55, "u nnz {}", r.u.nnz());
        assert!(r.v.nnz() <= 120, "v nnz {}", r.v.nnz());
        r.u.validate().unwrap();
        r.v.validate().unwrap();
    }

    #[test]
    fn u_only_enforcement_leaves_v_free() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 13);
        let opts = NmfOptions::new(5)
            .with_iters(6)
            .with_sparsity(SparsityMode::u_only(50))
            .with_seed(7);
        let r = factorize(&tdm, &opts);
        assert!(r.u.nnz() <= 50);
        // V is unenforced: it keeps every doc reachable from U's support,
        // far above U's budget (it need not be fully dense on a tiny corpus)
        assert!(
            r.v.nnz() > r.u.nnz() * 2,
            "v should stay much denser than u, nnz {}",
            r.v.nnz()
        );
    }

    #[test]
    fn per_column_enforcement_bounds_columns() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 17);
        let mut opts = NmfOptions::new(5)
            .with_iters(6)
            .with_sparsity(SparsityMode::PerColumn {
                t_u_col: Some(10),
                t_v_col: Some(30),
            })
            .with_seed(9);
        // Exact mode for a strict bound; KeepTies may exceed it when two
        // documents produce identical weights (observed on tiny corpora)
        opts.tie_mode = crate::sparse::TieMode::Exact;
        let r = factorize(&tdm, &opts);
        for &c in &r.u.col_nnz() {
            assert!(c <= 10);
        }
        for &c in &r.v.col_nnz() {
            assert!(c <= 30);
        }
        // per-column budget → even distribution by construction
        let counts = r.u.col_nnz();
        assert!(counts.iter().all(|&c| c > 0), "some topic starved: {counts:?}");
    }

    #[test]
    fn memory_tracking_reports_peak() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 19);
        let opts = NmfOptions::new(5)
            .with_iters(5)
            .with_sparsity(SparsityMode::both(50, 50))
            .with_init_nnz(60)
            .with_seed(11);
        let r = factorize(&tdm, &opts);
        assert!(r.memory.max_combined_nnz >= r.memory.final_u_nnz + r.memory.final_v_nnz);
        assert!(r.memory.max_intermediate_nnz > 0);
        // sparse init + enforcement ⇒ far below dense storage
        let dense_total = tdm.n_terms() * 5 + tdm.n_docs() * 5;
        assert!(
            r.memory.max_combined_nnz < dense_total,
            "peak {} vs dense {}",
            r.memory.max_combined_nnz,
            dense_total
        );
    }

    #[test]
    fn tol_stops_early() {
        let tdm = tiny_tdm();
        // projected ALS can cycle near the optimum, so use a tolerance
        // comfortably above float-noise level
        let opts = NmfOptions::new(2).with_iters(500).with_tol(1e-4).with_seed(13);
        let r = factorize(&tdm, &opts);
        assert!(r.iterations < 500, "never converged");
        assert!(r.final_residual() < 1e-4);
    }

    #[test]
    fn thread_count_does_not_change_the_result() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 23);
        let mut base = NmfOptions::new(3)
            .with_iters(6)
            .with_seed(29)
            .with_sparsity(SparsityMode::both(40, 80))
            .with_threads(1);
        base.tie_mode = crate::sparse::TieMode::Exact;
        let serial = factorize(&tdm, &base);
        for threads in [2usize, 4, 7] {
            let r = factorize(&tdm, &base.clone().with_threads(threads));
            assert_eq!(r.u, serial.u, "threads {threads}");
            assert_eq!(r.v, serial.v, "threads {threads}");
            assert_eq!(r.residuals, serial.residuals, "threads {threads}");
            assert_eq!(r.memory, serial.memory, "threads {threads}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(5).with_seed(99);
        let r1 = factorize(&tdm, &opts);
        let r2 = factorize(&tdm, &opts);
        assert_eq!(r1.u, r2.u);
        assert_eq!(r1.v, r2.v);
        assert_eq!(r1.residuals, r2.residuals);
    }

    #[test]
    #[should_panic(expected = "row count")]
    fn mismatched_initial_guess_panics() {
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2);
        let bad = Csr::zeros(3, 2);
        factorize_from(&tdm, &opts, bad);
    }

    fn assert_same_result(a: &NmfResult, b: &NmfResult) {
        assert_eq!(a.u, b.u);
        assert_eq!(a.v, b.v);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.residuals, b.residuals);
        assert_eq!(a.errors, b.errors);
        assert_eq!(a.memory, b.memory);
    }

    #[test]
    fn resume_from_checkpoint_matches_uninterrupted_run() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 31);
        let ck = std::env::temp_dir().join("esnmf_als_resume_test.esnmf");
        let _ = std::fs::remove_file(&ck);

        let mut opts = NmfOptions::new(3)
            .with_iters(9)
            .with_seed(17)
            .with_sparsity(SparsityMode::both(40, 90));
        opts.tie_mode = crate::sparse::TieMode::Exact;
        let uninterrupted = factorize(&tdm, &opts);

        // same run, checkpointing every 4 iterations, "crashing" at 8
        let ck_opts = opts.clone().with_iters(8).with_checkpoint(&ck, 4);
        let _partial = factorize(&tdm, &ck_opts);
        let snap = crate::io::Snapshot::load(&ck).unwrap();
        assert_eq!(snap.progress.iterations, 8);

        // resume to the full budget: bit-identical to never crashing
        let resumed = super::resume(&tdm, &opts, &snap).unwrap();
        assert_same_result(&resumed, &uninterrupted);
        std::fs::remove_file(&ck).unwrap();
    }

    #[test]
    fn resume_refuses_wrong_corpus_and_wrong_k() {
        let tdm = generate_tdm(&reuters_sim(Scale::Tiny), 37);
        let other = generate_tdm(&reuters_sim(Scale::Tiny), 38);
        let opts = NmfOptions::new(3).with_iters(4).with_seed(5);
        let r = factorize(&tdm, &opts);
        let snap = crate::io::Snapshot::new(
            opts.clone(),
            r.u,
            r.v,
            &tdm,
            crate::io::Progress {
                iterations: r.iterations,
                residuals: r.residuals,
                errors: r.errors,
                memory: r.memory,
                elapsed_s: 0.0,
            },
        );
        // wrong corpus → digest refusal
        let err = super::resume(&other, &opts, &snap).unwrap_err();
        assert!(format!("{err:#}").contains("digest"), "{err:#}");
        // wrong k → typed refusal
        let bad_k = NmfOptions::new(7).with_iters(8);
        let err = super::resume(&tdm, &bad_k, &snap).unwrap_err();
        assert!(format!("{err:#}").contains("k="), "{err:#}");
    }

    #[test]
    fn resume_past_budget_or_tolerance_returns_the_stored_result() {
        let tdm = tiny_tdm();
        let opts = NmfOptions::new(2).with_iters(6).with_seed(3);
        let r = factorize(&tdm, &opts);
        let snap = crate::io::Snapshot::new(
            opts.clone(),
            r.u.clone(),
            r.v.clone(),
            &tdm,
            crate::io::Progress {
                iterations: r.iterations,
                residuals: r.residuals.clone(),
                errors: r.errors.clone(),
                memory: r.memory,
                elapsed_s: r.elapsed_s,
            },
        );
        // same budget: nothing left to do, stored result comes back
        let same = super::resume(&tdm, &opts, &snap).unwrap();
        assert_same_result(&same, &r);
        // extended budget: runs exactly the extra iterations
        let more = super::resume(&tdm, &opts.clone().with_iters(9), &snap).unwrap();
        assert_eq!(more.iterations, 9);
        assert_eq!(more.residuals[..6], r.residuals[..]);
        let full = factorize(&tdm, &opts.clone().with_iters(9));
        assert_same_result(&more, &full);
    }
}
