//! Argument-parsing substrate (clap is not in the offline vendor set).
//!
//! Supports `program <subcommand> [--flag] [--key value] [positional...]`
//! with typed getters and an unknown-flag check.

use std::collections::{HashMap, HashSet};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: HashSet<String>,
    consumed: HashSet<String>,
}

impl Args {
    /// Parse raw arguments (excluding argv[0]). `--key value`, `--key=value`
    /// and bare `--flag` are all accepted; the first non-option token is
    /// the subcommand, later ones are positional.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err("bare -- is not supported".into());
                }
                if let Some((key, value)) = stripped.split_once('=') {
                    args.options.insert(key.to_string(), value.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let value = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), value);
                } else {
                    args.flags.insert(stripped.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    /// Boolean flag lookup. `--flag value` is greedily parsed as an option
    /// at parse time (the parser cannot know which names are flags);
    /// calling `flag()` on such a name reclassifies the captured token as
    /// positional — so only call `flag()` on genuinely boolean names.
    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.insert(name.to_string());
        if self.flags.contains(name) {
            return true;
        }
        if let Some(v) = self.options.remove(name) {
            self.positional.push(v);
            return true;
        }
        false
    }

    pub fn opt_str(&mut self, name: &str) -> Option<String> {
        self.consumed.insert(name.to_string());
        self.options.get(name).cloned()
    }

    pub fn str_or(&mut self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or_else(|| default.to_string())
    }

    pub fn opt_parse<T: std::str::FromStr>(&mut self, name: &str) -> Result<Option<T>, String> {
        match self.opt_str(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("invalid value {v:?} for --{name}")),
        }
    }

    pub fn parse_or<T: std::str::FromStr>(&mut self, name: &str, default: T) -> Result<T, String> {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }

    /// A worker-count option accepting `N` or the bare word `auto`
    /// (→ `Some(0)`, "use every available core") — the CLI twin of
    /// `ConfigFile::threads`.
    pub fn opt_threads(&mut self, name: &str) -> Result<Option<usize>, String> {
        match self.opt_str(name) {
            None => Ok(None),
            Some(v) if v == "auto" => Ok(Some(0)),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("invalid value {v:?} for --{name} (N or auto)")),
        }
    }

    /// Error on any option/flag never looked at (catches typos).
    pub fn check_unknown(&self) -> Result<(), String> {
        let mut unknown: Vec<&String> = self
            .options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(*k))
            .collect();
        unknown.sort();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown option(s): {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let mut a = parse("factorize --k 5 --iters 75 --verbose pos1");
        assert_eq!(a.subcommand.as_deref(), Some("factorize"));
        assert_eq!(a.parse_or("k", 0usize).unwrap(), 5);
        assert_eq!(a.parse_or("iters", 0usize).unwrap(), 75);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        a.check_unknown().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let mut a = parse("run --seed=42 --scale=tiny");
        assert_eq!(a.parse_or("seed", 0u64).unwrap(), 42);
        assert_eq!(a.str_or("scale", "x"), "tiny");
    }

    #[test]
    fn trailing_flag() {
        let mut a = parse("run --fast");
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn unknown_flag_detected() {
        let mut a = parse("run --k 5 --oops 3");
        let _ = a.parse_or("k", 0usize);
        let err = a.check_unknown().unwrap_err();
        assert!(err.contains("--oops"));
    }

    #[test]
    fn invalid_value_errors() {
        let mut a = parse("run --k five");
        assert!(a.opt_parse::<usize>("k").is_err());
    }

    #[test]
    fn threads_option_accepts_auto_and_integers() {
        let mut a = parse("serve --serve-threads auto --threads 4");
        assert_eq!(a.opt_threads("serve-threads").unwrap(), Some(0));
        assert_eq!(a.opt_threads("threads").unwrap(), Some(4));
        assert_eq!(a.opt_threads("missing").unwrap(), None);
        let mut a = parse("serve --serve-threads lots");
        assert!(a.opt_threads("serve-threads").is_err());
    }

    #[test]
    fn defaults() {
        let mut a = parse("run");
        assert_eq!(a.str_or("corpus", "reuters"), "reuters");
        assert_eq!(a.parse_or("k", 7usize).unwrap(), 7);
    }
}
