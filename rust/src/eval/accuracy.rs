//! Eq. 3.3 document-clustering accuracy.
//!
//! A document "belongs" to a topic when its entry in that column of V is
//! nonzero. A topic's accuracy is the count of same-journal document
//! pairs, affinely rescaled so that 1 = all documents from one journal
//! and 0 = documents uniformly spread over the `n_J` journals:
//!
//! ```text
//! Acc = (Σ_{i<k} Jnl(i,k) − α) / (β − α)
//! α   = ⌊n_D/n_J⌋ · ( n_J(⌊n_D/n_J⌋−1)/2 + n_D mod n_J )
//! β   = n_D(n_D−1)/2
//! ```
//!
//! Topics with ≤ 1 member are defined to have Acc = 1.

use crate::sparse::Csr;

/// α of Eq. 3.4: same-journal pairs under the most-uniform assignment of
/// `n_d` documents to `n_j` journals.
pub fn alpha(n_d: usize, n_j: usize) -> f64 {
    assert!(n_j > 0);
    let q = n_d / n_j;
    let r = n_d % n_j;
    q as f64 * ((n_j * (q.saturating_sub(1))) as f64 / 2.0 + r as f64)
}

/// β of Eq. 3.4: all document pairs.
pub fn beta(n_d: usize) -> f64 {
    (n_d * n_d.saturating_sub(1)) as f64 / 2.0
}

/// Accuracy of one topic given the journal labels of its member docs.
pub fn accuracy_from_members(member_labels: &[u32], n_journals: usize) -> f64 {
    let n_d = member_labels.len();
    if n_d <= 1 {
        return 1.0;
    }
    // count same-journal pairs via per-journal membership counts
    let mut counts = std::collections::HashMap::new();
    for &l in member_labels {
        *counts.entry(l).or_insert(0usize) += 1;
    }
    let same: f64 = counts
        .values()
        .map(|&c| (c * (c - 1)) as f64 / 2.0)
        .sum();
    let a = alpha(n_d, n_journals);
    let b = beta(n_d);
    if (b - a).abs() < f64::EPSILON {
        return 1.0; // degenerate: uniform == clustered (e.g. n_d < n_j small cases)
    }
    (same - a) / (b - a)
}

/// Accuracy of topic `col` of `v` (docs × topics) against `labels`.
pub fn topic_accuracy(v: &Csr, col: usize, labels: &[u32], n_journals: usize) -> f64 {
    assert_eq!(v.rows, labels.len(), "labels must cover every document");
    let mut members = Vec::new();
    for doc in 0..v.rows {
        if v.get(doc, col) != 0.0 {
            members.push(labels[doc]);
        }
    }
    accuracy_from_members(&members, n_journals)
}

/// Mean over all topic columns — the quantity plotted in Figs. 4/5/8.
pub fn mean_topic_accuracy(v: &Csr, labels: &[u32], n_journals: usize) -> f64 {
    if v.cols == 0 {
        return 0.0;
    }
    // column membership via one CSR scan instead of v.cols point lookups
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); v.cols];
    for doc in 0..v.rows {
        let (idx, _) = v.row(doc);
        for &c in idx {
            members[c as usize].push(labels[doc]);
        }
    }
    members
        .iter()
        .map(|m| accuracy_from_members(m, n_journals))
        .sum::<f64>()
        / v.cols as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_beta_formulas() {
        // 6 docs, 3 journals: uniform = 2 per journal → 3 pairs
        assert_eq!(alpha(6, 3), 3.0);
        assert_eq!(beta(6), 15.0);
        // 7 docs, 3 journals: (3,2,2) → 3+1+1 = 5... Eq 3.4: q=2, r=1:
        // 2*((3*1)/2 + 1) = 2*(1.5+1) = 5
        assert_eq!(alpha(7, 3), 5.0);
    }

    #[test]
    fn perfect_cluster_scores_one() {
        let labels = vec![2u32; 10];
        assert!((accuracy_from_members(&labels, 5) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn uniform_spread_scores_zero() {
        // 10 docs over 5 journals, 2 each
        let labels: Vec<u32> = (0..10).map(|i| (i % 5) as u32).collect();
        assert!(accuracy_from_members(&labels, 5).abs() < 1e-12);
    }

    #[test]
    fn singleton_and_empty_topics_score_one() {
        assert_eq!(accuracy_from_members(&[], 5), 1.0);
        assert_eq!(accuracy_from_members(&[3], 5), 1.0);
    }

    #[test]
    fn accuracy_is_bounded() {
        use crate::util::prop;
        use crate::util::rng::Rng;
        prop::check("accuracy-bounds", 1500, 64, |rng: &mut Rng| {
            let n_j = rng.range(1, 6);
            let n_d = rng.range(0, 40);
            let labels: Vec<u32> = (0..n_d).map(|_| rng.below(n_j) as u32).collect();
            let acc = accuracy_from_members(&labels, n_j);
            assert!(
                (-1.0..=1.0 + 1e-9).contains(&acc),
                "acc {acc} out of range for labels {labels:?} n_j {n_j}"
            );
        });
    }

    #[test]
    fn topic_accuracy_reads_column_membership() {
        // V: 4 docs × 2 topics; docs 0,1 in topic 0; docs 2,3 in topic 1
        let v = Csr::from_dense(4, 2, &[
            0.5, 0.0, //
            0.3, 0.0, //
            0.0, 0.9, //
            0.0, 0.1,
        ]);
        let labels = vec![0, 0, 1, 0];
        assert_eq!(topic_accuracy(&v, 0, &labels, 2), 1.0);
        // topic 1 members have labels {1, 0}: 0 same pairs of 1 total,
        // α(2,2)=0, β=1 → 0
        assert_eq!(topic_accuracy(&v, 1, &labels, 2), 0.0);
        assert_eq!(mean_topic_accuracy(&v, &labels, 2), 0.5);
    }

    #[test]
    fn mean_accuracy_matches_per_topic() {
        let v = Csr::from_dense(3, 2, &[1.0, 1.0, 1.0, 0.0, 0.0, 1.0]);
        let labels = vec![0, 0, 1];
        let want = (topic_accuracy(&v, 0, &labels, 2)
            + topic_accuracy(&v, 1, &labels, 2))
            / 2.0;
        assert!((mean_topic_accuracy(&v, &labels, 2) - want).abs() < 1e-12);
    }
}
