//! Held-out mean per-token log-likelihood — the objective-agnostic
//! predictive measure reported next to the rel-error / accuracy lines.
//!
//! Every [`HELDOUT_STRIDE`]-th document is re-projected from scratch
//! against the frozen term factor `U` (the same [`FoldIn`] solve the
//! topic server answers FOLDIN with — the trained `V` row is never
//! consulted), the factorization's implied unigram distribution
//!
//! ```text
//! p(w | d) = ⟨U_w, x̂_d⟩ / (colsums(U) · x̂_d)
//! ```
//!
//! is evaluated at each of the document's tokens, and the count-weighted
//! mean of `ln p(w | d)` is returned. Higher (closer to zero) is better.
//! Predictions are floored at [`KL_EPS`] inside the log, so a topic row
//! that misses a token entirely costs a large-but-finite penalty instead
//! of `-inf` — the same no-epsilon-in-the-math, epsilon-only-in-the-log
//! discipline as the streamed KL divergence.
//!
//! The measure is comparable *across objectives* (both Frobenius- and
//! KL-trained models are scored under the identical likelihood), which
//! is exactly what the per-objective training errors (relative Frobenius
//! error vs. mean per-token KL) are not.

use crate::nmf::foldin::FoldIn;
use crate::nmf::objective::{ObjectiveKind, KL_EPS};
use crate::sparse::source::{RowCursor, RowSource};
use crate::sparse::{Csr, TieMode};

/// Every stride-th document (by column id) is scored; the rest are
/// skipped. 7 is coprime to the corpus generators' topic cycling, so the
/// sample crosses all ground-truth clusters.
pub const HELDOUT_STRIDE: usize = 7;

/// The result of a held-out scoring pass.
#[derive(Clone, Copy, Debug)]
pub struct HeldOut {
    /// documents scored (every [`HELDOUT_STRIDE`]-th, empty ones skipped)
    pub docs: usize,
    /// total token mass scored (sum of the scored documents' counts)
    pub tokens: f64,
    /// count-weighted mean of `ln p(w | d)` over the scored tokens;
    /// `0.0` when nothing was scorable
    pub mean_log_likelihood: f64,
}

/// Score the factorization's predictive likelihood on every
/// [`HELDOUT_STRIDE`]-th document of `a_cols` (the docs-major
/// orientation: row `d` holds document `d`'s term counts). Each scored
/// document is folded in against `u` under `objective` — with the same
/// nonzero budget `t` and tie discipline the model would serve with —
/// and its tokens are scored under the implied unigram distribution.
pub fn heldout_mean_log_likelihood(
    a_cols: &dyn RowSource,
    u: &Csr,
    objective: ObjectiveKind,
    t: Option<usize>,
    tie: TieMode,
) -> HeldOut {
    let k = u.cols;
    let solver = FoldIn::with_objective(u, objective, t, tie);
    // per-topic column sums of U in f64 — the normalizer of p(w | d)
    let mut col_sums = vec![0.0f64; k];
    for w in 0..u.rows {
        let (idx, val) = u.row(w);
        for (&c, &v) in idx.iter().zip(val) {
            col_sums[c as usize] += v as f64;
        }
    }
    let mut cur = RowCursor::new();
    let mut doc: Vec<(usize, f32)> = Vec::new();
    let (mut docs, mut tokens, mut ll) = (0usize, 0.0f64, 0.0f64);
    for d in (0..a_cols.rows()).step_by(HELDOUT_STRIDE.max(1)) {
        let view = a_cols.load(d, d + 1, &mut cur);
        let (idx, val) = view.row(0);
        doc.clear();
        doc.extend(
            idx.iter()
                .zip(val)
                .filter(|(_, &a)| a > 0.0)
                .map(|(&w, &a)| (w as usize, a)),
        );
        if doc.is_empty() {
            continue;
        }
        let x = solver.solve(u, &doc);
        let denom: f64 = col_sums
            .iter()
            .zip(&x)
            .map(|(&s, &xc)| s * xc as f64)
            .sum();
        for &(w, a) in &doc {
            // ⟨U_w, x̂⟩ — U's row w is sparse, x̂ is dense length-k
            let (idx, val) = u.row(w);
            let pred: f64 = idx
                .iter()
                .zip(val)
                .map(|(&c, &v)| v as f64 * x[c as usize] as f64)
                .sum();
            let p = if denom > 0.0 { pred / denom } else { 0.0 };
            ll += a as f64 * p.max(KL_EPS).ln();
            tokens += a as f64;
        }
        docs += 1;
    }
    HeldOut {
        docs,
        tokens,
        mean_log_likelihood: if tokens > 0.0 { ll / tokens } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One topic whose U column is exactly the empirical term
    /// distribution of every document: p(w | d) reduces to the
    /// empirical unigram, so the mean log-likelihood is the negated
    /// empirical entropy — the best any unigram model can do.
    #[test]
    fn perfect_single_topic_model_attains_the_empirical_entropy() {
        // every doc is the same bag: term 0 ×3, term 1 ×1
        let n_docs = 15;
        let mut cols = vec![0.0f32; n_docs * 2];
        for d in 0..n_docs {
            cols[d * 2] = 3.0;
            cols[d * 2 + 1] = 1.0;
        }
        let a_cols = Csr::from_dense(n_docs, 2, &cols);
        let u = Csr::from_dense(2, 1, &[0.75, 0.25]);
        for objective in [ObjectiveKind::Frobenius, ObjectiveKind::Kl] {
            let h = heldout_mean_log_likelihood(
                &a_cols,
                &u,
                objective,
                None,
                TieMode::KeepTies,
            );
            // stride 7 over 15 docs → docs 0, 7, 14
            assert_eq!(h.docs, 3, "{objective:?}");
            assert!((h.tokens - 12.0).abs() < 1e-9, "{objective:?}");
            let want = 0.75 * 0.75f64.ln() + 0.25 * 0.25f64.ln();
            assert!(
                (h.mean_log_likelihood - want).abs() < 1e-4,
                "{objective:?}: {} vs {want}",
                h.mean_log_likelihood
            );
        }
    }

    #[test]
    fn a_matching_model_beats_a_mismatched_one() {
        // docs dominated by term 0; the matched model concentrates its
        // mass there, the mismatched one inverts it
        let n_docs = 8;
        let mut cols = vec![0.0f32; n_docs * 3];
        for d in 0..n_docs {
            cols[d * 3] = 5.0;
            cols[d * 3 + 1] = 1.0;
        }
        let a_cols = Csr::from_dense(n_docs, 3, &cols);
        let good = Csr::from_dense(3, 1, &[5.0, 1.0, 0.1]);
        let bad = Csr::from_dense(3, 1, &[0.1, 1.0, 5.0]);
        for objective in [ObjectiveKind::Frobenius, ObjectiveKind::Kl] {
            let hg =
                heldout_mean_log_likelihood(&a_cols, &good, objective, None, TieMode::KeepTies);
            let hb =
                heldout_mean_log_likelihood(&a_cols, &bad, objective, None, TieMode::KeepTies);
            assert!(
                hg.mean_log_likelihood > hb.mean_log_likelihood,
                "{objective:?}: good {} vs bad {}",
                hg.mean_log_likelihood,
                hb.mean_log_likelihood
            );
            assert!(hg.mean_log_likelihood <= 0.0);
            assert!(hb.mean_log_likelihood.is_finite());
        }
    }

    #[test]
    fn unmodeled_tokens_are_floored_not_infinite() {
        // U gives term 2 zero mass in every topic: its tokens hit the
        // KL_EPS floor and the likelihood stays finite
        let a_cols = Csr::from_dense(1, 3, &[1.0, 1.0, 4.0]);
        let u = Csr::from_dense(3, 2, &[1.0, 0.5, 0.5, 1.0, 0.0, 0.0]);
        for objective in [ObjectiveKind::Frobenius, ObjectiveKind::Kl] {
            let h = heldout_mean_log_likelihood(&a_cols, &u, objective, None, TieMode::KeepTies);
            assert_eq!(h.docs, 1, "{objective:?}");
            assert!(h.mean_log_likelihood.is_finite(), "{objective:?}");
            assert!(h.mean_log_likelihood < KL_EPS.ln() / 2.0, "{objective:?}");
        }
    }

    #[test]
    fn empty_documents_and_empty_samples_are_skipped() {
        // doc 0 is empty (the only one the stride visits): nothing scored
        let mut cols = vec![0.0f32; 3 * 2];
        cols[1 * 2] = 1.0;
        cols[2 * 2] = 1.0;
        let a_cols = Csr::from_dense(3, 2, &cols);
        let u = Csr::from_dense(2, 1, &[1.0, 1.0]);
        let h = heldout_mean_log_likelihood(
            &a_cols,
            &u,
            ObjectiveKind::Frobenius,
            None,
            TieMode::KeepTies,
        );
        assert_eq!(h.docs, 0);
        assert_eq!(h.tokens, 0.0);
        assert_eq!(h.mean_log_likelihood, 0.0);
    }
}
