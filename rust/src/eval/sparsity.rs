//! The Fig. 1 sparsity report: how dense the factors (and their product)
//! become under plain projected ALS versus enforced sparsity.

use crate::sparse::{ops, Csr};

#[derive(Clone, Debug)]
pub struct SparsityReport {
    pub a_sparsity: f64,
    pub u_sparsity: f64,
    pub v_sparsity: f64,
    pub uvt_sparsity: f64,
    pub a_nnz: usize,
    pub u_nnz: usize,
    pub v_nnz: usize,
    pub uvt_nnz: usize,
}

impl SparsityReport {
    /// Compute the Fig. 1 rows. `U·Vᵀ`'s *structural* sparsity is computed
    /// from the factor supports without materializing the dense product.
    pub fn compute(a: &Csr, u: &Csr, v: &Csr) -> SparsityReport {
        SparsityReport::from_parts(a.rows, a.cols, a.nnz(), u, v)
    }

    /// As [`SparsityReport::compute`] from `A`'s shape and nonzero count
    /// alone — the out-of-core path, where `A` lives in a corpus store
    /// and only its stats are resident. Identical numbers to `compute`
    /// on the same corpus.
    pub fn from_parts(
        a_rows: usize,
        a_cols: usize,
        a_nnz: usize,
        u: &Csr,
        v: &Csr,
    ) -> SparsityReport {
        let uvt = ops::spmm(u, &v.transpose());
        SparsityReport {
            a_sparsity: sparsity_fraction(a_rows, a_cols, a_nnz),
            u_sparsity: u.sparsity(),
            v_sparsity: v.sparsity(),
            uvt_sparsity: uvt.sparsity(),
            a_nnz,
            u_nnz: u.nnz(),
            v_nnz: v.nnz(),
            uvt_nnz: uvt.nnz(),
        }
    }

    /// The Fig. 1 rows *without* the `U·Vᵀ` product — the out-of-core
    /// reporting path: the product's structural support can approach a
    /// dense `n_terms × n_docs` for weakly enforced factors, which
    /// would reintroduce after the run exactly the O(n·m) memory the
    /// store-streamed factorization existed to avoid.
    pub fn format_factors_only(
        dataset: &str,
        a_rows: usize,
        a_cols: usize,
        a_nnz: usize,
        u: &Csr,
        v: &Csr,
    ) -> String {
        format!(
            "{dataset}\nMatrix | Sparsity | NNZ\n--- | --- | ---\nA | {:.2}% | {}\nU | {:.2}% | {}\nV | {:.2}% | {}\n",
            sparsity_fraction(a_rows, a_cols, a_nnz) * 100.0,
            a_nnz,
            u.sparsity() * 100.0,
            u.nnz(),
            v.sparsity() * 100.0,
            v.nnz(),
        )
    }

    /// Markdown rows in the paper's Fig. 1 layout.
    pub fn format(&self, dataset: &str) -> String {
        format!(
            "{dataset}\nMatrix | Sparsity | NNZ\n--- | --- | ---\nA | {:.2}% | {}\nU | {:.2}% | {}\nV | {:.2}% | {}\nUV^T | {:.2}% | {}\n",
            self.a_sparsity * 100.0,
            self.a_nnz,
            self.u_sparsity * 100.0,
            self.u_nnz,
            self.v_sparsity * 100.0,
            self.v_nnz,
            self.uvt_sparsity * 100.0,
            self.uvt_nnz,
        )
    }
}

/// Fraction of exactly-zero cells for a matrix known only by shape and
/// nonzero count — [`Csr::sparsity`] for corpora that are not resident
/// (the out-of-core store), empty shapes counting as fully sparse.
pub fn sparsity_fraction(rows: usize, cols: usize, nnz: usize) -> f64 {
    if rows * cols == 0 {
        return 1.0;
    }
    1.0 - nnz as f64 / (rows * cols) as f64
}

/// Hoyer's sparsity measure (the constraint used by [10] in the paper):
/// `(√n − ‖x‖₁/‖x‖₂) / (√n − 1)` over the matrix entries. 1 = a single
/// nonzero, 0 = all entries equal. Complements the exact-zero fraction —
/// it also sees "soft" sparsity in the value distribution.
pub fn hoyer_sparsity(m: &Csr) -> f64 {
    let n = (m.rows * m.cols) as f64;
    if n <= 1.0 {
        return 0.0;
    }
    let l1: f64 = m.values.iter().map(|&v| v.abs() as f64).sum();
    let l2 = m.fro_norm();
    if l2 == 0.0 {
        return 0.0; // all-zero matrix: measure undefined; report 0
    }
    let root_n = n.sqrt();
    ((root_n - l1 / l2) / (root_n - 1.0)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hoyer_extremes() {
        // single nonzero → 1
        let single = Csr::from_dense(2, 2, &[3.0, 0.0, 0.0, 0.0]);
        assert!((hoyer_sparsity(&single) - 1.0).abs() < 1e-9);
        // all equal → 0
        let flat = Csr::from_dense(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        assert!(hoyer_sparsity(&flat).abs() < 1e-9);
        // zero matrix → 0 by convention
        assert_eq!(hoyer_sparsity(&Csr::zeros(3, 3)), 0.0);
    }

    #[test]
    fn hoyer_monotone_in_concentration() {
        let spread = Csr::from_dense(1, 4, &[1.0, 1.0, 1.0, 1.0]);
        let peaked = Csr::from_dense(1, 4, &[10.0, 0.1, 0.1, 0.1]);
        assert!(hoyer_sparsity(&peaked) > hoyer_sparsity(&spread));
    }

    #[test]
    fn report_values() {
        let a = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let u = Csr::from_dense(2, 1, &[1.0, 0.0]);
        let v = Csr::from_dense(2, 1, &[1.0, 1.0]);
        let r = SparsityReport::compute(&a, &u, &v);
        assert_eq!(r.a_sparsity, 0.5);
        assert_eq!(r.u_sparsity, 0.5);
        assert_eq!(r.v_sparsity, 0.0);
        // u vᵀ = [[1,1],[0,0]] → sparsity 0.5
        assert_eq!(r.uvt_sparsity, 0.5);
        let s = r.format("test-data");
        assert!(s.contains("test-data"));
        assert!(s.contains("50.00%"));
    }
}
