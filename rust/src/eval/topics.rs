//! Topic inspection: the top-magnitude terms per topic (the tables of
//! Fig. 2, Table 1 and Fig. 7) and per-column nonzero distribution.

use crate::sparse::Csr;

/// The `top` highest-magnitude terms of topic `col` of `u`
/// (terms × topics), as (term string, weight), descending.
pub fn top_terms(u: &Csr, terms: &[String], col: usize, top: usize) -> Vec<(String, f32)> {
    assert_eq!(u.rows, terms.len(), "terms must cover every row of U");
    let mut entries: Vec<(String, f32)> = Vec::new();
    for r in 0..u.rows {
        let v = u.get(r, col);
        if v != 0.0 {
            entries.push((terms[r].clone(), v));
        }
    }
    entries.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
    entries.truncate(top);
    entries
}

/// A printable table: one row per rank, one column per topic (the paper's
/// topic-table layout). Topics with fewer terms get blank cells.
pub fn topic_term_table(u: &Csr, terms: &[String], top: usize) -> Vec<Vec<String>> {
    let per_topic: Vec<Vec<(String, f32)>> = (0..u.cols)
        .map(|c| top_terms(u, terms, c, top))
        .collect();
    (0..top)
        .map(|rank| {
            per_topic
                .iter()
                .map(|t| t.get(rank).map(|(w, _)| w.clone()).unwrap_or_default())
                .collect()
        })
        .collect()
}

/// Render the table with a header row, markdown-ish.
pub fn format_topic_table(table: &[Vec<String>], k: usize) -> String {
    let mut out = String::new();
    let header: Vec<String> = (1..=k).map(|i| format!("Topic {i}")).collect();
    out.push_str(&header.join(" | "));
    out.push('\n');
    out.push_str(&vec!["---"; k].join(" | "));
    out.push('\n');
    for row in table {
        out.push_str(&row.join(" | "));
        out.push('\n');
    }
    out
}

/// Coefficient of variation of per-column nnz — the Table-1 "uneven
/// distribution" statistic (0 = perfectly even).
pub fn column_nnz_cv(m: &Csr) -> f64 {
    let counts: Vec<f64> = m.col_nnz().iter().map(|&c| c as f64).collect();
    let mean = crate::util::stats::mean(&counts);
    if mean == 0.0 {
        return 0.0;
    }
    crate::util::stats::stddev(&counts) / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Csr, Vec<String>) {
        let u = Csr::from_dense(4, 2, &[
            0.9, 0.0, //
            0.5, 0.1, //
            0.0, 0.8, //
            0.7, 0.0,
        ]);
        let terms = ["coffee", "crop", "electrons", "quotas"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        (u, terms)
    }

    #[test]
    fn top_terms_ordered_by_magnitude() {
        let (u, terms) = sample();
        let t0 = top_terms(&u, &terms, 0, 5);
        assert_eq!(
            t0.iter().map(|(w, _)| w.as_str()).collect::<Vec<_>>(),
            vec!["coffee", "quotas", "crop"]
        );
        let t1 = top_terms(&u, &terms, 1, 1);
        assert_eq!(t1[0].0, "electrons");
    }

    #[test]
    fn table_has_blank_cells_for_short_topics() {
        let (u, terms) = sample();
        let table = topic_term_table(&u, &terms, 3);
        assert_eq!(table.len(), 3);
        assert_eq!(table[0], vec!["coffee", "electrons"]);
        assert_eq!(table[2], vec!["crop", ""]); // topic 2 has only 2 terms
    }

    #[test]
    fn format_includes_header() {
        let (u, terms) = sample();
        let s = format_topic_table(&topic_term_table(&u, &terms, 2), 2);
        assert!(s.starts_with("Topic 1 | Topic 2"));
        assert!(s.contains("coffee"));
    }

    #[test]
    fn cv_zero_for_even_distribution() {
        let m = Csr::from_dense(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(column_nnz_cv(&m), 0.0);
        let skew = Csr::from_dense(3, 2, &[1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
        assert!(column_nnz_cv(&skew) > 0.9);
    }
}
