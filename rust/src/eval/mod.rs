//! Evaluation: the paper's §3 measures.
//!
//! * [`accuracy`] — Eq. 3.3 document-clustering accuracy against
//!   ground-truth journal labels.
//! * [`topics`] — top-magnitude terms per topic (the Fig. 2/7 and Table 1
//!   topic tables) and nonzero-distribution statistics.
//! * [`sparsity`] — the Fig. 1 sparsity table for A, U, V and U·Vᵀ.
//! * [`loglik`] — held-out mean per-token log-likelihood, the
//!   objective-agnostic predictive measure (comparable across the
//!   Frobenius and KL training objectives).

pub mod accuracy;
pub mod loglik;
pub mod sparsity;
pub mod topics;

pub use accuracy::{mean_topic_accuracy, topic_accuracy};
pub use loglik::{heldout_mean_log_likelihood, HeldOut, HELDOUT_STRIDE};
pub use sparsity::{sparsity_fraction, SparsityReport};
pub use topics::{top_terms, topic_term_table};
