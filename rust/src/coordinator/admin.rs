//! Localhost admin/observability listener, shared by the serving plane
//! (`esnmf serve --admin-port`) and the solver plane
//! (`esnmf factorize --admin-port`).
//!
//! A second, operator-facing TCP endpoint that shares process state with
//! the data plane but never competes with user traffic for its worker
//! pool:
//!
//! ```text
//! HEALTH          → "OK up generation=<g> requests=<n>" (serve)
//!                   "OK up spans_entered=<n>"           (factorize)
//! READY           → "OK ready generation=<g>" | "ERR not ready: <why>"
//! METRICS         → Prometheus text exposition, terminated by "# EOF"
//! PROGRESS        → "OK running iteration=<i>/<n> residual=<r> ..." (any plane)
//! TRACEDUMP       → trace-ring JSONL snapshot, terminated by "# EOF"
//! PROVENANCE      → "OK path=... crc32=... digest=... k=... ..." (one line)
//! RELOAD <path>   → "OK swapped generation=<g> k=<k>" | "ERR reload failed: ..."
//! PING            → "OK pong"
//! QUIT            → closes the connection
//! ```
//!
//! Which commands answer depends on the plane: each listener serves an
//! [`AdminSurface`] that handles its own commands and declines the rest
//! (`ERR unsupported command on this plane`). `PING`, `PROGRESS`, and
//! `TRACEDUMP` read process-global state (the trace ring and progress
//! atomics in [`crate::util::trace`]) and are answered uniformly by the
//! shared dispatcher before the surface is consulted.
//!
//! `READY` tracks [`ServerState::ready`]: it flips false on a recorded
//! corpus-store fault and recovers on the next successful swap. A failed
//! `RELOAD` does **not** flip it — the previous model is still serving,
//! untouched, and a rolling deploy probing `READY` must keep routing
//! traffic here.
//!
//! Connections are handled serially on one dedicated thread: admin
//! traffic is one operator or one scrape loop, and serializing it means
//! a `RELOAD` (the only slow command) cannot race another `RELOAD`.
//! Binding is restricted to loopback by the driver; the listener itself
//! also refuses non-loopback addresses as defense in depth.

use super::metrics;
use super::server::ServerState;
use crate::io::store::ResidentCounter;
use crate::io::wire::{is_timeout, AdminRequest, LineReader};
use crate::util::trace;
use crate::Result;
use std::io::{ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Stop-flag poll interval for a blocked admin read.
const READ_POLL: Duration = Duration::from_millis(50);

/// Bounded response write, as on the data plane: a scraper that stops
/// reading gets disconnected instead of wedging the admin thread.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// One plane's answers to admin commands. Return `None` for commands
/// the plane doesn't support; the dispatcher renders the refusal so
/// every listener declines uniformly.
pub trait AdminSurface: Send + Sync {
    fn admin(&self, req: &AdminRequest) -> Option<String>;
}

/// Answer one admin command line against `surface`. Pure request →
/// response (no I/O), so unit tests drive the full command surface
/// without a socket.
pub fn dispatch_line(surface: &dyn AdminSurface, line: &str) -> String {
    let req = match AdminRequest::parse(line.trim()) {
        Ok(req) => req,
        // a parse failure IS the response line (wire-layer contract)
        Err(err) => return err,
    };
    // plane-independent commands: these read process-global state and
    // must answer identically on every listener
    match req {
        AdminRequest::Ping => return "OK pong".into(),
        AdminRequest::Progress => return trace::progress::render(),
        // multi-line: readers consume until the `# EOF` terminator
        AdminRequest::TraceDump => return format!("{}# EOF", trace::ring_jsonl()),
        _ => {}
    }
    surface
        .admin(&req)
        .unwrap_or_else(|| "ERR unsupported command on this plane".into())
}

/// Serving-plane compatibility wrapper around [`dispatch_line`].
pub fn admin_command(state: &ServerState, line: &str) -> String {
    dispatch_line(state, line)
}

impl AdminSurface for ServerState {
    fn admin(&self, req: &AdminRequest) -> Option<String> {
        Some(match req {
            AdminRequest::Health => format!(
                "OK up generation={} requests={}",
                self.generation(),
                self.metrics.counter("server.requests").get()
            ),
            AdminRequest::Ready => {
                if self.ready() {
                    format!("OK ready generation={}", self.generation())
                } else {
                    let why = self
                        .fault_message()
                        .unwrap_or_else(|| "no servable model".into());
                    format!("ERR not ready: {why}")
                }
            }
            // multi-line: scrapers read until the `# EOF` terminator
            AdminRequest::Metrics => format!("{}# EOF", self.metrics.prometheus()),
            AdminRequest::Provenance => {
                let active = self.active();
                let p = &active.provenance;
                fn opt<T: std::fmt::Display>(v: &Option<T>) -> String {
                    v.as_ref().map_or_else(|| "-".into(), |x| x.to_string())
                }
                format!(
                    "OK path={} crc32={} digest={} k={} terms={} docs={} \
                     sparsity={} options={} objective={} foldin_t={} loaded_unix_ms={} generation={}",
                    opt(&p.path),
                    p.file_crc32
                        .map_or_else(|| "-".into(), |c| format!("{c:#010x}")),
                    p.corpus_digest
                        .map_or_else(|| "-".into(), |d| format!("{d:#018x}")),
                    p.k,
                    p.n_terms,
                    p.n_docs,
                    p.sparsity,
                    p.options,
                    p.objective,
                    opt(&p.foldin_t),
                    p.loaded_unix_ms,
                    active.generation,
                )
            }
            AdminRequest::Reload { path } => match self.swap_model(std::path::Path::new(path)) {
                Ok(active) => {
                    crate::log_info!(
                        "admin",
                        "hot-swapped model from {path} (generation {})",
                        active.generation
                    );
                    format!(
                        "OK swapped generation={} k={}",
                        active.generation,
                        active.model.k()
                    )
                }
                Err(e) => format!("ERR reload failed: {e}"),
            },
            AdminRequest::Ping | AdminRequest::Progress | AdminRequest::TraceDump => {
                return None; // handled by the dispatcher
            }
        })
    }
}

/// Admin surface for a `factorize` run (local or distributed
/// coordinator). Serves the process-global metrics registry — where the
/// distributed per-worker counters and kernel telemetry live — plus
/// out-of-core store gauges sampled from the shared
/// [`ResidentCounter`] at scrape time.
pub struct FactorizeAdmin {
    resident: Option<Arc<ResidentCounter>>,
}

impl FactorizeAdmin {
    pub fn new(resident: Option<Arc<ResidentCounter>>) -> Self {
        FactorizeAdmin { resident }
    }
}

impl AdminSurface for FactorizeAdmin {
    fn admin(&self, req: &AdminRequest) -> Option<String> {
        match req {
            AdminRequest::Health => {
                Some(format!("OK up spans_entered={}", trace::spans_entered()))
            }
            AdminRequest::Metrics => {
                let reg = metrics::global();
                if let Some(r) = &self.resident {
                    // sampled at scrape time: the solver never touches
                    // the registry on its read path
                    let clamp = |v: u64| v.min(i64::MAX as u64) as i64;
                    reg.gauge("store.resident_bytes")
                        .set(clamp(r.current() as u64));
                    reg.gauge("store.resident_peak_bytes")
                        .set(clamp(r.peak() as u64));
                    reg.gauge("store.shard_reads_hit").set(clamp(r.cache_hits()));
                    reg.gauge("store.shard_reads_miss")
                        .set(clamp(r.cache_misses()));
                }
                Some(format!("{}# EOF", reg.prometheus()))
            }
            _ => None,
        }
    }
}

fn admin_conn(stream: TcpStream, surface: &dyn AdminSurface, stop: &AtomicBool) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = LineReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    loop {
        let line = loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match reader.read_line() {
                Ok(Some(l)) => break l,
                Ok(None) => return,
                Err(e) if is_timeout(&e) => continue,
                Err(_) => return,
            }
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.eq_ignore_ascii_case("QUIT") {
            let _ = writeln!(writer, "OK bye");
            return;
        }
        let response = dispatch_line(surface, line);
        if writeln!(writer, "{response}").is_err() {
            return;
        }
    }
}

/// The admin listener handle; stops (gracefully) on [`AdminServer::stop`]
/// or drop, exactly like the data-plane `TopicServer`.
pub struct AdminServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl AdminServer {
    /// Serving-plane wrapper around [`AdminServer::start_on`].
    pub fn start(addr: &str, state: Arc<ServerState>) -> Result<AdminServer> {
        AdminServer::start_on(addr, state)
    }

    /// Bind `addr` (loopback only — e.g. `127.0.0.1:9090`, or port 0 for
    /// an ephemeral test port) and serve admin commands against
    /// `surface` on one dedicated `esnmf-admin` thread.
    pub fn start_on(addr: &str, surface: Arc<dyn AdminSurface>) -> Result<AdminServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        if !local.ip().is_loopback() {
            return Err(anyhow::anyhow!(
                "admin listener must bind loopback, got {local} \
                 (RELOAD and METRICS are operator-only)"
            ));
        }
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("esnmf-admin".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let _ = stream.set_nonblocking(false);
                            // serial, panic-isolated: one bad admin
                            // connection costs itself, never the listener
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || admin_conn(stream, surface.as_ref(), &stop2),
                            ));
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(e) => {
                            crate::log_warn!("admin", "accept failed: {e}");
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            })?;
        Ok(AdminServer {
            addr: local,
            stop,
            join: Some(join),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the admin thread (in-flight connection
    /// observes the flag within its read-poll interval).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for AdminServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::metrics::MetricsRegistry;
    use super::super::model::TopicModel;
    use super::super::server::respond;
    use crate::sparse::Csr;

    fn state() -> ServerState {
        let u = Csr::from_dense(3, 2, &[0.9, 0.0, 0.4, 0.0, 0.0, 0.7]);
        let v = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let model = TopicModel::new(
            u,
            v,
            vec!["coffee".into(), "crop".into(), "electrons".into()],
        );
        ServerState::new(Arc::new(model), MetricsRegistry::new(), 16)
    }

    #[test]
    fn health_reports_generation_and_requests() {
        let s = state();
        let _ = respond(&s, "PING");
        let _ = respond(&s, "TOPICS");
        assert_eq!(admin_command(&s, "HEALTH"), "OK up generation=0 requests=2");
        assert_eq!(admin_command(&s, "health"), "OK up generation=0 requests=2");
    }

    #[test]
    fn ready_tracks_store_faults() {
        let s = state();
        assert_eq!(admin_command(&s, "READY"), "OK ready generation=0");
        s.set_store_fault("corpus store i/o: short read");
        assert_eq!(
            admin_command(&s, "READY"),
            "ERR not ready: corpus store i/o: short read"
        );
    }

    #[test]
    fn metrics_exports_prometheus_with_terminator() {
        let s = state();
        let _ = respond(&s, "CLASSIFY coffee");
        let text = admin_command(&s, "METRICS");
        assert!(text.ends_with("# EOF"), "{text}");
        assert!(text.contains("esnmf_server_requests 1\n"), "{text}");
        assert!(
            text.contains("# TYPE esnmf_server_latency_classify_us histogram\n"),
            "{text}"
        );
    }

    /// Prometheus text-format conformance for the METRICS surface: metric
    /// name charset, label syntax, histogram bucket monotonicity and
    /// `+Inf`/`_sum`/`_count` consistency, exactly one trailing `# EOF`.
    fn assert_prometheus_conformant(text: &str) {
        assert!(text.ends_with("# EOF"), "missing terminator: {text:?}");
        assert_eq!(text.matches("# EOF").count(), 1, "multiple EOFs: {text:?}");
        let body = text.strip_suffix("# EOF").unwrap();
        fn valid_name(name: &str) -> bool {
            !name.is_empty()
                && name.chars().next().is_some_and(|c| {
                    c.is_ascii_alphabetic() || c == '_' || c == ':'
                })
                && name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        }
        use std::collections::BTreeMap;
        // histogram name → (buckets in order, sum, count, saw +Inf)
        let mut hists: BTreeMap<String, (Vec<u64>, Option<f64>, Option<u64>, Option<u64>)> =
            BTreeMap::new();
        for line in body.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut parts = rest.split(' ');
                let name = parts.next().unwrap_or("");
                let kind = parts.next().unwrap_or("");
                assert!(valid_name(name), "bad TYPE name: {line}");
                assert!(
                    matches!(kind, "counter" | "gauge" | "histogram"),
                    "bad TYPE kind: {line}"
                );
                if kind == "histogram" {
                    hists.insert(name.to_string(), (Vec::new(), None, None, None));
                }
                continue;
            }
            assert!(!line.starts_with('#'), "unexpected comment: {line}");
            let (name_part, value) = line.rsplit_once(' ').expect("name value");
            assert!(value.parse::<f64>().is_ok(), "bad value: {line}");
            let (name, labels) = match name_part.split_once('{') {
                Some((n, l)) => (n, Some(l.strip_suffix('}').expect("closed label set"))),
                None => (name_part, None),
            };
            assert!(valid_name(name), "bad metric name: {line}");
            if let Some(labels) = labels {
                for pair in labels.split(',') {
                    let (k, v) = pair.split_once('=').expect("label k=v");
                    assert!(valid_name(k), "bad label name: {line}");
                    assert!(
                        v.starts_with('"') && v.ends_with('"') && v.len() >= 2,
                        "unquoted label value: {line}"
                    );
                    let inner = &v[1..v.len() - 1];
                    assert!(
                        !inner.contains('"') && !inner.contains('\n') && !inner.contains('\\'),
                        "label value needs escaping we never emit: {line}"
                    );
                }
            }
            if let Some(base) = name.strip_suffix("_bucket") {
                let (_, le) = labels
                    .expect("bucket has le label")
                    .split_once("le=\"")
                    .expect("le label");
                let le = le.strip_suffix('"').unwrap();
                let cum: u64 = value.parse().unwrap();
                let h = hists.get_mut(base).expect("bucket after TYPE histogram");
                if le == "+Inf" {
                    h.3 = Some(cum);
                } else {
                    assert!(le.parse::<f64>().is_ok(), "bad le bound: {line}");
                    assert!(h.3.is_none(), "+Inf bucket must come last: {line}");
                    h.0.push(cum);
                }
            } else if let Some(base) = name.strip_suffix("_sum") {
                if let Some(h) = hists.get_mut(base) {
                    h.1 = Some(value.parse().unwrap());
                }
            } else if let Some(base) = name.strip_suffix("_count") {
                if let Some(h) = hists.get_mut(base) {
                    h.2 = Some(value.parse().unwrap());
                }
            }
        }
        assert!(!hists.is_empty(), "conformance run must cover a histogram");
        for (name, (buckets, sum, count, inf)) in hists {
            assert!(
                buckets.windows(2).all(|w| w[0] <= w[1]),
                "{name}: buckets not monotone: {buckets:?}"
            );
            let inf = inf.unwrap_or_else(|| panic!("{name}: missing +Inf bucket"));
            let count = count.unwrap_or_else(|| panic!("{name}: missing _count"));
            assert!(sum.is_some(), "{name}: missing _sum");
            assert_eq!(inf, count, "{name}: +Inf bucket must equal _count");
            if let Some(&last) = buckets.last() {
                assert!(last <= inf, "{name}: finite bucket above +Inf");
            }
        }
    }

    #[test]
    fn serve_metrics_are_prometheus_conformant() {
        let s = state();
        let _ = respond(&s, "CLASSIFY coffee");
        let _ = respond(&s, "TOPICS");
        assert_prometheus_conformant(&admin_command(&s, "METRICS"));
    }

    #[test]
    fn factorize_metrics_are_prometheus_conformant_and_export_store_gauges() {
        let resident = Arc::new(ResidentCounter::default());
        let surface = FactorizeAdmin::new(Some(Arc::clone(&resident)));
        // the global registry needs at least one histogram for the
        // conformance sweep to exercise bucket checks
        metrics::global().histogram("dist.roundtrip").observe_us(42);
        let text = dispatch_line(&surface, "METRICS");
        assert_prometheus_conformant(&text);
        assert!(text.contains("esnmf_store_resident_bytes "), "{text}");
        assert!(text.contains("esnmf_store_resident_peak_bytes "), "{text}");
        assert!(text.contains("esnmf_store_shard_reads_hit "), "{text}");
        assert!(text.contains("esnmf_store_shard_reads_miss "), "{text}");
    }

    #[test]
    fn factorize_surface_declines_serving_commands() {
        let surface = FactorizeAdmin::new(None);
        assert!(dispatch_line(&surface, "HEALTH").starts_with("OK up spans_entered="));
        assert_eq!(
            dispatch_line(&surface, "READY"),
            "ERR unsupported command on this plane"
        );
        assert_eq!(
            dispatch_line(&surface, "RELOAD /tmp/x.esnmf"),
            "ERR unsupported command on this plane"
        );
        assert_eq!(dispatch_line(&surface, "PING"), "OK pong");
    }

    #[test]
    fn progress_and_tracedump_answer_on_every_plane() {
        let s = state();
        let p = admin_command(&s, "PROGRESS");
        assert!(p.starts_with("OK "), "{p}");
        let dump = admin_command(&s, "TRACEDUMP");
        assert!(dump.ends_with("# EOF"), "{dump}");
        assert!(
            dump.lines().next().unwrap().contains("esnmf-trace-"),
            "{dump}"
        );
        let f = FactorizeAdmin::new(None);
        assert!(dispatch_line(&f, "PROGRESS").starts_with("OK "));
        assert!(dispatch_line(&f, "TRACEDUMP").ends_with("# EOF"));
    }

    #[test]
    fn provenance_is_one_line_of_key_value_pairs() {
        let s = state();
        let line = admin_command(&s, "PROVENANCE");
        assert!(!line.contains('\n'));
        assert!(line.starts_with("OK path=- crc32=- "), "{line}");
        assert!(line.contains(" k=2 terms=3 docs=2 "), "{line}");
        assert!(line.contains(" objective=frobenius "), "{line}");
        assert!(line.ends_with("generation=0"), "{line}");
        for pair in line.trim_start_matches("OK ").split(' ') {
            assert!(pair.contains('='), "not key=value: {pair:?} in {line}");
        }
    }

    #[test]
    fn reload_rejects_bad_usage_and_missing_files() {
        let s = state();
        assert_eq!(admin_command(&s, "RELOAD"), "ERR usage: RELOAD <path.esnmf>");
        assert!(admin_command(&s, "RELOAD a b").starts_with("ERR usage"));
        let r = admin_command(&s, "RELOAD /nonexistent/model.esnmf");
        assert!(r.starts_with("ERR reload failed:"), "{r}");
        assert_eq!(s.generation(), 0);
        assert!(s.ready(), "failed reload must not flip READY");
    }

    #[test]
    fn unknown_commands_answer_err() {
        let s = state();
        assert!(admin_command(&s, "FROBNICATE").starts_with("ERR unknown"));
        assert_eq!(admin_command(&s, ""), "ERR empty command");
        assert_eq!(admin_command(&s, "PING"), "OK pong");
    }
}
