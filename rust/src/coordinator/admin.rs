//! Localhost admin/observability listener (`esnmf serve --admin-port`).
//!
//! A second, operator-facing TCP endpoint that shares the
//! [`ServerState`] with the data plane but never competes with user
//! traffic for its worker pool:
//!
//! ```text
//! HEALTH          → "OK up generation=<g> requests=<n>"
//! READY           → "OK ready generation=<g>" | "ERR not ready: <why>"
//! METRICS         → Prometheus text exposition, terminated by "# EOF"
//! PROVENANCE      → "OK path=... crc32=... digest=... k=... ..." (one line)
//! RELOAD <path>   → "OK swapped generation=<g> k=<k>" | "ERR reload failed: ..."
//! PING            → "OK pong"
//! QUIT            → closes the connection
//! ```
//!
//! `READY` tracks [`ServerState::ready`]: it flips false on a recorded
//! corpus-store fault and recovers on the next successful swap. A failed
//! `RELOAD` does **not** flip it — the previous model is still serving,
//! untouched, and a rolling deploy probing `READY` must keep routing
//! traffic here.
//!
//! Connections are handled serially on one dedicated thread: admin
//! traffic is one operator or one scrape loop, and serializing it means
//! a `RELOAD` (the only slow command) cannot race another `RELOAD`.
//! Binding is restricted to loopback by the driver; the listener itself
//! also refuses non-loopback addresses as defense in depth.

use super::server::ServerState;
use crate::io::wire::{is_timeout, AdminRequest, LineReader};
use crate::Result;
use std::io::{ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Stop-flag poll interval for a blocked admin read.
const READ_POLL: Duration = Duration::from_millis(50);

/// Bounded response write, as on the data plane: a scraper that stops
/// reading gets disconnected instead of wedging the admin thread.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Answer one admin command line. Pure request → response (no I/O), so
/// unit tests drive the full command surface without a socket.
pub fn admin_command(state: &ServerState, line: &str) -> String {
    let req = match AdminRequest::parse(line.trim()) {
        Ok(req) => req,
        // a parse failure IS the response line (wire-layer contract)
        Err(err) => return err,
    };
    match req {
        AdminRequest::Health => format!(
            "OK up generation={} requests={}",
            state.generation(),
            state.metrics.counter("server.requests").get()
        ),
        AdminRequest::Ready => {
            if state.ready() {
                format!("OK ready generation={}", state.generation())
            } else {
                let why = state
                    .fault_message()
                    .unwrap_or_else(|| "no servable model".into());
                format!("ERR not ready: {why}")
            }
        }
        // multi-line: scrapers read until the `# EOF` terminator
        AdminRequest::Metrics => format!("{}# EOF", state.metrics.prometheus()),
        AdminRequest::Provenance => {
            let active = state.active();
            let p = &active.provenance;
            fn opt<T: std::fmt::Display>(v: &Option<T>) -> String {
                v.as_ref().map_or_else(|| "-".into(), |x| x.to_string())
            }
            format!(
                "OK path={} crc32={} digest={} k={} terms={} docs={} \
                 sparsity={} options={} objective={} foldin_t={} loaded_unix_ms={} generation={}",
                opt(&p.path),
                p.file_crc32
                    .map_or_else(|| "-".into(), |c| format!("{c:#010x}")),
                p.corpus_digest
                    .map_or_else(|| "-".into(), |d| format!("{d:#018x}")),
                p.k,
                p.n_terms,
                p.n_docs,
                p.sparsity,
                p.options,
                p.objective,
                opt(&p.foldin_t),
                p.loaded_unix_ms,
                active.generation,
            )
        }
        AdminRequest::Reload { path } => match state.swap_model(std::path::Path::new(&path)) {
            Ok(active) => {
                crate::log_info!(
                    "admin",
                    "hot-swapped model from {path} (generation {})",
                    active.generation
                );
                format!(
                    "OK swapped generation={} k={}",
                    active.generation,
                    active.model.k()
                )
            }
            Err(e) => format!("ERR reload failed: {e}"),
        },
        AdminRequest::Ping => "OK pong".into(),
    }
}

fn admin_conn(stream: TcpStream, state: &ServerState, stop: &AtomicBool) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = LineReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    loop {
        let line = loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match reader.read_line() {
                Ok(Some(l)) => break l,
                Ok(None) => return,
                Err(e) if is_timeout(&e) => continue,
                Err(_) => return,
            }
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.eq_ignore_ascii_case("QUIT") {
            let _ = writeln!(writer, "OK bye");
            return;
        }
        let response = admin_command(state, line);
        if writeln!(writer, "{response}").is_err() {
            return;
        }
    }
}

/// The admin listener handle; stops (gracefully) on [`AdminServer::stop`]
/// or drop, exactly like the data-plane `TopicServer`.
pub struct AdminServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl AdminServer {
    /// Bind `addr` (loopback only — e.g. `127.0.0.1:9090`, or port 0 for
    /// an ephemeral test port) and serve admin commands against `state`
    /// on one dedicated `esnmf-admin` thread.
    pub fn start(addr: &str, state: Arc<ServerState>) -> Result<AdminServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        if !local.ip().is_loopback() {
            return Err(anyhow::anyhow!(
                "admin listener must bind loopback, got {local} \
                 (RELOAD and METRICS are operator-only)"
            ));
        }
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("esnmf-admin".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let _ = stream.set_nonblocking(false);
                            // serial, panic-isolated: one bad admin
                            // connection costs itself, never the listener
                            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || admin_conn(stream, &state, &stop2),
                            ));
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(e) => {
                            crate::log_warn!("admin", "accept failed: {e}");
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            })?;
        Ok(AdminServer {
            addr: local,
            stop,
            join: Some(join),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the admin thread (in-flight connection
    /// observes the flag within its read-poll interval).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for AdminServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::metrics::MetricsRegistry;
    use super::super::model::TopicModel;
    use super::super::server::respond;
    use crate::sparse::Csr;

    fn state() -> ServerState {
        let u = Csr::from_dense(3, 2, &[0.9, 0.0, 0.4, 0.0, 0.0, 0.7]);
        let v = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let model = TopicModel::new(
            u,
            v,
            vec!["coffee".into(), "crop".into(), "electrons".into()],
        );
        ServerState::new(Arc::new(model), MetricsRegistry::new(), 16)
    }

    #[test]
    fn health_reports_generation_and_requests() {
        let s = state();
        let _ = respond(&s, "PING");
        let _ = respond(&s, "TOPICS");
        assert_eq!(admin_command(&s, "HEALTH"), "OK up generation=0 requests=2");
        assert_eq!(admin_command(&s, "health"), "OK up generation=0 requests=2");
    }

    #[test]
    fn ready_tracks_store_faults() {
        let s = state();
        assert_eq!(admin_command(&s, "READY"), "OK ready generation=0");
        s.set_store_fault("corpus store i/o: short read");
        assert_eq!(
            admin_command(&s, "READY"),
            "ERR not ready: corpus store i/o: short read"
        );
    }

    #[test]
    fn metrics_exports_prometheus_with_terminator() {
        let s = state();
        let _ = respond(&s, "CLASSIFY coffee");
        let text = admin_command(&s, "METRICS");
        assert!(text.ends_with("# EOF"), "{text}");
        assert!(text.contains("esnmf_server_requests 1\n"), "{text}");
        assert!(
            text.contains("# TYPE esnmf_server_latency_classify_us histogram\n"),
            "{text}"
        );
    }

    #[test]
    fn provenance_is_one_line_of_key_value_pairs() {
        let s = state();
        let line = admin_command(&s, "PROVENANCE");
        assert!(!line.contains('\n'));
        assert!(line.starts_with("OK path=- crc32=- "), "{line}");
        assert!(line.contains(" k=2 terms=3 docs=2 "), "{line}");
        assert!(line.contains(" objective=frobenius "), "{line}");
        assert!(line.ends_with("generation=0"), "{line}");
        for pair in line.trim_start_matches("OK ").split(' ') {
            assert!(pair.contains('='), "not key=value: {pair:?} in {line}");
        }
    }

    #[test]
    fn reload_rejects_bad_usage_and_missing_files() {
        let s = state();
        assert_eq!(admin_command(&s, "RELOAD"), "ERR usage: RELOAD <path.esnmf>");
        assert!(admin_command(&s, "RELOAD a b").starts_with("ERR usage"));
        let r = admin_command(&s, "RELOAD /nonexistent/model.esnmf");
        assert!(r.starts_with("ERR reload failed:"), "{r}");
        assert_eq!(s.generation(), 0);
        assert!(s.ready(), "failed reload must not flip READY");
    }

    #[test]
    fn unknown_commands_answer_err() {
        let s = state();
        assert!(admin_command(&s, "FROBNICATE").starts_with("ERR unknown"));
        assert_eq!(admin_command(&s, ""), "ERR empty command");
        assert_eq!(admin_command(&s, "PING"), "OK pong");
    }
}
