//! The stateful side of distributed factorization: the coordinator.
//!
//! The coordinator owns everything the single-process run owns — the
//! iteration loop, residual/error tracking, checkpoint cadence, the
//! memory telemetry — and replaces only the compute placement: each
//! half-step's block list is partitioned into contiguous spans
//! ([`pool::split_ranges`]) scattered to the joined workers, the
//! replies are merged in fixed global block order, and the two-pass
//! global top-t exchanges per-span [`TopTSelector`] summaries instead
//! of candidate matrices.
//!
//! # Determinism contract (the reason this file is small)
//!
//! An N-worker run is bit-identical to the single-process blocked run
//! at every worker count, including under worker failure:
//!
//! * every participant derives the same block geometry from the
//!   resolved `block_rows` the coordinator ships in each request;
//! * the fixed factor and the objective's auxiliary data (the ridged
//!   Gram inverse under Frobenius, the column sums — plus the previous
//!   iterate — under KL) travel as exact bits, and fragments are
//!   produced by the same [`StreamCtx`] code path a local run uses — a
//!   fragment's bits cannot depend on who computed it;
//! * fragments are assembled in ascending global block order, with the
//!   `Exact` tie budget consumed by the coordinator's serial scan;
//! * the top-t cutoff is an order statistic, so absorbing per-span
//!   selector summaries in any order yields the in-process cutoff;
//! * the memory tracker is max-based and observes the same multiset of
//!   scratch sizes, so the telemetry matches too.
//!
//! A span whose worker dies, stalls past the reply timeout, refuses, or
//! answers with a malformed frame is reassigned to surviving workers
//! and, when none remain, computed locally — the coordinator shares the
//! `.estdm`, so completion never depends on any worker surviving.

use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use crate::coordinator::{metrics, pool};
use crate::io::wire::{read_msg, write_msg, ComputeReq, PassReq, WorkerMsg, WORKER_PROTOCOL_VERSION};
use crate::io::CorpusStore;
use crate::nmf::als::{
    self, enforcement_for, stream_half_step, AlsCorpus, BlockCompute, BlockEmit, CandSource,
    Enforce, HalfSteps, Keep, Solve, StreamCtx,
};
use crate::nmf::{MemoryTracker, NmfOptions, NmfResult, ObjectiveKind};
use crate::sparse::source::RowSource;
use crate::sparse::{ops, topk, Csr, TieMode};
use crate::util::trace;
use crate::EsnmfError;

/// Knobs of one distributed run (CLI: `--dist-listen`, `--dist-workers`,
/// `--dist-timeout`).
#[derive(Clone, Debug)]
pub struct DistOptions {
    /// listener address workers join, e.g. `127.0.0.1:7611`
    pub listen: String,
    /// workers to wait for before starting (at least one must join)
    pub workers: usize,
    /// per-reply deadline; a worker silent past it is marked dead and
    /// its span reassigned
    pub timeout: Duration,
}

/// One joined worker connection.
struct WorkerConn {
    stream: TcpStream,
    peer: String,
    alive: bool,
}

impl WorkerConn {
    /// One request/reply exchange. `Err` is a human-readable reason the
    /// worker is now considered dead (timeout, hangup, refusal, or a
    /// malformed frame).
    fn roundtrip(&mut self, msg: &WorkerMsg, timeout: Duration) -> Result<WorkerMsg, String> {
        self.stream
            .set_read_timeout(Some(timeout))
            .map_err(|e| format!("set timeout: {e}"))?;
        write_msg(&mut self.stream, msg).map_err(|e| format!("send failed: {e}"))?;
        match read_msg(&mut self.stream) {
            Ok(WorkerMsg::Refuse { message }) => Err(format!("worker refused: {message}")),
            Ok(reply) => Ok(reply),
            Err(e) => Err(format!("reply failed: {e}")),
        }
    }
}

/// The distributed half-step engine plugged into the shared iteration
/// loop ([`als::factorize_corpus_with`]).
struct DistEngine {
    conns: Vec<WorkerConn>,
    timeout: Duration,
}

/// Run a distributed factorization over the shared on-disk corpus:
/// bind the worker listener, admit `dopts.workers` workers (each
/// verified against this store's digest and shape), and drive the
/// standard iteration loop with span-scattered half-steps.
pub fn run_distributed(
    store: &CorpusStore,
    opts: &NmfOptions,
    dopts: &DistOptions,
) -> Result<NmfResult, EsnmfError> {
    let listener = TcpListener::bind(&dopts.listen)?;
    run_distributed_on(listener, store, opts, dopts)
}

/// [`run_distributed`] over an already-bound listener. Lets callers
/// (tests, embedders) bind `127.0.0.1:0`, read the real address from
/// `listener.local_addr()`, and hand workers that address before the
/// coordinator starts admitting — no port race.
pub fn run_distributed_on(
    listener: TcpListener,
    store: &CorpusStore,
    opts: &NmfOptions,
    dopts: &DistOptions,
) -> Result<NmfResult, EsnmfError> {
    if dopts.workers == 0 {
        return Err(EsnmfError::config(
            "--dist-workers must be >= 1 (or drop --distributed)",
        ));
    }
    let conns = admit_workers(listener, store, opts.objective, dopts)?;
    let mut engine = DistEngine {
        conns,
        timeout: dopts.timeout,
    };
    let result = als::factorize_corpus_with(store, opts, &mut engine);
    emit_worker_summaries(&engine.conns);
    engine.shutdown();
    Ok(result)
}

/// Per-worker telemetry counter under the process-global registry.
/// `wi` is the worker's stable admission index.
fn worker_counter(wi: usize, what: &str) -> std::sync::Arc<metrics::Counter> {
    metrics::global().counter(&format!("dist.worker{wi}.{what}"))
}

/// Bump one per-worker counter and the matching `dist.<what>` run total
/// together, so per-worker values always sum to the totals.
fn count_worker(wi: usize, what: &str, n: u64) {
    if n == 0 {
        return;
    }
    worker_counter(wi, what).add(n);
    metrics::global().counter(&format!("dist.{what}")).add(n);
}

const WORKER_COUNTER_KINDS: [&str; 6] = [
    "requests",
    "compute_us",
    "wait_us",
    "items",
    "straggler_rounds",
    "reassigned_spans",
];

/// End-of-run telemetry: one `worker_summary` trace event per admitted
/// worker plus a `dist_totals` event, all read back from the registry —
/// the CI trace smoke asserts the per-worker events sum to the totals.
fn emit_worker_summaries(conns: &[WorkerConn]) {
    for wi in 0..conns.len() {
        let mut fields: Vec<(&'static str, f64)> = vec![("worker", wi as f64)];
        for kind in WORKER_COUNTER_KINDS {
            fields.push((kind, worker_counter(wi, kind).get() as f64));
        }
        fields.push(("alive", f64::from(u8::from(conns[wi].alive))));
        trace::event("worker_summary", &fields);
    }
    let mut fields: Vec<(&'static str, f64)> = vec![("workers", conns.len() as f64)];
    for kind in WORKER_COUNTER_KINDS {
        let total = metrics::global().counter(&format!("dist.{kind}")).get();
        fields.push((kind, total as f64));
    }
    trace::event("dist_totals", &fields);
}

/// Accept and handshake workers until `dopts.workers` have joined or the
/// join deadline passes. At least one worker must join; a short-handed
/// start warns and proceeds (missing spans fall back to local compute —
/// the run completes either way).
fn admit_workers(
    listener: TcpListener,
    store: &CorpusStore,
    objective: ObjectiveKind,
    dopts: &DistOptions,
) -> Result<Vec<WorkerConn>, EsnmfError> {
    listener.set_nonblocking(true)?;
    crate::log_info!(
        "dist",
        "waiting for {} worker(s) on {}",
        dopts.workers,
        dopts.listen
    );
    let deadline = Instant::now() + dopts.timeout;
    let mut conns = Vec::new();
    while conns.len() < dopts.workers && Instant::now() < deadline {
        match listener.accept() {
            Ok((stream, peer)) => match handshake(store, objective, stream, &peer.to_string()) {
                Ok(conn) => {
                    crate::log_info!("dist", "worker {} joined ({}/{})", conn.peer, conns.len() + 1, dopts.workers);
                    conns.push(conn);
                }
                Err(why) => {
                    crate::log_warn!("dist", "rejected worker {peer}: {why}");
                }
            },
            Err(e) if crate::io::wire::is_timeout(&e) => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => return Err(e.into()),
        }
    }
    if conns.is_empty() {
        return Err(EsnmfError::protocol(format!(
            "no workers joined {} within {:?}",
            dopts.listen, dopts.timeout
        )));
    }
    if conns.len() < dopts.workers {
        crate::log_warn!(
            "dist",
            "starting short-handed: {}/{} workers joined",
            conns.len(),
            dopts.workers
        );
    }
    Ok(conns)
}

/// Verify one joining worker: protocol version, that it opened the
/// *same* corpus (digest + shape), and that it was launched under this
/// run's objective — all before any work flows.
fn handshake(
    store: &CorpusStore,
    objective: ObjectiveKind,
    stream: TcpStream,
    peer: &str,
) -> Result<WorkerConn, String> {
    let mut conn = WorkerConn {
        stream,
        peer: peer.to_string(),
        alive: true,
    };
    conn.stream.set_nodelay(true).ok();
    conn.stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .map_err(|e| format!("set timeout: {e}"))?;
    let refuse = |conn: &mut WorkerConn, message: String| {
        let _ = write_msg(&mut conn.stream, &WorkerMsg::Refuse { message: message.clone() });
        Err(message)
    };
    match read_msg(&mut conn.stream) {
        Ok(WorkerMsg::Hello {
            version,
            digest,
            n_terms,
            n_docs,
            objective: worker_objective,
        }) => {
            if version != WORKER_PROTOCOL_VERSION {
                return refuse(
                    &mut conn,
                    format!("protocol v{version}, coordinator speaks v{WORKER_PROTOCOL_VERSION}"),
                );
            }
            if worker_objective != objective {
                return refuse(
                    &mut conn,
                    format!(
                        "objective mismatch: worker runs {}, this factorization is {}",
                        worker_objective.name(),
                        objective.name()
                    ),
                );
            }
            if digest != store.digest()
                || n_terms as usize != AlsCorpus::n_terms(store)
                || n_docs as usize != AlsCorpus::n_docs(store)
            {
                return refuse(
                    &mut conn,
                    format!(
                        "corpus mismatch: worker serves digest {digest:#018x} ({n_terms}×{n_docs}), \
                         coordinator has {:#018x} ({}×{})",
                        store.digest(),
                        AlsCorpus::n_terms(store),
                        AlsCorpus::n_docs(store)
                    ),
                );
            }
            write_msg(
                &mut conn.stream,
                &WorkerMsg::Welcome {
                    version: WORKER_PROTOCOL_VERSION,
                },
            )
            .map_err(|e| format!("welcome failed: {e}"))?;
            Ok(conn)
        }
        Ok(other) => refuse(&mut conn, format!("expected Hello, got {other:?}")),
        Err(e) => Err(format!("bad hello: {e}")),
    }
}

impl DistEngine {
    fn shutdown(&mut self) {
        for conn in self.conns.iter_mut().filter(|c| c.alive) {
            let _ = write_msg(&mut conn.stream, &WorkerMsg::Shutdown);
        }
    }

    fn half_step(
        &mut self,
        corpus: &dyn AlsCorpus,
        factor: &Csr,
        prev: &Csr,
        step_u: bool,
        opts: &NmfOptions,
        mem: &mut MemoryTracker,
    ) -> Csr {
        let row_src = if step_u {
            corpus.a_rows()
        } else {
            corpus.a_cols()
        };
        assert_eq!(row_src.cols(), factor.rows, "half-step contraction mismatch");
        // computed once here so every worker solves against identical
        // bits: the ridged Gram inverse (Frobenius) or the fixed
        // factor's column sums (KL)
        let aux = opts.objective.implementation().step_aux(factor, opts.threads);
        let block_rows = opts.resolved_block_rows();
        let src = CandSource {
            src: row_src,
            factor,
            dense: match opts.objective {
                ObjectiveKind::Frobenius => ops::dense_factor(factor),
                // the dense fast path belongs to the SpMM fill, unused by KL
                ObjectiveKind::Kl => None,
            },
            defl: None,
        };
        let compute = match opts.objective {
            ObjectiveKind::Frobenius => BlockCompute::Solve(Solve::Gram(aux.clone())),
            ObjectiveKind::Kl => {
                assert_eq!(prev.rows, row_src.rows(), "KL previous-iterate row mismatch");
                BlockCompute::Kl {
                    prev,
                    col_sums: aux.clone(),
                }
            }
        };
        let ctx = StreamCtx::with_compute(src, compute, opts.k, opts.threads, block_rows);
        let enforce = enforcement_for(opts.sparsity, step_u);

        // one block (or no one left to help): the in-process pipeline is
        // what a single-process run would execute here — use it verbatim
        if ctx.n_blocks() <= 1 || !self.conns.iter().any(|c| c.alive) {
            return stream_half_step(&ctx, enforce, opts.tie_mode, opts.threads, mem);
        }

        let req = |span: (usize, usize), pass: PassReq| {
            WorkerMsg::Compute(ComputeReq {
                step_u,
                objective: opts.objective,
                k: opts.k as u32,
                block_rows: block_rows as u64,
                span: (span.0 as u64, span.1 as u64),
                factor: factor.clone(),
                aux: aux.clone(),
                prev: match opts.objective {
                    ObjectiveKind::Frobenius => None,
                    ObjectiveKind::Kl => Some(prev.clone()),
                },
                pass,
            })
        };

        let emit_merged = |engine: &mut DistEngine,
                           keep: Keep,
                           trim: Option<(f32, usize)>,
                           mem: &mut MemoryTracker| {
            let (keep_tag, tau) = keep.to_wire();
            let span_emits = scatter(
                &mut engine.conns,
                engine.timeout,
                "scatter_emit",
                ctx.n_blocks(),
                |span| req(span, PassReq::Emit { keep_tag, tau }),
                |msg, span| parse_fragments(msg, span, &ctx),
                |span| ctx.emit_span(span.0, span.1, keep),
            );
            let emits: Vec<BlockEmit> = span_emits.into_iter().flatten().collect();
            let mut merge_span = trace::span("merge");
            merge_span.field("fragments", emits.len() as f64);
            let csr = ctx.assemble(emits, trim, mem);
            merge_span.field("nnz", csr.nnz() as f64);
            drop(merge_span);
            csr
        };

        match enforce {
            Enforce::No => emit_merged(self, Keep::All, None, mem),
            Enforce::Threshold(tau) => emit_merged(self, Keep::FiniteAtLeast(tau), None, mem),
            Enforce::PerColumn(t) => {
                let mut csr = emit_merged(self, Keep::All, None, mem);
                // same access-pattern cost (and telemetry) as in-process:
                // the unenforced CSR is a transient intermediate
                mem.observe_intermediate(csr.nnz());
                topk::enforce_top_t_per_column_par(&mut csr, t, opts.tie_mode, opts.threads);
                csr
            }
            Enforce::Global(t) => {
                // pass 1: per-span O(t) selector summaries
                let selected = scatter(
                    &mut self.conns,
                    self.timeout,
                    "scatter_select",
                    ctx.n_blocks(),
                    |span| req(span, PassReq::Select { t: t as u64 }),
                    |msg, span| parse_selected(msg, span, t),
                    |span| ctx.select_span(span.0, span.1, t),
                );
                let mut sel = topk::TopTSelector::new(t);
                for (lens, part) in selected {
                    for len in lens {
                        mem.observe_intermediate(len);
                    }
                    sel.absorb(part);
                }
                // pass 2: emission under the merged global cutoff
                match sel.cutoff() {
                    None => emit_merged(self, Keep::All, None, mem),
                    Some((tau, above)) => match opts.tie_mode {
                        TieMode::KeepTies => emit_merged(self, Keep::AtLeast(tau), None, mem),
                        TieMode::Exact => {
                            emit_merged(self, Keep::AboveOrTie(tau), Some((tau, t - above)), mem)
                        }
                    },
                }
            }
        }
    }
}

impl HalfSteps for DistEngine {
    fn v(
        &mut self,
        corpus: &dyn AlsCorpus,
        u: &Csr,
        v_prev: &Csr,
        opts: &NmfOptions,
        mem: &mut MemoryTracker,
    ) -> Csr {
        self.half_step(corpus, u, v_prev, false, opts, mem)
    }

    fn u(
        &mut self,
        corpus: &dyn AlsCorpus,
        v: &Csr,
        u_prev: &Csr,
        opts: &NmfOptions,
        mem: &mut MemoryTracker,
    ) -> Csr {
        self.half_step(corpus, v, u_prev, true, opts, mem)
    }
}

/// Validate one pass-1 reply into `(scratch_lens, selector)`.
fn parse_selected(
    msg: WorkerMsg,
    span: (usize, usize),
    t: usize,
) -> Result<(Vec<usize>, topk::TopTSelector), String> {
    match msg {
        WorkerMsg::Selected {
            scratch_lens,
            positives,
            heap,
            ..
        } => {
            if scratch_lens.len() != span.1 - span.0 {
                return Err(format!(
                    "selected reply covers {} blocks, span {:?} has {}",
                    scratch_lens.len(),
                    span,
                    span.1 - span.0
                ));
            }
            Ok((
                scratch_lens.iter().map(|&l| l as usize).collect(),
                topk::TopTSelector::from_wire_parts(t, positives as usize, &heap),
            ))
        }
        other => Err(format!("expected Selected, got {other:?}")),
    }
}

/// Validate one pass-2 reply into assembly-ready fragments: block count,
/// per-block row coverage, fragment self-consistency, and column bounds
/// are all checked before a byte reaches [`StreamCtx::assemble`].
fn parse_fragments(
    msg: WorkerMsg,
    span: (usize, usize),
    ctx: &StreamCtx<'_>,
) -> Result<Vec<BlockEmit>, String> {
    let WorkerMsg::Fragments { emits, .. } = msg else {
        return Err("expected Fragments, got another frame type".to_string());
    };
    if emits.len() != span.1 - span.0 {
        return Err(format!(
            "fragment reply covers {} blocks, span {:?} has {}",
            emits.len(),
            span,
            span.1 - span.0
        ));
    }
    let k = ctx.k();
    let mut out = Vec::with_capacity(emits.len());
    for (i, e) in emits.into_iter().enumerate() {
        let (lo, hi) = ctx.block_bounds(span.0 + i);
        if e.row_nnz.len() != hi - lo {
            return Err(format!(
                "fragment {} has {} rows, block {:?} has {}",
                span.0 + i,
                e.row_nnz.len(),
                (lo, hi),
                hi - lo
            ));
        }
        let total: usize = e.row_nnz.iter().map(|&n| n as usize).sum();
        if total != e.indices.len() || total != e.values.len() {
            return Err(format!(
                "fragment {} is inconsistent: row_nnz sums to {total}, {} indices / {} values",
                span.0 + i,
                e.indices.len(),
                e.values.len()
            ));
        }
        if e.indices.iter().any(|&c| c as usize >= k) {
            return Err(format!("fragment {} has a column index >= k={k}", span.0 + i));
        }
        out.push(BlockEmit::from_wire(e));
    }
    Ok(out)
}

/// Scatter one pass over the block list: partition into contiguous
/// spans (one per live worker), exchange concurrently, reassign failed
/// spans to survivors, and compute any still-unserved span locally.
/// Results come back in span order — global block order — whatever the
/// failure pattern.
fn scatter<R, M, P, L>(
    conns: &mut [WorkerConn],
    timeout: Duration,
    label: &'static str,
    n_blocks: usize,
    make: M,
    parse: P,
    local: L,
) -> Vec<R>
where
    M: Fn((usize, usize)) -> WorkerMsg,
    P: Fn(WorkerMsg, (usize, usize)) -> Result<R, String>,
    L: Fn((usize, usize)) -> R,
{
    let mut pass_span = trace::span(label);
    let live = conns.iter().filter(|c| c.alive).count();
    pass_span.field("n_blocks", n_blocks as f64);
    pass_span.field("workers", live as f64);
    let spans = pool::split_ranges(n_blocks, live);
    let mut results: Vec<Option<R>> = spans.iter().map(|_| None).collect();
    let mut rounds = 0u64;

    loop {
        let pending: Vec<usize> = results
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.is_none().then_some(i))
            .collect();
        if pending.is_empty() {
            break;
        }
        let alive: Vec<usize> = conns
            .iter()
            .enumerate()
            .filter_map(|(i, c)| c.alive.then_some(i))
            .collect();
        if alive.is_empty() {
            break;
        }
        rounds += 1;
        // one span per live worker per round; leftovers wait for the
        // next round (or for the local fallback)
        let batch: Vec<(usize, usize)> = pending.into_iter().zip(alive).collect();
        let jobs: Vec<(usize, WorkerMsg)> =
            batch.iter().map(|&(si, wi)| (wi, make(spans[si]))).collect();
        let replies = exchange(conns, timeout, jobs);
        // a worker is straggling when another finished the same round's
        // spans more than twice as fast — counted, never acted on
        let fastest_ok = replies
            .iter()
            .filter(|(r, _)| r.is_ok())
            .map(|&(_, us)| us)
            .min();
        let ok_count = replies.iter().filter(|(r, _)| r.is_ok()).count();
        for (&(si, wi), (reply, roundtrip_us)) in batch.iter().zip(replies) {
            let outcome = reply.and_then(|msg| {
                let summary = msg.summary();
                parse(msg, spans[si]).map(|r| (r, summary))
            });
            match outcome {
                Ok((r, summary)) => {
                    results[si] = Some(r);
                    count_worker(wi, "requests", 1);
                    if let Some(s) = summary {
                        count_worker(wi, "compute_us", s.compute_us);
                        count_worker(wi, "wait_us", roundtrip_us.saturating_sub(s.compute_us));
                        count_worker(wi, "items", s.items);
                    }
                    if let Some(floor) = fastest_ok {
                        if ok_count >= 2 && roundtrip_us > floor.saturating_mul(2) {
                            count_worker(wi, "straggler_rounds", 1);
                        }
                    }
                }
                Err(why) => {
                    crate::log_warn!(
                        "dist",
                        "worker {} dropped (span {:?}): {why}",
                        conns[wi].peer,
                        spans[si]
                    );
                    conns[wi].alive = false;
                    count_worker(wi, "reassigned_spans", 1);
                    trace::event(
                        "reassign",
                        &[
                            ("worker", wi as f64),
                            ("span_lo", spans[si].0 as f64),
                            ("span_hi", spans[si].1 as f64),
                        ],
                    );
                }
            }
        }
    }
    pass_span.field("rounds", rounds as f64);

    // guaranteed completion: the coordinator shares the store, so any
    // span no worker served is computed here with the identical engine
    results
        .into_iter()
        .zip(spans)
        .map(|(r, span)| {
            r.unwrap_or_else(|| {
                crate::log_warn!("dist", "computing span {span:?} locally (no live workers)");
                metrics::global().counter("dist.local_fallback_spans").inc();
                trace::event(
                    "local_fallback",
                    &[("span_lo", span.0 as f64), ("span_hi", span.1 as f64)],
                );
                local(span)
            })
        })
        .collect()
}

/// Run the batch's request/reply exchanges concurrently, one scoped
/// thread per assigned worker. Reply order matches job order; each reply
/// carries its roundtrip wall time in µs (send → parseable frame), the
/// coordinator-side half of the wait accounting.
fn exchange(
    conns: &mut [WorkerConn],
    timeout: Duration,
    jobs: Vec<(usize, WorkerMsg)>,
) -> Vec<(Result<WorkerMsg, String>, u64)> {
    let mut slots: Vec<Option<&mut WorkerConn>> = conns.iter_mut().map(Some).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = jobs
            .into_iter()
            .map(|(wi, msg)| {
                let conn = slots[wi].take().expect("one job per worker per exchange");
                s.spawn(move || {
                    let started = Instant::now();
                    let reply = conn.roundtrip(&msg, timeout);
                    let us = started.elapsed().as_micros().min(u64::MAX as u128) as u64;
                    (reply, us)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| (Err("exchange thread panicked".into()), 0))
            })
            .collect()
    })
}
