//! The thin, stateless side of distributed factorization.
//!
//! A worker owns nothing but a read-only handle on the shared `.estdm`
//! corpus store and one TCP connection to the coordinator. Every
//! [`ComputeReq`] it receives is self-contained — which half-step and
//! objective, the fixed factor (bit-exact CSR), the objective's
//! auxiliary data (ridged Gram inverse or column sums + previous
//! iterate), the resolved block geometry, and the assigned span of the
//! global block list — so a worker can join, die, or be replaced at any
//! iteration boundary without the coordinator losing state. The compute
//! itself is the same [`StreamCtx`] engine the single-process blocked
//! half-step runs, restricted to the assigned span: a fragment's bits
//! cannot depend on who computed it.
//!
//! Failure model: every malformed frame, shape mismatch, or latched
//! store fault answers with a typed [`WorkerMsg::Refuse`] (never a hang,
//! never a panic on the request path); the coordinator treats a refusing
//! or silent worker identically — mark dead, reassign the span.

use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::io::wire::{
    read_msg, write_msg, ComputeReq, PassReq, WorkerMsg, WorkerSummary, WORKER_PROTOCOL_VERSION,
};
use crate::io::CorpusStore;
use crate::nmf::als::{AlsCorpus, BlockCompute, BlockEmit, CandSource, Keep, Solve, StreamCtx};
use crate::nmf::ObjectiveKind;
use crate::sparse::{ops, source::RowSource};
use crate::EsnmfError;

/// How long [`run_worker`] keeps retrying the initial connect — workers
/// routinely start before the coordinator binds its listener.
const CONNECT_RETRY_WINDOW: Duration = Duration::from_secs(30);

/// Open the shared corpus store, join the coordinator, and serve compute
/// requests until a `Shutdown` frame (or the coordinator hangs up).
/// `objective` is announced in the handshake — a coordinator running
/// different per-block math refuses the pairing before any work flows.
pub fn run_worker(
    store_path: &Path,
    coordinator: &str,
    objective: ObjectiveKind,
    threads: usize,
) -> Result<(), EsnmfError> {
    let store = CorpusStore::open(store_path)?;
    let mut stream = connect_with_retry(coordinator)?;
    stream.set_nodelay(true).ok();

    write_msg(
        &mut stream,
        &WorkerMsg::Hello {
            version: WORKER_PROTOCOL_VERSION,
            digest: store.digest(),
            n_terms: AlsCorpus::n_terms(&store) as u64,
            n_docs: AlsCorpus::n_docs(&store) as u64,
            objective,
        },
    )?;
    match read_msg(&mut stream)? {
        WorkerMsg::Welcome { version } if version == WORKER_PROTOCOL_VERSION => {}
        WorkerMsg::Welcome { version } => {
            return Err(EsnmfError::protocol(format!(
                "coordinator speaks protocol v{version}, this worker v{WORKER_PROTOCOL_VERSION}"
            )));
        }
        WorkerMsg::Refuse { message } => {
            return Err(EsnmfError::protocol(format!(
                "coordinator refused this worker: {message}"
            )));
        }
        other => {
            return Err(EsnmfError::protocol(format!(
                "expected Welcome, got {other:?}"
            )));
        }
    }
    crate::log_info!("worker", "joined coordinator at {coordinator}");

    loop {
        match read_msg(&mut stream) {
            Ok(WorkerMsg::Compute(req)) => {
                let reply = compute(&store, &req, objective, threads)
                    .unwrap_or_else(|message| WorkerMsg::Refuse { message });
                write_msg(&mut stream, &reply)?;
            }
            Ok(WorkerMsg::Ping) => write_msg(&mut stream, &WorkerMsg::Pong)?,
            Ok(WorkerMsg::Shutdown) => {
                crate::log_info!("worker", "coordinator sent shutdown, exiting");
                return Ok(());
            }
            Ok(other) => {
                let _ = write_msg(
                    &mut stream,
                    &WorkerMsg::Refuse {
                        message: format!("unexpected frame {other:?} on the worker plane"),
                    },
                );
                return Err(EsnmfError::protocol(format!(
                    "coordinator sent unexpected frame {other:?}"
                )));
            }
            // coordinator hung up without a Shutdown (it crashed or was
            // killed): a stateless worker has nothing to save — exit
            // cleanly so supervisors do not restart-loop against nothing
            Err(EsnmfError::Io(e)) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                crate::log_warn!("worker", "coordinator connection closed, exiting");
                return Ok(());
            }
            // a corrupt frame: refuse (typed, best-effort) and close —
            // the stream framing is unrecoverable after garbage
            Err(e @ EsnmfError::Wire(_)) => {
                let _ = write_msg(
                    &mut stream,
                    &WorkerMsg::Refuse {
                        message: e.to_string(),
                    },
                );
                return Err(e);
            }
            Err(e) => return Err(e),
        }
    }
}

fn connect_with_retry(coordinator: &str) -> Result<TcpStream, EsnmfError> {
    let deadline = Instant::now() + CONNECT_RETRY_WINDOW;
    loop {
        match TcpStream::connect(coordinator) {
            Ok(s) => return Ok(s),
            Err(e) if Instant::now() < deadline => {
                crate::log_debug!("worker", "connect to {coordinator} failed ({e}), retrying");
                std::thread::sleep(Duration::from_millis(200));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn summary_for(started: Instant, items: u64) -> WorkerSummary {
    WorkerSummary {
        compute_us: started.elapsed().as_micros().min(u64::MAX as u128) as u64,
        items,
    }
}

/// Execute one self-contained compute request against the local store
/// handle. `Err` is the refusal message — every input is validated
/// before it can panic a kernel.
fn compute(
    store: &CorpusStore,
    req: &ComputeReq,
    objective: ObjectiveKind,
    threads: usize,
) -> Result<WorkerMsg, String> {
    let k = req.k as usize;
    let block_rows = req.block_rows as usize;
    if req.objective != objective {
        return Err(format!(
            "request runs objective {}, this worker was launched with {}",
            req.objective.name(),
            objective.name()
        ));
    }
    if k == 0 {
        return Err("k must be >= 1".into());
    }
    if block_rows == 0 {
        return Err("block_rows must be >= 1".into());
    }
    if req.factor.cols != k {
        return Err(format!(
            "factor has {} columns, request says k={k}",
            req.factor.cols
        ));
    }
    let want_aux = req.objective.implementation().aux_len(k);
    if req.aux.len() != want_aux {
        return Err(format!(
            "auxiliary data has {} entries, objective {} wants {want_aux} at k={k}",
            req.aux.len(),
            req.objective.name()
        ));
    }
    let row_src: &dyn RowSource = if req.step_u {
        AlsCorpus::a_rows(store)
    } else {
        AlsCorpus::a_cols(store)
    };
    if row_src.cols() != req.factor.rows {
        return Err(format!(
            "contraction mismatch: streamed rows have {} columns, factor has {} rows",
            row_src.cols(),
            req.factor.rows
        ));
    }
    let prev = match (req.objective, &req.prev) {
        (ObjectiveKind::Frobenius, None) => None,
        (ObjectiveKind::Frobenius, Some(_)) => {
            return Err("frobenius request carries a previous factor".into());
        }
        (ObjectiveKind::Kl, None) => {
            return Err("kl request is missing the previous factor".into());
        }
        (ObjectiveKind::Kl, Some(p)) => {
            if p.cols != k || p.rows != row_src.rows() {
                return Err(format!(
                    "previous factor is {}×{}, wanted {}×{k}",
                    p.rows,
                    p.cols,
                    row_src.rows()
                ));
            }
            Some(p)
        }
    };
    let src = CandSource {
        src: row_src,
        factor: &req.factor,
        dense: match req.objective {
            ObjectiveKind::Frobenius => ops::dense_factor(&req.factor),
            // the dense fast path belongs to the SpMM fill, unused by KL
            ObjectiveKind::Kl => None,
        },
        defl: None,
    };
    let compute = match prev {
        None => BlockCompute::Solve(Solve::Gram(req.aux.clone())),
        Some(prev) => BlockCompute::Kl {
            prev,
            col_sums: req.aux.clone(),
        },
    };
    let ctx = StreamCtx::with_compute(src, compute, k, threads, block_rows);
    let (lo, hi) = (req.span.0 as usize, req.span.1 as usize);
    if lo > hi || hi > ctx.n_blocks() {
        return Err(format!(
            "span {:?} outside the {}-block geometry",
            req.span,
            ctx.n_blocks()
        ));
    }
    // the v3 span summary: wall time inside the pass plus items produced
    // (candidates offered / nonzeros emitted) — telemetry the coordinator
    // aggregates, never an input to the factorization
    let started = Instant::now();
    let reply = match &req.pass {
        PassReq::Select { t } => {
            let (lens, sel) = ctx.select_span(lo, hi, *t as usize);
            let (positives, heap) = sel.into_wire_parts();
            let items: u64 = lens.iter().map(|&l| l as u64).sum();
            WorkerMsg::Selected {
                scratch_lens: lens.iter().map(|&l| l as u64).collect(),
                positives: positives as u64,
                heap,
                summary: summary_for(started, items),
            }
        }
        PassReq::Emit { keep_tag, tau } => {
            let keep = Keep::from_wire(*keep_tag, *tau)
                .ok_or_else(|| format!("bad keep tag {keep_tag}"))?;
            let emits = ctx.emit_span(lo, hi, keep);
            let wire: Vec<_> = emits.into_iter().map(BlockEmit::into_wire).collect();
            let items: u64 = wire.iter().map(|e| e.values.len() as u64).sum();
            WorkerMsg::Fragments {
                emits: wire,
                summary: summary_for(started, items),
            }
        }
    };
    // a latched shard-read fault means this span was computed on partial
    // data: refuse instead of shipping silently-wrong fragments
    if let Some(fault) = AlsCorpus::store_error(store) {
        return Err(format!("corpus store fault: {fault}"));
    }
    Ok(reply)
}
