//! Topic-query server: a concurrent line-oriented TCP protocol over a
//! frozen [`TopicModel`].
//!
//! ```text
//! TOPICS                      → "OK k=<k>"
//! TOPTERMS <topic> [n]        → "OK term:weight term:weight ..."
//! CLASSIFY <word> <word> ...  → "OK topic:<id> score:<s> ..."
//! FOLDIN <word:count> ...     → "OK nnz=<n> topic:<id>:<w> ..."
//! DOCS <topic> [n]            → "OK doc:weight ..."
//! BATCH <n>                   → "OK batch=<n>" + the next n lines'
//!                               responses, in order
//! STATS                       → "OK objective=<name> <metrics snapshot>"
//! PING                        → "OK pong"
//! QUIT                        → closes the connection
//! ```
//!
//! Unknown or malformed commands answer `ERR ...` (never a panic, never a
//! silently-defaulted argument); blank lines are ignored. Every request
//! and response is newline-delimited. See `rust/README.md` for the full
//! wire-protocol contract.
//!
//! # Concurrency model
//!
//! The accept loop dispatches each connection onto a fixed
//! [`ThreadPool`] ([`ServeOptions::threads`] workers), which **bounds**
//! the number of simultaneously-served connections — excess accepts queue
//! on the pool channel and are picked up as workers free. Shutdown is
//! graceful: the accept loop stops, in-flight requests finish, and every
//! connection handler observes the stop flag within its read-poll
//! interval and closes.
//!
//! CLASSIFY / FOLDIN responses are memoized in a shared LRU keyed by
//! [`normalize_query`]; hits/misses and per-command latency histograms
//! land in the [`MetricsRegistry`] and are visible through `STATS`.
//! Identical cacheable misses in flight at the same moment are
//! single-flighted: one request runs the solve, the rest wait on its
//! result (`server.cache.stampede_suppressed` counts the waiters).
//!
//! # Hot model swap
//!
//! The active [`TopicModel`] lives behind an `ArcSwap`-style slot
//! ([`ServerState::swap_model`]): each request clones the `Arc` once and
//! serves its whole lifetime — classification, fold-in, cache key — from
//! that one snapshot, so a concurrent swap can never show a request two
//! models. Cache keys carry the model *generation*, making a stale
//! cross-generation hit impossible by construction; the swap additionally
//! clears the LRU to reclaim the dead generation's memory. Swaps are
//! driven by the admin listener's `RELOAD <path>` command
//! ([`super::admin`]) or by [`watch_model`] mtime polling; a failed
//! reload leaves the previous model serving untouched.

use super::cache::LruCache;
use super::metrics::{lock_unpoisoned, Counter, Gauge, Histogram, MetricsRegistry};
use super::model::{Provenance, TopicModel};
use super::pool::ThreadPool;
use crate::io::wire::{is_timeout, parse_batch_n, LineReader, ServeRequest};
use crate::nmf::FoldInScratch;
use crate::Result;
use std::collections::HashMap;
use std::io::{ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// the cap is shared wire-layer policy now, but `server::MAX_BATCH` stays
// the public path
pub use crate::io::wire::MAX_BATCH;

/// How often a blocked connection handler wakes to poll the stop flag.
const READ_POLL: Duration = Duration::from_millis(50);

/// Upper bound on a blocking response write: a client that stops reading
/// gets its connection closed instead of pinning a worker (and blocking
/// shutdown) forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Consecutive hard `accept` failures tolerated before the listener gives
/// up. Transient errors (EMFILE under fd pressure, ECONNABORTED) must not
/// kill the accept loop.
const MAX_ACCEPT_ERRORS: u32 = 100;

/// Serving knobs (`esnmf serve --serve-threads --cache-size`).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Connection-worker count = max simultaneously served connections.
    pub threads: usize,
    /// LRU entries for CLASSIFY/FOLDIN responses (0 disables caching).
    pub cache_size: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            threads: 8,
            cache_size: 1024,
        }
    }
}

/// The histogram labels, one per command plus the unknown-command bucket.
const LATENCY_LABELS: [&str; 8] = [
    "topics", "topterms", "classify", "foldin", "docs", "stats", "ping", "other",
];

/// One installed model: the factors, the swap generation that installed
/// them, and where they came from. Requests and the admin listener clone
/// the containing `Arc` once and read a consistent triple for as long as
/// they hold it, however many swaps land meanwhile.
pub struct ActiveModel {
    pub model: Arc<TopicModel>,
    /// monotone swap counter; 0 = the model the server started with
    pub generation: u64,
    pub provenance: Provenance,
}

/// A waiting place for one in-flight cacheable computation: the first
/// computer publishes its response here and notifies; duplicate requests
/// block on the condvar instead of re-running the solve.
type InflightSlot = Arc<(Mutex<Option<String>>, Condvar)>;

/// Everything a connection handler needs, shared across the pool. The
/// request-path metric handles (counters, per-command histograms) are
/// resolved once here so [`respond`] never touches the registry's name
/// maps — the hot path is lock-free except for the model slot and the
/// LRU. Every mutex is taken through
/// [`lock_unpoisoned`](super::metrics::lock_unpoisoned): a panicking
/// request thread must cost one response, never the server.
pub struct ServerState {
    pub metrics: MetricsRegistry,
    /// the hot-swap slot; see the module docs
    active: Mutex<Arc<ActiveModel>>,
    /// allocator for [`ActiveModel::generation`]
    generation: AtomicU64,
    /// false after a corpus-store fault, until a successful swap installs
    /// a servable model again (`READY` on the admin listener)
    ready: AtomicBool,
    /// fast-path flag for `fault` (checked per request, lock-free)
    faulted: AtomicBool,
    /// first recorded corpus-store fault, served as `ERR corpus store
    /// unavailable: ...` to model queries
    fault: Mutex<Option<String>>,
    cache: Mutex<LruCache>,
    cache_enabled: bool,
    /// single-flight table: normalized+generation-tagged key → the slot
    /// duplicate concurrent misses wait on
    inflight: Mutex<HashMap<String, InflightSlot>>,
    requests: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    stampede_suppressed: Arc<Counter>,
    swaps: Arc<Counter>,
    swap_failures: Arc<Counter>,
    /// parallel to [`LATENCY_LABELS`]
    latency: Vec<Arc<Histogram>>,
    /// pooled fold-in scratch buffers, one checked out per in-flight
    /// request — the serving-side analogue of the solver's per-worker
    /// RowBlock reuse, so a warm server answers FOLDIN with zero
    /// per-request allocation growth
    foldin_scratch: Mutex<Vec<FoldInScratch>>,
    /// fresh scratches ever created (`server.foldin.scratch_allocs`):
    /// bounded by the peak number of simultaneously served requests,
    /// never by the request count — the hammer test pins that
    scratch_allocs: Arc<Counter>,
}

impl ServerState {
    pub fn new(model: Arc<TopicModel>, metrics: MetricsRegistry, cache_size: usize) -> Self {
        let latency = LATENCY_LABELS
            .iter()
            .map(|l| metrics.histogram(&format!("server.latency.{l}")))
            .collect();
        let provenance = Provenance::from_model(&model);
        ServerState {
            active: Mutex::new(Arc::new(ActiveModel {
                model,
                generation: 0,
                provenance,
            })),
            generation: AtomicU64::new(0),
            ready: AtomicBool::new(true),
            faulted: AtomicBool::new(false),
            fault: Mutex::new(None),
            inflight: Mutex::new(HashMap::new()),
            requests: metrics.counter("server.requests"),
            cache_hits: metrics.counter("server.cache.hits"),
            cache_misses: metrics.counter("server.cache.misses"),
            stampede_suppressed: metrics.counter("server.cache.stampede_suppressed"),
            swaps: metrics.counter("server.model.swaps"),
            swap_failures: metrics.counter("server.model.swap_failures"),
            scratch_allocs: metrics.counter("server.foldin.scratch_allocs"),
            latency,
            metrics,
            cache: Mutex::new(LruCache::new(cache_size)),
            cache_enabled: cache_size > 0,
            foldin_scratch: Mutex::new(Vec::new()),
        }
    }

    /// Replace the startup provenance (the `--model` serve path captures
    /// the snapshot's provenance before constructing the model; builder
    /// style so it composes with [`ServerState::new`]).
    pub fn with_provenance(self, provenance: Provenance) -> Self {
        {
            let mut slot = lock_unpoisoned(&self.active);
            *slot = Arc::new(ActiveModel {
                model: Arc::clone(&slot.model),
                generation: slot.generation,
                provenance,
            });
        }
        self
    }

    /// The active model snapshot. One clone per request: everything the
    /// request does (answering, cache keying) reads this one value.
    pub fn active(&self) -> Arc<ActiveModel> {
        Arc::clone(&lock_unpoisoned(&self.active))
    }

    /// Convenience: just the active [`TopicModel`].
    pub fn model(&self) -> Arc<TopicModel> {
        Arc::clone(&lock_unpoisoned(&self.active).model)
    }

    /// Current swap generation (0 until the first successful swap).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// Readiness for the admin listener: true while a servable model is
    /// installed and no corpus-store fault is outstanding.
    pub fn ready(&self) -> bool {
        self.ready.load(Ordering::Relaxed)
    }

    /// The recorded corpus-store fault, if any.
    pub fn fault_message(&self) -> Option<String> {
        if !self.faulted.load(Ordering::Relaxed) {
            return None;
        }
        lock_unpoisoned(&self.fault).clone()
    }

    /// Record a corpus-store read failure: model queries answer
    /// `ERR corpus store unavailable: ...` (PING and STATS keep working
    /// so operators can see the state) and `READY` flips false until a
    /// successful [`ServerState::swap_model`] installs a fresh model.
    pub fn set_store_fault(&self, msg: impl Into<String>) {
        *lock_unpoisoned(&self.fault) = Some(msg.into());
        self.faulted.store(true, Ordering::Relaxed);
        self.ready.store(false, Ordering::Relaxed);
    }

    /// Atomic hot model swap: load and fully validate the `.esnmf` at
    /// `path` — parse, CRC, and the one-time Gram-inverse precompute all
    /// happen here, **off** the request path — then install it with a
    /// single pointer store. In-flight requests finish against the model
    /// they started with; new requests see the new model and a bumped
    /// cache generation (plus a cleared LRU, reclaiming the dead
    /// generation's entries). On error the old model keeps serving,
    /// fully untouched, and `READY` is unaffected.
    pub fn swap_model(
        &self,
        path: &std::path::Path,
    ) -> std::result::Result<Arc<ActiveModel>, String> {
        let (snap, crc) = crate::io::Snapshot::load_with_crc(path).map_err(|e| {
            self.swap_failures.inc();
            format!("loading {}: {e}", path.display())
        })?;
        let provenance = Provenance::from_snapshot(&snap, path.to_str(), Some(crc));
        let model = Arc::new(TopicModel::from_snapshot(snap));
        let generation = self.generation.fetch_add(1, Ordering::Relaxed) + 1;
        let active = Arc::new(ActiveModel {
            model,
            generation,
            provenance,
        });
        *lock_unpoisoned(&self.active) = Arc::clone(&active);
        // stale hits are already impossible (generation-tagged keys);
        // clearing reclaims the unreachable old generation's memory
        lock_unpoisoned(&self.cache).clear();
        *lock_unpoisoned(&self.fault) = None;
        self.faulted.store(false, Ordering::Relaxed);
        self.ready.store(true, Ordering::Relaxed);
        self.swaps.inc();
        Ok(active)
    }

    /// Current number of cached responses (for tests / introspection).
    pub fn cache_len(&self) -> usize {
        lock_unpoisoned(&self.cache).len()
    }

    /// Run one command line through a pooled scratch: pop (or create and
    /// count) a [`FoldInScratch`], answer, return it to the pool.
    fn run_command(&self, model: &TopicModel, line: &str) -> String {
        let mut scratch = lock_unpoisoned(&self.foldin_scratch).pop().unwrap_or_else(|| {
            self.scratch_allocs.inc();
            FoldInScratch::default()
        });
        let response = handle_command_with(model, &self.metrics, line, &mut scratch);
        lock_unpoisoned(&self.foldin_scratch).push(scratch);
        response
    }
}

/// Poll `path`'s mtime every `interval` and hot-swap the model whenever
/// it changes (`esnmf serve --watch-model`). Failed reloads — a writer
/// mid-copy, a corrupt file — log a warning and leave the old model
/// serving; the next mtime change retries. Detached daemon thread, runs
/// for the process lifetime.
pub fn watch_model(state: Arc<ServerState>, path: std::path::PathBuf, interval: Duration) {
    let _ = std::thread::Builder::new()
        .name("esnmf-watch".into())
        .spawn(move || {
            let mtime = |p: &std::path::Path| p.metadata().and_then(|m| m.modified()).ok();
            let mut last = mtime(&path);
            loop {
                std::thread::sleep(interval);
                let now = mtime(&path);
                if now.is_some() && now != last {
                    last = now;
                    match state.swap_model(&path) {
                        Ok(active) => crate::log_info!(
                            "server",
                            "hot-swapped model from {} (generation {})",
                            path.display(),
                            active.generation
                        ),
                        Err(e) => crate::log_warn!(
                            "server",
                            "--watch-model reload failed, keeping the old model: {e}"
                        ),
                    }
                }
            }
        });
}

/// Canonical cache key for the cacheable commands (CLASSIFY / FOLDIN):
/// command uppercased, arguments case-folded with
/// [`crate::text::normalize_term`] — the *same* normalization the
/// tokenizer applied while building the vocabulary and the model applies
/// on lookup — then sorted. Both commands are order-independent sums over
/// their arguments, so permutations of one bag of words share an entry;
/// sharing the normalization function is what guarantees two queries get
/// one cache entry **iff** the model answers them identically (an
/// independent lowercasing that disagreed with the tokenizer on any word
/// would serve wrong cached CLASSIFY/FOLDIN answers). `None` = not
/// cacheable.
pub fn normalize_query(line: &str) -> Option<String> {
    let mut parts = line.split_whitespace();
    let cmd = parts.next()?.to_ascii_uppercase();
    if cmd != "CLASSIFY" && cmd != "FOLDIN" {
        return None;
    }
    let mut args: Vec<String> = parts.map(crate::text::normalize_term).collect();
    args.sort_unstable();
    Some(format!("{cmd} {}", args.join(" ")))
}

/// Index into [`LATENCY_LABELS`] for a command line.
fn latency_label_idx(line: &str) -> usize {
    let cmd = line.split_whitespace().next().unwrap_or("");
    LATENCY_LABELS
        .iter()
        .position(|l| cmd.eq_ignore_ascii_case(l))
        .unwrap_or(LATENCY_LABELS.len() - 1)
}

/// Handle one protocol line (no caching, no framing — see [`respond`]).
/// Public for direct unit testing; the serving path goes through
/// [`handle_command_with`] and a pooled scratch.
pub fn handle_command(model: &TopicModel, metrics: &MetricsRegistry, line: &str) -> String {
    handle_command_with(model, metrics, line, &mut FoldInScratch::default())
}

/// [`handle_command`] with caller-pooled fold-in scratch (identical
/// answers; the scratch only removes per-request allocation). Parsing —
/// including every ERR string — lives in the shared wire layer
/// ([`ServeRequest::parse`]); this function only executes parsed
/// requests against the model.
pub fn handle_command_with(
    model: &TopicModel,
    metrics: &MetricsRegistry,
    line: &str,
    scratch: &mut FoldInScratch,
) -> String {
    let req = match ServeRequest::parse(line, model.k()) {
        Ok(req) => req,
        Err(err) => return err,
    };
    match req {
        ServeRequest::Topics => format!("OK k={}", model.k()),
        ServeRequest::TopTerms { topic, n } => {
            let terms = model.topic_terms(topic, n);
            let body: Vec<String> = terms
                .iter()
                .map(|(t, w)| format!("{t}:{w:.4}"))
                .collect();
            format!("OK {}", body.join(" "))
        }
        ServeRequest::Classify { words } => {
            let ranked = model.classify(&words);
            let body: Vec<String> = ranked
                .iter()
                .take(3)
                .map(|(t, s)| format!("topic:{t} score:{s:.4}"))
                .collect();
            format!("OK {}", body.join(" "))
        }
        ServeRequest::FoldIn { doc } => {
            let ranked = model.fold_in_with(&doc, scratch);
            let mut body = vec![format!("nnz={}", ranked.len())];
            body.extend(ranked.iter().map(|(t, w)| format!("topic:{t}:{w:.4}")));
            format!("OK {}", body.join(" "))
        }
        ServeRequest::Docs { topic, n } => {
            let docs = model.topic_documents(topic, n);
            let body: Vec<String> =
                docs.iter().map(|(d, w)| format!("{d}:{w:.4}")).collect();
            format!("OK {}", body.join(" "))
        }
        // the serving objective leads so operators can tell a KL model
        // from a Frobenius one without the admin plane
        ServeRequest::Stats => format!(
            "OK objective={} {}",
            model.objective().name(),
            metrics.format()
        ),
        ServeRequest::Ping => "OK pong".into(),
        // connection control never reaches this handler on its own line;
        // inside a BATCH body it is rejected so the response count holds
        ServeRequest::Quit => "ERR QUIT not allowed inside BATCH".into(),
        ServeRequest::Batch { .. } => "ERR BATCH cannot be nested".into(),
    }
}

/// Handle one line through the full request path: request counter, LRU
/// cache for CLASSIFY/FOLDIN (hit/miss counters, generation-tagged keys,
/// single-flight), and the per-command latency histogram. Public so tests
/// can drive the exact serving path without a socket.
pub fn respond(state: &ServerState, line: &str) -> String {
    let start = Instant::now();
    let line = line.trim();
    state.requests.inc();
    let response = respond_inner(state, line);
    state.latency[latency_label_idx(line)].observe(start.elapsed());
    response
}

/// Removes the computer's in-flight entry and wakes its waiters on scope
/// exit — **including an unwind** out of the solve, in which case the
/// waiters get an ERR instead of blocking forever.
struct InflightGuard<'a> {
    state: &'a ServerState,
    key: &'a str,
    slot: &'a InflightSlot,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        lock_unpoisoned(&self.state.inflight).remove(self.key);
        let (result, cv) = &**self.slot;
        let mut published = lock_unpoisoned(result);
        if published.is_none() {
            *published = Some("ERR request failed".into());
        }
        drop(published);
        cv.notify_all();
    }
}

fn respond_inner(state: &ServerState, line: &str) -> String {
    // a recorded corpus-store fault fails model queries fast; PING and
    // STATS keep answering so operators can observe the state
    if state.faulted.load(Ordering::Relaxed) {
        let cmd = line.split_whitespace().next().unwrap_or("");
        if !cmd.eq_ignore_ascii_case("PING") && !cmd.eq_ignore_ascii_case("STATS") {
            if let Some(msg) = state.fault_message() {
                return format!("ERR corpus store unavailable: {msg}");
            }
        }
    }
    // one Arc clone pins model + generation for this whole request: a
    // concurrent swap can neither mix models within a response nor let a
    // response computed against the old model satisfy a new-generation
    // cache lookup (the key below carries `active.generation`)
    let active = state.active();
    // normalization is pure overhead when the cache is off, so gate first
    let key = if state.cache_enabled {
        normalize_query(line).map(|q| format!("g{} {q}", active.generation))
    } else {
        None
    };
    let Some(key) = key else {
        return state.run_command(&active.model, line);
    };
    if let Some(hit) = lock_unpoisoned(&state.cache).get(&key) {
        state.cache_hits.inc();
        return hit;
    }
    // single-flight: the first miss for a key computes, concurrent
    // duplicates wait on its published result instead of re-running the
    // solve (a stampede of identical FOLDINs used to run N solves)
    let claim = {
        let mut inflight = lock_unpoisoned(&state.inflight);
        match inflight.get(&key) {
            Some(slot) => Err(Arc::clone(slot)),
            None => {
                let slot: InflightSlot = Arc::new((Mutex::new(None), Condvar::new()));
                inflight.insert(key.clone(), Arc::clone(&slot));
                Ok(slot)
            }
        }
    };
    match claim {
        Err(slot) => {
            // waiters account as hits: every cacheable request is still
            // exactly one hit or one miss, and the solve ran once
            state.stampede_suppressed.inc();
            state.cache_hits.inc();
            let (result, cv) = &*slot;
            let mut published = lock_unpoisoned(result);
            while published.is_none() {
                published = cv
                    .wait(published)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            published.clone().expect("published single-flight result")
        }
        Ok(slot) => {
            state.cache_misses.inc();
            let _guard = InflightGuard {
                state,
                key: &key,
                slot: &slot,
            };
            let fresh = state.run_command(&active.model, line);
            // never cache ERR: malformed lines must not be able to
            // evict legitimate entries (waiters still receive the ERR)
            if fresh.starts_with("OK") {
                lock_unpoisoned(&state.cache).insert(key.clone(), fresh.clone());
            }
            *lock_unpoisoned(&slot.0) = Some(fresh.clone());
            fresh
        }
    }
}

/// Decrements the active-connections gauge on scope exit — including an
/// unwind out of the handler, so a panicking connection cannot leak the
/// gauge.
struct ActiveGuard(Arc<Gauge>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.add(-1);
    }
}

fn serve_conn(stream: TcpStream, state: Arc<ServerState>, stop: Arc<AtomicBool>) {
    // line-oriented request/response: Nagle+delayed-ACK would add ~40 ms
    // per round trip otherwise
    let _ = stream.set_nodelay(true);
    // short read timeout = the stop-flag poll interval for graceful drain
    let _ = stream.set_read_timeout(Some(READ_POLL));
    // bounded writes: a client that never reads cannot pin this worker
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let peer = stream.peer_addr().ok();
    let mut reader = LineReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    state.metrics.counter("server.connections.total").inc();
    let active = state.metrics.gauge("server.connections.active");
    active.add(1);
    let _active = ActiveGuard(active);

    'conn: loop {
        let line = loop {
            if stop.load(Ordering::Relaxed) {
                break 'conn;
            }
            match reader.read_line() {
                Ok(Some(l)) => break l,
                Ok(None) => break 'conn,
                Err(e) if is_timeout(&e) => continue,
                Err(_) => break 'conn,
            }
        };
        let line = line.trim();
        if line.is_empty() {
            continue; // blank lines are ignored, not answered
        }
        if line.eq_ignore_ascii_case("QUIT") {
            let _ = writeln!(writer, "OK bye");
            break;
        }
        let mut parts = line.split_whitespace();
        let first = parts.next().unwrap_or("");
        if first.eq_ignore_ascii_case("BATCH") {
            match parse_batch_n(parts.next(), parts.next()) {
                Err(e) => {
                    if writeln!(writer, "{e}").is_err() {
                        break;
                    }
                }
                Ok(n) => {
                    // collect the n pipelined lines; a shutdown mid-batch
                    // drops the connection rather than waiting on a slow
                    // client forever
                    let mut queued = Vec::with_capacity(n);
                    while queued.len() < n {
                        if stop.load(Ordering::Relaxed) {
                            break 'conn;
                        }
                        match reader.read_line() {
                            Ok(Some(l)) => queued.push(l),
                            Ok(None) => break 'conn,
                            Err(e) if is_timeout(&e) => continue,
                            Err(_) => break 'conn,
                        }
                    }
                    // answer in order, as one write (that is the whole
                    // point of the framing: one round trip); every body
                    // line — QUIT and nested BATCH included — goes
                    // through respond(), so the request/latency metrics
                    // count every answered line exactly once
                    let mut out = format!("OK batch={n}\n");
                    for q in &queued {
                        out.push_str(&respond(&state, q));
                        out.push('\n');
                    }
                    if writer.write_all(out.as_bytes()).is_err() {
                        break;
                    }
                }
            }
            continue;
        }
        let response = respond(&state, line);
        if writeln!(writer, "{response}").is_err() {
            break;
        }
    }
    crate::log_debug!("server", "connection from {peer:?} closed");
}

pub struct TopicServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
    state: Arc<ServerState>,
}

impl TopicServer {
    /// Bind and start serving on `addr` (e.g. "127.0.0.1:0" for an
    /// ephemeral port) with default [`ServeOptions`].
    pub fn start(
        addr: &str,
        model: Arc<TopicModel>,
        metrics: MetricsRegistry,
    ) -> Result<TopicServer> {
        TopicServer::start_with(addr, model, metrics, ServeOptions::default())
    }

    /// As [`TopicServer::start`] with explicit serving knobs. Connections
    /// are dispatched onto a fixed worker pool of `opts.threads`
    /// handlers; accepts beyond that queue until a worker frees.
    pub fn start_with(
        addr: &str,
        model: Arc<TopicModel>,
        metrics: MetricsRegistry,
        opts: ServeOptions,
    ) -> Result<TopicServer> {
        let state = Arc::new(ServerState::new(model, metrics, opts.cache_size));
        TopicServer::serve_state(addr, state, opts.threads)
    }

    /// Lowest-level constructor: serve an externally built
    /// [`ServerState`] — the `esnmf serve` driver uses this so the same
    /// state can be shared with the admin listener and the
    /// [`watch_model`] poller.
    pub fn serve_state(
        addr: &str,
        state: Arc<ServerState>,
        threads: usize,
    ) -> Result<TopicServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let shared = Arc::clone(&state);
        let pool_size = threads.max(1);
        let join = std::thread::Builder::new()
            .name("esnmf-server".into())
            .spawn(move || {
                let pool = ThreadPool::named(pool_size, "esnmf-serve");
                let mut accept_errors = 0u32;
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            accept_errors = 0;
                            let _ = stream.set_nonblocking(false);
                            let state = Arc::clone(&state);
                            let stop = Arc::clone(&stop2);
                            pool.execute(move || {
                                // isolate handler panics: a poisoned
                                // connection must cost one connection,
                                // not one pool worker forever
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(move || {
                                        serve_conn(stream, state, stop)
                                    }),
                                );
                            });
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(e) => {
                            // transient failures (EMFILE under fd pressure,
                            // ECONNABORTED) must not kill the listener
                            accept_errors += 1;
                            if accept_errors >= MAX_ACCEPT_ERRORS {
                                crate::log_warn!(
                                    "server",
                                    "accept failing persistently, giving up: {e}"
                                );
                                break;
                            }
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                    }
                }
                // graceful drain: dropping the pool joins every worker;
                // in-flight requests finish, then each handler sees the
                // stop flag within READ_POLL and closes its connection
                drop(pool);
            })?;
        Ok(TopicServer {
            addr: local,
            stop,
            join: Some(join),
            state: shared,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The shared serving state — hand this to the admin listener
    /// ([`super::admin::AdminServer`]), the [`watch_model`] poller, or a
    /// test that wants to drive swaps / faults directly.
    pub fn state(&self) -> Arc<ServerState> {
        Arc::clone(&self.state)
    }

    /// Stop accepting, drain in-flight requests, and join every worker.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for TopicServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;
    use std::io::Read;

    fn model() -> TopicModel {
        let u = Csr::from_dense(3, 2, &[0.9, 0.0, 0.4, 0.0, 0.0, 0.7]);
        let v = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        TopicModel::new(
            u,
            v,
            vec!["coffee".into(), "crop".into(), "electrons".into()],
        )
    }

    fn state(cache_size: usize) -> ServerState {
        ServerState::new(Arc::new(model()), MetricsRegistry::new(), cache_size)
    }

    #[test]
    fn command_topics() {
        let m = model();
        let reg = MetricsRegistry::new();
        assert_eq!(handle_command(&m, &reg, "TOPICS"), "OK k=2");
    }

    #[test]
    fn command_topterms() {
        let m = model();
        let reg = MetricsRegistry::new();
        let r = handle_command(&m, &reg, "TOPTERMS 0 2");
        assert!(r.starts_with("OK coffee:0.9000"), "{r}");
        assert!(handle_command(&m, &reg, "TOPTERMS 9 2").starts_with("ERR"));
        assert!(handle_command(&m, &reg, "TOPTERMS").starts_with("ERR"));
    }

    #[test]
    fn command_classify_and_docs() {
        let m = model();
        let reg = MetricsRegistry::new();
        let r = handle_command(&m, &reg, "CLASSIFY electrons");
        assert!(r.contains("topic:1"), "{r}");
        let r = handle_command(&m, &reg, "DOCS 0 5");
        assert!(r.starts_with("OK 0:1.0000"), "{r}");
        assert!(handle_command(&m, &reg, "CLASSIFY").starts_with("ERR"));
    }

    #[test]
    fn command_errors() {
        let m = model();
        let reg = MetricsRegistry::new();
        assert!(handle_command(&m, &reg, "FLY me to the moon").starts_with("ERR"));
        assert!(handle_command(&m, &reg, "").starts_with("ERR"));
        assert_eq!(handle_command(&m, &reg, "PING"), "OK pong");
    }

    #[test]
    fn malformed_numerics_answer_err_not_defaults() {
        let m = model();
        let reg = MetricsRegistry::new();
        // previously `TOPTERMS 0 abc` silently defaulted n to 5
        for bad in [
            "TOPTERMS 0 abc",
            "TOPTERMS 0 0",
            "TOPTERMS -1 2",
            "TOPTERMS 0 2 junk",
            "DOCS 0 abc",
            "DOCS 0 0",
            "DOCS 1.5 2",
            "DOCS 0 2 junk",
        ] {
            let r = handle_command(&m, &reg, bad);
            assert!(r.starts_with("ERR"), "{bad:?} answered {r:?}");
        }
        // n stays optional with a documented default
        assert!(handle_command(&m, &reg, "TOPTERMS 0").starts_with("OK"));
        assert!(handle_command(&m, &reg, "DOCS 0").starts_with("OK"));
    }

    #[test]
    fn foldin_command_output_and_errors() {
        let m = model();
        let reg = MetricsRegistry::new();
        let r = handle_command(&m, &reg, "FOLDIN coffee:2 crop:1");
        assert!(r.starts_with("OK nnz="), "{r}");
        assert!(r.contains("topic:0:"), "{r}");
        // unknown-only bags fold to the empty row, not an error
        assert_eq!(handle_command(&m, &reg, "FOLDIN zzzz:3"), "OK nnz=0");
        for bad in [
            "FOLDIN",
            "FOLDIN coffee",
            "FOLDIN :3",
            "FOLDIN coffee:abc",
            "FOLDIN coffee:-1",
            "FOLDIN coffee:0",
            "FOLDIN coffee:inf",
        ] {
            let r = handle_command(&m, &reg, bad);
            assert!(r.starts_with("ERR"), "{bad:?} answered {r:?}");
        }
    }

    #[test]
    fn normalize_query_canonicalizes() {
        assert_eq!(
            normalize_query("classify Crop  COFFEE"),
            Some("CLASSIFY coffee crop".into())
        );
        assert_eq!(
            normalize_query("FOLDIN b:2 a:1"),
            Some("FOLDIN a:1 b:2".into())
        );
        assert_eq!(normalize_query("TOPICS"), None);
        assert_eq!(normalize_query("STATS"), None);
        assert_eq!(normalize_query(""), None);
    }

    #[test]
    fn cache_key_normalization_matches_the_tokenizer() {
        // ΟΔΟΣ: str::to_lowercase gives "οδος" (final sigma) but the
        // tokenizer's vocabulary stores the char-wise "οδοσ". The cache
        // key must fold case exactly like the model's lookup, or the two
        // spellings would collapse onto one entry while the model answers
        // them differently (wrong cached answers).
        let key_upper = normalize_query("CLASSIFY ΟΔΟΣ").unwrap();
        let key_tokenized = normalize_query("CLASSIFY οδοσ").unwrap();
        assert_eq!(key_upper, key_tokenized);
        assert_eq!(key_upper, "CLASSIFY οδοσ");
        // and the full serving path agrees: a model whose vocabulary
        // holds the tokenizer form answers the uppercase query from cache
        // with the identical (hit-the-vocabulary) response
        let u = Csr::from_dense(2, 2, &[0.9, 0.0, 0.0, 0.8]);
        let v = Csr::from_dense(1, 2, &[1.0, 0.0]);
        let m = TopicModel::new(
            u,
            v,
            vec![crate::text::tokenize("ΟΔΟΣ")[0].clone(), "coffee".into()],
        );
        let s = ServerState::new(Arc::new(m), MetricsRegistry::new(), 16);
        let fresh = respond(&s, "CLASSIFY ΟΔΟΣ");
        let cached = respond(&s, "CLASSIFY οδοσ");
        assert_eq!(fresh, cached);
        assert!(fresh.contains("topic:0 score:1.0000"), "{fresh}");
        assert_eq!(s.metrics.counter("server.cache.hits").get(), 1);
    }

    #[test]
    fn respond_caches_classify_and_counts() {
        let s = state(16);
        let a = respond(&s, "CLASSIFY coffee crop");
        let b = respond(&s, "classify CROP coffee"); // same bag, permuted
        assert_eq!(a, b);
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 1);
        assert_eq!(s.metrics.counter("server.cache.hits").get(), 1);
        assert_eq!(s.metrics.counter("server.requests").get(), 2);
        assert_eq!(s.cache_len(), 1);
        // non-cacheable commands never touch the cache
        let _ = respond(&s, "TOPICS");
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 1);
        assert_eq!(s.cache_len(), 1);
        // latency histograms appear per command label
        assert_eq!(s.metrics.histogram("server.latency.classify").count(), 2);
        assert_eq!(s.metrics.histogram("server.latency.topics").count(), 1);
    }

    #[test]
    fn err_responses_are_never_cached() {
        let s = state(16);
        let a = respond(&s, "FOLDIN coffee:abc");
        assert!(a.starts_with("ERR"), "{a}");
        assert_eq!(s.cache_len(), 0, "malformed lines must not occupy the LRU");
        // still accounted as a (missed) cacheable request
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 1);
        let b = respond(&s, "FOLDIN coffee:abc");
        assert_eq!(a, b);
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 2);
        assert_eq!(s.metrics.counter("server.cache.hits").get(), 0);
    }

    #[test]
    fn scratch_pool_plateaus_at_the_concurrency_not_the_request_count() {
        // serial requests reuse one pooled scratch: however many
        // requests run, only the first allocates
        let s = state(0);
        for i in 0..50 {
            let r = respond(&s, &format!("FOLDIN coffee:{}", i % 5 + 1));
            assert!(r.starts_with("OK"), "{r}");
            let _ = respond(&s, "CLASSIFY coffee crop");
            let _ = respond(&s, "TOPICS");
        }
        assert_eq!(
            s.metrics.counter("server.foldin.scratch_allocs").get(),
            1,
            "serial serving must reuse one scratch"
        );
    }

    #[test]
    fn respond_with_cache_disabled_counts_nothing() {
        let s = state(0);
        let _ = respond(&s, "CLASSIFY coffee");
        let _ = respond(&s, "CLASSIFY coffee");
        assert_eq!(s.metrics.counter("server.cache.hits").get(), 0);
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 0);
        assert_eq!(s.metrics.counter("server.requests").get(), 2);
    }

    #[test]
    fn batch_header_parses_strictly() {
        assert_eq!(parse_batch_n(Some("3"), None), Ok(3));
        assert!(parse_batch_n(Some("0"), None).is_err());
        assert!(parse_batch_n(Some("abc"), None).is_err());
        assert!(parse_batch_n(None, None).is_err());
        assert!(parse_batch_n(Some("3"), Some("x")).is_err());
        let too_big = (MAX_BATCH + 1).to_string();
        assert!(parse_batch_n(Some(too_big.as_str()), None).is_err());
        let max = MAX_BATCH.to_string();
        assert_eq!(parse_batch_n(Some(max.as_str()), None), Ok(MAX_BATCH));
    }

    #[test]
    fn line_reader_splits_and_survives_partial_input() {
        struct Chunks(Vec<Vec<u8>>);
        impl Read for Chunks {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if self.0.is_empty() {
                    return Ok(0);
                }
                let chunk = self.0.remove(0);
                out[..chunk.len()].copy_from_slice(&chunk);
                Ok(chunk.len())
            }
        }
        let mut r = LineReader::new(Chunks(vec![
            b"PI".to_vec(),
            b"NG\r\nTOP".to_vec(),
            b"ICS\nlast".to_vec(),
        ]));
        assert_eq!(r.read_line().unwrap(), Some("PING".into()));
        assert_eq!(r.read_line().unwrap(), Some("TOPICS".into()));
        assert_eq!(r.read_line().unwrap(), Some("last".into()));
        assert_eq!(r.read_line().unwrap(), None);
    }

    #[test]
    fn line_reader_preserves_partial_line_across_timeouts() {
        struct TimeoutThen(Vec<Option<Vec<u8>>>);
        impl Read for TimeoutThen {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if self.0.is_empty() {
                    return Ok(0);
                }
                match self.0.remove(0) {
                    None => Err(std::io::Error::new(ErrorKind::WouldBlock, "timeout")),
                    Some(chunk) => {
                        out[..chunk.len()].copy_from_slice(&chunk);
                        Ok(chunk.len())
                    }
                }
            }
        }
        let mut r = LineReader::new(TimeoutThen(vec![
            Some(b"STA".to_vec()),
            None,
            Some(b"TS\n".to_vec()),
        ]));
        assert!(is_timeout(&r.read_line().unwrap_err()));
        assert_eq!(r.read_line().unwrap(), Some("STATS".into()));
    }

    /// `model()` with the two topics swapped — "coffee crop" classifies
    /// to topic 1 instead of 0, so a response is attributable to exactly
    /// one of the two models.
    fn swapped_model() -> TopicModel {
        let u = Csr::from_dense(3, 2, &[0.0, 0.9, 0.0, 0.4, 0.7, 0.0]);
        let v = Csr::from_dense(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        TopicModel::new(
            u,
            v,
            vec!["coffee".into(), "crop".into(), "electrons".into()],
        )
    }

    fn save_snapshot(name: &str, m: &TopicModel) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!(
            "esnmf_server_test_{}_{name}.esnmf",
            std::process::id()
        ));
        let snap = crate::io::Snapshot {
            options: crate::nmf::NmfOptions::new(m.k()),
            u: m.u.clone(),
            v: m.v.clone(),
            terms: m.terms.clone(),
            doc_labels: None,
            label_names: Vec::new(),
            corpus_digest: 7,
            progress: crate::io::Progress::default(),
        };
        snap.save(&path).unwrap();
        path
    }

    #[test]
    fn poisoned_server_locks_recover_and_serving_continues() {
        let s = Arc::new(state(16));
        assert!(respond(&s, "CLASSIFY coffee crop").starts_with("OK"));
        // simulate a request thread dying mid-request while holding every
        // request-path lock — this used to poison them all and turn each
        // subsequent request into a panic (a permanent outage)
        let s2 = Arc::clone(&s);
        let _ = std::thread::spawn(move || {
            let _cache = s2.cache.lock().unwrap();
            let _scratch = s2.foldin_scratch.lock().unwrap();
            let _active = s2.active.lock().unwrap();
            let _fault = s2.fault.lock().unwrap();
            let _inflight = s2.inflight.lock().unwrap();
            panic!("request handler dies mid-request");
        })
        .join();
        // every path still answers: cache hit, fresh solve, uncached
        assert!(respond(&s, "CLASSIFY coffee crop").starts_with("OK"));
        assert!(respond(&s, "FOLDIN coffee:2").starts_with("OK"));
        assert!(respond(&s, "TOPICS").starts_with("OK"));
        assert!(s.cache_len() >= 1);
        assert!(s.ready());
        assert_eq!(s.active().generation, 0);
    }

    #[test]
    fn single_flight_waiters_share_the_computers_result() {
        // deterministic: pre-claim the in-flight slot so the request is
        // forced onto the waiter path, then publish a sentinel result
        let s = Arc::new(state(16));
        let key = format!("g0 {}", normalize_query("CLASSIFY coffee crop").unwrap());
        let slot: InflightSlot = Arc::new((Mutex::new(None), Condvar::new()));
        lock_unpoisoned(&s.inflight).insert(key.clone(), Arc::clone(&slot));
        let s2 = Arc::clone(&s);
        let waiter = std::thread::spawn(move || respond(&s2, "classify CROP coffee"));
        std::thread::sleep(Duration::from_millis(50));
        *lock_unpoisoned(&slot.0) = Some("OK published-by-test".into());
        slot.1.notify_all();
        lock_unpoisoned(&s.inflight).remove(&key);
        assert_eq!(waiter.join().unwrap(), "OK published-by-test");
        assert_eq!(
            s.metrics.counter("server.cache.stampede_suppressed").get(),
            1
        );
        // the waiter accounts as a hit, keeping hit+miss == cacheable
        assert_eq!(s.metrics.counter("server.cache.hits").get(), 1);
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 0);
    }

    #[test]
    fn concurrent_identical_misses_solve_once() {
        let s = Arc::new(state(64));
        let n = 8;
        let barrier = Arc::new(std::sync::Barrier::new(n));
        let handles: Vec<_> = (0..n)
            .map(|_| {
                let s = Arc::clone(&s);
                let b = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    b.wait();
                    respond(&s, "FOLDIN coffee:2 crop:1")
                })
            })
            .collect();
        let answers: Vec<String> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(answers.iter().all(|a| a == &answers[0]), "{answers:?}");
        assert!(answers[0].starts_with("OK"), "{}", answers[0]);
        // the solve ran exactly once however the threads interleaved:
        // colliders wait on the in-flight slot, stragglers hit the cache
        assert_eq!(
            s.metrics.counter("server.cache.misses").get(),
            1,
            "identical concurrent misses must run one solve"
        );
        assert_eq!(s.metrics.counter("server.cache.hits").get(), n as u64 - 1);
    }

    #[test]
    fn store_fault_fails_model_queries_and_flips_ready() {
        let s = state(16);
        assert!(s.ready());
        assert!(s.fault_message().is_none());
        s.set_store_fault("corpus store i/o: short read");
        assert!(!s.ready());
        let r = respond(&s, "CLASSIFY coffee");
        assert!(r.starts_with("ERR corpus store unavailable:"), "{r}");
        assert!(respond(&s, "FOLDIN coffee:1").starts_with("ERR corpus store"));
        assert!(respond(&s, "TOPICS").starts_with("ERR corpus store"));
        // observability survives the fault
        assert_eq!(respond(&s, "PING"), "OK pong");
        assert!(respond(&s, "STATS").starts_with("OK"));
        // and the requests were still counted and timed
        assert_eq!(s.metrics.counter("server.requests").get(), 5);
    }

    #[test]
    fn hot_swap_bumps_generation_and_invalidates_the_cache() {
        let s = state(16);
        let old = respond(&s, "CLASSIFY coffee crop");
        assert!(old.contains("topic:0"), "{old}");
        assert_eq!(s.cache_len(), 1);
        let path = save_snapshot("swap", &swapped_model());
        let active = s.swap_model(&path).unwrap();
        assert_eq!(active.generation, 1);
        assert_eq!(s.generation(), 1);
        assert_eq!(s.cache_len(), 0, "swap must clear the response cache");
        // the same cacheable query now answers from the new model — a
        // cross-generation stale hit would resurrect topic:0
        let new = respond(&s, "CLASSIFY coffee crop");
        assert!(new.contains("topic:1"), "stale cross-generation hit: {new}");
        assert_eq!(s.metrics.counter("server.model.swaps").get(), 1);
        // provenance travels with the swap
        assert_eq!(active.provenance.corpus_digest, Some(7));
        assert!(active.provenance.path.as_deref().unwrap().ends_with(".esnmf"));
        assert!(active.provenance.file_crc32.is_some());
        assert_eq!(active.provenance.k, 2);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn failed_swap_leaves_the_old_model_serving_and_ready() {
        let s = state(16);
        let before = respond(&s, "CLASSIFY coffee crop");
        let path = std::env::temp_dir().join(format!(
            "esnmf_server_test_{}_corrupt.esnmf",
            std::process::id()
        ));
        std::fs::write(&path, b"definitely not a snapshot").unwrap();
        let err = s.swap_model(&path).unwrap_err();
        assert!(err.contains(&path.display().to_string()), "{err}");
        assert_eq!(s.generation(), 0);
        assert!(
            s.ready(),
            "a failed reload must not flip READY for the still-serving model"
        );
        assert_eq!(respond(&s, "CLASSIFY coffee crop"), before);
        assert_eq!(s.metrics.counter("server.model.swap_failures").get(), 1);
        assert_eq!(s.metrics.counter("server.model.swaps").get(), 0);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn successful_swap_clears_a_store_fault() {
        let s = state(16);
        s.set_store_fault("corpus store i/o: short read");
        assert!(!s.ready());
        assert!(respond(&s, "CLASSIFY coffee").starts_with("ERR corpus store"));
        let path = save_snapshot("fault_swap", &swapped_model());
        s.swap_model(&path).unwrap();
        assert!(s.ready());
        assert!(s.fault_message().is_none());
        assert!(respond(&s, "CLASSIFY coffee").starts_with("OK"));
        std::fs::remove_file(&path).unwrap();
    }

    // Full TCP round-trips (concurrency, BATCH, FOLDIN, shutdown, hot
    // swap under load) live in rust/tests/integration_server.rs and
    // rust/tests/integration_serving_plane.rs.
}
