//! Topic-query server: a concurrent line-oriented TCP protocol over a
//! frozen [`TopicModel`].
//!
//! ```text
//! TOPICS                      → "OK k=<k>"
//! TOPTERMS <topic> [n]        → "OK term:weight term:weight ..."
//! CLASSIFY <word> <word> ...  → "OK topic:<id> score:<s> ..."
//! FOLDIN <word:count> ...     → "OK nnz=<n> topic:<id>:<w> ..."
//! DOCS <topic> [n]            → "OK doc:weight ..."
//! BATCH <n>                   → "OK batch=<n>" + the next n lines'
//!                               responses, in order
//! STATS                       → "OK <metrics snapshot>"
//! PING                        → "OK pong"
//! QUIT                        → closes the connection
//! ```
//!
//! Unknown or malformed commands answer `ERR ...` (never a panic, never a
//! silently-defaulted argument); blank lines are ignored. Every request
//! and response is newline-delimited. See `rust/README.md` for the full
//! wire-protocol contract.
//!
//! # Concurrency model
//!
//! The accept loop dispatches each connection onto a fixed
//! [`ThreadPool`] ([`ServeOptions::threads`] workers), which **bounds**
//! the number of simultaneously-served connections — excess accepts queue
//! on the pool channel and are picked up as workers free. Shutdown is
//! graceful: the accept loop stops, in-flight requests finish, and every
//! connection handler observes the stop flag within its read-poll
//! interval and closes.
//!
//! CLASSIFY / FOLDIN responses are memoized in a shared LRU keyed by
//! [`normalize_query`]; hits/misses and per-command latency histograms
//! land in the [`MetricsRegistry`] and are visible through `STATS`.

use super::cache::LruCache;
use super::metrics::{Counter, Gauge, Histogram, MetricsRegistry};
use super::model::TopicModel;
use super::pool::ThreadPool;
use crate::nmf::FoldInScratch;
use crate::Result;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Upper bound on `BATCH <n>` so one line cannot pin a worker forever.
pub const MAX_BATCH: usize = 256;

/// Reject lines longer than this (a connection streaming garbage without
/// a newline would otherwise grow the buffer unboundedly).
const MAX_LINE_BYTES: usize = 1 << 20;

/// How often a blocked connection handler wakes to poll the stop flag.
const READ_POLL: Duration = Duration::from_millis(50);

/// Upper bound on a blocking response write: a client that stops reading
/// gets its connection closed instead of pinning a worker (and blocking
/// shutdown) forever.
const WRITE_TIMEOUT: Duration = Duration::from_secs(5);

/// Consecutive hard `accept` failures tolerated before the listener gives
/// up. Transient errors (EMFILE under fd pressure, ECONNABORTED) must not
/// kill the accept loop.
const MAX_ACCEPT_ERRORS: u32 = 100;

/// Serving knobs (`esnmf serve --serve-threads --cache-size`).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Connection-worker count = max simultaneously served connections.
    pub threads: usize,
    /// LRU entries for CLASSIFY/FOLDIN responses (0 disables caching).
    pub cache_size: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            threads: 8,
            cache_size: 1024,
        }
    }
}

/// The histogram labels, one per command plus the unknown-command bucket.
const LATENCY_LABELS: [&str; 8] = [
    "topics", "topterms", "classify", "foldin", "docs", "stats", "ping", "other",
];

/// Everything a connection handler needs, shared across the pool. The
/// request-path metric handles (counters, per-command histograms) are
/// resolved once here so [`respond`] never touches the registry's name
/// maps — the hot path is lock-free except for the LRU itself.
pub struct ServerState {
    pub model: Arc<TopicModel>,
    pub metrics: MetricsRegistry,
    cache: Mutex<LruCache>,
    cache_enabled: bool,
    requests: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    /// parallel to [`LATENCY_LABELS`]
    latency: Vec<Arc<Histogram>>,
    /// pooled fold-in scratch buffers, one checked out per in-flight
    /// request — the serving-side analogue of the solver's per-worker
    /// RowBlock reuse, so a warm server answers FOLDIN with zero
    /// per-request allocation growth
    foldin_scratch: Mutex<Vec<FoldInScratch>>,
    /// fresh scratches ever created (`server.foldin.scratch_allocs`):
    /// bounded by the peak number of simultaneously served requests,
    /// never by the request count — the hammer test pins that
    scratch_allocs: Arc<Counter>,
}

impl ServerState {
    pub fn new(model: Arc<TopicModel>, metrics: MetricsRegistry, cache_size: usize) -> Self {
        let latency = LATENCY_LABELS
            .iter()
            .map(|l| metrics.histogram(&format!("server.latency.{l}")))
            .collect();
        ServerState {
            model,
            requests: metrics.counter("server.requests"),
            cache_hits: metrics.counter("server.cache.hits"),
            cache_misses: metrics.counter("server.cache.misses"),
            scratch_allocs: metrics.counter("server.foldin.scratch_allocs"),
            latency,
            metrics,
            cache: Mutex::new(LruCache::new(cache_size)),
            cache_enabled: cache_size > 0,
            foldin_scratch: Mutex::new(Vec::new()),
        }
    }

    /// Current number of cached responses (for tests / introspection).
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Run one command line through a pooled scratch: pop (or create and
    /// count) a [`FoldInScratch`], answer, return it to the pool.
    fn run_command(&self, line: &str) -> String {
        let mut scratch = self.foldin_scratch.lock().unwrap().pop().unwrap_or_else(|| {
            self.scratch_allocs.inc();
            FoldInScratch::default()
        });
        let response = handle_command_with(&self.model, &self.metrics, line, &mut scratch);
        self.foldin_scratch.lock().unwrap().push(scratch);
        response
    }
}

/// Canonical cache key for the cacheable commands (CLASSIFY / FOLDIN):
/// command uppercased, arguments case-folded with
/// [`crate::text::normalize_term`] — the *same* normalization the
/// tokenizer applied while building the vocabulary and the model applies
/// on lookup — then sorted. Both commands are order-independent sums over
/// their arguments, so permutations of one bag of words share an entry;
/// sharing the normalization function is what guarantees two queries get
/// one cache entry **iff** the model answers them identically (an
/// independent lowercasing that disagreed with the tokenizer on any word
/// would serve wrong cached CLASSIFY/FOLDIN answers). `None` = not
/// cacheable.
pub fn normalize_query(line: &str) -> Option<String> {
    let mut parts = line.split_whitespace();
    let cmd = parts.next()?.to_ascii_uppercase();
    if cmd != "CLASSIFY" && cmd != "FOLDIN" {
        return None;
    }
    let mut args: Vec<String> = parts.map(crate::text::normalize_term).collect();
    args.sort_unstable();
    Some(format!("{cmd} {}", args.join(" ")))
}

/// Index into [`LATENCY_LABELS`] for a command line.
fn latency_label_idx(line: &str) -> usize {
    let cmd = line.split_whitespace().next().unwrap_or("");
    LATENCY_LABELS
        .iter()
        .position(|l| cmd.eq_ignore_ascii_case(l))
        .unwrap_or(LATENCY_LABELS.len() - 1)
}

/// Strictly parse `<topic> [n]`: malformed numerics, `n = 0`, trailing
/// garbage, and out-of-range topics all answer ERR (never a default).
fn parse_topic_n(
    parts: &mut std::str::SplitWhitespace,
    usage: &str,
    k: usize,
) -> std::result::Result<(usize, usize), String> {
    let topic = match parts.next() {
        None => return Err(format!("ERR usage: {usage}")),
        Some(tok) => match tok.parse::<usize>() {
            Ok(t) => t,
            Err(_) => return Err(format!("ERR bad topic {tok:?} (usage: {usage})")),
        },
    };
    let n = match parts.next() {
        None => 5,
        Some(tok) => match tok.parse::<usize>() {
            Ok(0) => return Err(format!("ERR n must be >= 1 (usage: {usage})")),
            Ok(n) => n,
            Err(_) => return Err(format!("ERR bad count {tok:?} (usage: {usage})")),
        },
    };
    if parts.next().is_some() {
        return Err(format!("ERR trailing arguments (usage: {usage})"));
    }
    if topic >= k {
        return Err(format!("ERR topic {topic} out of range (k={k})"));
    }
    Ok((topic, n))
}

/// Handle one protocol line (no caching, no framing — see [`respond`]).
/// Public for direct unit testing; the serving path goes through
/// [`handle_command_with`] and a pooled scratch.
pub fn handle_command(model: &TopicModel, metrics: &MetricsRegistry, line: &str) -> String {
    handle_command_with(model, metrics, line, &mut FoldInScratch::default())
}

/// [`handle_command`] with caller-pooled fold-in scratch (identical
/// answers; the scratch only removes per-request allocation).
pub fn handle_command_with(
    model: &TopicModel,
    metrics: &MetricsRegistry,
    line: &str,
    scratch: &mut FoldInScratch,
) -> String {
    let mut parts = line.split_whitespace();
    let cmd = parts.next().unwrap_or("").to_ascii_uppercase();
    match cmd.as_str() {
        "TOPICS" => format!("OK k={}", model.k()),
        "TOPTERMS" => {
            let (topic, n) = match parse_topic_n(&mut parts, "TOPTERMS <topic> [n]", model.k()) {
                Ok(t) => t,
                Err(e) => return e,
            };
            let terms = model.topic_terms(topic, n);
            let body: Vec<String> = terms
                .iter()
                .map(|(t, w)| format!("{t}:{w:.4}"))
                .collect();
            format!("OK {}", body.join(" "))
        }
        "CLASSIFY" => {
            let words: Vec<&str> = parts.collect();
            if words.is_empty() {
                return "ERR usage: CLASSIFY <word> ...".into();
            }
            let ranked = model.classify(&words);
            let body: Vec<String> = ranked
                .iter()
                .take(3)
                .map(|(t, s)| format!("topic:{t} score:{s:.4}"))
                .collect();
            format!("OK {}", body.join(" "))
        }
        "FOLDIN" => {
            const USAGE: &str = "ERR usage: FOLDIN <word:count> ...";
            let mut doc: Vec<(&str, f32)> = Vec::new();
            for tok in parts {
                let Some((word, count)) = tok.rsplit_once(':') else {
                    return format!("{USAGE} (bad pair {tok:?})");
                };
                if word.is_empty() {
                    return format!("{USAGE} (bad pair {tok:?})");
                }
                match count.parse::<f32>() {
                    Ok(c) if c.is_finite() && c > 0.0 => doc.push((word, c)),
                    _ => return format!("{USAGE} (bad count {count:?} in {tok:?})"),
                }
            }
            if doc.is_empty() {
                return USAGE.into();
            }
            let ranked = model.fold_in_with(&doc, scratch);
            let mut body = vec![format!("nnz={}", ranked.len())];
            body.extend(ranked.iter().map(|(t, w)| format!("topic:{t}:{w:.4}")));
            format!("OK {}", body.join(" "))
        }
        "DOCS" => {
            let (topic, n) = match parse_topic_n(&mut parts, "DOCS <topic> [n]", model.k()) {
                Ok(t) => t,
                Err(e) => return e,
            };
            let docs = model.topic_documents(topic, n);
            let body: Vec<String> =
                docs.iter().map(|(d, w)| format!("{d}:{w:.4}")).collect();
            format!("OK {}", body.join(" "))
        }
        "STATS" => format!("OK {}", metrics.format()),
        "PING" => "OK pong".into(),
        // connection control never reaches this handler on its own line;
        // inside a BATCH body it is rejected so the response count holds
        "QUIT" => "ERR QUIT not allowed inside BATCH".into(),
        "BATCH" => "ERR BATCH cannot be nested".into(),
        "" => "ERR empty command".into(),
        other => format!("ERR unknown command {other:?}"),
    }
}

/// Handle one line through the full request path: request counter, LRU
/// cache for CLASSIFY/FOLDIN (hit/miss counters), and the per-command
/// latency histogram. Public so tests can drive the exact serving path
/// without a socket.
pub fn respond(state: &ServerState, line: &str) -> String {
    let start = Instant::now();
    let line = line.trim();
    state.requests.inc();
    // normalization is pure overhead when the cache is off, so gate first
    let key = if state.cache_enabled {
        normalize_query(line)
    } else {
        None
    };
    let response = match key {
        Some(key) => {
            let cached = state.cache.lock().unwrap().get(&key);
            match cached {
                Some(hit) => {
                    state.cache_hits.inc();
                    hit
                }
                None => {
                    state.cache_misses.inc();
                    let fresh = state.run_command(line);
                    // never cache ERR: malformed lines must not be able to
                    // evict legitimate entries
                    if fresh.starts_with("OK") {
                        state.cache.lock().unwrap().insert(key, fresh.clone());
                    }
                    fresh
                }
            }
        }
        None => state.run_command(line),
    };
    state.latency[latency_label_idx(line)].observe(start.elapsed());
    response
}

fn parse_batch_n(tok: Option<&str>, extra: Option<&str>) -> std::result::Result<usize, String> {
    if extra.is_some() {
        return Err(format!("ERR trailing arguments (usage: BATCH <n>, 1..={MAX_BATCH})"));
    }
    match tok.and_then(|s| s.parse::<usize>().ok()) {
        Some(n) if (1..=MAX_BATCH).contains(&n) => Ok(n),
        _ => Err(format!("ERR usage: BATCH <n> (1..={MAX_BATCH})")),
    }
}

/// Minimal buffered line reader that survives read timeouts: a partial
/// line stays buffered across `WouldBlock`/`TimedOut`, so the connection
/// loop can poll the stop flag between read attempts. (`BufReader` makes
/// no such guarantee for `read_line` under errors.)
struct LineReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
    start: usize,
}

impl<R: Read> LineReader<R> {
    fn new(inner: R) -> Self {
        LineReader {
            inner,
            buf: Vec::new(),
            start: 0,
        }
    }

    /// Next newline-terminated line without the terminator (a trailing
    /// `\r` is stripped). `Ok(None)` = clean EOF; timeouts bubble up as
    /// errors with any partial line preserved for the next call.
    fn read_line(&mut self) -> std::io::Result<Option<String>> {
        loop {
            if let Some(pos) = self.buf[self.start..].iter().position(|&b| b == b'\n') {
                let end = self.start + pos;
                let mut slice = &self.buf[self.start..end];
                if slice.last() == Some(&b'\r') {
                    slice = &slice[..slice.len() - 1];
                }
                let line = String::from_utf8_lossy(slice).into_owned();
                self.start = end + 1;
                if self.start >= self.buf.len() {
                    self.buf.clear();
                    self.start = 0;
                }
                return Ok(Some(line));
            }
            if self.start > 0 {
                self.buf.drain(..self.start);
                self.start = 0;
            }
            if self.buf.len() > MAX_LINE_BYTES {
                return Err(std::io::Error::new(
                    ErrorKind::InvalidData,
                    "request line too long",
                ));
            }
            let mut chunk = [0u8; 4096];
            match self.inner.read(&mut chunk) {
                Ok(0) => {
                    if self.buf.is_empty() {
                        return Ok(None);
                    }
                    // final unterminated line before EOF
                    let mut slice = &self.buf[..];
                    if slice.last() == Some(&b'\r') {
                        slice = &slice[..slice.len() - 1];
                    }
                    let line = String::from_utf8_lossy(slice).into_owned();
                    self.buf.clear();
                    return Ok(Some(line));
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) => return Err(e),
            }
        }
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Decrements the active-connections gauge on scope exit — including an
/// unwind out of the handler, so a panicking connection cannot leak the
/// gauge.
struct ActiveGuard(Arc<Gauge>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.add(-1);
    }
}

fn serve_conn(stream: TcpStream, state: Arc<ServerState>, stop: Arc<AtomicBool>) {
    // line-oriented request/response: Nagle+delayed-ACK would add ~40 ms
    // per round trip otherwise
    let _ = stream.set_nodelay(true);
    // short read timeout = the stop-flag poll interval for graceful drain
    let _ = stream.set_read_timeout(Some(READ_POLL));
    // bounded writes: a client that never reads cannot pin this worker
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let peer = stream.peer_addr().ok();
    let mut reader = LineReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    state.metrics.counter("server.connections.total").inc();
    let active = state.metrics.gauge("server.connections.active");
    active.add(1);
    let _active = ActiveGuard(active);

    'conn: loop {
        let line = loop {
            if stop.load(Ordering::Relaxed) {
                break 'conn;
            }
            match reader.read_line() {
                Ok(Some(l)) => break l,
                Ok(None) => break 'conn,
                Err(e) if is_timeout(&e) => continue,
                Err(_) => break 'conn,
            }
        };
        let line = line.trim();
        if line.is_empty() {
            continue; // blank lines are ignored, not answered
        }
        if line.eq_ignore_ascii_case("QUIT") {
            let _ = writeln!(writer, "OK bye");
            break;
        }
        let mut parts = line.split_whitespace();
        let first = parts.next().unwrap_or("");
        if first.eq_ignore_ascii_case("BATCH") {
            match parse_batch_n(parts.next(), parts.next()) {
                Err(e) => {
                    if writeln!(writer, "{e}").is_err() {
                        break;
                    }
                }
                Ok(n) => {
                    // collect the n pipelined lines; a shutdown mid-batch
                    // drops the connection rather than waiting on a slow
                    // client forever
                    let mut queued = Vec::with_capacity(n);
                    while queued.len() < n {
                        if stop.load(Ordering::Relaxed) {
                            break 'conn;
                        }
                        match reader.read_line() {
                            Ok(Some(l)) => queued.push(l),
                            Ok(None) => break 'conn,
                            Err(e) if is_timeout(&e) => continue,
                            Err(_) => break 'conn,
                        }
                    }
                    // answer in order, as one write (that is the whole
                    // point of the framing: one round trip); every body
                    // line — QUIT and nested BATCH included — goes
                    // through respond(), so the request/latency metrics
                    // count every answered line exactly once
                    let mut out = format!("OK batch={n}\n");
                    for q in &queued {
                        out.push_str(&respond(&state, q));
                        out.push('\n');
                    }
                    if writer.write_all(out.as_bytes()).is_err() {
                        break;
                    }
                }
            }
            continue;
        }
        let response = respond(&state, line);
        if writeln!(writer, "{response}").is_err() {
            break;
        }
    }
    crate::log_debug!("server", "connection from {peer:?} closed");
}

pub struct TopicServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl TopicServer {
    /// Bind and start serving on `addr` (e.g. "127.0.0.1:0" for an
    /// ephemeral port) with default [`ServeOptions`].
    pub fn start(
        addr: &str,
        model: Arc<TopicModel>,
        metrics: MetricsRegistry,
    ) -> Result<TopicServer> {
        TopicServer::start_with(addr, model, metrics, ServeOptions::default())
    }

    /// As [`TopicServer::start`] with explicit serving knobs. Connections
    /// are dispatched onto a fixed worker pool of `opts.threads`
    /// handlers; accepts beyond that queue until a worker frees.
    pub fn start_with(
        addr: &str,
        model: Arc<TopicModel>,
        metrics: MetricsRegistry,
        opts: ServeOptions,
    ) -> Result<TopicServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let state = Arc::new(ServerState::new(model, metrics, opts.cache_size));
        let pool_size = opts.threads.max(1);
        let join = std::thread::Builder::new()
            .name("esnmf-server".into())
            .spawn(move || {
                let pool = ThreadPool::named(pool_size, "esnmf-serve");
                let mut accept_errors = 0u32;
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            accept_errors = 0;
                            let _ = stream.set_nonblocking(false);
                            let state = Arc::clone(&state);
                            let stop = Arc::clone(&stop2);
                            pool.execute(move || {
                                // isolate handler panics: a poisoned
                                // connection must cost one connection,
                                // not one pool worker forever
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(move || {
                                        serve_conn(stream, state, stop)
                                    }),
                                );
                            });
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(e) => {
                            // transient failures (EMFILE under fd pressure,
                            // ECONNABORTED) must not kill the listener
                            accept_errors += 1;
                            if accept_errors >= MAX_ACCEPT_ERRORS {
                                crate::log_warn!(
                                    "server",
                                    "accept failing persistently, giving up: {e}"
                                );
                                break;
                            }
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                    }
                }
                // graceful drain: dropping the pool joins every worker;
                // in-flight requests finish, then each handler sees the
                // stop flag within READ_POLL and closes its connection
                drop(pool);
            })?;
        Ok(TopicServer {
            addr: local,
            stop,
            join: Some(join),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight requests, and join every worker.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for TopicServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;

    fn model() -> TopicModel {
        let u = Csr::from_dense(3, 2, &[0.9, 0.0, 0.4, 0.0, 0.0, 0.7]);
        let v = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        TopicModel::new(
            u,
            v,
            vec!["coffee".into(), "crop".into(), "electrons".into()],
        )
    }

    fn state(cache_size: usize) -> ServerState {
        ServerState::new(Arc::new(model()), MetricsRegistry::new(), cache_size)
    }

    #[test]
    fn command_topics() {
        let m = model();
        let reg = MetricsRegistry::new();
        assert_eq!(handle_command(&m, &reg, "TOPICS"), "OK k=2");
    }

    #[test]
    fn command_topterms() {
        let m = model();
        let reg = MetricsRegistry::new();
        let r = handle_command(&m, &reg, "TOPTERMS 0 2");
        assert!(r.starts_with("OK coffee:0.9000"), "{r}");
        assert!(handle_command(&m, &reg, "TOPTERMS 9 2").starts_with("ERR"));
        assert!(handle_command(&m, &reg, "TOPTERMS").starts_with("ERR"));
    }

    #[test]
    fn command_classify_and_docs() {
        let m = model();
        let reg = MetricsRegistry::new();
        let r = handle_command(&m, &reg, "CLASSIFY electrons");
        assert!(r.contains("topic:1"), "{r}");
        let r = handle_command(&m, &reg, "DOCS 0 5");
        assert!(r.starts_with("OK 0:1.0000"), "{r}");
        assert!(handle_command(&m, &reg, "CLASSIFY").starts_with("ERR"));
    }

    #[test]
    fn command_errors() {
        let m = model();
        let reg = MetricsRegistry::new();
        assert!(handle_command(&m, &reg, "FLY me to the moon").starts_with("ERR"));
        assert!(handle_command(&m, &reg, "").starts_with("ERR"));
        assert_eq!(handle_command(&m, &reg, "PING"), "OK pong");
    }

    #[test]
    fn malformed_numerics_answer_err_not_defaults() {
        let m = model();
        let reg = MetricsRegistry::new();
        // previously `TOPTERMS 0 abc` silently defaulted n to 5
        for bad in [
            "TOPTERMS 0 abc",
            "TOPTERMS 0 0",
            "TOPTERMS -1 2",
            "TOPTERMS 0 2 junk",
            "DOCS 0 abc",
            "DOCS 0 0",
            "DOCS 1.5 2",
            "DOCS 0 2 junk",
        ] {
            let r = handle_command(&m, &reg, bad);
            assert!(r.starts_with("ERR"), "{bad:?} answered {r:?}");
        }
        // n stays optional with a documented default
        assert!(handle_command(&m, &reg, "TOPTERMS 0").starts_with("OK"));
        assert!(handle_command(&m, &reg, "DOCS 0").starts_with("OK"));
    }

    #[test]
    fn foldin_command_output_and_errors() {
        let m = model();
        let reg = MetricsRegistry::new();
        let r = handle_command(&m, &reg, "FOLDIN coffee:2 crop:1");
        assert!(r.starts_with("OK nnz="), "{r}");
        assert!(r.contains("topic:0:"), "{r}");
        // unknown-only bags fold to the empty row, not an error
        assert_eq!(handle_command(&m, &reg, "FOLDIN zzzz:3"), "OK nnz=0");
        for bad in [
            "FOLDIN",
            "FOLDIN coffee",
            "FOLDIN :3",
            "FOLDIN coffee:abc",
            "FOLDIN coffee:-1",
            "FOLDIN coffee:0",
            "FOLDIN coffee:inf",
        ] {
            let r = handle_command(&m, &reg, bad);
            assert!(r.starts_with("ERR"), "{bad:?} answered {r:?}");
        }
    }

    #[test]
    fn normalize_query_canonicalizes() {
        assert_eq!(
            normalize_query("classify Crop  COFFEE"),
            Some("CLASSIFY coffee crop".into())
        );
        assert_eq!(
            normalize_query("FOLDIN b:2 a:1"),
            Some("FOLDIN a:1 b:2".into())
        );
        assert_eq!(normalize_query("TOPICS"), None);
        assert_eq!(normalize_query("STATS"), None);
        assert_eq!(normalize_query(""), None);
    }

    #[test]
    fn cache_key_normalization_matches_the_tokenizer() {
        // ΟΔΟΣ: str::to_lowercase gives "οδος" (final sigma) but the
        // tokenizer's vocabulary stores the char-wise "οδοσ". The cache
        // key must fold case exactly like the model's lookup, or the two
        // spellings would collapse onto one entry while the model answers
        // them differently (wrong cached answers).
        let key_upper = normalize_query("CLASSIFY ΟΔΟΣ").unwrap();
        let key_tokenized = normalize_query("CLASSIFY οδοσ").unwrap();
        assert_eq!(key_upper, key_tokenized);
        assert_eq!(key_upper, "CLASSIFY οδοσ");
        // and the full serving path agrees: a model whose vocabulary
        // holds the tokenizer form answers the uppercase query from cache
        // with the identical (hit-the-vocabulary) response
        let u = Csr::from_dense(2, 2, &[0.9, 0.0, 0.0, 0.8]);
        let v = Csr::from_dense(1, 2, &[1.0, 0.0]);
        let m = TopicModel::new(
            u,
            v,
            vec![crate::text::tokenize("ΟΔΟΣ")[0].clone(), "coffee".into()],
        );
        let s = ServerState::new(Arc::new(m), MetricsRegistry::new(), 16);
        let fresh = respond(&s, "CLASSIFY ΟΔΟΣ");
        let cached = respond(&s, "CLASSIFY οδοσ");
        assert_eq!(fresh, cached);
        assert!(fresh.contains("topic:0 score:1.0000"), "{fresh}");
        assert_eq!(s.metrics.counter("server.cache.hits").get(), 1);
    }

    #[test]
    fn respond_caches_classify_and_counts() {
        let s = state(16);
        let a = respond(&s, "CLASSIFY coffee crop");
        let b = respond(&s, "classify CROP coffee"); // same bag, permuted
        assert_eq!(a, b);
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 1);
        assert_eq!(s.metrics.counter("server.cache.hits").get(), 1);
        assert_eq!(s.metrics.counter("server.requests").get(), 2);
        assert_eq!(s.cache_len(), 1);
        // non-cacheable commands never touch the cache
        let _ = respond(&s, "TOPICS");
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 1);
        assert_eq!(s.cache_len(), 1);
        // latency histograms appear per command label
        assert_eq!(s.metrics.histogram("server.latency.classify").count(), 2);
        assert_eq!(s.metrics.histogram("server.latency.topics").count(), 1);
    }

    #[test]
    fn err_responses_are_never_cached() {
        let s = state(16);
        let a = respond(&s, "FOLDIN coffee:abc");
        assert!(a.starts_with("ERR"), "{a}");
        assert_eq!(s.cache_len(), 0, "malformed lines must not occupy the LRU");
        // still accounted as a (missed) cacheable request
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 1);
        let b = respond(&s, "FOLDIN coffee:abc");
        assert_eq!(a, b);
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 2);
        assert_eq!(s.metrics.counter("server.cache.hits").get(), 0);
    }

    #[test]
    fn scratch_pool_plateaus_at_the_concurrency_not_the_request_count() {
        // serial requests reuse one pooled scratch: however many
        // requests run, only the first allocates
        let s = state(0);
        for i in 0..50 {
            let r = respond(&s, &format!("FOLDIN coffee:{}", i % 5 + 1));
            assert!(r.starts_with("OK"), "{r}");
            let _ = respond(&s, "CLASSIFY coffee crop");
            let _ = respond(&s, "TOPICS");
        }
        assert_eq!(
            s.metrics.counter("server.foldin.scratch_allocs").get(),
            1,
            "serial serving must reuse one scratch"
        );
    }

    #[test]
    fn respond_with_cache_disabled_counts_nothing() {
        let s = state(0);
        let _ = respond(&s, "CLASSIFY coffee");
        let _ = respond(&s, "CLASSIFY coffee");
        assert_eq!(s.metrics.counter("server.cache.hits").get(), 0);
        assert_eq!(s.metrics.counter("server.cache.misses").get(), 0);
        assert_eq!(s.metrics.counter("server.requests").get(), 2);
    }

    #[test]
    fn batch_header_parses_strictly() {
        assert_eq!(parse_batch_n(Some("3"), None), Ok(3));
        assert!(parse_batch_n(Some("0"), None).is_err());
        assert!(parse_batch_n(Some("abc"), None).is_err());
        assert!(parse_batch_n(None, None).is_err());
        assert!(parse_batch_n(Some("3"), Some("x")).is_err());
        let too_big = (MAX_BATCH + 1).to_string();
        assert!(parse_batch_n(Some(too_big.as_str()), None).is_err());
        let max = MAX_BATCH.to_string();
        assert_eq!(parse_batch_n(Some(max.as_str()), None), Ok(MAX_BATCH));
    }

    #[test]
    fn line_reader_splits_and_survives_partial_input() {
        struct Chunks(Vec<Vec<u8>>);
        impl Read for Chunks {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if self.0.is_empty() {
                    return Ok(0);
                }
                let chunk = self.0.remove(0);
                out[..chunk.len()].copy_from_slice(&chunk);
                Ok(chunk.len())
            }
        }
        let mut r = LineReader::new(Chunks(vec![
            b"PI".to_vec(),
            b"NG\r\nTOP".to_vec(),
            b"ICS\nlast".to_vec(),
        ]));
        assert_eq!(r.read_line().unwrap(), Some("PING".into()));
        assert_eq!(r.read_line().unwrap(), Some("TOPICS".into()));
        assert_eq!(r.read_line().unwrap(), Some("last".into()));
        assert_eq!(r.read_line().unwrap(), None);
    }

    #[test]
    fn line_reader_preserves_partial_line_across_timeouts() {
        struct TimeoutThen(Vec<Option<Vec<u8>>>);
        impl Read for TimeoutThen {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if self.0.is_empty() {
                    return Ok(0);
                }
                match self.0.remove(0) {
                    None => Err(std::io::Error::new(ErrorKind::WouldBlock, "timeout")),
                    Some(chunk) => {
                        out[..chunk.len()].copy_from_slice(&chunk);
                        Ok(chunk.len())
                    }
                }
            }
        }
        let mut r = LineReader::new(TimeoutThen(vec![
            Some(b"STA".to_vec()),
            None,
            Some(b"TS\n".to_vec()),
        ]));
        assert!(is_timeout(&r.read_line().unwrap_err()));
        assert_eq!(r.read_line().unwrap(), Some("STATS".into()));
    }

    // Full TCP round-trips (concurrency, BATCH, FOLDIN, shutdown) live in
    // rust/tests/integration_server.rs.
}
