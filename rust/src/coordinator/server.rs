//! Topic-query server: a line-oriented TCP protocol over a frozen
//! [`TopicModel`].
//!
//! ```text
//! TOPICS                      → "OK k=<k>"
//! TOPTERMS <topic> <n>        → "OK term:weight term:weight ..."
//! CLASSIFY <word> <word> ...  → "OK topic:<id> score:<s> ..."
//! DOCS <topic> <n>            → "OK doc:weight ..."
//! STATS                       → "OK <metrics snapshot>"
//! PING                        → "OK pong"
//! QUIT                        → closes the connection
//! ```
//!
//! Unknown commands answer `ERR ...`; every request is newline-delimited.

use super::metrics::MetricsRegistry;
use super::model::TopicModel;
use crate::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

pub struct TopicServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

/// Handle one protocol line. Public for direct unit testing.
pub fn handle_command(model: &TopicModel, metrics: &MetricsRegistry, line: &str) -> String {
    let mut parts = line.split_whitespace();
    let cmd = parts.next().unwrap_or("").to_ascii_uppercase();
    match cmd.as_str() {
        "TOPICS" => format!("OK k={}", model.k()),
        "TOPTERMS" => {
            let topic: usize = match parts.next().and_then(|s| s.parse().ok()) {
                Some(t) => t,
                None => return "ERR usage: TOPTERMS <topic> <n>".into(),
            };
            let n: usize = parts.next().and_then(|s| s.parse().ok()).unwrap_or(5);
            if topic >= model.k() {
                return format!("ERR topic {topic} out of range (k={})", model.k());
            }
            let terms = model.topic_terms(topic, n);
            let body: Vec<String> = terms
                .iter()
                .map(|(t, w)| format!("{t}:{w:.4}"))
                .collect();
            format!("OK {}", body.join(" "))
        }
        "CLASSIFY" => {
            let words: Vec<&str> = parts.collect();
            if words.is_empty() {
                return "ERR usage: CLASSIFY <word> ...".into();
            }
            let ranked = model.classify(&words);
            let body: Vec<String> = ranked
                .iter()
                .take(3)
                .map(|(t, s)| format!("topic:{t} score:{s:.4}"))
                .collect();
            format!("OK {}", body.join(" "))
        }
        "DOCS" => {
            let topic: usize = match parts.next().and_then(|s| s.parse().ok()) {
                Some(t) => t,
                None => return "ERR usage: DOCS <topic> <n>".into(),
            };
            let n: usize = parts.next().and_then(|s| s.parse().ok()).unwrap_or(5);
            if topic >= model.k() {
                return format!("ERR topic {topic} out of range (k={})", model.k());
            }
            let docs = model.topic_documents(topic, n);
            let body: Vec<String> =
                docs.iter().map(|(d, w)| format!("{d}:{w:.4}")).collect();
            format!("OK {}", body.join(" "))
        }
        "STATS" => format!("OK {}", metrics.format()),
        "PING" => "OK pong".into(),
        "" => "ERR empty command".into(),
        other => format!("ERR unknown command {other:?}"),
    }
}

fn serve_conn(stream: TcpStream, model: Arc<TopicModel>, metrics: MetricsRegistry) {
    // line-oriented request/response: Nagle+delayed-ACK would add ~40 ms
    // per round trip otherwise
    let _ = stream.set_nodelay(true);
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let requests = metrics.counter("server.requests");
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().eq_ignore_ascii_case("QUIT") {
            let _ = writeln!(writer, "OK bye");
            break;
        }
        requests.inc();
        let response = handle_command(&model, &metrics, &line);
        if writeln!(writer, "{response}").is_err() {
            break;
        }
    }
    crate::log_debug!("server", "connection from {peer:?} closed");
}

impl TopicServer {
    /// Bind and start serving on `addr` (e.g. "127.0.0.1:0" for an
    /// ephemeral port). Connections are handled on spawned threads.
    pub fn start(addr: &str, model: Arc<TopicModel>, metrics: MetricsRegistry) -> Result<TopicServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("esnmf-server".into())
            .spawn(move || {
                let mut conns: Vec<JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let _ = stream.set_nonblocking(false);
                            let model = Arc::clone(&model);
                            let metrics = metrics.clone();
                            conns.push(std::thread::spawn(move || {
                                serve_conn(stream, model, metrics)
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })?;
        Ok(TopicServer {
            addr: local,
            stop,
            join: Some(join),
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for TopicServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csr;

    fn model() -> TopicModel {
        let u = Csr::from_dense(3, 2, &[0.9, 0.0, 0.4, 0.0, 0.0, 0.7]);
        let v = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        TopicModel::new(
            u,
            v,
            vec!["coffee".into(), "crop".into(), "electrons".into()],
        )
    }

    #[test]
    fn command_topics() {
        let m = model();
        let reg = MetricsRegistry::new();
        assert_eq!(handle_command(&m, &reg, "TOPICS"), "OK k=2");
    }

    #[test]
    fn command_topterms() {
        let m = model();
        let reg = MetricsRegistry::new();
        let r = handle_command(&m, &reg, "TOPTERMS 0 2");
        assert!(r.starts_with("OK coffee:0.9000"), "{r}");
        assert!(handle_command(&m, &reg, "TOPTERMS 9 2").starts_with("ERR"));
        assert!(handle_command(&m, &reg, "TOPTERMS").starts_with("ERR"));
    }

    #[test]
    fn command_classify_and_docs() {
        let m = model();
        let reg = MetricsRegistry::new();
        let r = handle_command(&m, &reg, "CLASSIFY electrons");
        assert!(r.contains("topic:1"), "{r}");
        let r = handle_command(&m, &reg, "DOCS 0 5");
        assert!(r.starts_with("OK 0:1.0000"), "{r}");
        assert!(handle_command(&m, &reg, "CLASSIFY").starts_with("ERR"));
    }

    #[test]
    fn command_errors() {
        let m = model();
        let reg = MetricsRegistry::new();
        assert!(handle_command(&m, &reg, "FLY me to the moon").starts_with("ERR"));
        assert!(handle_command(&m, &reg, "").starts_with("ERR"));
        assert_eq!(handle_command(&m, &reg, "PING"), "OK pong");
    }

    // Full TCP round-trip lives in rust/tests/integration_server.rs.
}
