//! Streaming corpus ingestion with backpressure.
//!
//! Documents flow  producer → [bounded channel] → tokenizer workers →
//! [bounded channel] → single-threaded TDM builder.  The bounded channels
//! (`sync_channel`) are the backpressure: a slow builder stalls the
//! tokenizers, which stall the producer, so memory stays O(capacity)
//! regardless of corpus size. Documents are resequenced at the builder so
//! ids/labels match arrival order deterministically.

use crate::text::{TdmBuilder, TermDocMatrix};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

#[derive(Clone, Debug)]
pub struct IngestConfig {
    /// tokenizer worker threads
    pub workers: usize,
    /// bounded-channel capacity (documents in flight per stage)
    pub capacity: usize,
}

impl Default for IngestConfig {
    fn default() -> Self {
        IngestConfig {
            workers: 4,
            capacity: 64,
        }
    }
}

/// One raw document entering the pipeline.
pub struct RawDoc {
    pub text: String,
    pub label: Option<String>,
}

struct TokenizedDoc {
    seq: usize,
    tokens: Vec<String>,
    label: Option<String>,
}

/// Stream `docs` through the pipeline into a frozen term-document matrix.
/// Returns the matrix and the number of documents ingested.
pub fn ingest_stream(
    docs: impl Iterator<Item = RawDoc>,
    config: &IngestConfig,
) -> (TermDocMatrix, usize) {
    let workers = config.workers.max(1);
    let cap = config.capacity.max(1);

    let (raw_tx, raw_rx) = mpsc::sync_channel::<(usize, RawDoc)>(cap);
    let raw_rx = Arc::new(Mutex::new(raw_rx));
    let (tok_tx, tok_rx) = mpsc::sync_channel::<TokenizedDoc>(cap);

    let handles: Vec<_> = (0..workers)
        .map(|i| {
            let raw_rx = Arc::clone(&raw_rx);
            let tok_tx = tok_tx.clone();
            std::thread::Builder::new()
                .name(format!("esnmf-tokenize-{i}"))
                .spawn(move || loop {
                    let item = { raw_rx.lock().unwrap().recv() };
                    match item {
                        Ok((seq, doc)) => {
                            let tokens = crate::text::tokenize(&doc.text);
                            if tok_tx
                                .send(TokenizedDoc {
                                    seq,
                                    tokens,
                                    label: doc.label,
                                })
                                .is_err()
                            {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                })
                .expect("spawn tokenizer")
        })
        .collect();
    drop(tok_tx);

    // builder thread: resequence + build
    let builder_handle = std::thread::Builder::new()
        .name("esnmf-tdm-builder".into())
        .spawn(move || {
            let mut builder = TdmBuilder::new();
            let mut next_seq = 0usize;
            let mut pending: BTreeMap<usize, TokenizedDoc> = BTreeMap::new();
            for doc in tok_rx {
                pending.insert(doc.seq, doc);
                while let Some(doc) = pending.remove(&next_seq) {
                    builder.add_tokens(&doc.tokens, doc.label.as_deref());
                    next_seq += 1;
                }
            }
            // drain any stragglers (possible only if seqs were skipped)
            for (_, doc) in pending {
                builder.add_tokens(&doc.tokens, doc.label.as_deref());
            }
            (builder.n_docs(), builder.freeze())
        })
        .expect("spawn builder");

    // producer: the calling thread feeds the pipeline (and is throttled
    // by the bounded channel when the pipeline is saturated)
    let mut count = 0usize;
    for doc in docs {
        raw_tx.send((count, doc)).expect("pipeline died");
        count += 1;
    }
    drop(raw_tx);
    for h in handles {
        let _ = h.join();
    }
    let (n_docs, tdm) = builder_handle.join().expect("builder panicked");
    debug_assert_eq!(n_docs, count);
    (tdm, count)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn docs(n: usize) -> Vec<RawDoc> {
        (0..n)
            .map(|i| RawDoc {
                text: if i % 2 == 0 {
                    format!("coffee crop quotas coffee doc{i} coffee")
                } else {
                    format!("electrons atoms hydrogen electrons doc{i}")
                },
                label: Some(if i % 2 == 0 { "econ" } else { "sci" }.to_string()),
            })
            .collect()
    }

    #[test]
    fn pipeline_matches_sequential_build() {
        let raw = docs(40);
        let mut builder = TdmBuilder::new();
        for d in &raw {
            builder.add_text(&d.text, d.label.as_deref());
        }
        let sequential = builder.freeze();

        let (streamed, count) = ingest_stream(
            docs(40).into_iter(),
            &IngestConfig {
                workers: 4,
                capacity: 8,
            },
        );
        assert_eq!(count, 40);
        assert_eq!(streamed.n_docs(), sequential.n_docs());
        assert_eq!(streamed.n_terms(), sequential.n_terms());
        assert_eq!(streamed.a, sequential.a); // resequencing ⇒ identical
        assert_eq!(streamed.doc_labels, sequential.doc_labels);
    }

    #[test]
    fn tiny_capacity_still_completes() {
        let (tdm, count) = ingest_stream(
            docs(100).into_iter(),
            &IngestConfig {
                workers: 2,
                capacity: 1, // maximal backpressure
            },
        );
        assert_eq!(count, 100);
        assert_eq!(tdm.n_docs(), 100);
    }

    #[test]
    fn empty_stream() {
        let (tdm, count) = ingest_stream(std::iter::empty(), &IngestConfig::default());
        assert_eq!(count, 0);
        assert_eq!(tdm.n_docs(), 0);
    }
}
