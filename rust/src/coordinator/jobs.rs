//! Factorization job management: submit → queue → run on the pool →
//! poll/wait for a summarized result.

use super::pool::{self, ThreadPool};
use crate::backend::{AlsBackend, NativeBackend};
use crate::nmf::{factorize_sequential, NmfOptions, NmfResult, SequentialOptions};
use crate::text::TermDocMatrix;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

pub type JobId = u64;

/// What to run.
#[derive(Clone, Debug)]
pub enum JobSpec {
    Als(NmfOptions),
    Sequential(SequentialOptions),
}

/// Lifecycle of a job.
#[derive(Clone, Debug)]
pub enum JobStatus {
    Queued,
    Running,
    Done(Arc<NmfResult>),
    Failed(String),
}

impl JobStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done(_) | JobStatus::Failed(_))
    }
}

struct Inner {
    statuses: Mutex<HashMap<JobId, JobStatus>>,
    cv: Condvar,
}

/// Shared job manager. Cloning shares the same job table and pool.
#[derive(Clone)]
pub struct JobManager {
    pool: Arc<ThreadPool>,
    inner: Arc<Inner>,
    next_id: Arc<Mutex<JobId>>,
}

impl JobManager {
    pub fn new(workers: usize) -> Self {
        JobManager {
            pool: Arc::new(ThreadPool::new(workers)),
            inner: Arc::new(Inner {
                statuses: Mutex::new(HashMap::new()),
                cv: Condvar::new(),
            }),
            next_id: Arc::new(Mutex::new(1)),
        }
    }

    fn set_status(&self, id: JobId, status: JobStatus) {
        let mut map = self.inner.statuses.lock().unwrap();
        map.insert(id, status);
        self.inner.cv.notify_all();
    }

    /// Jobs queued or running right now — the divisor for sharing the
    /// machine's cores between concurrent factorizations.
    fn active_jobs(&self) -> usize {
        self.inner
            .statuses
            .lock()
            .unwrap()
            .values()
            .filter(|s| !s.is_terminal())
            .count()
    }

    /// Submit a factorization of `tdm` under `spec`; returns immediately.
    pub fn submit(&self, tdm: Arc<TermDocMatrix>, spec: JobSpec) -> JobId {
        let id = {
            let mut next = self.next_id.lock().unwrap();
            let id = *next;
            *next += 1;
            id
        };
        self.set_status(id, JobStatus::Queued);
        let this = self.clone();
        self.pool.execute(move || {
            this.set_status(id, JobStatus::Running);
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                match &spec {
                    JobSpec::Als(opts) => {
                        // divide the machine between whatever is live right
                        // now: an idle pool gives one job every core, a busy
                        // pool shares them. Results are bit-identical at any
                        // thread count, so this only shifts wall-clock.
                        let share = pool::default_threads() / this.active_jobs().max(1);
                        let mut opts = opts.clone();
                        opts.threads = opts.threads.min(share.max(1));
                        NativeBackend::new().factorize(&tdm, &opts)
                    }
                    JobSpec::Sequential(opts) => Ok(factorize_sequential(&tdm, opts)),
                }
            }));
            match outcome {
                Ok(Ok(result)) => this.set_status(id, JobStatus::Done(Arc::new(result))),
                Ok(Err(e)) => this.set_status(id, JobStatus::Failed(e.to_string())),
                Err(_) => this.set_status(id, JobStatus::Failed("job panicked".into())),
            }
        });
        id
    }

    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.inner.statuses.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job reaches a terminal state.
    pub fn wait(&self, id: JobId) -> JobStatus {
        let mut map = self.inner.statuses.lock().unwrap();
        loop {
            match map.get(&id) {
                Some(s) if s.is_terminal() => return s.clone(),
                Some(_) => {
                    map = self.inner.cv.wait(map).unwrap();
                }
                None => return JobStatus::Failed(format!("unknown job {id}")),
            }
        }
    }

    /// Convenience: wait and unwrap the result.
    pub fn wait_result(&self, id: JobId) -> crate::Result<Arc<NmfResult>> {
        match self.wait(id) {
            JobStatus::Done(r) => Ok(r),
            JobStatus::Failed(e) => anyhow::bail!("job {id} failed: {e}"),
            _ => unreachable!("wait returned non-terminal status"),
        }
    }

    pub fn job_ids(&self) -> Vec<JobId> {
        let mut ids: Vec<JobId> =
            self.inner.statuses.lock().unwrap().keys().copied().collect();
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nmf::SparsityMode;
    use crate::text::TdmBuilder;

    fn tdm() -> Arc<TermDocMatrix> {
        let mut b = TdmBuilder::new();
        for _ in 0..5 {
            b.add_text("coffee crop coffee quotas brazil crop", Some("econ"));
            b.add_text("electrons atoms electrons hydrogen atoms", Some("sci"));
        }
        Arc::new(b.freeze())
    }

    #[test]
    fn submit_and_wait() {
        let mgr = JobManager::new(2);
        let id = mgr.submit(
            tdm(),
            JobSpec::Als(NmfOptions::new(2).with_iters(5).with_seed(1)),
        );
        let result = mgr.wait_result(id).unwrap();
        assert_eq!(result.iterations, 5);
    }

    #[test]
    fn concurrent_jobs_all_complete() {
        let mgr = JobManager::new(4);
        let corpus = tdm();
        let ids: Vec<JobId> = (0..8)
            .map(|i| {
                let spec = if i % 2 == 0 {
                    JobSpec::Als(
                        NmfOptions::new(2)
                            .with_iters(4)
                            .with_seed(i)
                            .with_sparsity(SparsityMode::both(20, 20)),
                    )
                } else {
                    JobSpec::Sequential(SequentialOptions::new(2, 4).with_seed(i))
                };
                mgr.submit(Arc::clone(&corpus), spec)
            })
            .collect();
        for id in ids {
            assert!(matches!(mgr.wait(id), JobStatus::Done(_)));
        }
        assert_eq!(mgr.job_ids().len(), 8);
    }

    #[test]
    fn unknown_job_fails_cleanly() {
        let mgr = JobManager::new(1);
        assert!(matches!(mgr.wait(999), JobStatus::Failed(_)));
        assert!(mgr.status(999).is_none());
    }

    #[test]
    fn panicking_job_reports_failure() {
        let mgr = JobManager::new(1);
        // k larger than terms triggers internal panic via assert in init?
        // use an empty corpus with k>0: gram of empty factors is fine, so
        // force failure with an impossible initial guess instead
        let empty = Arc::new(TdmBuilder::new().freeze());
        let id = mgr.submit(empty, JobSpec::Als(NmfOptions::new(3).with_iters(2)));
        // empty corpus: factorize should still complete (degenerate) or
        // fail — either way it must reach a terminal state
        let s = mgr.wait(id);
        assert!(s.is_terminal());
    }
}
