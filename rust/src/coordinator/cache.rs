//! A fixed-capacity LRU map for server responses.
//!
//! The topic server keys this by *normalized query* (see
//! `server::normalize_query`), so permutations of the same CLASSIFY /
//! FOLDIN bag of words share one entry. Implemented as a HashMap over an
//! index-linked doubly-linked list (no pointer juggling, no external
//! crates): every operation is O(1) expected.

use std::collections::HashMap;

const NIL: usize = usize::MAX;

#[derive(Debug)]
struct Entry {
    key: String,
    val: String,
    prev: usize,
    next: usize,
}

/// Least-recently-used string→string cache. Capacity 0 disables it:
/// `get` always misses and `insert` is a no-op.
#[derive(Debug)]
pub struct LruCache {
    cap: usize,
    map: HashMap<String, usize>,
    entries: Vec<Entry>,
    /// most recently used entry (NIL when empty)
    head: usize,
    /// least recently used entry (NIL when empty)
    tail: usize,
    free: Vec<usize>,
}

impl LruCache {
    pub fn new(cap: usize) -> Self {
        LruCache {
            cap,
            map: HashMap::with_capacity(cap.min(1024)),
            entries: Vec::with_capacity(cap.min(1024)),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    fn detach(&mut self, i: usize) {
        let (p, n) = (self.entries[i].prev, self.entries[i].next);
        if p != NIL {
            self.entries[p].next = n;
        } else {
            self.head = n;
        }
        if n != NIL {
            self.entries[n].prev = p;
        } else {
            self.tail = p;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.entries[i].prev = NIL;
        self.entries[i].next = self.head;
        if self.head != NIL {
            self.entries[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&mut self, key: &str) -> Option<String> {
        let &i = self.map.get(key)?;
        self.detach(i);
        self.push_front(i);
        Some(self.entries[i].val.clone())
    }

    /// Insert or refresh `key`, evicting the least-recently-used entry
    /// when at capacity.
    pub fn insert(&mut self, key: String, val: String) {
        if self.cap == 0 {
            return;
        }
        if let Some(&i) = self.map.get(&key) {
            self.entries[i].val = val;
            self.detach(i);
            self.push_front(i);
            return;
        }
        if self.map.len() >= self.cap {
            let evict = self.tail;
            self.detach(evict);
            let old_key = std::mem::take(&mut self.entries[evict].key);
            self.map.remove(&old_key);
            self.free.push(evict);
        }
        let entry = Entry {
            key: key.clone(),
            val,
            prev: NIL,
            next: NIL,
        };
        let i = match self.free.pop() {
            Some(slot) => {
                self.entries[slot] = entry;
                slot
            }
            None => {
                self.entries.push(entry);
                self.entries.len() - 1
            }
        };
        self.map.insert(key, i);
        self.push_front(i);
    }

    /// Drop every entry, keeping the capacity and the allocations. The
    /// server calls this on a hot model swap: generation-tagged keys
    /// already make stale hits impossible, clearing reclaims the dead
    /// generation's memory in one O(n) sweep.
    pub fn clear(&mut self) {
        self.map.clear();
        self.entries.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys_mru_to_lru(c: &LruCache) -> Vec<String> {
        let mut out = Vec::new();
        let mut i = c.head;
        while i != NIL {
            out.push(c.entries[i].key.clone());
            i = c.entries[i].next;
        }
        out
    }

    #[test]
    fn insert_get_roundtrip() {
        let mut c = LruCache::new(4);
        c.insert("a".into(), "1".into());
        c.insert("b".into(), "2".into());
        assert_eq!(c.get("a"), Some("1".into()));
        assert_eq!(c.get("b"), Some("2".into()));
        assert_eq!(c.get("zz"), None);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        c.insert("a".into(), "1".into());
        c.insert("b".into(), "2".into());
        // touch a, so b is now the LRU
        assert!(c.get("a").is_some());
        c.insert("c".into(), "3".into());
        assert_eq!(c.len(), 2);
        assert_eq!(c.get("b"), None, "b was LRU and must be evicted");
        assert_eq!(c.get("a"), Some("1".into()));
        assert_eq!(c.get("c"), Some("3".into()));
    }

    #[test]
    fn reinsert_refreshes_value_and_recency() {
        let mut c = LruCache::new(2);
        c.insert("a".into(), "1".into());
        c.insert("b".into(), "2".into());
        c.insert("a".into(), "1'".into()); // refresh, b becomes LRU
        c.insert("c".into(), "3".into());
        assert_eq!(c.get("a"), Some("1'".into()));
        assert_eq!(c.get("b"), None);
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = LruCache::new(0);
        c.insert("a".into(), "1".into());
        assert_eq!(c.get("a"), None);
        assert!(c.is_empty());
    }

    #[test]
    fn capacity_one_churns_correctly() {
        let mut c = LruCache::new(1);
        for i in 0..10 {
            c.insert(format!("k{i}"), format!("v{i}"));
            assert_eq!(c.len(), 1);
            assert_eq!(c.get(&format!("k{i}")), Some(format!("v{i}")));
            if i > 0 {
                assert_eq!(c.get(&format!("k{}", i - 1)), None);
            }
        }
    }

    #[test]
    fn clear_empties_and_cache_keeps_working() {
        let mut c = LruCache::new(3);
        for i in 0..5 {
            c.insert(format!("k{i}"), format!("v{i}"));
        }
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get("k4"), None);
        assert_eq!(keys_mru_to_lru(&c), Vec::<String>::new());
        c.insert("x".into(), "1".into());
        c.insert("y".into(), "2".into());
        assert_eq!(c.get("x"), Some("1".into()));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn recency_list_stays_consistent_under_churn() {
        let mut c = LruCache::new(3);
        for i in 0..50 {
            c.insert(format!("k{}", i % 7), format!("v{i}"));
            let _ = c.get(&format!("k{}", (i + 3) % 7));
            let keys = keys_mru_to_lru(&c);
            assert_eq!(keys.len(), c.len());
            assert!(c.len() <= 3);
            for k in &keys {
                assert!(c.map.contains_key(k), "list key {k} missing from map");
            }
        }
    }
}
