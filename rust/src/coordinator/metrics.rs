//! Process-wide metrics: named atomic counters and gauges with a
//! printable snapshot. Lock-free on the hot path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Registry handing out shared counters/gauges by name.
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    counters: Arc<Mutex<BTreeMap<String, Arc<Counter>>>>,
    gauges: Arc<Mutex<BTreeMap<String, Arc<Gauge>>>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().unwrap();
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Stable-ordered snapshot for logging / the STATS server command.
    pub fn snapshot(&self) -> Vec<(String, i64)> {
        let mut out = Vec::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push((name.clone(), c.get() as i64));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push((name.clone(), g.get()));
        }
        out
    }

    pub fn format(&self) -> String {
        self.snapshot()
            .into_iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_shared_by_name() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("requests");
        let b = reg.counter("requests");
        a.inc();
        b.add(2);
        assert_eq!(reg.counter("requests").get(), 3);
    }

    #[test]
    fn gauges_and_snapshot() {
        let reg = MetricsRegistry::new();
        reg.counter("x").inc();
        reg.gauge("queue_depth").set(-5);
        let snap = reg.snapshot();
        assert!(snap.contains(&("x".to_string(), 1)));
        assert!(snap.contains(&("queue_depth".to_string(), -5)));
        assert!(reg.format().contains("queue_depth=-5"));
    }

    #[test]
    fn concurrent_increments() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("hits");
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }
}
