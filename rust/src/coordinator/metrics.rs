//! Process-wide metrics: named atomic counters, gauges, and latency
//! histograms with a printable snapshot. Lock-free on the hot path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Duration;

/// The process-global registry backing the factorize/worker planes (the
/// distributed coordinator's per-worker counters, the out-of-core store
/// gauges, the factorize admin listener's METRICS command). The serving
/// plane keeps its own per-instance registry on `ServerState` — replica
/// tests run several servers in one process and must not share metrics.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

/// Lock `m`, recovering the guard if a previous holder panicked. Every
/// mutex in the serving plane (registry maps, response cache, scratch
/// pool, model slot) only ever holds state that is valid between
/// individual writes — inserts, single assignments, pushes — so a
/// panicking holder cannot leave a half-updated invariant behind and the
/// poison flag carries no information worth dying for. Using this
/// everywhere turns "one bad request panicked" from a permanent serving
/// outage (every later `.lock().unwrap()` re-panics) into a non-event.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Relative update — safe under concurrent writers, unlike get+set.
    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Upper bucket bounds in microseconds (10µs … 10s); one extra overflow
/// bucket catches everything slower.
pub const HISTOGRAM_BOUNDS_US: [u64; 7] =
    [10, 100, 1_000, 10_000, 100_000, 1_000_000, 10_000_000];

/// Fixed log-scale latency histogram. Observations are bucketed by
/// microsecond bounds; the exported counts are cumulative (every bucket
/// includes all faster ones), so downstream consumers can difference
/// adjacent buckets without re-reading the bound table.
pub struct Histogram {
    buckets: [AtomicU64; HISTOGRAM_BOUNDS_US.len() + 1],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn observe_us(&self, us: u64) {
        let idx = HISTOGRAM_BOUNDS_US
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(HISTOGRAM_BOUNDS_US.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn observe(&self, elapsed: Duration) {
        self.observe_us(elapsed.as_micros().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Cumulative counts, one per bound plus the overflow bucket.
    pub fn cumulative(&self) -> Vec<u64> {
        let mut total = 0u64;
        self.buckets
            .iter()
            .map(|b| {
                total += b.load(Ordering::Relaxed);
                total
            })
            .collect()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// Registry handing out shared counters/gauges/histograms by name.
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    counters: Arc<Mutex<BTreeMap<String, Arc<Counter>>>>,
    gauges: Arc<Mutex<BTreeMap<String, Arc<Gauge>>>>,
    histograms: Arc<Mutex<BTreeMap<String, Arc<Histogram>>>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = lock_unpoisoned(&self.counters);
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = lock_unpoisoned(&self.gauges);
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = lock_unpoisoned(&self.histograms);
        Arc::clone(map.entry(name.to_string()).or_default())
    }

    /// Stable-ordered snapshot for logging / the STATS server command.
    /// Histograms export `<name>.count`, `<name>.sum_us`, and cumulative
    /// `<name>.le_<bound>us` / `<name>.inf` bucket counts.
    pub fn snapshot(&self) -> Vec<(String, i64)> {
        let mut out = Vec::new();
        for (name, c) in lock_unpoisoned(&self.counters).iter() {
            out.push((name.clone(), c.get() as i64));
        }
        for (name, g) in lock_unpoisoned(&self.gauges).iter() {
            out.push((name.clone(), g.get()));
        }
        for (name, h) in lock_unpoisoned(&self.histograms).iter() {
            out.push((format!("{name}.count"), h.count() as i64));
            out.push((format!("{name}.sum_us"), h.sum_us() as i64));
            for (i, cum) in h.cumulative().into_iter().enumerate() {
                let label = match HISTOGRAM_BOUNDS_US.get(i) {
                    Some(bound) => format!("{name}.le_{bound}us"),
                    None => format!("{name}.inf"),
                };
                out.push((label, cum as i64));
            }
        }
        out
    }

    pub fn format(&self) -> String {
        self.snapshot()
            .into_iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Prometheus text exposition of the whole registry, served by the
    /// admin listener's `METRICS` command. Metric names are the dotted
    /// registry names with `.` → `_` under an `esnmf_` prefix; histogram
    /// bucket bounds stay in microseconds (`le` labels are the
    /// [`HISTOGRAM_BOUNDS_US`] values, `+Inf` for the overflow bucket)
    /// and the `_sum` is microseconds to match.
    pub fn prometheus(&self) -> String {
        fn sanitize(name: &str) -> String {
            let mut s: String = name
                .chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect();
            s.insert_str(0, "esnmf_");
            s
        }
        let mut out = String::new();
        for (name, c) in lock_unpoisoned(&self.counters).iter() {
            let n = sanitize(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {}\n", c.get()));
        }
        for (name, g) in lock_unpoisoned(&self.gauges).iter() {
            let n = sanitize(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", g.get()));
        }
        for (name, h) in lock_unpoisoned(&self.histograms).iter() {
            let n = format!("{}_us", sanitize(name));
            out.push_str(&format!("# TYPE {n} histogram\n"));
            for (i, cum) in h.cumulative().into_iter().enumerate() {
                match HISTOGRAM_BOUNDS_US.get(i) {
                    Some(bound) => {
                        out.push_str(&format!("{n}_bucket{{le=\"{bound}\"}} {cum}\n"));
                    }
                    None => {
                        out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {cum}\n"));
                    }
                }
            }
            out.push_str(&format!("{n}_sum {}\n", h.sum_us()));
            out.push_str(&format!("{n}_count {}\n", h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_shared_by_name() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("requests");
        let b = reg.counter("requests");
        a.inc();
        b.add(2);
        assert_eq!(reg.counter("requests").get(), 3);
    }

    #[test]
    fn gauges_and_snapshot() {
        let reg = MetricsRegistry::new();
        reg.counter("x").inc();
        reg.gauge("queue_depth").set(-5);
        let snap = reg.snapshot();
        assert!(snap.contains(&("x".to_string(), 1)));
        assert!(snap.contains(&("queue_depth".to_string(), -5)));
        assert!(reg.format().contains("queue_depth=-5"));
    }

    #[test]
    fn gauge_add_is_relative() {
        let reg = MetricsRegistry::new();
        let g = reg.gauge("inflight");
        g.add(3);
        g.add(-1);
        assert_eq!(g.get(), 2);
    }

    #[test]
    fn histogram_buckets_and_snapshot() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("server.latency.classify");
        h.observe_us(5); // ≤ 10µs
        h.observe_us(10); // boundary: still ≤ 10µs
        h.observe_us(50_000); // ≤ 100ms
        h.observe_us(99_000_000); // overflow bucket
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum_us(), 5 + 10 + 50_000 + 99_000_000);
        let cum = h.cumulative();
        assert_eq!(cum.len(), HISTOGRAM_BOUNDS_US.len() + 1);
        assert_eq!(cum[0], 2); // the two ≤10µs observations
        assert_eq!(cum[4], 3); // ≤100ms includes everything but overflow
        assert_eq!(*cum.last().unwrap(), 4);
        let snap = reg.snapshot();
        assert!(snap.contains(&("server.latency.classify.count".to_string(), 4)));
        assert!(snap.contains(&("server.latency.classify.le_10us".to_string(), 2)));
        assert!(snap.contains(&("server.latency.classify.inf".to_string(), 4)));
        assert!(reg.format().contains("server.latency.classify.count=4"));
    }

    #[test]
    fn histogram_observe_duration() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("lat");
        h.observe(Duration::from_micros(500));
        assert_eq!(h.count(), 1);
        assert_eq!(h.cumulative()[2], 1); // ≤ 1ms
        assert_eq!(h.cumulative()[1], 0); // not ≤ 100µs
    }

    #[test]
    fn prometheus_export_is_parseable_and_complete() {
        let reg = MetricsRegistry::new();
        reg.counter("server.requests").add(7);
        reg.gauge("server.connections.active").set(-2);
        reg.histogram("server.latency.classify").observe_us(50);
        let text = reg.prometheus();
        assert!(text.contains("# TYPE esnmf_server_requests counter\n"));
        assert!(text.contains("esnmf_server_requests 7\n"));
        assert!(text.contains("esnmf_server_connections_active -2\n"));
        assert!(text.contains("# TYPE esnmf_server_latency_classify_us histogram\n"));
        assert!(text.contains("esnmf_server_latency_classify_us_bucket{le=\"100\"} 1\n"));
        assert!(text.contains("esnmf_server_latency_classify_us_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("esnmf_server_latency_classify_us_sum 50\n"));
        assert!(text.contains("esnmf_server_latency_classify_us_count 1\n"));
        // every line is a comment or `name[{labels}] value`
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("name value");
            assert!(name.starts_with("esnmf_"), "{line}");
            assert!(value.parse::<f64>().is_ok(), "{line}");
        }
    }

    #[test]
    fn poisoned_registry_locks_recover() {
        let reg = MetricsRegistry::new();
        reg.counter("survivor").inc();
        // a thread that panics while holding every registry lock poisons
        // them all — exactly what a panicking request thread used to do
        let reg2 = reg.clone();
        let _ = std::thread::spawn(move || {
            let _c = reg2.counters.lock().unwrap();
            let _g = reg2.gauges.lock().unwrap();
            let _h = reg2.histograms.lock().unwrap();
            panic!("holder dies");
        })
        .join();
        // the registry keeps handing out metrics and snapshotting
        reg.counter("survivor").inc();
        reg.gauge("after").set(1);
        reg.histogram("lat").observe_us(3);
        let snap = reg.snapshot();
        assert!(snap.contains(&("survivor".to_string(), 2)));
        assert!(!reg.prometheus().is_empty());
    }

    #[test]
    fn concurrent_increments() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("hits");
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }
}
