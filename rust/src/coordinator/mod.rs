//! Layer-3 coordination: thread pool, streaming ingestion with
//! backpressure, job management, metrics, and the topic-query server.
//!
//! The paper's contribution is an algorithm, so the coordinator is the
//! production harness around it: documents stream through a bounded
//! pipeline into the term-document matrix, factorization jobs run on a
//! worker pool (one corpus can be factorized under many configurations
//! concurrently — exactly what the experiment harness does), and the
//! resulting topic models are served over a line protocol. The [`dist`] /
//! [`worker`] pair extends the same harness across processes: stateless
//! workers over a shared `.estdm` pull half-step spans from a stateful
//! coordinator on the worker wire plane ([`crate::io::wire`]).

pub mod admin;
pub mod cache;
pub mod dist;
pub mod ingest;
pub mod jobs;
pub mod metrics;
pub mod model;
pub mod pool;
pub mod server;
pub mod worker;

pub use admin::{admin_command, dispatch_line, AdminServer, AdminSurface, FactorizeAdmin};
pub use cache::LruCache;
pub use dist::{run_distributed, run_distributed_on, DistOptions};
pub use ingest::{ingest_stream, IngestConfig};
pub use jobs::{JobId, JobManager, JobSpec, JobStatus};
pub use metrics::MetricsRegistry;
pub use model::{Provenance, TopicModel};
pub use pool::{default_threads, ThreadPool};
pub use server::{watch_model, ActiveModel, ServeOptions, ServerState, TopicServer};
pub use worker::run_worker;
