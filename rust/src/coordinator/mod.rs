//! Layer-3 coordination: thread pool, streaming ingestion with
//! backpressure, job management, metrics, and the topic-query server.
//!
//! The paper's contribution is an algorithm, so the coordinator is the
//! production harness around it: documents stream through a bounded
//! pipeline into the term-document matrix, factorization jobs run on a
//! worker pool (one corpus can be factorized under many configurations
//! concurrently — exactly what the experiment harness does), and the
//! resulting topic models are served over a line protocol.

pub mod cache;
pub mod ingest;
pub mod jobs;
pub mod metrics;
pub mod model;
pub mod pool;
pub mod server;

pub use cache::LruCache;
pub use ingest::{ingest_stream, IngestConfig};
pub use jobs::{JobId, JobManager, JobSpec, JobStatus};
pub use metrics::MetricsRegistry;
pub use model::TopicModel;
pub use pool::{default_threads, ThreadPool};
pub use server::{ServeOptions, ServerState, TopicServer};
