//! A servable topic model: the frozen factors plus vocabulary, with the
//! query operations the topic server exposes.

use crate::eval::topics::top_terms;
use crate::sparse::Csr;

#[derive(Clone, Debug)]
pub struct TopicModel {
    /// term/topic factor (terms × k)
    pub u: Csr,
    /// document/topic factor (docs × k)
    pub v: Csr,
    pub terms: Vec<String>,
    /// term → row id (built once at construction)
    term_ids: std::collections::HashMap<String, usize>,
}

impl TopicModel {
    pub fn new(u: Csr, v: Csr, terms: Vec<String>) -> Self {
        assert_eq!(u.rows, terms.len());
        let term_ids = terms
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i))
            .collect();
        TopicModel {
            u,
            v,
            terms,
            term_ids,
        }
    }

    pub fn k(&self) -> usize {
        self.u.cols
    }

    /// Top `n` terms of a topic, as (term, weight).
    pub fn topic_terms(&self, topic: usize, n: usize) -> Vec<(String, f32)> {
        if topic >= self.k() {
            return Vec::new();
        }
        top_terms(&self.u, &self.terms, topic, n)
    }

    /// Classify a bag of words: per-topic score `Σ_w U[w, c]`, normalized
    /// to sum 1 over topics (all-zero → uniform). Returns (topic, score)
    /// descending.
    pub fn classify<S: AsRef<str>>(&self, words: &[S]) -> Vec<(usize, f32)> {
        let k = self.k();
        let mut scores = vec![0.0f32; k];
        for w in words {
            if let Some(&row) = self.term_ids.get(&w.as_ref().to_lowercase()) {
                let (idx, val) = self.u.row(row);
                for (&c, &v) in idx.iter().zip(val) {
                    scores[c as usize] += v;
                }
            }
        }
        let total: f32 = scores.iter().sum();
        if total > 0.0 {
            for s in &mut scores {
                *s /= total;
            }
        } else if k > 0 {
            for s in &mut scores {
                *s = 1.0 / k as f32;
            }
        }
        let mut ranked: Vec<(usize, f32)> = scores.into_iter().enumerate().collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        ranked
    }

    /// Documents most associated with a topic: (doc id, weight) descending.
    pub fn topic_documents(&self, topic: usize, n: usize) -> Vec<(usize, f32)> {
        let mut docs: Vec<(usize, f32)> = (0..self.v.rows)
            .filter_map(|d| {
                let w = self.v.get(d, topic);
                (w != 0.0).then_some((d, w))
            })
            .collect();
        docs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        docs.truncate(n);
        docs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> TopicModel {
        let u = Csr::from_dense(4, 2, &[
            0.9, 0.0, //
            0.6, 0.0, //
            0.0, 0.8, //
            0.0, 0.5,
        ]);
        let v = Csr::from_dense(3, 2, &[0.7, 0.0, 0.0, 0.9, 0.2, 0.1]);
        let terms = ["coffee", "crop", "electrons", "atoms"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        TopicModel::new(u, v, terms)
    }

    #[test]
    fn topic_terms_sorted() {
        let m = model();
        let t = m.topic_terms(0, 5);
        assert_eq!(t[0].0, "coffee");
        assert_eq!(t.len(), 2);
        assert!(m.topic_terms(7, 5).is_empty());
    }

    #[test]
    fn classify_picks_right_topic() {
        let m = model();
        let r = m.classify(&["coffee", "crop"]);
        assert_eq!(r[0].0, 0);
        assert!(r[0].1 > 0.99);
        let r = m.classify(&["Electrons"]); // case-insensitive
        assert_eq!(r[0].0, 1);
    }

    #[test]
    fn classify_unknown_words_uniform() {
        let m = model();
        let r = m.classify(&["zzzz"]);
        assert!((r[0].1 - 0.5).abs() < 1e-6);
    }

    #[test]
    fn topic_documents_ranked() {
        let m = model();
        let d = m.topic_documents(1, 10);
        assert_eq!(d[0], (1, 0.9));
        assert_eq!(d.len(), 2);
    }
}
