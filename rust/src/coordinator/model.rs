//! A servable topic model: the frozen factors plus vocabulary, with the
//! query operations the topic server exposes (including fold-in of
//! documents never seen at training time).

use crate::eval::topics::top_terms;
use crate::nmf::FoldIn;
use crate::sparse::{Csr, TieMode};

#[derive(Clone, Debug)]
pub struct TopicModel {
    /// term/topic factor (terms × k)
    pub u: Csr,
    /// document/topic factor (docs × k)
    pub v: Csr,
    pub terms: Vec<String>,
    /// term → row id (built once at construction)
    term_ids: std::collections::HashMap<String, usize>,
    /// single-document solver over the frozen `u` (Gram inverse
    /// precomputed once at construction)
    foldin: FoldIn,
}

impl TopicModel {
    pub fn new(u: Csr, v: Csr, terms: Vec<String>) -> Self {
        assert_eq!(u.rows, terms.len());
        let term_ids = terms
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i))
            .collect();
        let foldin = FoldIn::new(&u, None, TieMode::Exact);
        TopicModel {
            u,
            v,
            terms,
            term_ids,
            foldin,
        }
    }

    /// Cap the nonzeros of every folded-in document row (None leaves
    /// fold-in unenforced). Uses `Exact` tie mode: a hard budget is what
    /// a serving-side memory contract wants.
    pub fn with_foldin_budget(mut self, t: Option<usize>) -> Self {
        self.foldin.t = t;
        self
    }

    pub fn foldin_budget(&self) -> Option<usize> {
        self.foldin.t
    }

    pub fn k(&self) -> usize {
        self.u.cols
    }

    /// Top `n` terms of a topic, as (term, weight).
    pub fn topic_terms(&self, topic: usize, n: usize) -> Vec<(String, f32)> {
        if topic >= self.k() {
            return Vec::new();
        }
        top_terms(&self.u, &self.terms, topic, n)
    }

    /// Classify a bag of words: per-topic score `Σ_w U[w, c]`, normalized
    /// to sum 1 over topics (all-zero → uniform). Returns (topic, score)
    /// descending.
    pub fn classify<S: AsRef<str>>(&self, words: &[S]) -> Vec<(usize, f32)> {
        let k = self.k();
        let mut scores = vec![0.0f32; k];
        for w in words {
            if let Some(&row) = self.term_ids.get(&w.as_ref().to_lowercase()) {
                let (idx, val) = self.u.row(row);
                for (&c, &v) in idx.iter().zip(val) {
                    scores[c as usize] += v;
                }
            }
        }
        let total: f32 = scores.iter().sum();
        if total > 0.0 {
            for s in &mut scores {
                *s /= total;
            }
        } else if k > 0 {
            for s in &mut scores {
                *s = 1.0 / k as f32;
            }
        }
        let mut ranked: Vec<(usize, f32)> = scores.into_iter().enumerate().collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        ranked
    }

    /// Fold an unseen document into topic space: one enforced-sparse
    /// non-negative least-squares half-step against the frozen `U` (the
    /// same Algorithm-2 update the training loop runs per document row).
    /// Input is (word, count) pairs; unknown words are ignored with the
    /// same case-insensitive lookup as [`Self::classify`]. Returns the
    /// nonzero (topic, weight) entries, weight-descending (ties broken by
    /// topic id).
    pub fn fold_in<S: AsRef<str>>(&self, doc: &[(S, f32)]) -> Vec<(usize, f32)> {
        let pairs: Vec<(usize, f32)> = doc
            .iter()
            .filter_map(|(w, c)| {
                self.term_ids
                    .get(&w.as_ref().to_lowercase())
                    .map(|&row| (row, *c))
            })
            .collect();
        let x = self.foldin.solve(&self.u, &pairs);
        let mut out: Vec<(usize, f32)> = x
            .into_iter()
            .enumerate()
            .filter(|&(_, w)| w > 0.0)
            .collect();
        out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        out
    }

    /// Documents most associated with a topic: (doc id, weight) descending.
    pub fn topic_documents(&self, topic: usize, n: usize) -> Vec<(usize, f32)> {
        let mut docs: Vec<(usize, f32)> = (0..self.v.rows)
            .filter_map(|d| {
                let w = self.v.get(d, topic);
                (w != 0.0).then_some((d, w))
            })
            .collect();
        docs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        docs.truncate(n);
        docs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> TopicModel {
        let u = Csr::from_dense(4, 2, &[
            0.9, 0.0, //
            0.6, 0.0, //
            0.0, 0.8, //
            0.0, 0.5,
        ]);
        let v = Csr::from_dense(3, 2, &[0.7, 0.0, 0.0, 0.9, 0.2, 0.1]);
        let terms = ["coffee", "crop", "electrons", "atoms"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        TopicModel::new(u, v, terms)
    }

    #[test]
    fn topic_terms_sorted() {
        let m = model();
        let t = m.topic_terms(0, 5);
        assert_eq!(t[0].0, "coffee");
        assert_eq!(t.len(), 2);
        assert!(m.topic_terms(7, 5).is_empty());
    }

    #[test]
    fn classify_picks_right_topic() {
        let m = model();
        let r = m.classify(&["coffee", "crop"]);
        assert_eq!(r[0].0, 0);
        assert!(r[0].1 > 0.99);
        let r = m.classify(&["Electrons"]); // case-insensitive
        assert_eq!(r[0].0, 1);
    }

    #[test]
    fn classify_unknown_words_uniform() {
        let m = model();
        let r = m.classify(&["zzzz"]);
        assert!((r[0].1 - 0.5).abs() < 1e-6);
    }

    #[test]
    fn fold_in_ranks_like_classify() {
        let m = model();
        let folded = m.fold_in(&[("coffee", 2.0), ("crop", 1.0)]);
        assert!(!folded.is_empty());
        assert_eq!(folded[0].0, m.classify(&["coffee", "crop"])[0].0);
        // case-insensitive like classify
        let folded_upper = m.fold_in(&[("Coffee", 2.0), ("CROP", 1.0)]);
        assert_eq!(folded, folded_upper);
    }

    #[test]
    fn fold_in_unknown_words_empty() {
        let m = model();
        assert!(m.fold_in(&[("zzzz", 3.0)]).is_empty());
        assert!(m.fold_in::<&str>(&[]).is_empty());
    }

    #[test]
    fn fold_in_budget_caps_nnz() {
        let m = model().with_foldin_budget(Some(1));
        assert_eq!(m.foldin_budget(), Some(1));
        // both topics get mass without a budget; with t=1 only one survives
        let folded = m.fold_in(&[("coffee", 1.0), ("electrons", 1.0)]);
        assert_eq!(folded.len(), 1);
        let unbudgeted = model().fold_in(&[("coffee", 1.0), ("electrons", 1.0)]);
        assert!(unbudgeted.len() >= 2);
        // the survivor is the highest-weight topic of the unbudgeted row
        assert_eq!(folded[0].0, unbudgeted[0].0);
    }

    #[test]
    fn topic_documents_ranked() {
        let m = model();
        let d = m.topic_documents(1, 10);
        assert_eq!(d[0], (1, 0.9));
        assert_eq!(d.len(), 2);
    }
}
