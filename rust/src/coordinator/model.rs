//! A servable topic model: the frozen factors plus vocabulary, with the
//! query operations the topic server exposes (including fold-in of
//! documents never seen at training time).

use crate::eval::topics::top_terms;
use crate::io::Snapshot;
use crate::nmf::{FoldIn, FoldInScratch, NmfOptions, ObjectiveKind, SparsityMode};
use crate::sparse::{Csr, TieMode};
use crate::text::normalize_term;

/// Where the active model came from — captured when a snapshot is loaded
/// (or a freshly factorized model installed) and served verbatim by the
/// admin listener's `PROVENANCE` command. [`Snapshot`] is consumed by
/// [`TopicModel::from_snapshot`], so this record is taken *before*
/// construction and travels with the model through every hot swap.
#[derive(Clone, Debug, Default)]
pub struct Provenance {
    /// snapshot file the model was loaded from (None: factorized in-process)
    pub path: Option<String>,
    /// CRC-32 of the snapshot file bytes (None: factorized in-process)
    pub file_crc32: Option<u32>,
    /// training corpus digest pinned by the snapshot / corpus
    pub corpus_digest: Option<u64>,
    pub k: usize,
    pub n_terms: usize,
    pub n_docs: usize,
    /// compact [`SparsityMode`] label, see [`sparsity_label`]
    pub sparsity: String,
    /// compact solver-options label, see [`options_label`]
    pub options: String,
    /// training objective name (`frobenius` / `kl`) — fold-in answers
    /// are solved under this same objective
    pub objective: String,
    /// serving-side fold-in nonzero budget (None = unenforced)
    pub foldin_t: Option<usize>,
    /// wall-clock load time, milliseconds since the unix epoch
    pub loaded_unix_ms: u64,
}

impl Provenance {
    /// Capture a snapshot's provenance (call before
    /// [`TopicModel::from_snapshot`] consumes it).
    pub fn from_snapshot(snap: &Snapshot, path: Option<&str>, file_crc32: Option<u32>) -> Self {
        Provenance {
            path: path.map(str::to_string),
            file_crc32,
            corpus_digest: Some(snap.corpus_digest),
            k: snap.options.k,
            n_terms: snap.terms.len(),
            n_docs: snap.v.rows,
            sparsity: sparsity_label(&snap.options.sparsity),
            options: options_label(&snap.options),
            objective: snap.options.objective.name().into(),
            foldin_t: snap.t_v(),
            loaded_unix_ms: now_unix_ms(),
        }
    }

    /// Provenance of a model factorized (or constructed) in-process.
    pub fn from_model(model: &TopicModel) -> Self {
        Provenance {
            path: None,
            file_crc32: None,
            corpus_digest: None,
            k: model.k(),
            n_terms: model.terms.len(),
            n_docs: model.v.rows,
            sparsity: String::new(),
            options: String::new(),
            objective: model.objective().name().into(),
            foldin_t: model.foldin_budget(),
            loaded_unix_ms: now_unix_ms(),
        }
    }
}

/// Compact, space-free [`SparsityMode`] label for one-line admin output.
pub fn sparsity_label(mode: &SparsityMode) -> String {
    fn opt(v: Option<usize>) -> String {
        v.map_or_else(|| "-".into(), |t| t.to_string())
    }
    match mode {
        SparsityMode::None => "none".into(),
        SparsityMode::Global { t_u, t_v } => {
            format!("global(t_u={},t_v={})", opt(*t_u), opt(*t_v))
        }
        SparsityMode::PerColumn { t_u_col, t_v_col } => {
            format!("percol(t_u_col={},t_v_col={})", opt(*t_u_col), opt(*t_v_col))
        }
        SparsityMode::Threshold { tau_u, tau_v } => format!(
            "threshold(tau_u={},tau_v={})",
            tau_u.map_or_else(|| "-".into(), |t| t.to_string()),
            tau_v.map_or_else(|| "-".into(), |t| t.to_string()),
        ),
    }
}

/// Compact, space-free solver-options label for one-line admin output
/// (the machine-local knobs — threads, block height, checkpointing — are
/// deliberately omitted: they are not part of what the model *is*).
pub fn options_label(opts: &NmfOptions) -> String {
    format!(
        "iters={},tol={},seed={:#x},tie={:?}",
        opts.max_iters, opts.tol, opts.seed, opts.tie_mode
    )
}

fn now_unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis().min(u64::MAX as u128) as u64)
        .unwrap_or(0)
}

#[derive(Clone, Debug)]
pub struct TopicModel {
    /// term/topic factor (terms × k)
    pub u: Csr,
    /// document/topic factor (docs × k)
    pub v: Csr,
    pub terms: Vec<String>,
    /// term → row id (built once at construction)
    term_ids: std::collections::HashMap<String, usize>,
    /// single-document solver over the frozen `u` (Gram inverse
    /// precomputed once at construction)
    foldin: FoldIn,
}

impl TopicModel {
    pub fn new(u: Csr, v: Csr, terms: Vec<String>) -> Self {
        assert_eq!(u.rows, terms.len());
        let term_ids = terms
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i))
            .collect();
        let foldin = FoldIn::new(&u, None, TieMode::Exact);
        TopicModel {
            u,
            v,
            terms,
            term_ids,
            foldin,
        }
    }

    /// Build a servable model straight from a persisted [`Snapshot`] —
    /// the `esnmf serve --model` cold-start path: no factorization, just
    /// the (bit-exact) stored factors plus the one-time Gram-inverse
    /// precompute. The fold-in budget defaults to the snapshot's
    /// training-time `t_v` (override with
    /// [`TopicModel::with_foldin_budget`]).
    pub fn from_snapshot(snap: Snapshot) -> Self {
        let budget = snap.t_v();
        let objective = snap.options.objective;
        TopicModel::new(snap.u, snap.v, snap.terms)
            .with_foldin_budget(budget)
            .with_objective(objective)
    }

    /// Cap the nonzeros of every folded-in document row (None leaves
    /// fold-in unenforced). Uses `Exact` tie mode: a hard budget is what
    /// a serving-side memory contract wants.
    pub fn with_foldin_budget(mut self, t: Option<usize>) -> Self {
        self.foldin.t = t;
        self
    }

    /// Solve fold-ins under this objective — what
    /// [`TopicModel::from_snapshot`] sets from the snapshot's training
    /// objective, so FOLDIN/CLASSIFY answers minimize the same
    /// divergence the model was trained under.
    pub fn with_objective(mut self, objective: ObjectiveKind) -> Self {
        if self.foldin.objective() != objective {
            self.foldin = FoldIn::with_objective(&self.u, objective, self.foldin.t, self.foldin.tie);
        }
        self
    }

    /// The objective fold-ins are solved under.
    pub fn objective(&self) -> ObjectiveKind {
        self.foldin.objective()
    }

    pub fn foldin_budget(&self) -> Option<usize> {
        self.foldin.t
    }

    pub fn k(&self) -> usize {
        self.u.cols
    }

    /// Top `n` terms of a topic, as (term, weight).
    pub fn topic_terms(&self, topic: usize, n: usize) -> Vec<(String, f32)> {
        if topic >= self.k() {
            return Vec::new();
        }
        top_terms(&self.u, &self.terms, topic, n)
    }

    /// Classify a bag of words: per-topic score `Σ_w U[w, c]`, normalized
    /// to sum 1 over topics (all-zero → uniform). Returns (topic, score)
    /// descending.
    pub fn classify<S: AsRef<str>>(&self, words: &[S]) -> Vec<(usize, f32)> {
        let k = self.k();
        let mut scores = vec![0.0f32; k];
        for w in words {
            if let Some(&row) = self.term_ids.get(&normalize_term(w.as_ref())) {
                let (idx, val) = self.u.row(row);
                for (&c, &v) in idx.iter().zip(val) {
                    scores[c as usize] += v;
                }
            }
        }
        let total: f32 = scores.iter().sum();
        if total > 0.0 {
            for s in &mut scores {
                *s /= total;
            }
        } else if k > 0 {
            for s in &mut scores {
                *s = 1.0 / k as f32;
            }
        }
        let mut ranked: Vec<(usize, f32)> = scores.into_iter().enumerate().collect();
        // total_cmp: a NaN weight (degenerate Gram inverse) must rank, not
        // panic the serving thread
        ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
        ranked
    }

    /// Fold an unseen document into topic space: one enforced-sparse
    /// non-negative least-squares half-step against the frozen `U` (the
    /// same Algorithm-2 update the training loop runs per document row).
    /// Input is (word, count) pairs; unknown words are ignored with the
    /// same case-insensitive lookup as [`Self::classify`]. Returns the
    /// nonzero (topic, weight) entries, weight-descending (ties broken by
    /// topic id).
    pub fn fold_in<S: AsRef<str>>(&self, doc: &[(S, f32)]) -> Vec<(usize, f32)> {
        self.fold_in_with(doc, &mut FoldInScratch::default())
    }

    /// [`TopicModel::fold_in`] through caller-pooled scratch buffers —
    /// the topic server keeps a pool of [`FoldInScratch`]es so a warm
    /// serving path answers fold-ins with zero allocation growth (only
    /// the returned pairs are allocated; they *are* the response).
    /// Identical answers to [`TopicModel::fold_in`].
    pub fn fold_in_with<S: AsRef<str>>(
        &self,
        doc: &[(S, f32)],
        scratch: &mut FoldInScratch,
    ) -> Vec<(usize, f32)> {
        let mut pairs = std::mem::take(&mut scratch.pairs);
        pairs.clear();
        pairs.extend(doc.iter().filter_map(|(w, c)| {
            self.term_ids
                .get(&normalize_term(w.as_ref()))
                .map(|&row| (row, *c))
        }));
        let x = self.foldin.solve_into(&self.u, &pairs, scratch);
        let mut out: Vec<(usize, f32)> = x
            .iter()
            .copied()
            .enumerate()
            .filter(|&(_, w)| w > 0.0)
            .collect();
        out.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        scratch.pairs = pairs;
        out
    }

    /// Documents most associated with a topic: (doc id, weight) descending.
    pub fn topic_documents(&self, topic: usize, n: usize) -> Vec<(usize, f32)> {
        let mut docs: Vec<(usize, f32)> = (0..self.v.rows)
            .filter_map(|d| {
                let w = self.v.get(d, topic);
                (w != 0.0).then_some((d, w))
            })
            .collect();
        docs.sort_by(|a, b| b.1.total_cmp(&a.1));
        docs.truncate(n);
        docs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> TopicModel {
        let u = Csr::from_dense(4, 2, &[
            0.9, 0.0, //
            0.6, 0.0, //
            0.0, 0.8, //
            0.0, 0.5,
        ]);
        let v = Csr::from_dense(3, 2, &[0.7, 0.0, 0.0, 0.9, 0.2, 0.1]);
        let terms = ["coffee", "crop", "electrons", "atoms"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        TopicModel::new(u, v, terms)
    }

    #[test]
    fn topic_terms_sorted() {
        let m = model();
        let t = m.topic_terms(0, 5);
        assert_eq!(t[0].0, "coffee");
        assert_eq!(t.len(), 2);
        assert!(m.topic_terms(7, 5).is_empty());
    }

    #[test]
    fn classify_picks_right_topic() {
        let m = model();
        let r = m.classify(&["coffee", "crop"]);
        assert_eq!(r[0].0, 0);
        assert!(r[0].1 > 0.99);
        let r = m.classify(&["Electrons"]); // case-insensitive
        assert_eq!(r[0].0, 1);
    }

    #[test]
    fn classify_unknown_words_uniform() {
        let m = model();
        let r = m.classify(&["zzzz"]);
        assert!((r[0].1 - 0.5).abs() < 1e-6);
    }

    #[test]
    fn fold_in_ranks_like_classify() {
        let m = model();
        let folded = m.fold_in(&[("coffee", 2.0), ("crop", 1.0)]);
        assert!(!folded.is_empty());
        assert_eq!(folded[0].0, m.classify(&["coffee", "crop"])[0].0);
        // case-insensitive like classify
        let folded_upper = m.fold_in(&[("Coffee", 2.0), ("CROP", 1.0)]);
        assert_eq!(folded, folded_upper);
    }

    #[test]
    fn fold_in_unknown_words_empty() {
        let m = model();
        assert!(m.fold_in(&[("zzzz", 3.0)]).is_empty());
        assert!(m.fold_in::<&str>(&[]).is_empty());
    }

    #[test]
    fn fold_in_budget_caps_nnz() {
        let m = model().with_foldin_budget(Some(1));
        assert_eq!(m.foldin_budget(), Some(1));
        // both topics get mass without a budget; with t=1 only one survives
        let folded = m.fold_in(&[("coffee", 1.0), ("electrons", 1.0)]);
        assert_eq!(folded.len(), 1);
        let unbudgeted = model().fold_in(&[("coffee", 1.0), ("electrons", 1.0)]);
        assert!(unbudgeted.len() >= 2);
        // the survivor is the highest-weight topic of the unbudgeted row
        assert_eq!(folded[0].0, unbudgeted[0].0);
    }

    #[test]
    fn topic_documents_ranked() {
        let m = model();
        let d = m.topic_documents(1, 10);
        assert_eq!(d[0], (1, 0.9));
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn nan_weights_rank_instead_of_panicking() {
        // a degenerate Gram inverse can leak NaN into the factors; every
        // ranking sort must stay total (previously partial_cmp().unwrap()
        // panicked the serving thread)
        let u = Csr::from_dense(4, 2, &[
            f32::NAN, 0.0, //
            0.6, 0.0, //
            0.0, 0.8, //
            0.0, 0.5,
        ]);
        let v = Csr::from_dense(3, 2, &[0.7, 0.0, 0.0, f32::NAN, 0.2, 0.1]);
        let terms = ["coffee", "crop", "electrons", "atoms"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let m = TopicModel::new(u, v, terms);
        // classify with a NaN-tainted term: no panic, all topics ranked
        let r = m.classify(&["coffee", "crop"]);
        assert_eq!(r.len(), 2);
        // doc ranking over a NaN weight: no panic, every nonzero doc listed
        let d = m.topic_documents(1, 10);
        assert_eq!(d.len(), 2);
        // fold-in against the NaN-tainted U: no panic
        let _ = m.fold_in(&[("coffee", 1.0), ("electrons", 2.0)]);
        // finite weights still rank correctly among themselves
        let clean = m.topic_documents(0, 10);
        assert_eq!(clean, vec![(0, 0.7), (2, 0.2)]);
    }

    #[test]
    fn lookup_normalization_matches_the_tokenizer() {
        // Greek ΟΔΟΣ: str::to_lowercase gives final sigma "οδος", but the
        // tokenizer stores the char-wise "οδοσ" — the lookup must agree
        // with the tokenizer or served answers silently miss the term
        let toks = crate::text::tokenize("ΟΔΟΣ ΟΔΟΣ");
        assert_eq!(toks[0], "οδοσ");
        let u = Csr::from_dense(2, 2, &[0.9, 0.0, 0.0, 0.8]);
        let v = Csr::from_dense(1, 2, &[1.0, 0.0]);
        let m = TopicModel::new(u, v, vec![toks[0].clone(), "coffee".into()]);
        let r = m.classify(&["ΟΔΟΣ"]);
        assert_eq!(r[0].0, 0);
        assert!(r[0].1 > 0.99, "uppercase query missed the vocabulary: {r:?}");
        let folded = m.fold_in(&[("ΟΔΟΣ", 2.0)]);
        assert!(!folded.is_empty(), "fold-in missed the vocabulary");
    }

    #[test]
    fn provenance_labels_are_single_token() {
        use crate::nmf::{NmfOptions, SparsityMode};
        assert_eq!(sparsity_label(&SparsityMode::None), "none");
        assert_eq!(
            sparsity_label(&SparsityMode::both(30, 40)),
            "global(t_u=30,t_v=40)"
        );
        assert_eq!(
            sparsity_label(&SparsityMode::u_only(9)),
            "global(t_u=9,t_v=-)"
        );
        // admin responses are single-line, space-separated key=value
        // pairs, so neither label may contain whitespace
        for mode in [
            SparsityMode::None,
            SparsityMode::both(1, 2),
            SparsityMode::PerColumn {
                t_u_col: Some(3),
                t_v_col: None,
            },
            SparsityMode::Threshold {
                tau_u: Some(0.5),
                tau_v: None,
            },
        ] {
            assert!(!sparsity_label(&mode).contains(' '), "{mode:?}");
        }
        assert!(!options_label(&NmfOptions::new(2)).contains(' '));
    }

    #[test]
    fn provenance_from_snapshot_captures_the_digest_and_budget() {
        use crate::nmf::{factorize, NmfOptions, SparsityMode};
        use crate::text::TdmBuilder;
        let mut b = TdmBuilder::new();
        b.add_text("coffee crop coffee", None);
        b.add_text("atoms electrons atoms", None);
        let tdm = b.freeze();
        let opts = NmfOptions::new(2)
            .with_iters(3)
            .with_sparsity(SparsityMode::both(10, 12));
        let r = factorize(&tdm, &opts);
        let snap = crate::io::Snapshot::new(
            opts,
            r.u,
            r.v,
            &tdm,
            crate::io::Progress::default(),
        );
        let prov = Provenance::from_snapshot(&snap, Some("m.esnmf"), Some(0xdead_beef));
        assert_eq!(prov.corpus_digest, Some(snap.corpus_digest));
        assert_eq!(prov.k, 2);
        assert_eq!(prov.n_terms, tdm.terms.len());
        assert_eq!(prov.foldin_t, Some(12));
        assert_eq!(prov.file_crc32, Some(0xdead_beef));
        assert_eq!(prov.objective, "frobenius");
        assert!(prov.loaded_unix_ms > 0);
        let m = TopicModel::from_snapshot(snap);
        let from_model = Provenance::from_model(&m);
        assert_eq!(from_model.k, 2);
        assert_eq!(from_model.foldin_t, Some(12));
        assert_eq!(from_model.corpus_digest, None);
        assert_eq!(from_model.objective, "frobenius");
    }

    #[test]
    fn kl_snapshot_serves_kl_foldins() {
        use crate::nmf::{factorize, NmfOptions};
        use crate::text::TdmBuilder;
        let mut b = TdmBuilder::new();
        for _ in 0..4 {
            b.add_text("coffee crop quotas coffee", Some("econ"));
            b.add_text("electrons atoms hydrogen", Some("sci"));
        }
        let tdm = b.freeze();
        let opts = NmfOptions::new(2)
            .with_iters(6)
            .with_seed(5)
            .with_objective(ObjectiveKind::Kl);
        let r = factorize(&tdm, &opts);
        let snap = crate::io::Snapshot::new(
            opts,
            r.u.clone(),
            r.v.clone(),
            &tdm,
            crate::io::Progress::default(),
        );
        let prov = Provenance::from_snapshot(&snap, None, None);
        assert_eq!(prov.objective, "kl");
        let m = TopicModel::from_snapshot(snap);
        assert_eq!(m.objective(), ObjectiveKind::Kl);
        // answers match a hand-built KL fold-in over the same factors
        let want = TopicModel::new(r.u, r.v, tdm.terms.clone())
            .with_objective(ObjectiveKind::Kl);
        let doc = [("coffee", 2.0f32), ("atoms", 1.0)];
        assert_eq!(m.fold_in(&doc), want.fold_in(&doc));
    }

    #[test]
    fn from_snapshot_serves_identically_to_the_source_model() {
        use crate::nmf::{factorize, NmfOptions, SparsityMode};
        use crate::text::TdmBuilder;
        let mut b = TdmBuilder::new();
        for _ in 0..5 {
            b.add_text("coffee crop quotas coffee brazil crop", Some("econ"));
            b.add_text("electrons atoms hydrogen electrons atoms", Some("sci"));
        }
        let tdm = b.freeze();
        let opts = NmfOptions::new(2)
            .with_iters(10)
            .with_seed(11)
            .with_sparsity(SparsityMode::both(30, 40));
        let r = factorize(&tdm, &opts);
        let fresh = TopicModel::new(r.u.clone(), r.v.clone(), tdm.terms.clone())
            .with_foldin_budget(Some(40));
        let snap = crate::io::Snapshot::new(
            opts,
            r.u,
            r.v,
            &tdm,
            crate::io::Progress::default(),
        );
        let loaded =
            TopicModel::from_snapshot(crate::io::Snapshot::from_bytes(&snap.to_bytes()).unwrap());
        // fold-in budget defaulted from the snapshot's t_v
        assert_eq!(loaded.foldin_budget(), Some(40));
        // classify + fold-in answers are bit-identical
        let words = ["coffee", "crop", "electrons"];
        assert_eq!(fresh.classify(&words), loaded.classify(&words));
        let doc = [("coffee", 2.0f32), ("atoms", 1.0)];
        assert_eq!(fresh.fold_in(&doc), loaded.fold_in(&doc));
        for t in 0..2 {
            assert_eq!(fresh.topic_terms(t, 5), loaded.topic_terms(t, 5));
            assert_eq!(fresh.topic_documents(t, 5), loaded.topic_documents(t, 5));
        }
    }
}
