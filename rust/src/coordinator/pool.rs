//! Worker-pool and scoped parallel-for primitives.
//!
//! Two execution substrates live here, sharing one partitioning scheme:
//!
//! * [`ThreadPool`] — a fixed-size pool of persistent workers over
//!   `std::sync::mpsc` (tokio is not in the offline vendor set). The job
//!   manager uses it for whole factorizations, which are `'static` jobs.
//! * [`scoped_map_ranges`] / [`scoped_partition_map_mut`] — scoped
//!   parallel-for over index ranges, used *inside* a single factorization
//!   to row-partition the ALS hot-path kernels (SpMM products, gram
//!   accumulations, projection, top-t enforcement). Scoped threads borrow
//!   the operands directly, so the kernels need no `Arc`/clone plumbing.
//!
//! # Partitioning scheme
//!
//! All kernels partition their *output* rows (or flat scalar ranges) into
//! contiguous pieces via [`split_ranges`] (one near-equal piece per
//! worker) or [`fixed_chunks`] (fixed-width pieces independent of the
//! worker count — the unit of deterministic reductions). Each piece is
//! computed independently; results are merged strictly in piece order.
//!
//! # Determinism contract
//!
//! Parallel execution is **bit-for-bit identical to serial** at any
//! thread count:
//!
//! * Row-local kernels (SpMM, projection, the small solve) compute each
//!   output row with the same instruction sequence regardless of which
//!   worker owns it, so any contiguous partition concatenates to the
//!   serial result.
//! * Reductions (gram matrices, tie counts) accumulate per *fixed-width
//!   chunk* ([`fixed_chunks`] boundaries do not depend on the thread
//!   count) and merge partial results in ascending chunk order, so the
//!   floating-point rounding sequence is the same for every thread count
//!   — including 1: the serial paths run the identical chunked
//!   computation.
//! * Order-sensitive tie-breaking (top-t `Exact` mode) is split by
//!   prefix-counting ties per piece, reproducing the serial
//!   left-to-right budget scan exactly.
//!
//! The property tests in `tests/prop_invariants.rs` pin this contract for
//! thread counts {1, 2, 4, 7}.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Number of workers to use when the caller does not say: the machine's
/// available parallelism (≥ 1).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Items-per-worker floor for flat elementwise work: below this, scoped
/// thread spawn overhead dominates the work itself.
pub const MIN_ITEMS_PER_WORKER: usize = 4096;

/// Clamp a requested worker count so each worker gets at least
/// [`MIN_ITEMS_PER_WORKER`] items (never below 1). Purely a speed
/// decision — results are bit-identical at any worker count — so hot
/// paths apply it at their entry point while the `_par` kernels honor
/// whatever count they are handed (the equivalence tests rely on that).
pub fn effective_workers(items: usize, threads: usize) -> usize {
    threads.clamp(1, (items / MIN_ITEMS_PER_WORKER).max(1))
}

/// Contiguous near-equal ranges covering `0..total` (at most `parts`
/// pieces, never an empty piece unless `total == 0`).
pub fn split_ranges(total: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.min(total).max(1);
    let base = total / parts;
    let rem = total % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Fixed-width chunk boundaries covering `0..total`. Unlike
/// [`split_ranges`] the boundaries depend only on `chunk`, never on the
/// worker count — deterministic reductions accumulate per chunk and merge
/// in chunk order so every thread count rounds identically.
pub fn fixed_chunks(total: usize, chunk: usize) -> Vec<(usize, usize)> {
    let chunk = chunk.max(1);
    let mut out = Vec::with_capacity(total / chunk + 1);
    let mut lo = 0;
    while lo < total {
        let hi = (lo + chunk).min(total);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Apply `f` to every `(lo, hi)` range on up to `threads` scoped workers,
/// returning the results in range order. Ranges are claimed dynamically
/// (atomic cursor) so uneven pieces still balance; the merge order is
/// fixed, so the output does not depend on scheduling.
pub fn scoped_map_ranges<R, F>(threads: usize, ranges: &[(usize, usize)], f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize) -> R + Sync,
{
    scoped_map_ranges_with(threads, ranges, || (), |_, lo, hi| f(lo, hi))
}

/// [`scoped_map_ranges`] with per-worker scratch state: `init` runs once
/// per worker (once total on the serial path) and the state is handed
/// back to `f` for every range that worker claims. This is how the
/// blocked ALS half-steps reuse one candidate [`RowBlock`] allocation per
/// worker instead of materializing every block at once — the whole point
/// of the bounded-memory pipeline.
///
/// The state must not influence the *value* `f` returns for a given range
/// (it is scratch, not an accumulator): which worker claims which range
/// is scheduling-dependent, and the determinism contract above only holds
/// when `f(state, lo, hi)` is a pure function of `(lo, hi)`.
///
/// [`RowBlock`]: crate::sparse::RowBlock
pub fn scoped_map_ranges_with<S, R, I, F>(
    threads: usize,
    ranges: &[(usize, usize)],
    init: I,
    f: F,
) -> Vec<R>
where
    S: Send,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, usize) -> R + Sync,
{
    scoped_map_ranges_with_states(threads, ranges, init, f).0
}

/// As [`scoped_map_ranges_with`], additionally returning each worker's
/// final state. The per-range results come back in range order as
/// always; the states come back in **no guaranteed order** (which
/// worker claimed which ranges is scheduling-dependent), so callers
/// must fold them with an order-independent reduction. This is how the
/// blocked global enforcement keeps its pass-1 memory at one O(t)
/// selector per *worker* instead of one per block.
pub fn scoped_map_ranges_with_states<S, R, I, F>(
    threads: usize,
    ranges: &[(usize, usize)],
    init: I,
    f: F,
) -> (Vec<R>, Vec<S>)
where
    S: Send,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, usize) -> R + Sync,
{
    let n = ranges.len();
    if threads <= 1 || n <= 1 {
        let mut state = init();
        let out: Vec<R> = ranges.iter().map(|&(lo, hi)| f(&mut state, lo, hi)).collect();
        return (out, vec![state]);
    }
    let next = AtomicUsize::new(0);
    let workers = threads.min(n);
    let per_worker: Vec<(Vec<(usize, R)>, S)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    let mut state = init();
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let (lo, hi) = ranges[i];
                        local.push((i, f(&mut state, lo, hi)));
                    }
                    (local, state)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel-for worker panicked"))
            .collect()
    });
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut states = Vec::with_capacity(per_worker.len());
    for (pairs, state) in per_worker {
        for (i, r) in pairs {
            slots[i] = Some(r);
        }
        states.push(state);
    }
    let out = slots
        .into_iter()
        .map(|s| s.expect("range not executed"))
        .collect();
    (out, states)
}

/// Partition `data` into up to `threads` contiguous pieces whose lengths
/// are multiples of `granule` (so a logical row is never split), run `f`
/// on each piece concurrently, and return the per-piece results in piece
/// order. `f` receives the piece's element offset into `data`.
pub fn scoped_partition_map_mut<T, R, F>(
    threads: usize,
    data: &mut [T],
    granule: usize,
    f: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut [T]) -> R + Sync,
{
    let granule = granule.max(1);
    debug_assert_eq!(data.len() % granule, 0, "granule must divide data");
    let n_granules = data.len() / granule;
    let parts = split_ranges(n_granules, threads.max(1));
    if threads <= 1 || parts.len() <= 1 {
        return vec![f(0, data)];
    }
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(parts.len());
        let mut rest = data;
        let mut offset = 0usize;
        for &(lo, hi) in &parts {
            let len = (hi - lo) * granule;
            let (piece, tail) = std::mem::take(&mut rest).split_at_mut(len);
            rest = tail;
            let at = offset;
            let f = &f;
            handles.push(s.spawn(move || f(at, piece)));
            offset += len;
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("partition worker panicked"))
            .collect()
    })
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size pool of persistent workers for `'static` jobs (the job
/// manager's unit of work is a whole factorization).
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (clamped to ≥ 1).
    pub fn new(size: usize) -> ThreadPool {
        ThreadPool::named(size, "esnmf-worker")
    }

    /// As [`ThreadPool::new`] with a thread-name prefix, so different
    /// pools (factorization jobs vs. served connections) are tellable
    /// apart in a debugger or thread dump.
    pub fn named(size: usize, prefix: &str) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("{prefix}-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
        }
    }

    /// Default pool sized to the machine.
    pub fn with_default_size() -> ThreadPool {
        ThreadPool::new(default_threads().min(16))
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Apply `f` to every item in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        let count = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..count).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_clamps_to_one() {
        assert_eq!(ThreadPool::new(0).size(), 1);
        assert_eq!(ThreadPool::named(0, "t").size(), 1);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must join, so all jobs complete
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn split_ranges_covers_everything() {
        for (total, parts) in [(10usize, 3usize), (1, 4), (0, 2), (7, 7), (100, 8)] {
            let ranges = split_ranges(total, parts);
            let mut covered = 0;
            let mut prev_hi = 0;
            for &(lo, hi) in &ranges {
                assert_eq!(lo, prev_hi);
                covered += hi - lo;
                prev_hi = hi;
            }
            assert_eq!(covered, total, "total {total} parts {parts}");
        }
    }

    #[test]
    fn effective_workers_floors_small_work() {
        assert_eq!(effective_workers(100, 8), 1);
        assert_eq!(effective_workers(MIN_ITEMS_PER_WORKER, 8), 1);
        assert_eq!(effective_workers(2 * MIN_ITEMS_PER_WORKER, 8), 2);
        assert_eq!(effective_workers(10 * MIN_ITEMS_PER_WORKER, 8), 8);
        assert_eq!(effective_workers(0, 0), 1);
        // every worker is guaranteed the documented minimum
        for items in [1usize, 4095, 4096, 10_000, 1 << 20] {
            let w = effective_workers(items, 64);
            assert!(w == 1 || items / w >= MIN_ITEMS_PER_WORKER, "items={items} w={w}");
        }
    }

    #[test]
    fn fixed_chunks_independent_of_parts() {
        let chunks = fixed_chunks(2500, 1024);
        assert_eq!(chunks, vec![(0, 1024), (1024, 2048), (2048, 2500)]);
        assert_eq!(fixed_chunks(0, 1024), vec![]);
        assert_eq!(fixed_chunks(3, 0), vec![(0, 1), (1, 2), (2, 3)]); // clamped
    }

    #[test]
    fn scoped_map_ranges_ordered_at_any_thread_count() {
        let ranges = fixed_chunks(97, 10);
        let serial = scoped_map_ranges(1, &ranges, |lo, hi| (lo, hi));
        for threads in [2, 4, 7, 16] {
            let par = scoped_map_ranges(threads, &ranges, |lo, hi| (lo, hi));
            assert_eq!(par, serial, "threads {threads}");
        }
    }

    #[test]
    fn scoped_map_ranges_with_reuses_per_worker_state() {
        let ranges = fixed_chunks(50, 5);
        for threads in [1usize, 2, 4, 7] {
            let inits = AtomicUsize::new(0);
            let (out, states) = scoped_map_ranges_with_states(
                threads,
                &ranges,
                || {
                    inits.fetch_add(1, Ordering::SeqCst);
                    Vec::<usize>::new()
                },
                |scratch, lo, hi| {
                    // scratch survives across claims (reuse), but the
                    // returned value depends only on (lo, hi)
                    scratch.push(lo);
                    (lo, hi)
                },
            );
            assert_eq!(out, ranges, "threads {threads}");
            let created = inits.load(Ordering::SeqCst);
            let cap = if threads <= 1 { 1 } else { threads.min(ranges.len()) };
            assert!(
                created >= 1 && created <= cap,
                "threads {threads}: {created} states for cap {cap}"
            );
            // one state back per created worker; together they saw
            // every range exactly once
            assert_eq!(states.len(), created, "threads {threads}");
            let mut claimed: Vec<usize> = states.into_iter().flatten().collect();
            claimed.sort_unstable();
            let want: Vec<usize> = ranges.iter().map(|&(lo, _)| lo).collect();
            assert_eq!(claimed, want, "threads {threads}");
        }
    }

    #[test]
    fn scoped_partition_map_mut_covers_disjoint_pieces() {
        for threads in [1usize, 2, 4, 7] {
            let mut data = vec![0u32; 6 * 5]; // 6 logical rows of width 5
            let offsets = scoped_partition_map_mut(threads, &mut data, 5, |offset, piece| {
                assert_eq!(offset % 5, 0, "piece must align to the granule");
                for v in piece.iter_mut() {
                    *v += 1;
                }
                offset
            });
            assert!(data.iter().all(|&v| v == 1), "threads {threads}");
            let mut sorted = offsets.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, offsets, "results must be in piece order");
        }
    }
}
