//! A fixed-size worker pool over `std::sync::mpsc` (tokio is not in the
//! offline vendor set; the coordinator's needs are fully met by threads).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (clamped to ≥ 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("esnmf-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
        }
    }

    /// Default pool sized to the machine.
    pub fn with_default_size() -> ThreadPool {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        ThreadPool::new(n.min(16))
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Apply `f` to every item in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        let count = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..count).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit on recv Err
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pool_clamps_to_one() {
        assert_eq!(ThreadPool::new(0).size(), 1);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must join, so all jobs complete
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
