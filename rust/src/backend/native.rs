//! The native sparse backend — a thin adapter over [`crate::nmf::als`].

use super::AlsBackend;
use crate::nmf::{self, NmfOptions, NmfResult};
use crate::text::TermDocMatrix;
use crate::Result;

#[derive(Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl AlsBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn factorize(&mut self, tdm: &TermDocMatrix, opts: &NmfOptions) -> Result<NmfResult> {
        Ok(nmf::factorize(tdm, opts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::TdmBuilder;

    #[test]
    fn native_backend_runs() {
        let mut b = TdmBuilder::new();
        for _ in 0..4 {
            b.add_text("coffee crop coffee quotas brazil", Some("econ"));
            b.add_text("electrons atoms electrons hydrogen", Some("sci"));
        }
        let tdm = b.freeze();
        let mut backend = NativeBackend::new();
        let r = backend
            .factorize(&tdm, &NmfOptions::new(2).with_iters(10).with_seed(4))
            .unwrap();
        assert_eq!(r.iterations, 10);
        assert_eq!(backend.name(), "native");
    }
}
