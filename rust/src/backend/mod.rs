//! ALS backends behind one trait: the sparse native engine (the paper's
//! system) and the dense-block XLA/PJRT engine (the AOT three-layer path).
//!
//! Both run *the same algorithm* — identical projection, identical top-t
//! semantics (ties kept), identical Gram ridge — so on tie-free data their
//! iterates agree to float tolerance; `rust/tests/integration_runtime.rs`
//! asserts exactly that.

pub mod native;
pub mod xla_backend;

use crate::nmf::{NmfOptions, NmfResult};
use crate::text::TermDocMatrix;
use crate::Result;

pub use native::NativeBackend;
pub use xla_backend::XlaBackend;

/// A factorization engine.
pub trait AlsBackend {
    fn name(&self) -> &'static str;
    fn factorize(&mut self, tdm: &TermDocMatrix, opts: &NmfOptions) -> Result<NmfResult>;
}

/// Backend selection for CLI/config.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    Native,
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "sparse" | "rust" => Some(BackendKind::Native),
            "xla" | "pjrt" | "dense" => Some(BackendKind::Xla),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parse() {
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("XLA"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("gpu"), None);
    }
}
