//! The XLA/PJRT dense-block backend.
//!
//! Each ALS iteration is ONE device execution of the fused Layer-2 graph
//! (`als_iter_{n}x{m}x{k}.hlo.txt`): both half-steps, projection and top-t
//! enforcement happen inside the artifact; rust only marshals buffers and
//! tracks convergence between iterations. Problems smaller than the
//! compiled shape are zero-padded (zero rows/columns are fixed points of
//! every ALS step, so padding does not perturb the iterates).

use super::AlsBackend;
use crate::nmf::memory::MemoryStats;
use crate::nmf::{init, NmfOptions, NmfResult, SparsityMode};
use crate::runtime::XlaExecutor;
use crate::sparse::Csr;
use crate::text::TermDocMatrix;
use crate::util::timer::Timer;
use crate::Result;
use anyhow::bail;

pub struct XlaBackend {
    exec: XlaExecutor,
    /// compiled program shape (from the manifest)
    n: usize,
    m: usize,
    k: usize,
}

impl XlaBackend {
    /// Wrap an executor handle targeting the artifact shape (n, m, k).
    pub fn new(exec: XlaExecutor, n: usize, m: usize, k: usize) -> Self {
        XlaBackend { exec, n, m, k }
    }

    /// Dense row-major zero-padded copy of the term-document matrix.
    fn densify_padded(&self, tdm: &TermDocMatrix) -> Vec<f32> {
        let mut a = vec![0.0f32; self.n * self.m];
        for r in 0..tdm.n_terms() {
            let (idx, val) = tdm.a.row(r);
            for (&c, &v) in idx.iter().zip(val) {
                a[r * self.m + c as usize] = v;
            }
        }
        a
    }

    fn budgets(&self, opts: &NmfOptions) -> Result<(i32, i32)> {
        match opts.sparsity {
            SparsityMode::None => Ok((0, 0)),
            SparsityMode::Global { t_u, t_v } => Ok((
                t_u.map(|t| t as i32).unwrap_or(0),
                t_v.map(|t| t as i32).unwrap_or(0),
            )),
            SparsityMode::PerColumn { .. } => {
                bail!("per-column enforcement is native-only (see DESIGN.md)")
            }
            SparsityMode::Threshold { .. } => {
                bail!("threshold enforcement is native-only (ablation mode)")
            }
        }
    }
}

/// Dense row-major (rows, k) buffer → CSR, dropping zeros/subnormals that
/// the artifact's MIN_TAU floor treats as zero.
fn dense_to_csr(padded_rows: usize, k: usize, data: &[f32], keep_rows: usize) -> Csr {
    debug_assert!(keep_rows <= padded_rows);
    debug_assert_eq!(data.len(), padded_rows * k);
    Csr::from_dense(keep_rows, k, &data[..keep_rows * k])
}

impl AlsBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn factorize(&mut self, tdm: &TermDocMatrix, opts: &NmfOptions) -> Result<NmfResult> {
        if tdm.n_terms() > self.n || tdm.n_docs() > self.m {
            bail!(
                "corpus ({} terms × {} docs) exceeds artifact shape ({} × {})",
                tdm.n_terms(),
                tdm.n_docs(),
                self.n,
                self.m
            );
        }
        if opts.k != self.k {
            bail!("k = {} does not match artifact k = {}", opts.k, self.k);
        }
        let (t_u, t_v) = self.budgets(opts)?;
        let timer = Timer::start();

        let a = self.densify_padded(tdm);
        // pad the initial guess into the artifact's row count
        let u0 = init::initial_u(tdm.n_terms(), self.k, opts.init_nnz, opts.seed);
        let mut u_dense = vec![0.0f32; self.n * self.k];
        for r in 0..u0.rows {
            let (idx, val) = u0.row(r);
            for (&c, &v) in idx.iter().zip(val) {
                u_dense[r * self.k + c as usize] = v;
            }
        }

        let norm_a_sq = tdm.a.fro_norm_sq();
        let mut residuals = Vec::with_capacity(opts.max_iters);
        let mut errors = Vec::new();
        let mut iterations = 0;
        let mut v_dense: Vec<f32> = vec![0.0; self.m * self.k];

        for _ in 0..opts.max_iters {
            let out = self.exec.als_iter(
                self.n,
                self.m,
                self.k,
                a.clone(),
                u_dense.clone(),
                t_u,
                t_v,
            )?;
            // relative residual ‖U_i − U_{i−1}‖/‖U_i‖ over the dense buffers
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (new, old) in out.u_new.iter().zip(&u_dense) {
                let d = (*new - *old) as f64;
                num += d * d;
                den += (*new as f64) * (*new as f64);
            }
            let r = if den > 0.0 {
                (num / den).sqrt()
            } else if num == 0.0 {
                0.0
            } else {
                f64::INFINITY
            };
            residuals.push(r);
            u_dense = out.u_new;
            v_dense = out.v;
            iterations += 1;

            if opts.track_error {
                let u_csr = dense_to_csr(self.n, self.k, &u_dense, tdm.n_terms());
                let v_csr = dense_to_csr(self.m, self.k, &v_dense, tdm.n_docs());
                errors.push(crate::nmf::rel_error_sparse(
                    &tdm.a, &u_csr, &v_csr, norm_a_sq,
                ));
            }
            if opts.tol > 0.0 && r < opts.tol {
                break;
            }
        }

        let u = dense_to_csr(self.n, self.k, &u_dense, tdm.n_terms());
        let v = dense_to_csr(self.m, self.k, &v_dense, tdm.n_docs());
        // dense backend: the device stores full (n+m)·k scalars throughout
        let memory = MemoryStats {
            max_combined_nnz: (self.n + self.m) * self.k,
            max_intermediate_nnz: self.m * self.k,
            final_u_nnz: u.nnz(),
            final_v_nnz: v.nnz(),
        };
        Ok(NmfResult {
            u,
            v,
            iterations,
            residuals,
            errors,
            memory,
            elapsed_s: timer.elapsed_s(),
        })
    }
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end in rust/tests/integration_runtime.rs (requires
    // compiled artifacts).
}
