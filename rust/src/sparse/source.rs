//! [`RowSource`] — the streaming contract between a sparse matrix and the
//! blocked ALS half-steps: "give me rows `r0..r1` as CSR".
//!
//! The blocked pipeline ([`crate::nmf::als`]) never needs the whole data
//! matrix at once — each half-step walks contiguous row blocks of one
//! orientation of `A`. Abstracting that access behind a trait is what
//! lets the same kernels run over a fully resident [`Csr`]/[`Csc`] *and*
//! over the on-disk sharded store ([`crate::io::store`]), where resident
//! corpus memory is bounded by the shards currently cached by the
//! workers instead of the whole matrix.
//!
//! Two pieces:
//!
//! * [`RowsRef`] — a borrowed CSR-shaped view of a contiguous row run.
//!   For resident matrices it borrows the matrix directly (zero copy);
//!   for disk-backed sources it borrows the cursor's cached shard or
//!   chunk buffers.
//! * [`RowCursor`] — per-worker streaming state. Sources that read from
//!   disk park their last-read shard (and any cross-shard copy buffers)
//!   here, so each worker keeps at most one shard resident and repeated
//!   blocks inside one shard cost one read. Resident matrices ignore it.
//!
//! # Determinism contract
//!
//! `load(lo, hi)` must present exactly the rows `lo..hi` of the logical
//! matrix, entries in ascending column order with identical value bits,
//! whatever the backing storage — the blocked half-steps' bit-identical
//! guarantee rests on every source producing the same row bytes.

use super::csc::Csc;
use super::csr::Csr;
use std::any::Any;

/// Borrowed CSR-shaped view of rows `lo..hi` of some matrix. `indptr`
/// has one entry per row plus one; entry positions index `indices` /
/// `values` after subtracting `indptr[0]`, so both rebased chunk buffers
/// and direct sub-slices of a resident CSR share one representation.
#[derive(Clone, Copy, Debug)]
pub struct RowsRef<'a> {
    indptr: &'a [usize],
    indices: &'a [u32],
    values: &'a [f32],
}

impl<'a> RowsRef<'a> {
    pub fn new(indptr: &'a [usize], indices: &'a [u32], values: &'a [f32]) -> Self {
        debug_assert!(!indptr.is_empty(), "indptr needs at least the sentinel");
        debug_assert_eq!(
            indptr.last().unwrap() - indptr[0],
            values.len(),
            "indptr span must cover the value slice"
        );
        debug_assert_eq!(indices.len(), values.len());
        RowsRef {
            indptr,
            indices,
            values,
        }
    }

    #[inline]
    pub fn n_rows(&self) -> usize {
        self.indptr.len() - 1
    }

    /// (column indices, values) of local row `i` (row `lo + i` of the
    /// source).
    #[inline]
    pub fn row(&self, i: usize) -> (&'a [u32], &'a [f32]) {
        let base = self.indptr[0];
        let s = self.indptr[i] - base;
        let e = self.indptr[i + 1] - base;
        (&self.indices[s..e], &self.values[s..e])
    }
}

/// Per-worker streaming state for a [`RowSource`]. One cursor lives in
/// each worker's scratch (next to its candidate
/// [`RowBlock`](super::RowBlock)) and is reused across the blocks that
/// worker claims — exactly the allocation-reuse discipline of the
/// blocked pipeline, applied to corpus bytes.
#[derive(Debug, Default)]
pub struct RowCursor {
    /// chunk buffers for ranges no single cached unit can serve
    /// (rebased indptr starting at 0)
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    /// source-private cache (the store parks its last-read shard here;
    /// dropping the box releases the shard's resident-byte charge)
    pub cache: Option<Box<dyn Any + Send>>,
}

impl RowCursor {
    pub fn new() -> Self {
        RowCursor::default()
    }

    /// Reset the chunk buffers (allocations kept) and seed the rebased
    /// indptr — callers then append rows with [`Self::push_row`].
    pub fn begin_chunk(&mut self) {
        self.indptr.clear();
        self.indices.clear();
        self.values.clear();
        self.indptr.push(0);
    }

    /// Append one row's entries to the chunk.
    pub fn push_row(&mut self, indices: &[u32], values: &[f32]) {
        debug_assert_eq!(indices.len(), values.len());
        self.indices.extend_from_slice(indices);
        self.values.extend_from_slice(values);
        self.indptr.push(self.values.len());
    }

    /// View of the accumulated chunk.
    pub fn chunk_view(&self) -> RowsRef<'_> {
        RowsRef::new(&self.indptr, &self.indices, &self.values)
    }
}

/// A sparse matrix readable as contiguous CSR row runs — the streaming
/// contract of the blocked ALS half-steps (see the module docs).
pub trait RowSource: Sync {
    /// Logical row count (the half-step's output rows).
    fn rows(&self) -> usize;

    /// Logical column count (the contraction dimension).
    fn cols(&self) -> usize;

    /// Stored nonzeros of the whole matrix.
    fn nnz(&self) -> usize;

    /// Present rows `lo..hi`. Resident sources return a borrowed view
    /// and never touch `cur`; disk-backed sources load through `cur`
    /// (shard cache + chunk buffers). This signature has no error
    /// channel by design — the hot loops stay branch-free — so
    /// implementations over fallible backing storage must stay total:
    /// an unreadable range is served as shape-correct **empty rows**
    /// (which every streaming kernel skips) and the failure is latched
    /// on the source for callers to check between steps (see
    /// [`crate::io::store`]'s failure model). A mid-run read failure
    /// must never panic a multi-hour factorization.
    fn load<'a>(&'a self, lo: usize, hi: usize, cur: &'a mut RowCursor) -> RowsRef<'a>;
}

impl RowSource for Csr {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn nnz(&self) -> usize {
        self.nnz()
    }

    fn load<'a>(&'a self, lo: usize, hi: usize, _cur: &'a mut RowCursor) -> RowsRef<'a> {
        RowsRef::new(
            &self.indptr[lo..=hi],
            &self.indices[self.indptr[lo]..self.indptr[hi]],
            &self.values[self.indptr[lo]..self.indptr[hi]],
        )
    }
}

/// The transpose view: a CSC matrix is, byte for byte, the CSR of its
/// transpose, so "rows" of this source are the *columns* of the logical
/// matrix. This is exactly what the update-V half-step streams (`Aᵀ`'s
/// rows = `A`'s columns).
impl RowSource for Csc {
    fn rows(&self) -> usize {
        self.cols
    }

    fn cols(&self) -> usize {
        self.rows
    }

    fn nnz(&self) -> usize {
        self.nnz()
    }

    fn load<'a>(&'a self, lo: usize, hi: usize, _cur: &'a mut RowCursor) -> RowsRef<'a> {
        RowsRef::new(
            &self.indptr[lo..=hi],
            &self.indices[self.indptr[lo]..self.indptr[hi]],
            &self.values[self.indptr[lo]..self.indptr[hi]],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        Csr::from_dense(4, 3, &[
            1.0, 0.0, 2.0, //
            0.0, 0.0, 0.0, //
            3.0, 4.0, 0.0, //
            0.0, 5.0, 6.0,
        ])
    }

    #[test]
    fn csr_views_match_direct_rows() {
        let m = sample();
        let mut cur = RowCursor::new();
        for lo in 0..=m.rows {
            for hi in lo..=m.rows {
                let view = m.load(lo, hi, &mut cur);
                assert_eq!(view.n_rows(), hi - lo);
                for r in lo..hi {
                    assert_eq!(view.row(r - lo), m.row(r), "rows {lo}..{hi} row {r}");
                }
            }
        }
    }

    #[test]
    fn csc_views_are_the_transpose_rows() {
        let m = sample();
        let t = m.transpose();
        let csc = m.to_csc();
        assert_eq!(RowSource::rows(&csc), m.cols);
        assert_eq!(RowSource::cols(&csc), m.rows);
        let mut cur = RowCursor::new();
        let view = csc.load(0, csc.cols, &mut cur);
        for c in 0..m.cols {
            assert_eq!(view.row(c), t.row(c), "column {c}");
        }
    }

    #[test]
    fn chunk_buffers_rebase_and_reuse() {
        let m = sample();
        let mut cur = RowCursor::new();
        // copy rows 2..4 into the chunk and compare against the direct view
        cur.begin_chunk();
        for r in 2..4 {
            let (idx, val) = m.row(r);
            cur.push_row(idx, val);
        }
        {
            let view = cur.chunk_view();
            assert_eq!(view.n_rows(), 2);
            assert_eq!(view.row(0), m.row(2));
            assert_eq!(view.row(1), m.row(3));
        }
        // reuse: a second chunk starts clean but keeps the allocations
        let cap = cur.indices.capacity();
        cur.begin_chunk();
        cur.push_row(&[0], &[9.0]);
        let view = cur.chunk_view();
        assert_eq!(view.n_rows(), 1);
        assert_eq!(view.row(0), (&[0u32][..], &[9.0f32][..]));
        assert!(cur.indices.capacity() >= cap.min(1));
    }

    #[test]
    fn empty_ranges_are_legal() {
        let m = sample();
        let mut cur = RowCursor::new();
        let view = m.load(1, 1, &mut cur);
        assert_eq!(view.n_rows(), 0);
    }
}
