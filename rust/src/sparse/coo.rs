//! Coordinate-format sparse matrix: the mutable builder format.
//!
//! Ingestion (the term-document pipeline) appends triplets as documents
//! stream in; [`Coo::to_csr`] sorts, merges duplicates and freezes into
//! compressed storage.

use super::csr::Csr;

#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub entries: Vec<(u32, u32, f32)>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo {
            rows,
            cols,
            entries: Vec::new(),
        }
    }

    /// Append one entry. Duplicates are summed on freeze.
    #[inline]
    pub fn push(&mut self, row: usize, col: usize, val: f32) {
        debug_assert!(row < self.rows && col < self.cols, "({row},{col}) out of bounds");
        if val != 0.0 {
            self.entries.push((row as u32, col as u32, val));
        }
    }

    pub fn nnz_upper_bound(&self) -> usize {
        self.entries.len()
    }

    /// Freeze into CSR: sort by (row, col), merge duplicate coordinates by
    /// summation, drop entries that cancel to exactly zero.
    pub fn to_csr(&self) -> Csr {
        let mut entries = self.entries.clone();
        entries.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);

        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices: Vec<u32> = Vec::with_capacity(entries.len());
        let mut values: Vec<f32> = Vec::with_capacity(entries.len());

        let mut i = 0;
        while i < entries.len() {
            let (r, c, mut v) = entries[i];
            let mut j = i + 1;
            while j < entries.len() && entries[j].0 == r && entries[j].1 == c {
                v += entries[j].2;
                j += 1;
            }
            if v != 0.0 {
                indices.push(c);
                values.push(v);
                indptr[r as usize + 1] += 1;
            }
            i = j;
        }
        for r in 0..self.rows {
            indptr[r + 1] += indptr[r];
        }
        Csr {
            rows: self.rows,
            cols: self.cols,
            indptr,
            indices,
            values,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_freezes() {
        let mut c = Coo::new(3, 4);
        c.push(0, 1, 1.0);
        c.push(2, 3, 2.0);
        c.push(0, 1, 0.5); // duplicate, summed
        c.push(1, 0, 0.0); // dropped
        let m = c.to_csr();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(0, 1), 1.5);
        assert_eq!(m.get(2, 3), 2.0);
        assert_eq!(m.get(1, 0), 0.0);
    }

    #[test]
    fn cancelling_duplicates_are_dropped() {
        let mut c = Coo::new(2, 2);
        c.push(1, 1, 3.0);
        c.push(1, 1, -3.0);
        assert_eq!(c.to_csr().nnz(), 0);
    }

    #[test]
    fn empty_matrix() {
        let m = Coo::new(5, 7).to_csr();
        assert_eq!(m.rows, 5);
        assert_eq!(m.cols, 7);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.indptr.len(), 6);
    }

    #[test]
    fn unsorted_input_sorts() {
        let mut c = Coo::new(3, 3);
        c.push(2, 2, 1.0);
        c.push(0, 0, 2.0);
        c.push(1, 2, 3.0);
        c.push(1, 0, 4.0);
        let m = c.to_csr();
        assert_eq!(m.row(1).0, &[0, 2]);
        assert_eq!(m.row(1).1, &[4.0, 3.0]);
    }
}
