//! Top-t selection — the enforced-sparsity primitive (Algorithm 2, steps
//! 2 and 4).
//!
//! The paper "finds the magnitude of the t-th largest entry and sets all
//! entries with magnitudes lower than that to zero" — i.e. ties at the
//! threshold are *kept* ([`TieMode::KeepTies`]). [`TieMode::Exact`] instead
//! guarantees `nnz ≤ t` by breaking threshold ties by position, which is
//! what a hard memory budget wants. On continuous data the two coincide.
//!
//! Selection uses quickselect (O(nnz) expected) rather than the paper's
//! full sort — see EXPERIMENTS.md §Perf for the measured win; a sort-based
//! reference implementation is kept for property tests.

use super::csr::Csr;
use super::rowblock::RowBlock;
use crate::coordinator::pool;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TieMode {
    /// Paper semantics: keep every entry ≥ the t-th largest value.
    #[default]
    KeepTies,
    /// Keep exactly min(t, nnz) entries; threshold ties kept left-to-right.
    Exact,
}

/// Value of the t-th largest element (1-indexed) of `vals`, via iterative
/// quickselect with a deterministic median-of-three pivot. `t == 0` or an
/// empty slice yields +inf (nothing passes); `t >= len` yields the minimum
/// (everything passes).
pub fn nth_largest(vals: &mut [f32], t: usize) -> f32 {
    if t == 0 || vals.is_empty() {
        return f32::INFINITY;
    }
    if t >= vals.len() {
        return vals.iter().copied().fold(f32::INFINITY, f32::min);
    }
    // select index t-1 in descending order == index len-t ascending
    let target = vals.len() - t;
    let (mut lo, mut hi) = (0usize, vals.len() - 1);
    loop {
        if lo == hi {
            return vals[lo];
        }
        let pivot = median_of_three(vals, lo, hi);
        let (lt, gt) = three_way_partition(vals, lo, hi, pivot);
        if target < lt {
            hi = lt - 1;
        } else if target > gt {
            lo = gt + 1;
        } else {
            return pivot;
        }
    }
}

fn median_of_three(vals: &[f32], lo: usize, hi: usize) -> f32 {
    let mid = lo + (hi - lo) / 2;
    let (a, b, c) = (vals[lo], vals[mid], vals[hi]);
    if (a <= b) == (b <= c) {
        b
    } else if (b <= a) == (a <= c) {
        a
    } else {
        c
    }
}

/// Dutch-flag partition of vals[lo..=hi] around `pivot`; returns the index
/// range [lt, gt] that equals the pivot after partitioning.
fn three_way_partition(vals: &mut [f32], lo: usize, hi: usize, pivot: f32) -> (usize, usize) {
    let (mut lt, mut i, mut gt) = (lo, lo, hi);
    while i <= gt {
        if vals[i] < pivot {
            vals.swap(lt, i);
            lt += 1;
            i += 1;
        } else if vals[i] > pivot {
            vals.swap(i, gt);
            if gt == 0 {
                break;
            }
            gt -= 1;
        } else {
            i += 1;
        }
    }
    (lt, gt)
}

/// Sort-based reference for `nth_largest` (the paper's stated method).
/// Uses `total_cmp`, so NaN input (e.g. a candidate solved against a
/// degenerate Gram inverse) sorts ahead of +∞ instead of panicking the
/// comparator — the same bug class PR 3 fixed in `coordinator/model.rs`.
pub fn nth_largest_by_sort(vals: &[f32], t: usize) -> f32 {
    if t == 0 || vals.is_empty() {
        return f32::INFINITY;
    }
    let mut sorted = vals.to_vec();
    sorted.sort_by(|a, b| b.total_cmp(a));
    sorted[t.min(sorted.len()) - 1]
}

/// Streaming top-t selection over positive values — the pass-1 operator
/// of the blocked global enforcement ([`crate::nmf::als`]). Blocks feed
/// their candidate values in any order; the selector holds only the `t`
/// largest seen (a min-heap, O(t) memory) plus a total count, so finding
/// the global cutoff never materializes the full candidate matrix.
///
/// Determinism: [`Self::cutoff`] returns the t-th largest *value* of the
/// offered multiset — an order statistic, independent of arrival order —
/// so it equals `nth_largest` over the serially-gathered positives no
/// matter how blocks or workers interleave.
#[derive(Clone, Debug, Default)]
pub struct TopTSelector {
    t: usize,
    /// min-heap of the `t` largest positives seen (`heap[0]` is smallest)
    heap: Vec<f32>,
    /// total positives offered, absorbed selectors included
    positives: usize,
}

impl TopTSelector {
    pub fn new(t: usize) -> Self {
        TopTSelector {
            t,
            heap: Vec::new(),
            positives: 0,
        }
    }

    /// Feed one candidate value. Zeros, negatives and NaN are never
    /// enforcement candidates (matching the `v > 0.0` gather of
    /// [`enforce_top_t_rowblock`]) and are ignored.
    #[inline]
    pub fn offer(&mut self, v: f32) {
        if v <= 0.0 || v.is_nan() {
            return;
        }
        self.positives += 1;
        self.insert(v);
    }

    /// Bulk [`Self::offer`] over a candidate slice — the select pass of
    /// every blocked half-step feeds whole scratch rows through here.
    /// One tight scan with the heap-full rejection test (`v ≤ heap[0]`,
    /// the overwhelmingly common case once the heap warms up) inlined
    /// ahead of the insert machinery. Feeding values one at a time
    /// through [`Self::offer`] produces the identical selector state:
    /// the cutoff is an order statistic of the offered multiset either
    /// way.
    pub fn offer_all(&mut self, vals: &[f32]) {
        if self.t == 0 {
            // nothing is ever retained; only the positive count matters
            self.positives += vals.iter().filter(|&&v| v > 0.0).count();
            return;
        }
        for &v in vals {
            if v <= 0.0 || v.is_nan() {
                continue;
            }
            self.positives += 1;
            if self.heap.len() < self.t {
                self.heap.push(v);
                self.sift_up(self.heap.len() - 1);
            } else if v > self.heap[0] {
                self.heap[0] = v;
                self.sift_down();
            }
        }
    }

    /// Merge a per-block selector built with the same `t`.
    pub fn absorb(&mut self, other: TopTSelector) {
        debug_assert_eq!(self.t, other.t, "selectors must share a budget");
        self.positives += other.positives;
        for v in other.heap {
            self.insert(v);
        }
    }

    fn insert(&mut self, v: f32) {
        if self.t == 0 {
            return;
        }
        if self.heap.len() < self.t {
            self.heap.push(v);
            self.sift_up(self.heap.len() - 1);
        } else if v > self.heap[0] {
            self.heap[0] = v;
            self.sift_down();
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i] < self.heap[parent] {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self) {
        let n = self.heap.len();
        let mut i = 0;
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < n && self.heap[l] < self.heap[smallest] {
                smallest = l;
            }
            if r < n && self.heap[r] < self.heap[smallest] {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }

    /// The enforcement cutoff `(tau, strictly_above_count)`, or `None`
    /// when every positive already fits the budget (the
    /// `positives.len() <= t` fast path of the in-memory operators).
    /// `strictly_above_count ≤ t - 1`, so `t - above` — the `Exact`-mode
    /// tie budget — never underflows.
    pub fn cutoff(&self) -> Option<(f32, usize)> {
        if self.positives <= self.t {
            return None;
        }
        if self.t == 0 {
            // nth_largest(_, 0) semantics: nothing passes the cutoff
            return Some((f32::INFINITY, 0));
        }
        let tau = self.heap[0];
        // every value strictly above the t-th largest is one of the t
        // largest, i.e. in the heap — counting there is exact
        Some((tau, self.heap.iter().filter(|&&v| v > tau).count()))
    }

    /// Export this selector's state for the worker wire: the positive
    /// count and the retained heap values. Because [`Self::cutoff`] is an
    /// order statistic, a coordinator that absorbs these summaries from
    /// every worker computes the same cutoff as one selector fed all
    /// candidates directly — the heap of a subset's top-t contains every
    /// member of the global top-t that the subset holds.
    pub(crate) fn into_wire_parts(self) -> (usize, Vec<f32>) {
        (self.positives, self.heap)
    }

    /// Rebuild a worker's exported selector state for absorption. The
    /// caller supplies its own `t`; `heap` values re-enter through the
    /// ordinary insert path so invariants hold even for a hostile peer.
    pub(crate) fn from_wire_parts(t: usize, positives: usize, heap: &[f32]) -> Self {
        let mut s = TopTSelector::new(t);
        for &v in heap {
            if v > 0.0 && !v.is_nan() {
                s.insert(v);
            }
        }
        s.positives = positives;
        s
    }
}

/// Keep only the `t` largest stored values of a CSR matrix (all values are
/// assumed positive — factors are projected before enforcement).
pub fn enforce_top_t_csr(m: &mut Csr, t: usize, mode: TieMode) {
    if m.nnz() <= t {
        return;
    }
    let mut scratch = m.values.clone();
    let tau = nth_largest(&mut scratch, t);
    match mode {
        TieMode::KeepTies => m.retain(|_, _, v| v >= tau),
        TieMode::Exact => {
            let above = m.values.iter().filter(|&&v| v > tau).count();
            let mut tie_budget = t - above;
            m.retain(|_, _, v| {
                if v > tau {
                    true
                } else if v == tau && tie_budget > 0 {
                    tie_budget -= 1;
                    true
                } else {
                    false
                }
            });
        }
    }
}

/// Keep only the `t` largest *positive* entries of a RowBlock in place
/// (zeroing the rest). This is the hot-path form used inside ALS, before
/// the intermediate is frozen to CSR.
pub fn enforce_top_t_rowblock(rb: &mut RowBlock, t: usize, mode: TieMode) {
    enforce_top_t_rowblock_par(rb, t, mode, 1);
}

/// Parallel [`enforce_top_t_rowblock`], bit-identical to serial at any
/// thread count:
///
/// * the positive entries are gathered per contiguous range and
///   concatenated in range order, reproducing the serial left-to-right
///   gather for any partition, so quickselect sees the same sequence and
///   returns the same threshold `tau`;
/// * the `KeepTies` zeroing pass is elementwise;
/// * the `Exact` tie budget is split by prefix-counting `== tau` entries
///   per range, reproducing the serial left-to-right budget scan.
pub fn enforce_top_t_rowblock_par(rb: &mut RowBlock, t: usize, mode: TieMode, threads: usize) {
    let ranges = pool::split_ranges(rb.data.len(), threads);
    let data = &rb.data;
    let mut gathered = pool::scoped_map_ranges(threads, &ranges, |lo, hi| {
        data[lo..hi]
            .iter()
            .copied()
            .filter(|&v| v > 0.0)
            .collect::<Vec<f32>>()
    });
    let mut positives: Vec<f32> = if gathered.len() == 1 {
        gathered.pop().unwrap()
    } else {
        let mut all = Vec::with_capacity(gathered.iter().map(Vec::len).sum());
        for part in gathered {
            all.extend_from_slice(&part);
        }
        all
    };
    if positives.len() <= t {
        return;
    }
    let tau = nth_largest(&mut positives, t);
    match mode {
        TieMode::KeepTies => {
            pool::scoped_partition_map_mut(threads, &mut rb.data, 1, |_, piece| {
                for v in piece {
                    if *v < tau {
                        *v = 0.0;
                    }
                }
            });
        }
        TieMode::Exact => {
            // per-range (above, ties) counts on the same boundaries as the
            // mutate pass below (both come from split_ranges)
            let data = &rb.data;
            let counts = pool::scoped_map_ranges(threads, &ranges, |lo, hi| {
                let mut above = 0usize;
                let mut ties = 0usize;
                for &v in &data[lo..hi] {
                    if v > tau {
                        above += 1;
                    } else if v == tau {
                        ties += 1;
                    }
                }
                (above, ties)
            });
            let total_above: usize = counts.iter().map(|c| c.0).sum();
            // tau is the t-th largest positive, so at most t-1 entries
            // exceed it and the subtraction cannot underflow
            let mut remaining = t - total_above;
            let budgets: Vec<usize> = counts
                .iter()
                .map(|&(_, ties)| {
                    let take = remaining.min(ties);
                    remaining -= take;
                    take
                })
                .collect();
            pool::scoped_partition_map_mut(threads, &mut rb.data, 1, |offset, piece| {
                let part = ranges
                    .binary_search_by_key(&offset, |&(lo, _)| lo)
                    .expect("partition boundaries must match split_ranges");
                let mut tie_budget = budgets[part];
                for v in piece {
                    if *v > tau {
                        continue;
                    }
                    if *v == tau && tie_budget > 0 {
                        tie_budget -= 1;
                    } else {
                        *v = 0.0;
                    }
                }
            });
        }
    }
}

/// Keep only the `t` largest *positive* entries of a single dense
/// column/vector in place, zeroing the rest — the single-column form of
/// the paper's enforcement operator. This is the inference-time entry
/// point: fold-in ([`crate::nmf::foldin`]) applies it to the one projected
/// row it produces per unseen document, with the same tie semantics as
/// the training-time operators above.
pub fn enforce_top_t_vec(vals: &mut [f32], t: usize, mode: TieMode) {
    enforce_top_t_vec_with(vals, t, mode, &mut Vec::new());
}

/// [`enforce_top_t_vec`] with a caller-owned gather buffer, so a serving
/// hot path (fold-in answers one of these per request) can pool its
/// scratch instead of allocating per call. Identical results — the
/// buffer is cleared and refilled exactly as the fresh allocation was.
pub fn enforce_top_t_vec_with(
    vals: &mut [f32],
    t: usize,
    mode: TieMode,
    positives: &mut Vec<f32>,
) {
    positives.clear();
    positives.extend(vals.iter().copied().filter(|&v| v > 0.0));
    if positives.len() <= t {
        return;
    }
    let tau = nth_largest(positives, t);
    match mode {
        TieMode::KeepTies => {
            for v in vals.iter_mut() {
                if *v < tau {
                    *v = 0.0;
                }
            }
        }
        TieMode::Exact => {
            let above = vals.iter().filter(|&&v| v > tau).count();
            // tau is the t-th largest positive, so above ≤ t-1
            let mut tie_budget = t - above;
            for v in vals.iter_mut() {
                if *v > tau {
                    continue;
                }
                if *v == tau && tie_budget > 0 {
                    tie_budget -= 1;
                } else {
                    *v = 0.0;
                }
            }
        }
    }
}

/// Per-column enforcement (§4 of the paper): keep the `t_per_col` largest
/// entries of each column independently. Deliberately goes through a
/// column gather — the same access-pattern penalty the paper reports for
/// column-wise enforcement on compressed row/column formats.
pub fn enforce_top_t_per_column(m: &mut Csr, t_per_col: usize, mode: TieMode) {
    enforce_top_t_per_column_par(m, t_per_col, mode, 1);
}

/// Parallel [`enforce_top_t_per_column`], bit-identical to serial at any
/// thread count: the column gather is row-range partitioned and merged in
/// range order (same per-column value sequence as the serial scan), the
/// per-column thresholds are computed on independent column partitions,
/// and the retain pass is row-range partitioned too — `KeepTies` filters
/// with a row-local predicate ([`Csr::retain_par`]); `Exact` first
/// prefix-counts each range's `== tau` ties per column and splits every
/// column's budget across ranges in row order, reproducing the serial
/// left-to-right budget scan, then filters ranges independently and
/// concatenates the fragments in order.
pub fn enforce_top_t_per_column_par(
    m: &mut Csr,
    t_per_col: usize,
    mode: TieMode,
    threads: usize,
) {
    let k = m.cols;
    if k == 0 {
        return;
    }
    // gather each column's values (column access in CSR = full scan),
    // one partial gather per row range, appended in range order
    let row_ranges = pool::split_ranges(m.rows, threads);
    let shared: &Csr = m;
    let gathered = pool::scoped_map_ranges(threads, &row_ranges, |lo, hi| {
        let mut cols: Vec<Vec<f32>> = vec![Vec::new(); k];
        for r in lo..hi {
            let (idx, val) = shared.row(r);
            for (&c, &v) in idx.iter().zip(val) {
                cols[c as usize].push(v);
            }
        }
        cols
    });
    let mut col_vals: Vec<Vec<f32>> = vec![Vec::new(); k];
    for mut part in gathered {
        for (c, vals) in part.iter_mut().enumerate() {
            col_vals[c].append(vals);
        }
    }
    // per-column thresholds: columns are independent, so a contiguous
    // column partition needs no merge discipline beyond ordering
    let thresholds: Vec<(f32, usize)> =
        pool::scoped_partition_map_mut(threads, &mut col_vals, 1, |_, piece| {
            piece
                .iter_mut()
                .map(|vals| {
                    if vals.len() > t_per_col {
                        let tau = nth_largest(vals, t_per_col);
                        let budget = if mode == TieMode::Exact {
                            t_per_col - vals.iter().filter(|&&v| v > tau).count()
                        } else {
                            usize::MAX
                        };
                        (tau, budget)
                    } else {
                        (f32::NEG_INFINITY, usize::MAX)
                    }
                })
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect();
    let taus: Vec<f32> = thresholds.iter().map(|t| t.0).collect();
    let tie_budgets: Vec<usize> = thresholds.iter().map(|t| t.1).collect();
    match mode {
        TieMode::KeepTies => m.retain_par(threads, |_, c, v| v >= taus[c as usize]),
        TieMode::Exact => retain_exact_par(m, &taus, tie_budgets, threads),
    }
}

/// The `Exact`-mode compaction of per-column enforcement, row-range
/// parallel: the per-column tie budgets are scan-order state, so each
/// range's share is prefix-counted first (ranges earlier in row order
/// consume ties first, exactly like the serial left-to-right scan), then
/// ranges filter independently and the fragments concatenate in order —
/// bit-identical to the serial retain at any thread count.
fn retain_exact_par(m: &mut Csr, taus: &[f32], mut budgets: Vec<usize>, threads: usize) {
    if threads <= 1 || m.rows < 2 {
        // the serial reference scan
        return m.retain(|_, c, v| {
            let c = c as usize;
            if v > taus[c] {
                true
            } else if v == taus[c] && budgets[c] > 0 {
                budgets[c] -= 1;
                true
            } else {
                false
            }
        });
    }
    let ranges = pool::split_ranges(m.rows, threads);
    let shared: &Csr = m;
    // pass 1: per-range, per-column `== tau` counts
    let tie_counts = pool::scoped_map_ranges(threads, &ranges, |lo, hi| {
        let mut ties = vec![0usize; taus.len()];
        for r in lo..hi {
            let (idx, val) = shared.row(r);
            for (&c, &v) in idx.iter().zip(val) {
                if v == taus[c as usize] {
                    ties[c as usize] += 1;
                }
            }
        }
        ties
    });
    // split every column's budget across ranges in row order
    let range_budgets: Vec<Vec<usize>> = tie_counts
        .iter()
        .map(|ties| {
            ties.iter()
                .enumerate()
                .map(|(c, &t)| {
                    let take = budgets[c].min(t);
                    budgets[c] -= take;
                    take
                })
                .collect()
        })
        .collect();
    // pass 2: filter each range with its own budgets
    let frags = pool::scoped_map_ranges(threads, &ranges, |lo, hi| {
        let part = ranges
            .binary_search_by_key(&lo, |&(l, _)| l)
            .expect("range boundaries must match split_ranges");
        let mut local = range_budgets[part].clone();
        let mut indices = Vec::new();
        let mut values = Vec::new();
        let mut row_ends = Vec::with_capacity(hi - lo);
        for r in lo..hi {
            let (idx, val) = shared.row(r);
            for (&c, &v) in idx.iter().zip(val) {
                let col = c as usize;
                let keep = if v > taus[col] {
                    true
                } else if v == taus[col] && local[col] > 0 {
                    local[col] -= 1;
                    true
                } else {
                    false
                };
                if keep {
                    indices.push(c);
                    values.push(v);
                }
            }
            row_ends.push(indices.len());
        }
        (indices, values, row_ends)
    });
    m.replace_from_fragments(frags);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn nth_largest_agrees_with_sort() {
        prop::check("quickselect-vs-sort", 600, 96, |rng: &mut Rng| {
            let n = rng.range(1, 200);
            let mut vals: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.f64() < 0.2 {
                        // force ties
                        (rng.below(5) as f32) * 0.5
                    } else {
                        rng.f32() * 10.0
                    }
                })
                .collect();
            let t = rng.range(1, n + 2);
            let want = nth_largest_by_sort(&vals, t);
            let got = nth_largest(&mut vals, t);
            assert_eq!(got, want, "t={t} n={n}");
        });
    }

    #[test]
    fn nth_largest_by_sort_survives_nan_input() {
        // regression: b.partial_cmp(a).unwrap() panicked on NaN (the same
        // bug class PR 3 fixed in the serving-layer ranking sorts). NaN
        // sorts ahead of +∞ under total_cmp, so finite t still lands on a
        // finite order statistic.
        let vals = [1.0f32, f32::NAN, 3.0, 2.0];
        assert_eq!(nth_largest_by_sort(&vals, 2), 3.0);
        assert_eq!(nth_largest_by_sort(&vals, 4), 1.0);
        assert!(nth_largest_by_sort(&[f32::NAN], 1).is_nan());
        // all-NaN never panics either
        assert!(nth_largest_by_sort(&[f32::NAN, f32::NAN], 2).is_nan());
    }

    #[test]
    fn selector_cutoff_matches_quickselect() {
        prop::check("selector-vs-quickselect", 1800, 64, |rng: &mut Rng| {
            let n = rng.range(1, 150);
            let vals: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.f64() < 0.25 {
                        (rng.below(5) as f32) * 0.5 // ties and zeros
                    } else if rng.f64() < 0.1 {
                        -rng.f32() // negatives are ignored
                    } else {
                        rng.f32() * 10.0
                    }
                })
                .collect();
            let t = rng.range(0, n + 2);
            // reference: the serial gather + quickselect of the in-memory
            // enforcement operators
            let mut positives: Vec<f32> = vals.iter().copied().filter(|&v| v > 0.0).collect();
            let want = if positives.len() <= t {
                None
            } else {
                let tau = nth_largest(&mut positives, t);
                let above = positives.iter().filter(|&&v| v > tau).count();
                Some((tau, above))
            };
            // streamed in one selector…
            let mut all = TopTSelector::new(t);
            for &v in &vals {
                all.offer(v);
            }
            assert_eq!(all.cutoff(), want, "t={t} n={n}");
            // …and split across per-block selectors absorbed in order
            let split = rng.range(0, n + 1);
            let mut left = TopTSelector::new(t);
            let mut right = TopTSelector::new(t);
            for &v in &vals[..split] {
                left.offer(v);
            }
            for &v in &vals[split..] {
                right.offer(v);
            }
            left.absorb(right);
            assert_eq!(left.cutoff(), want, "t={t} split={split}");
        });
    }

    #[test]
    fn offer_all_matches_per_element_offers() {
        prop::check("offer-all-vs-offer", 1900, 64, |rng: &mut Rng| {
            let n = rng.range(0, 120);
            let vals: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.f64() < 0.2 {
                        0.0
                    } else if rng.f64() < 0.1 {
                        -rng.f32()
                    } else if rng.f64() < 0.05 {
                        f32::NAN
                    } else {
                        rng.f32() * 10.0
                    }
                })
                .collect();
            let t = rng.range(0, n + 2);
            let mut one_by_one = TopTSelector::new(t);
            for &v in &vals {
                one_by_one.offer(v);
            }
            // fed in two slices to exercise a warm heap mid-stream
            let split = rng.range(0, n + 1);
            let mut bulk = TopTSelector::new(t);
            bulk.offer_all(&vals[..split]);
            bulk.offer_all(&vals[split..]);
            assert_eq!(bulk.cutoff(), one_by_one.cutoff(), "t={t} n={n}");
        });
    }

    #[test]
    fn selector_edges() {
        // no positives at all → never enforces
        let mut s = TopTSelector::new(3);
        s.offer(0.0);
        s.offer(-1.0);
        s.offer(f32::NAN);
        assert_eq!(s.cutoff(), None);
        // t = 0 with positives present → infinite cutoff, zero above
        let mut s = TopTSelector::new(0);
        s.offer(1.0);
        assert_eq!(s.cutoff(), Some((f32::INFINITY, 0)));
        // exactly at budget → no enforcement
        let mut s = TopTSelector::new(2);
        s.offer(1.0);
        s.offer(2.0);
        assert_eq!(s.cutoff(), None);
        // over budget: tau = 2nd largest of {1,2,3} = 2.0, one strictly above
        s.offer(3.0);
        assert_eq!(s.cutoff(), Some((2.0, 1)));
        // all-tied input: tau is the tie, nothing strictly above
        let mut s = TopTSelector::new(2);
        for _ in 0..5 {
            s.offer(4.0);
        }
        assert_eq!(s.cutoff(), Some((4.0, 0)));
    }

    #[test]
    fn nth_largest_edges() {
        assert_eq!(nth_largest(&mut [], 3), f32::INFINITY);
        assert_eq!(nth_largest(&mut [1.0, 2.0], 0), f32::INFINITY);
        assert_eq!(nth_largest(&mut [1.0, 2.0], 5), 1.0);
        assert_eq!(nth_largest(&mut [7.0], 1), 7.0);
    }

    fn positive_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
        let data = prop::gen_sparse_dense(rng, rows, cols, density);
        Csr::from_dense(rows, cols, &data)
    }

    #[test]
    fn enforce_exact_keeps_exactly_t() {
        prop::check("exact-top-t", 700, 64, |rng: &mut Rng| {
            let (rows, cols) = (rng.range(1, 15), rng.range(1, 8));
            let mut m = positive_csr(rng, rows, cols, 0.6);
            let nnz0 = m.nnz();
            let t = rng.range(0, nnz0 + 3);
            let kept_expected = t.min(nnz0);
            let mut m2 = m.clone();
            enforce_top_t_csr(&mut m2, t, TieMode::Exact);
            assert_eq!(m2.nnz(), kept_expected);
            m2.validate().unwrap();
            // kept set dominates dropped set
            if m2.nnz() > 0 && m2.nnz() < nnz0 {
                let min_kept = m2.values.iter().copied().fold(f32::INFINITY, f32::min);
                enforce_top_t_csr(&mut m, t, TieMode::KeepTies);
                let dropped_max_bound = min_kept;
                assert!(m.values.iter().all(|&v| v >= dropped_max_bound * 0.999));
            }
        });
    }

    #[test]
    fn keep_ties_keeps_all_ties() {
        let mut m = Csr::from_dense(1, 5, &[3.0, 1.0, 3.0, 2.0, 3.0]);
        enforce_top_t_csr(&mut m, 2, TieMode::KeepTies);
        assert_eq!(m.nnz(), 3); // all three 3.0s survive
        let mut m = Csr::from_dense(1, 5, &[3.0, 1.0, 3.0, 2.0, 3.0]);
        enforce_top_t_csr(&mut m, 2, TieMode::Exact);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn enforce_noop_when_under_budget() {
        let mut m = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 2.0]);
        let before = m.clone();
        enforce_top_t_csr(&mut m, 10, TieMode::Exact);
        assert_eq!(m, before);
    }

    #[test]
    fn rowblock_enforcement_matches_csr() {
        prop::check("rowblock-vs-csr-top-t", 800, 48, |rng: &mut Rng| {
            let rows = rng.range(1, 12);
            let k = rng.range(1, 6);
            let data = prop::gen_sparse_dense(rng, rows, k, 0.7);
            let csr = Csr::from_dense(rows, k, &data);
            let mut rb = RowBlock::from_csr(&csr);
            let t = rng.range(0, csr.nnz() + 2);
            let mut csr2 = csr.clone();
            enforce_top_t_csr(&mut csr2, t, TieMode::KeepTies);
            enforce_top_t_rowblock(&mut rb, t, TieMode::KeepTies);
            assert_eq!(rb.to_csr(), csr2);
        });
    }

    #[test]
    fn per_column_enforcement_bounds_each_column() {
        prop::check("per-column-top-t", 900, 48, |rng: &mut Rng| {
            let (rows, cols) = (rng.range(1, 20), rng.range(1, 6));
            let mut m = positive_csr(rng, rows, cols, 0.7);
            let t = rng.range(1, 6);
            enforce_top_t_per_column(&mut m, t, TieMode::Exact);
            m.validate().unwrap();
            for (c, &count) in m.col_nnz().iter().enumerate() {
                assert!(count <= t, "column {c} has {count} > {t}");
            }
        });
    }

    #[test]
    fn ties_straddling_partition_boundaries() {
        // 12 entries, many duplicated magnitudes; at 4 threads the ranges
        // are 3 entries wide, so the 2.0-ties straddle every boundary
        let data = [2.0f32, 1.0, 2.0, 2.0, 5.0, 2.0, 2.0, 3.0, 2.0, 2.0, 1.0, 2.0];
        for t in [0usize, 1, 3, 5, 8, 11, 12, 20] {
            for mode in [TieMode::KeepTies, TieMode::Exact] {
                let mut serial = RowBlock::new(4, 3);
                for (r, row) in data.chunks(3).enumerate() {
                    serial.push_row(r, row);
                }
                let mut par = serial.clone();
                enforce_top_t_rowblock(&mut serial, t, mode);
                for threads in [2usize, 4, 7] {
                    let mut rb = par.clone();
                    enforce_top_t_rowblock_par(&mut rb, t, mode, threads);
                    assert_eq!(rb, serial, "t={t} mode={mode:?} threads={threads}");
                }
                if mode == TieMode::Exact {
                    let kept = serial.data.iter().filter(|&&v| v > 0.0).count();
                    assert_eq!(kept, t.min(data.len()), "t={t}");
                }
            }
        }
    }

    #[test]
    fn t_at_least_nnz_is_identity() {
        let mut rb = RowBlock::new(2, 3);
        rb.push_row(0, &[1.0, 2.0, 3.0]);
        rb.push_row(1, &[4.0, 0.0, 5.0]);
        for t in [5usize, 6, 100] {
            for mode in [TieMode::KeepTies, TieMode::Exact] {
                for threads in [1usize, 2, 4, 7] {
                    let mut m = rb.clone();
                    enforce_top_t_rowblock_par(&mut m, t, mode, threads);
                    assert_eq!(m, rb, "t={t} mode={mode:?} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn t_zero_clears_everything() {
        for mode in [TieMode::KeepTies, TieMode::Exact] {
            for threads in [1usize, 2, 4, 7] {
                let mut rb = RowBlock::new(2, 2);
                rb.push_row(0, &[1.0, 3.0]);
                rb.push_row(1, &[2.0, 4.0]);
                enforce_top_t_rowblock_par(&mut rb, 0, mode, threads);
                assert!(rb.data.iter().all(|&v| v == 0.0), "mode={mode:?}");
                let mut m = Csr::from_dense(2, 2, &[1.0, 3.0, 2.0, 4.0]);
                enforce_top_t_csr(&mut m, 0, mode);
                assert_eq!(m.nnz(), 0, "mode={mode:?}");
            }
        }
    }

    #[test]
    fn all_zero_columns_survive_per_column_enforcement() {
        // columns 1 and 3 hold no entries at all
        let mut m = Csr::from_dense(3, 4, &[
            5.0, 0.0, 1.0, 0.0, //
            4.0, 0.0, 2.0, 0.0, //
            3.0, 0.0, 6.0, 0.0,
        ]);
        let want_cols = vec![2usize, 0, 2, 0];
        for threads in [1usize, 2, 4, 7] {
            let mut got = m.clone();
            enforce_top_t_per_column_par(&mut got, 2, TieMode::Exact, threads);
            got.validate().unwrap();
            assert_eq!(got.col_nnz(), want_cols, "threads={threads}");
        }
        // degenerate shapes: no columns / no rows are no-ops, not panics
        let mut empty_cols = Csr::zeros(3, 0);
        enforce_top_t_per_column_par(&mut empty_cols, 1, TieMode::Exact, 4);
        assert_eq!(empty_cols.nnz(), 0);
        let mut empty_rows = Csr::zeros(0, 3);
        enforce_top_t_per_column_par(&mut empty_rows, 1, TieMode::KeepTies, 4);
        assert_eq!(empty_rows.nnz(), 0);
        enforce_top_t_per_column(&mut m, 0, TieMode::Exact);
        assert_eq!(m.nnz(), 0, "t_per_col = 0 clears every column");
    }

    #[test]
    fn per_column_ties_straddling_row_ranges_split_budgets_exactly() {
        // a tall matrix whose columns are almost entirely tied values:
        // at any thread count the per-range Exact budgets must reproduce
        // the serial left-to-right scan — including ranges that hold
        // more ties than their share of the budget
        let rows = 23;
        let cols = 3;
        let mut dense = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                dense[r * cols + c] = match (r + c) % 4 {
                    0 | 1 => 2.0,              // the tie value
                    2 => 5.0,                  // strictly above
                    _ => 1.0,                  // below, dropped
                };
            }
        }
        let m = Csr::from_dense(rows, cols, &dense);
        for t in [1usize, 3, 7, 12, 40] {
            let mut serial = m.clone();
            enforce_top_t_per_column(&mut serial, t, TieMode::Exact);
            for threads in [1usize, 4, 7] {
                let mut par = m.clone();
                enforce_top_t_per_column_par(&mut par, t, TieMode::Exact, threads);
                assert_eq!(par, serial, "t={t} threads={threads}");
                par.validate().unwrap();
                for (c, &count) in par.col_nnz().iter().enumerate() {
                    assert!(count <= t, "t={t} threads={threads} col {c}: {count}");
                }
            }
        }
    }

    #[test]
    fn per_column_parallel_matches_serial() {
        prop::check("per-column-par-vs-serial", 1000, 48, |rng: &mut Rng| {
            let (rows, cols) = (rng.range(1, 25), rng.range(1, 7));
            let m = positive_csr(rng, rows, cols, 0.6);
            let t = rng.range(0, 7);
            let mode = if rng.below(2) == 0 {
                TieMode::KeepTies
            } else {
                TieMode::Exact
            };
            let mut serial = m.clone();
            enforce_top_t_per_column(&mut serial, t, mode);
            for threads in [2usize, 4, 7] {
                let mut par = m.clone();
                enforce_top_t_per_column_par(&mut par, t, mode, threads);
                assert_eq!(par, serial, "t={t} mode={mode:?} threads={threads}");
            }
        });
    }

    #[test]
    fn vec_enforcement_matches_single_column_csr() {
        // the single-column entry point is the same operator as per-column
        // enforcement on a 1-column matrix — pin that, ties included
        prop::check("vec-vs-per-column", 1100, 64, |rng: &mut Rng| {
            let n = rng.range(1, 30);
            let dense: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.f64() < 0.3 {
                        0.0
                    } else if rng.f64() < 0.3 {
                        (rng.below(4) as f32 + 1.0) * 0.5 // force ties
                    } else {
                        rng.abs_normal_f32() + 1e-4
                    }
                })
                .collect();
            let t = rng.range(0, n + 2);
            let mode = if rng.below(2) == 0 {
                TieMode::KeepTies
            } else {
                TieMode::Exact
            };
            let mut vec_form = dense.clone();
            enforce_top_t_vec(&mut vec_form, t, mode);
            let mut csr_form = Csr::from_dense(n, 1, &dense);
            enforce_top_t_per_column(&mut csr_form, t, mode);
            assert_eq!(
                Csr::from_dense(n, 1, &vec_form),
                csr_form,
                "n={n} t={t} mode={mode:?}"
            );
        });
    }

    #[test]
    fn vec_enforcement_edges() {
        // t = 0 clears, t ≥ positives is the identity, Exact caps exactly
        let mut v = vec![1.0f32, 0.0, 3.0, 2.0];
        enforce_top_t_vec(&mut v, 0, TieMode::Exact);
        assert!(v.iter().all(|&x| x == 0.0));
        let mut v = vec![1.0f32, 0.0, 3.0];
        let before = v.clone();
        enforce_top_t_vec(&mut v, 2, TieMode::KeepTies);
        assert_eq!(v, before);
        let mut v = vec![2.0f32, 2.0, 2.0, 1.0];
        enforce_top_t_vec(&mut v, 2, TieMode::Exact);
        assert_eq!(v.iter().filter(|&&x| x > 0.0).count(), 2);
        let mut v = vec![2.0f32, 2.0, 2.0, 1.0];
        enforce_top_t_vec(&mut v, 2, TieMode::KeepTies);
        assert_eq!(v.iter().filter(|&&x| x > 0.0).count(), 3); // ties kept
    }

    #[test]
    fn per_column_keeps_largest_per_column() {
        let mut m = Csr::from_dense(4, 2, &[
            5.0, 1.0, //
            4.0, 2.0, //
            3.0, 8.0, //
            2.0, 9.0,
        ]);
        enforce_top_t_per_column(&mut m, 2, TieMode::Exact);
        assert_eq!(m.get(0, 0), 5.0);
        assert_eq!(m.get(1, 0), 4.0);
        assert_eq!(m.get(2, 1), 8.0);
        assert_eq!(m.get(3, 1), 9.0);
        assert_eq!(m.nnz(), 4);
    }
}
