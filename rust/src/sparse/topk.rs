//! Top-t selection — the enforced-sparsity primitive (Algorithm 2, steps
//! 2 and 4).
//!
//! The paper "finds the magnitude of the t-th largest entry and sets all
//! entries with magnitudes lower than that to zero" — i.e. ties at the
//! threshold are *kept* ([`TieMode::KeepTies`]). [`TieMode::Exact`] instead
//! guarantees `nnz ≤ t` by breaking threshold ties by position, which is
//! what a hard memory budget wants. On continuous data the two coincide.
//!
//! Selection uses quickselect (O(nnz) expected) rather than the paper's
//! full sort — see EXPERIMENTS.md §Perf for the measured win; a sort-based
//! reference implementation is kept for property tests.

use super::csr::Csr;
use super::rowblock::RowBlock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TieMode {
    /// Paper semantics: keep every entry ≥ the t-th largest value.
    #[default]
    KeepTies,
    /// Keep exactly min(t, nnz) entries; threshold ties kept left-to-right.
    Exact,
}

/// Value of the t-th largest element (1-indexed) of `vals`, via iterative
/// quickselect with a deterministic median-of-three pivot. `t == 0` or an
/// empty slice yields +inf (nothing passes); `t >= len` yields the minimum
/// (everything passes).
pub fn nth_largest(vals: &mut [f32], t: usize) -> f32 {
    if t == 0 || vals.is_empty() {
        return f32::INFINITY;
    }
    if t >= vals.len() {
        return vals.iter().copied().fold(f32::INFINITY, f32::min);
    }
    // select index t-1 in descending order == index len-t ascending
    let target = vals.len() - t;
    let (mut lo, mut hi) = (0usize, vals.len() - 1);
    loop {
        if lo == hi {
            return vals[lo];
        }
        let pivot = median_of_three(vals, lo, hi);
        let (lt, gt) = three_way_partition(vals, lo, hi, pivot);
        if target < lt {
            hi = lt - 1;
        } else if target > gt {
            lo = gt + 1;
        } else {
            return pivot;
        }
    }
}

fn median_of_three(vals: &[f32], lo: usize, hi: usize) -> f32 {
    let mid = lo + (hi - lo) / 2;
    let (a, b, c) = (vals[lo], vals[mid], vals[hi]);
    if (a <= b) == (b <= c) {
        b
    } else if (b <= a) == (a <= c) {
        a
    } else {
        c
    }
}

/// Dutch-flag partition of vals[lo..=hi] around `pivot`; returns the index
/// range [lt, gt] that equals the pivot after partitioning.
fn three_way_partition(vals: &mut [f32], lo: usize, hi: usize, pivot: f32) -> (usize, usize) {
    let (mut lt, mut i, mut gt) = (lo, lo, hi);
    while i <= gt {
        if vals[i] < pivot {
            vals.swap(lt, i);
            lt += 1;
            i += 1;
        } else if vals[i] > pivot {
            vals.swap(i, gt);
            if gt == 0 {
                break;
            }
            gt -= 1;
        } else {
            i += 1;
        }
    }
    (lt, gt)
}

/// Sort-based reference for `nth_largest` (the paper's stated method).
pub fn nth_largest_by_sort(vals: &[f32], t: usize) -> f32 {
    if t == 0 || vals.is_empty() {
        return f32::INFINITY;
    }
    let mut sorted = vals.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sorted[t.min(sorted.len()) - 1]
}

/// Keep only the `t` largest stored values of a CSR matrix (all values are
/// assumed positive — factors are projected before enforcement).
pub fn enforce_top_t_csr(m: &mut Csr, t: usize, mode: TieMode) {
    if m.nnz() <= t {
        return;
    }
    let mut scratch = m.values.clone();
    let tau = nth_largest(&mut scratch, t);
    match mode {
        TieMode::KeepTies => m.retain(|_, _, v| v >= tau),
        TieMode::Exact => {
            let above = m.values.iter().filter(|&&v| v > tau).count();
            let mut tie_budget = t - above;
            m.retain(|_, _, v| {
                if v > tau {
                    true
                } else if v == tau && tie_budget > 0 {
                    tie_budget -= 1;
                    true
                } else {
                    false
                }
            });
        }
    }
}

/// Keep only the `t` largest *positive* entries of a RowBlock in place
/// (zeroing the rest). This is the hot-path form used inside ALS, before
/// the intermediate is frozen to CSR.
pub fn enforce_top_t_rowblock(rb: &mut RowBlock, t: usize, mode: TieMode) {
    let mut positives: Vec<f32> = rb.data.iter().copied().filter(|&v| v > 0.0).collect();
    if positives.len() <= t {
        return;
    }
    let tau = nth_largest(&mut positives, t);
    match mode {
        TieMode::KeepTies => {
            for v in &mut rb.data {
                if *v < tau {
                    *v = 0.0;
                }
            }
        }
        TieMode::Exact => {
            let above = rb.data.iter().filter(|&&v| v > tau).count();
            let mut tie_budget = t - above;
            for v in &mut rb.data {
                if *v > tau {
                    continue;
                }
                if *v == tau && tie_budget > 0 {
                    tie_budget -= 1;
                } else {
                    *v = 0.0;
                }
            }
        }
    }
}

/// Per-column enforcement (§4 of the paper): keep the `t_per_col` largest
/// entries of each column independently. Deliberately goes through a
/// column gather — the same access-pattern penalty the paper reports for
/// column-wise enforcement on compressed row/column formats.
pub fn enforce_top_t_per_column(m: &mut Csr, t_per_col: usize, mode: TieMode) {
    let k = m.cols;
    // gather each column's values (column access in CSR = full scan)
    let mut col_vals: Vec<Vec<f32>> = vec![Vec::new(); k];
    for r in 0..m.rows {
        let (idx, val) = m.row(r);
        for (&c, &v) in idx.iter().zip(val) {
            col_vals[c as usize].push(v);
        }
    }
    let mut taus = vec![f32::NEG_INFINITY; k];
    let mut tie_budgets = vec![usize::MAX; k];
    for c in 0..k {
        if col_vals[c].len() > t_per_col {
            let tau = nth_largest(&mut col_vals[c], t_per_col);
            taus[c] = tau;
            if mode == TieMode::Exact {
                let above = col_vals[c].iter().filter(|&&v| v > tau).count();
                tie_budgets[c] = t_per_col - above;
            }
        }
    }
    match mode {
        TieMode::KeepTies => m.retain(|_, c, v| v >= taus[c as usize]),
        TieMode::Exact => m.retain(|_, c, v| {
            let c = c as usize;
            if v > taus[c] {
                true
            } else if v == taus[c] && tie_budgets[c] > 0 {
                tie_budgets[c] -= 1;
                true
            } else {
                false
            }
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn nth_largest_agrees_with_sort() {
        prop::check("quickselect-vs-sort", 600, 96, |rng: &mut Rng| {
            let n = rng.range(1, 200);
            let mut vals: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.f64() < 0.2 {
                        // force ties
                        (rng.below(5) as f32) * 0.5
                    } else {
                        rng.f32() * 10.0
                    }
                })
                .collect();
            let t = rng.range(1, n + 2);
            let want = nth_largest_by_sort(&vals, t);
            let got = nth_largest(&mut vals, t);
            assert_eq!(got, want, "t={t} n={n}");
        });
    }

    #[test]
    fn nth_largest_edges() {
        assert_eq!(nth_largest(&mut [], 3), f32::INFINITY);
        assert_eq!(nth_largest(&mut [1.0, 2.0], 0), f32::INFINITY);
        assert_eq!(nth_largest(&mut [1.0, 2.0], 5), 1.0);
        assert_eq!(nth_largest(&mut [7.0], 1), 7.0);
    }

    fn positive_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
        let data = prop::gen_sparse_dense(rng, rows, cols, density);
        Csr::from_dense(rows, cols, &data)
    }

    #[test]
    fn enforce_exact_keeps_exactly_t() {
        prop::check("exact-top-t", 700, 64, |rng: &mut Rng| {
            let (rows, cols) = (rng.range(1, 15), rng.range(1, 8));
            let mut m = positive_csr(rng, rows, cols, 0.6);
            let nnz0 = m.nnz();
            let t = rng.range(0, nnz0 + 3);
            let kept_expected = t.min(nnz0);
            let mut m2 = m.clone();
            enforce_top_t_csr(&mut m2, t, TieMode::Exact);
            assert_eq!(m2.nnz(), kept_expected);
            m2.validate().unwrap();
            // kept set dominates dropped set
            if m2.nnz() > 0 && m2.nnz() < nnz0 {
                let min_kept = m2.values.iter().copied().fold(f32::INFINITY, f32::min);
                enforce_top_t_csr(&mut m, t, TieMode::KeepTies);
                let dropped_max_bound = min_kept;
                assert!(m.values.iter().all(|&v| v >= dropped_max_bound * 0.999));
            }
        });
    }

    #[test]
    fn keep_ties_keeps_all_ties() {
        let mut m = Csr::from_dense(1, 5, &[3.0, 1.0, 3.0, 2.0, 3.0]);
        enforce_top_t_csr(&mut m, 2, TieMode::KeepTies);
        assert_eq!(m.nnz(), 3); // all three 3.0s survive
        let mut m = Csr::from_dense(1, 5, &[3.0, 1.0, 3.0, 2.0, 3.0]);
        enforce_top_t_csr(&mut m, 2, TieMode::Exact);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn enforce_noop_when_under_budget() {
        let mut m = Csr::from_dense(2, 2, &[1.0, 0.0, 0.0, 2.0]);
        let before = m.clone();
        enforce_top_t_csr(&mut m, 10, TieMode::Exact);
        assert_eq!(m, before);
    }

    #[test]
    fn rowblock_enforcement_matches_csr() {
        prop::check("rowblock-vs-csr-top-t", 800, 48, |rng: &mut Rng| {
            let rows = rng.range(1, 12);
            let k = rng.range(1, 6);
            let data = prop::gen_sparse_dense(rng, rows, k, 0.7);
            let csr = Csr::from_dense(rows, k, &data);
            let mut rb = RowBlock::from_csr(&csr);
            let t = rng.range(0, csr.nnz() + 2);
            let mut csr2 = csr.clone();
            enforce_top_t_csr(&mut csr2, t, TieMode::KeepTies);
            enforce_top_t_rowblock(&mut rb, t, TieMode::KeepTies);
            assert_eq!(rb.to_csr(), csr2);
        });
    }

    #[test]
    fn per_column_enforcement_bounds_each_column() {
        prop::check("per-column-top-t", 900, 48, |rng: &mut Rng| {
            let (rows, cols) = (rng.range(1, 20), rng.range(1, 6));
            let mut m = positive_csr(rng, rows, cols, 0.7);
            let t = rng.range(1, 6);
            enforce_top_t_per_column(&mut m, t, TieMode::Exact);
            m.validate().unwrap();
            for (c, &count) in m.col_nnz().iter().enumerate() {
                assert!(count <= t, "column {c} has {count} > {t}");
            }
        });
    }

    #[test]
    fn per_column_keeps_largest_per_column() {
        let mut m = Csr::from_dense(4, 2, &[
            5.0, 1.0, //
            4.0, 2.0, //
            3.0, 8.0, //
            2.0, 9.0,
        ]);
        enforce_top_t_per_column(&mut m, 2, TieMode::Exact);
        assert_eq!(m.get(0, 0), 5.0);
        assert_eq!(m.get(1, 0), 4.0);
        assert_eq!(m.get(2, 1), 8.0);
        assert_eq!(m.get(3, 1), 9.0);
        assert_eq!(m.nnz(), 4);
    }
}
