//! Compressed sparse column storage.
//!
//! The data matrix keeps a CSC twin of its CSR form so the `Aᵀ·U` half of
//! ALS walks columns of `A` (= rows of `Aᵀ`) contiguously. MATLAB's native
//! sparse format — the paper's substrate — is CSC.

use super::csr::Csr;

#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    /// `indptr[c]..indptr[c+1]` indexes column c's entries. len = cols+1.
    pub indptr: Vec<usize>,
    /// Row index per entry, ascending within a column.
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csc {
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// (row indices, values) of column c.
    #[inline]
    pub fn col(&self, c: usize) -> (&[u32], &[f32]) {
        let lo = self.indptr[c];
        let hi = self.indptr[c + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    pub fn to_csr(&self) -> Csr {
        // CSC of M == CSR of Mᵀ; transposing that CSR yields CSR of M.
        let as_csr_of_t = Csr {
            rows: self.cols,
            cols: self.rows,
            indptr: self.indptr.clone(),
            indices: self.indices.clone(),
            values: self.values.clone(),
        };
        as_csr_of_t.transpose()
    }

    pub fn get(&self, r: usize, c: usize) -> f32 {
        let (idx, val) = self.col(c);
        match idx.binary_search(&(r as u32)) {
            Ok(pos) => val[pos],
            Err(_) => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_csc_roundtrip() {
        let m = Csr::from_dense(3, 4, &[
            1.0, 0.0, 2.0, 0.0, //
            0.0, 3.0, 0.0, 0.0, //
            4.0, 0.0, 0.0, 5.0,
        ]);
        let csc = m.to_csc();
        assert_eq!(csc.nnz(), m.nnz());
        assert_eq!(csc.get(0, 2), 2.0);
        assert_eq!(csc.get(2, 3), 5.0);
        assert_eq!(csc.get(1, 0), 0.0);
        assert_eq!(csc.to_csr(), m);
    }

    #[test]
    fn column_access() {
        let m = Csr::from_dense(3, 2, &[1.0, 0.0, 0.0, 2.0, 3.0, 0.0]).to_csc();
        let (idx, val) = m.col(0);
        assert_eq!(idx, &[0, 2]);
        assert_eq!(val, &[1.0, 3.0]);
        let (idx, val) = m.col(1);
        assert_eq!(idx, &[1]);
        assert_eq!(val, &[2.0]);
    }
}
