//! Sparse products for the ALS hot path.
//!
//! All three products of an ALS iteration are here:
//! * `atb`: `B = Aᵀ·U`   (update-V half, streams columns of CSC `A`)
//! * `ab`:  `C = A·V`    (update-U half, streams rows of CSR `A`)
//! * `gram`: `Xᵀ·X`      (the small (k,k) normal matrix)
//! plus `tr_cross` (the sparse-safe error trace) and a general Gustavson
//! `spmm` used by tests and the evaluation code.
//!
//! Both SpMM orientations are one kernel underneath
//! ([`stream_mul_into`]): the left operand is presented through the
//! [`RowSource`] streaming contract ("rows r0..r1 as CSR" — a CSC matrix
//! streams as its transpose's rows), so the identical instruction
//! sequence runs whether `A` is fully resident or paged in shard-by-shard
//! from the on-disk corpus store ([`crate::io::store`]). That is what
//! makes store-streamed factorization bit-identical to in-memory.

use super::csc::Csc;
use super::csr::Csr;
use super::rowblock::RowBlock;
use super::source::{RowCursor, RowSource};
use crate::coordinator::pool;

/// Rows per partial gram accumulation. Fixed (never derived from the
/// thread count) so the f64 rounding sequence of the ordered merge is
/// identical at every thread count — see the determinism contract in
/// [`crate::coordinator::pool`].
pub const GRAM_CHUNK_ROWS: usize = 1024;

/// Accumulator lanes of the dense-factor SpMM fast path. The inner loop
/// keeps a `[f32; ACC_LANES]` register partial per k-chunk — a fixed
/// width the autovectorizer maps straight onto SIMD lanes instead of
/// round-tripping every add through the k-wide scratch in memory. The
/// value changes scheduling only, never bits: per output column the
/// accumulation order over nonzeros is the same as the straight-line
/// loop's (see [`reference`]).
pub const ACC_LANES: usize = 8;

/// Dense row-major copy of a factor when it is dense enough that the
/// sparse row iteration's index indirection costs more than it saves.
/// The dense inner loop is branch-free over k and auto-vectorizes.
///
/// Public so the blocked half-step driver ([`crate::nmf::als`]) can make
/// this decision **once per half-step**: the dense/sparse inner loops
/// accumulate in different orders over explicit zeros, so the choice must
/// not vary per row block or the result bits would depend on `block_rows`.
pub fn dense_factor(x: &Csr) -> Option<Vec<f32>> {
    let total = x.rows * x.cols;
    if total == 0 || (x.nnz() as f64) < 0.5 * total as f64 {
        return None;
    }
    Some(x.to_dense())
}

/// Candidate rows `lo..hi` of `S·F` (optionally `S·F − D·M`, the
/// sequential-ALS deflation of Eqs. 4.7/4.8) where the left operand `S`
/// is streamed through a [`RowSource`], appended into `out` (cleared
/// first — `out` is a reusable scratch; `cur` is the worker's streaming
/// cursor). `f_dense` is the optional dense fast-path copy of `f`; pass
/// the same copy for every range of one half-step (see
/// [`dense_factor`]).
///
/// Replicates the pre-`RowSource` operators bit-for-bit: the SpMM body
/// reproduces the old `atb_into`/`ab_into` instruction sequence
/// (including the dense/sparse `any`-row semantics), and the fused
/// deflation reproduces `csr_times_small` + `rowblock_sub` exactly —
/// down to the negation of deflation-only rows — so the blocked
/// sequential solver emits the same bits the unfused pipeline did.
///
/// Restructured for speed (PR 9), bit-identical to
/// [`reference::stream_mul_into_ref`]:
/// * the dense-factor path accumulates through [`ACC_LANES`]-wide
///   register partials over contiguous row-major factor strides — per
///   output column the nonzeros are still summed in stored order, so
///   the bits are unchanged;
/// * the sparse-factor path stops memsetting the O(k) accumulator per
///   row: it records the scattered indices and clears only those, making
///   per-row cleanup O(nnz).
#[allow(clippy::too_many_arguments)]
pub fn stream_mul_into(
    s: &dyn RowSource,
    f: &Csr,
    f_dense: Option<&[f32]>,
    defl: Option<(&Csr, &[f32])>,
    lo: usize,
    hi: usize,
    cur: &mut RowCursor,
    out: &mut RowBlock,
) {
    assert_eq!(s.cols(), f.rows, "stream contraction mismatch");
    out.clear();
    let k = f.cols;
    let view = s.load(lo, hi, cur);
    let mut acc = vec![0.0f32; k];
    if let Some((d, m)) = defl {
        assert_eq!(d.rows, s.rows(), "deflation row mismatch");
        assert_eq!(m.len(), d.cols * f.cols, "deflation matrix shape");
        // The sequential-ALS fuse (Eqs. 4.7/4.8) keeps the historical
        // full-width loop: deflation rows overwrite the accumulator
        // wholesale so touched-index hygiene cannot hold an all-zero
        // invariant, and the path only ever runs with the tiny deflation
        // ranks of sequential ALS.
        let mut dacc = vec![0.0f32; k];
        for j in lo..hi {
            let (cols, vals) = view.row(j - lo);
            let mut any = false;
            if !cols.is_empty() {
                acc.iter_mut().for_each(|x| *x = 0.0);
                match f_dense {
                    Some(fd) => {
                        for (&i, &aij) in cols.iter().zip(vals) {
                            let frow = &fd[i as usize * k..(i as usize + 1) * k];
                            for (slot, &fv) in acc.iter_mut().zip(frow) {
                                *slot += aij * fv;
                            }
                        }
                        any = acc.iter().any(|&x| x != 0.0);
                    }
                    None => {
                        for (&i, &aij) in cols.iter().zip(vals) {
                            let (fidx, fval) = f.row(i as usize);
                            for (&c, &fv) in fidx.iter().zip(fval) {
                                acc[c as usize] += aij * fv;
                                any = true;
                            }
                        }
                    }
                }
            }
            let (didx, dval) = d.row(j);
            if didx.is_empty() {
                if any {
                    out.push_row(j, &acc);
                }
                continue;
            }
            // the deflation row, accumulated exactly as csr_times_small does
            dacc.iter_mut().for_each(|x| *x = 0.0);
            for (&c, &v) in didx.iter().zip(dval) {
                let mrow = &m[c as usize * k..(c as usize + 1) * k];
                for (a, &mv) in dacc.iter_mut().zip(mrow) {
                    *a += v * mv;
                }
            }
            if any {
                // both sides active: elementwise x − y (rowblock_sub's merge)
                for (a, &dv) in acc.iter_mut().zip(&dacc) {
                    *a -= dv;
                }
            } else {
                // deflation-only row: rowblock_sub stores the negation
                for (a, &dv) in acc.iter_mut().zip(&dacc) {
                    *a = -dv;
                }
            }
            out.push_row(j, &acc);
        }
        return;
    }
    match f_dense {
        Some(fd) => {
            // chunked-accumulator fast path: every non-empty row fully
            // overwrites `acc`, so no clearing is needed at all
            for j in lo..hi {
                let (cols, vals) = view.row(j - lo);
                if cols.is_empty() {
                    continue;
                }
                gather_row_chunked(&mut acc, fd, k, cols, vals);
                if acc.iter().any(|&x| x != 0.0) {
                    out.push_row(j, &acc);
                }
            }
        }
        None => {
            // scatter path over the sparse factor; `acc` holds an
            // all-zero invariant between rows, restored at O(nnz) by
            // clearing only the scattered indices
            let mut touched: Vec<u32> = Vec::new();
            for j in lo..hi {
                let (cols, vals) = view.row(j - lo);
                for (&i, &aij) in cols.iter().zip(vals) {
                    let (fidx, fval) = f.row(i as usize);
                    touched.extend_from_slice(fidx);
                    for (&c, &fv) in fidx.iter().zip(fval) {
                        acc[c as usize] += aij * fv;
                    }
                }
                if !touched.is_empty() {
                    out.push_row(j, &acc);
                }
                // duplicate indices across factor rows are harmless here
                // (clearing twice is still clearing)
                for c in touched.drain(..) {
                    acc[c as usize] = 0.0;
                }
            }
        }
    }
}

/// One output row of the dense-factor fast path:
/// `acc[c] = Σ_p vals[p] · fd[cols[p]·k + c]`, computed [`ACC_LANES`]
/// output columns at a time through a fixed-width register partial, with
/// one variable-width pass for the k-remainder. Per output column the
/// sum still runs over the nonzeros in stored order — exactly the order
/// the straight-line loop uses — so the result bits are unchanged
/// (pinned against [`reference::stream_mul_into_ref`] by the property
/// suite). Overwrites all k entries of `acc`.
#[inline]
fn gather_row_chunked(acc: &mut [f32], fd: &[f32], k: usize, cols: &[u32], vals: &[f32]) {
    let mut start = 0usize;
    while start + ACC_LANES <= k {
        let mut lanes = [0.0f32; ACC_LANES];
        for (&i, &aij) in cols.iter().zip(vals) {
            let base = i as usize * k + start;
            for (lane, &fv) in lanes.iter_mut().zip(&fd[base..base + ACC_LANES]) {
                *lane += aij * fv;
            }
        }
        acc[start..start + ACC_LANES].copy_from_slice(&lanes);
        start += ACC_LANES;
    }
    if start < k {
        let tail = k - start;
        let mut lanes = [0.0f32; ACC_LANES];
        for (&i, &aij) in cols.iter().zip(vals) {
            let base = i as usize * k + start;
            for (lane, &fv) in lanes.iter_mut().zip(&fd[base..base + tail]) {
                *lane += aij * fv;
            }
        }
        acc[start..].copy_from_slice(&lanes[..tail]);
    }
}

/// [`stream_mul_into`] over rows `lo..hi`, allocating a fresh RowBlock.
fn stream_mul_range(
    s: &dyn RowSource,
    f: &Csr,
    f_dense: Option<&[f32]>,
    defl: Option<(&Csr, &[f32])>,
    lo: usize,
    hi: usize,
    cur: &mut RowCursor,
) -> RowBlock {
    let mut out = RowBlock::new(s.rows(), f.cols);
    stream_mul_into(s, f, f_dense, defl, lo, hi, cur, &mut out);
    out
}

/// Materialize the whole product at once, row-partitioned across
/// `threads` scoped workers (one streaming cursor per worker),
/// concatenated in range order — bit-identical to the serial result.
pub fn stream_mul_par_with(
    s: &dyn RowSource,
    f: &Csr,
    f_dense: Option<&[f32]>,
    defl: Option<(&Csr, &[f32])>,
    threads: usize,
) -> RowBlock {
    let rows = s.rows();
    if threads <= 1 || rows < 2 * threads {
        let mut cur = RowCursor::new();
        return stream_mul_range(s, f, f_dense, defl, 0, rows, &mut cur);
    }
    let parts = pool::split_ranges(rows, threads);
    let blocks = pool::scoped_map_ranges_with(threads, &parts, RowCursor::new, |cur, lo, hi| {
        stream_mul_range(s, f, f_dense, defl, lo, hi, cur)
    });
    concat_rowblocks(rows, f.cols, blocks)
}

/// `B = Aᵀ · U` restricted to output rows `lo..hi` (columns of `a`),
/// appended into `out` (cleared first — `out` is a reusable scratch).
/// `u_dense` is the optional dense fast-path copy of `u`; pass the same
/// copy for every range of one half-step (see [`dense_factor`]).
pub fn atb_into(
    a: &Csc,
    u: &Csr,
    u_dense: Option<&[f32]>,
    lo: usize,
    hi: usize,
    out: &mut RowBlock,
) {
    assert_eq!(a.rows, u.rows, "Aᵀ·U contraction mismatch");
    let mut cur = RowCursor::new();
    stream_mul_into(a, u, u_dense, None, lo, hi, &mut cur, out);
}

/// `B = Aᵀ · U` where `a` is (n, m) in CSC and `u` is (n, k) CSR.
/// Returns the (m, k) intermediate with only active rows materialized.
pub fn atb(a: &Csc, u: &Csr) -> RowBlock {
    let ud = dense_factor(u);
    atb_par_with(a, u, ud.as_deref(), 1)
}

/// Parallel [`atb`]: contiguous output-row ranges across `threads` scoped
/// workers, concatenated in order — bit-identical to the serial result.
pub fn atb_par(a: &Csc, u: &Csr, threads: usize) -> RowBlock {
    let ud = dense_factor(u);
    atb_par_with(a, u, ud.as_deref(), threads)
}

/// [`atb_par`] with a caller-supplied dense fast-path copy (see
/// [`dense_factor`]) so one half-step computes the copy exactly once.
pub fn atb_par_with(a: &Csc, u: &Csr, u_dense: Option<&[f32]>, threads: usize) -> RowBlock {
    assert_eq!(a.rows, u.rows, "Aᵀ·U contraction mismatch");
    stream_mul_par_with(a, u, u_dense, None, threads)
}

/// `C = A · V` restricted to output rows `lo..hi` (rows of `a`),
/// appended into `out` (cleared first — `out` is a reusable scratch).
/// `v_dense` is the optional dense fast-path copy of `v`; pass the same
/// copy for every range of one half-step (see [`dense_factor`]).
pub fn ab_into(
    a: &Csr,
    v: &Csr,
    v_dense: Option<&[f32]>,
    lo: usize,
    hi: usize,
    out: &mut RowBlock,
) {
    assert_eq!(a.cols, v.rows, "A·V contraction mismatch");
    let mut cur = RowCursor::new();
    stream_mul_into(a, v, v_dense, None, lo, hi, &mut cur, out);
}

/// `C = A · V` where `a` is (n, m) in CSR and `v` is (m, k) CSR.
/// Returns the (n, k) intermediate with only active rows materialized.
pub fn ab(a: &Csr, v: &Csr) -> RowBlock {
    let vd = dense_factor(v);
    ab_par_with(a, v, vd.as_deref(), 1)
}

/// Parallel [`ab`], same contract as [`atb_par`].
pub fn ab_par(a: &Csr, v: &Csr, threads: usize) -> RowBlock {
    let vd = dense_factor(v);
    ab_par_with(a, v, vd.as_deref(), threads)
}

/// [`ab_par`] with a caller-supplied dense fast-path copy (see
/// [`dense_factor`]) so one half-step computes the copy exactly once.
pub fn ab_par_with(a: &Csr, v: &Csr, v_dense: Option<&[f32]>, threads: usize) -> RowBlock {
    assert_eq!(a.cols, v.rows, "A·V contraction mismatch");
    stream_mul_par_with(a, v, v_dense, None, threads)
}

/// Concatenate per-range RowBlocks (disjoint ascending row ranges).
fn concat_rowblocks(rows: usize, k: usize, blocks: Vec<RowBlock>) -> RowBlock {
    let total_rows: usize = blocks.iter().map(|b| b.row_ids.len()).sum();
    let mut out = RowBlock::new(rows, k);
    out.row_ids.reserve(total_rows);
    out.data.reserve(total_rows * k);
    for b in blocks {
        debug_assert!(out
            .row_ids
            .last()
            .zip(b.row_ids.first())
            .map_or(true, |(&last, &first)| last < first));
        out.row_ids.extend_from_slice(&b.row_ids);
        out.data.extend_from_slice(&b.data);
    }
    out
}

/// Upper-triangle gram accumulation of rows `lo..hi` in f64.
///
/// Rows at least half-dense take a contiguous fast path: the row is
/// scattered into a k-wide f64 scratch once, then each active column
/// accumulates against the contiguous tail `scratch[ci..k]` — unit-stride
/// loads the autovectorizer can chew on — instead of chasing the index
/// list per pair. The fast path adds explicit products against absent
/// columns, but those are all `±0.0` in f64 and provably cannot change
/// any accumulator's bit pattern: every accumulator starts at `+0.0`,
/// sums of finite nonzero-f32 products in f64 never produce `-0.0`
/// (underflow is impossible at f64 range and `x + (-x)` rounds to
/// `+0.0`), and adding `±0.0` to a value that is not `-0.0` is a bitwise
/// no-op. Rows containing a non-finite or exact-zero stored value fall
/// back to the all-pairs path (where `NaN·0.0 ≠ absent` and `-0.0`
/// accumulators become possible), as do sparse rows where the scatter
/// would dominate. Pinned bitwise against [`reference::gram_ref`].
fn gram_chunk(x: &Csr, lo: usize, hi: usize) -> Vec<f64> {
    let k = x.cols;
    let mut g = vec![0.0f64; k * k];
    let mut scratch = vec![0.0f64; k];
    for r in lo..hi {
        let (idx, val) = x.row(r);
        let dense_ok = idx.len() * 2 >= k && val.iter().all(|v| v.is_finite() && *v != 0.0);
        if dense_ok {
            for (&c, &v) in idx.iter().zip(val) {
                scratch[c as usize] = v as f64;
            }
            for (&c, &v) in idx.iter().zip(val) {
                let ci = c as usize;
                let vi = v as f64;
                let grow = &mut g[ci * k + ci..(ci + 1) * k];
                for (gv, &sv) in grow.iter_mut().zip(&scratch[ci..k]) {
                    *gv += vi * sv;
                }
            }
            for &c in idx {
                scratch[c as usize] = 0.0;
            }
        } else {
            for p in 0..idx.len() {
                let (ci, vi) = (idx[p] as usize, val[p] as f64);
                for q in p..idx.len() {
                    g[ci * k + idx[q] as usize] += vi * val[q] as f64;
                }
            }
        }
    }
    g
}

/// Ordered merge of per-chunk upper triangles → mirrored f32 (k, k).
fn gram_merge(partials: Vec<Vec<f64>>, k: usize) -> Vec<f32> {
    let mut g = vec![0.0f64; k * k];
    for part in partials {
        for (acc, v) in g.iter_mut().zip(part) {
            *acc += v;
        }
    }
    for i in 0..k {
        for j in 0..i {
            g[i * k + j] = g[j * k + i];
        }
    }
    g.into_iter().map(|x| x as f32).collect()
}

/// Gram matrix `Xᵀ·X` of a CSR factor (rows, k) → dense row-major (k, k).
/// Accumulates in f64 for stability over long reductions, per fixed
/// [`GRAM_CHUNK_ROWS`]-row chunk merged in chunk order (the same
/// computation [`gram_par`] distributes, so results agree bit-for-bit).
pub fn gram(x: &Csr) -> Vec<f32> {
    gram_par(x, 1)
}

/// Parallel [`gram`]: fixed-width row chunks across `threads` scoped
/// workers, partial (k, k) triangles merged in ascending chunk order —
/// bit-identical to the serial result at any thread count.
pub fn gram_par(x: &Csr, threads: usize) -> Vec<f32> {
    let chunks = pool::fixed_chunks(x.rows, GRAM_CHUNK_ROWS);
    let partials = pool::scoped_map_ranges(threads, &chunks, |lo, hi| gram_chunk(x, lo, hi));
    gram_merge(partials, x.cols)
}

/// `tr(Uᵀ A V) = Σ_{(i,j) ∈ nnz(A)} a_ij · ⟨U_i, V_j⟩` — the cross term of
/// the sparse-safe relative error (never materializes U·Vᵀ).
pub fn tr_cross(a: &Csr, u: &Csr, v: &Csr) -> f64 {
    tr_cross_source(a, u, v, a.rows.max(1))
}

/// [`tr_cross`] with `A` streamed through a [`RowSource`] in
/// `chunk_rows`-row runs — the out-of-core error pass. One f64
/// accumulator walks the rows in order, so the chunking (and therefore
/// the backing storage) cannot change the result bits; resident corpus
/// memory stays bounded by one chunk (plus the cursor's cached shard for
/// store-backed sources).
///
/// The k-wide scatter scratch holds an all-zero invariant between rows:
/// each row scatters its U entries in, reads them back through the dots,
/// and un-scatters the same indices afterwards — O(nnz(U_i)) per row
/// instead of the old O(k) memset (bit-identical: the scratch contents
/// at dot time are unchanged; pinned against
/// [`reference::tr_cross_source_ref`]).
pub fn tr_cross_source(a: &dyn RowSource, u: &Csr, v: &Csr, chunk_rows: usize) -> f64 {
    assert_eq!(a.rows(), u.rows);
    assert_eq!(a.cols(), v.rows);
    assert_eq!(u.cols, v.cols);
    let k = u.cols;
    let mut scratch = vec![0.0f32; k];
    let mut acc = 0.0f64;
    let mut cur = RowCursor::new();
    for (lo, hi) in pool::fixed_chunks(a.rows(), chunk_rows) {
        let view = a.load(lo, hi, &mut cur);
        for i in lo..hi {
            let (acols, avals) = view.row(i - lo);
            if acols.is_empty() {
                continue;
            }
            let (uidx, uval) = u.row(i);
            if uidx.is_empty() {
                continue;
            }
            for (&c, &uv) in uidx.iter().zip(uval) {
                scratch[c as usize] = uv;
            }
            for (&j, &aij) in acols.iter().zip(avals) {
                let (vidx, vval) = v.row(j as usize);
                let mut dot = 0.0f64;
                for (&c, &vv) in vidx.iter().zip(vval) {
                    dot += scratch[c as usize] as f64 * vv as f64;
                }
                acc += aij as f64 * dot;
            }
            // restore the all-zero invariant at O(nnz) cost
            for &c in uidx {
                scratch[c as usize] = 0.0;
            }
        }
    }
    acc
}

/// `tr(Gᵤ · Gᵥ)` for two dense row-major (k, k) Gram matrices.
pub fn tr_gram_product(gu: &[f32], gv: &[f32], k: usize) -> f64 {
    assert_eq!(gu.len(), k * k);
    assert_eq!(gv.len(), k * k);
    let mut acc = 0.0f64;
    // tr(Gu Gv) = Σ_ij Gu[i,j] Gv[j,i]; both symmetric → elementwise product.
    for i in 0..k * k {
        acc += gu[i] as f64 * gv[i] as f64;
    }
    acc
}

/// Cross-Gram `Xᵀ·Y` of two CSR factors sharing their row dimension:
/// (rows, kx)ᵀ · (rows, ky) → dense row-major (kx, ky). Needed by the
/// sequential-ALS deflation terms `U₁ᵀU₂` and `V₁ᵀV₂` (Eqs. 4.7/4.8).
pub fn cross_gram(x: &Csr, y: &Csr) -> Vec<f32> {
    assert_eq!(x.rows, y.rows, "cross_gram row mismatch");
    let (kx, ky) = (x.cols, y.cols);
    let mut g = vec![0.0f64; kx * ky];
    for r in 0..x.rows {
        let (xi, xv) = x.row(r);
        if xi.is_empty() {
            continue;
        }
        let (yi, yv) = y.row(r);
        for (&cx, &vx) in xi.iter().zip(xv) {
            let base = cx as usize * ky;
            for (&cy, &vy) in yi.iter().zip(yv) {
                g[base + cy as usize] += vx as f64 * vy as f64;
            }
        }
    }
    g.into_iter().map(|x| x as f32).collect()
}

/// `X · M` where `x` is a sparse (rows, kx) CSR factor and `m` a small
/// dense row-major (kx, kout) matrix → RowBlock with x's row support.
pub fn csr_times_small(x: &Csr, m: &[f32], kout: usize) -> RowBlock {
    assert_eq!(m.len(), x.cols * kout, "csr_times_small shape mismatch");
    let mut out = RowBlock::new(x.rows, kout);
    let mut acc = vec![0.0f32; kout];
    for r in 0..x.rows {
        let (idx, val) = x.row(r);
        if idx.is_empty() {
            continue;
        }
        acc.iter_mut().for_each(|a| *a = 0.0);
        for (&c, &v) in idx.iter().zip(val) {
            let mrow = &m[c as usize * kout..(c as usize + 1) * kout];
            for (a, &mv) in acc.iter_mut().zip(mrow) {
                *a += v * mv;
            }
        }
        out.push_row(r, &acc);
    }
    out
}

/// `a - b` over two RowBlocks with the same logical shape: union of the
/// active row sets, elementwise subtraction.
pub fn rowblock_sub(a: &RowBlock, b: &RowBlock) -> RowBlock {
    assert_eq!(a.rows, b.rows);
    assert_eq!(a.k, b.k);
    let k = a.k;
    let mut out = RowBlock::new(a.rows, k);
    let (mut p, mut q) = (0usize, 0usize);
    let mut scratch = vec![0.0f32; k];
    while p < a.row_ids.len() || q < b.row_ids.len() {
        let ra = a.row_ids.get(p).copied().unwrap_or(u32::MAX);
        let rb = b.row_ids.get(q).copied().unwrap_or(u32::MAX);
        if ra < rb {
            out.push_row(ra as usize, a.row_data(p));
            p += 1;
        } else if rb < ra {
            for (s, &v) in scratch.iter_mut().zip(b.row_data(q)) {
                *s = -v;
            }
            out.push_row(rb as usize, &scratch);
            q += 1;
        } else {
            for ((s, &x), &y) in scratch.iter_mut().zip(a.row_data(p)).zip(b.row_data(q)) {
                *s = x - y;
            }
            out.push_row(ra as usize, &scratch);
            p += 1;
            q += 1;
        }
    }
    out
}

/// General sparse × sparse product (Gustavson): (p, q)·(q, r) → (p, r) CSR.
pub fn spmm(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.cols, b.rows, "spmm contraction mismatch");
    let mut indptr = vec![0usize; a.rows + 1];
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    let mut acc = vec![0.0f32; b.cols];
    let mut touched: Vec<u32> = Vec::new();
    for i in 0..a.rows {
        let (acols, avals) = a.row(i);
        for (&j, &aij) in acols.iter().zip(avals) {
            let (bcols, bvals) = b.row(j as usize);
            for (&c, &bv) in bcols.iter().zip(bvals) {
                if acc[c as usize] == 0.0 {
                    touched.push(c);
                }
                acc[c as usize] += aij * bv;
            }
        }
        touched.sort_unstable();
        for &c in &touched {
            let v = acc[c as usize];
            if v != 0.0 {
                indices.push(c);
                values.push(v);
            }
            acc[c as usize] = 0.0;
        }
        touched.clear();
        indptr[i + 1] = values.len();
    }
    Csr {
        rows: a.rows,
        cols: b.cols,
        indptr,
        indices,
        values,
    }
}

/// Straight-line pre-restructure implementations of the hot kernels.
///
/// The restructured kernels in this module's parent are required to be
/// **bit-identical** to these: they are the oracle the property suite
/// (`tests/prop_kernels.rs`) pins against, and the "before" side of the
/// before/after points in `benches/micro_kernels.rs`. They intentionally
/// preserve the original instruction sequences — full O(k) scratch
/// clears per row, per-element k-wide memory accumulation in the
/// dense-factor path, and the all-pairs gram scatter.
pub mod reference {
    use super::*;

    /// Pre-restructure [`super::stream_mul_into`]: the original fused
    /// SpMM/deflation loop with a full O(k) accumulator clear per row.
    #[allow(clippy::too_many_arguments)]
    pub fn stream_mul_into_ref(
        s: &dyn RowSource,
        f: &Csr,
        f_dense: Option<&[f32]>,
        defl: Option<(&Csr, &[f32])>,
        lo: usize,
        hi: usize,
        cur: &mut RowCursor,
        out: &mut RowBlock,
    ) {
        assert_eq!(s.cols(), f.rows, "stream contraction mismatch");
        if let Some((d, m)) = defl {
            assert_eq!(d.rows, s.rows(), "deflation row mismatch");
            assert_eq!(m.len(), d.cols * f.cols, "deflation matrix shape");
        }
        out.clear();
        let k = f.cols;
        let view = s.load(lo, hi, cur);
        let mut acc = vec![0.0f32; k];
        let mut dacc = if defl.is_some() {
            vec![0.0f32; k]
        } else {
            Vec::new()
        };
        for j in lo..hi {
            let (cols, vals) = view.row(j - lo);
            let mut any = false;
            if !cols.is_empty() {
                acc.iter_mut().for_each(|x| *x = 0.0);
                match f_dense {
                    Some(fd) => {
                        for (&i, &aij) in cols.iter().zip(vals) {
                            let frow = &fd[i as usize * k..(i as usize + 1) * k];
                            for (slot, &fv) in acc.iter_mut().zip(frow) {
                                *slot += aij * fv;
                            }
                        }
                        any = acc.iter().any(|&x| x != 0.0);
                    }
                    None => {
                        for (&i, &aij) in cols.iter().zip(vals) {
                            let (fidx, fval) = f.row(i as usize);
                            for (&c, &fv) in fidx.iter().zip(fval) {
                                acc[c as usize] += aij * fv;
                                any = true;
                            }
                        }
                    }
                }
            }
            let Some((d, m)) = defl else {
                if any {
                    out.push_row(j, &acc);
                }
                continue;
            };
            let (didx, dval) = d.row(j);
            if didx.is_empty() {
                if any {
                    out.push_row(j, &acc);
                }
                continue;
            }
            dacc.iter_mut().for_each(|x| *x = 0.0);
            for (&c, &v) in didx.iter().zip(dval) {
                let mrow = &m[c as usize * k..(c as usize + 1) * k];
                for (a, &mv) in dacc.iter_mut().zip(mrow) {
                    *a += v * mv;
                }
            }
            if any {
                for (a, &dv) in acc.iter_mut().zip(&dacc) {
                    *a -= dv;
                }
            } else {
                for (a, &dv) in acc.iter_mut().zip(&dacc) {
                    *a = -dv;
                }
            }
            out.push_row(j, &acc);
        }
    }

    /// Pre-restructure serial gram: all-pairs upper-triangle scatter per
    /// row, fixed [`GRAM_CHUNK_ROWS`] chunks merged in ascending order.
    pub fn gram_ref(x: &Csr) -> Vec<f32> {
        let k = x.cols;
        let partials = pool::fixed_chunks(x.rows, GRAM_CHUNK_ROWS)
            .into_iter()
            .map(|(lo, hi)| {
                let mut g = vec![0.0f64; k * k];
                for r in lo..hi {
                    let (idx, val) = x.row(r);
                    for p in 0..idx.len() {
                        let (ci, vi) = (idx[p] as usize, val[p] as f64);
                        for q in p..idx.len() {
                            g[ci * k + idx[q] as usize] += vi * val[q] as f64;
                        }
                    }
                }
                g
            })
            .collect();
        gram_merge(partials, k)
    }

    /// Pre-restructure [`super::tr_cross_source`]: full O(k) scratch
    /// memset per streamed row.
    pub fn tr_cross_source_ref(a: &dyn RowSource, u: &Csr, v: &Csr, chunk_rows: usize) -> f64 {
        assert_eq!(a.rows(), u.rows);
        assert_eq!(a.cols(), v.rows);
        assert_eq!(u.cols, v.cols);
        let k = u.cols;
        let mut scratch = vec![0.0f32; k];
        let mut acc = 0.0f64;
        let mut cur = RowCursor::new();
        for (lo, hi) in pool::fixed_chunks(a.rows(), chunk_rows) {
            let view = a.load(lo, hi, &mut cur);
            for i in lo..hi {
                let (acols, avals) = view.row(i - lo);
                if acols.is_empty() {
                    continue;
                }
                let (uidx, uval) = u.row(i);
                if uidx.is_empty() {
                    continue;
                }
                scratch.iter_mut().for_each(|x| *x = 0.0);
                for (&c, &uv) in uidx.iter().zip(uval) {
                    scratch[c as usize] = uv;
                }
                for (&j, &aij) in acols.iter().zip(avals) {
                    let (vidx, vval) = v.row(j as usize);
                    let mut dot = 0.0f64;
                    for (&c, &vv) in vidx.iter().zip(vval) {
                        dot += scratch[c as usize] as f64 * vv as f64;
                    }
                    acc += aij as f64 * dot;
                }
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn dense_mm(a: &[f32], (ar, ac): (usize, usize), b: &[f32], bc: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; ar * bc];
        for i in 0..ar {
            for l in 0..ac {
                let av = a[i * ac + l];
                if av != 0.0 {
                    for j in 0..bc {
                        out[i * bc + j] += av * b[l * bc + j];
                    }
                }
            }
        }
        out
    }

    fn transpose_dense(a: &[f32], r: usize, c: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = a[i * c + j];
            }
        }
        out
    }

    #[test]
    fn atb_matches_dense_reference() {
        prop::check("atb-vs-dense", 100, 48, |rng: &mut Rng| {
            let n = rng.range(1, 12);
            let m = rng.range(1, 12);
            let k = rng.range(1, 6);
            let a_d = prop::gen_sparse_dense(rng, n, m, 0.4);
            let u_d = prop::gen_sparse_dense(rng, n, k, 0.5);
            let a = Csr::from_dense(n, m, &a_d);
            let u = Csr::from_dense(n, k, &u_d);
            let got = atb(&a.to_csc(), &u).to_csr().to_dense();
            let want = dense_mm(&transpose_dense(&a_d, n, m), (m, n), &u_d, k);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "atb mismatch {g} vs {w}");
            }
        });
    }

    #[test]
    fn ab_matches_dense_reference() {
        prop::check("ab-vs-dense", 200, 48, |rng: &mut Rng| {
            let n = rng.range(1, 12);
            let m = rng.range(1, 12);
            let k = rng.range(1, 6);
            let a_d = prop::gen_sparse_dense(rng, n, m, 0.4);
            let v_d = prop::gen_sparse_dense(rng, m, k, 0.5);
            let a = Csr::from_dense(n, m, &a_d);
            let v = Csr::from_dense(m, k, &v_d);
            let got = ab(&a, &v).to_csr().to_dense();
            let want = dense_mm(&a_d, (n, m), &v_d, k);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "ab mismatch {g} vs {w}");
            }
        });
    }

    #[test]
    fn gram_matches_dense_reference() {
        prop::check("gram-vs-dense", 300, 48, |rng: &mut Rng| {
            let n = rng.range(1, 20);
            let k = rng.range(1, 6);
            let x_d = prop::gen_sparse_dense(rng, n, k, 0.6);
            let x = Csr::from_dense(n, k, &x_d);
            let got = gram(&x);
            let want = dense_mm(&transpose_dense(&x_d, n, k), (k, n), &x_d, k);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "gram mismatch {g} vs {w}");
            }
        });
    }

    #[test]
    fn spmm_matches_dense_reference() {
        prop::check("spmm-vs-dense", 400, 48, |rng: &mut Rng| {
            let p = rng.range(1, 10);
            let q = rng.range(1, 10);
            let r = rng.range(1, 10);
            let a_d = prop::gen_sparse_dense(rng, p, q, 0.4);
            let b_d = prop::gen_sparse_dense(rng, q, r, 0.4);
            let a = Csr::from_dense(p, q, &a_d);
            let b = Csr::from_dense(q, r, &b_d);
            let c = spmm(&a, &b);
            c.validate().unwrap();
            let want = dense_mm(&a_d, (p, q), &b_d, r);
            let got = c.to_dense();
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "spmm mismatch {g} vs {w}");
            }
        });
    }

    #[test]
    fn tr_cross_matches_dense() {
        prop::check("tr-cross-vs-dense", 500, 32, |rng: &mut Rng| {
            let n = rng.range(1, 10);
            let m = rng.range(1, 10);
            let k = rng.range(1, 5);
            let a_d = prop::gen_sparse_dense(rng, n, m, 0.5);
            let u_d = prop::gen_sparse_dense(rng, n, k, 0.6);
            let v_d = prop::gen_sparse_dense(rng, m, k, 0.6);
            let a = Csr::from_dense(n, m, &a_d);
            let u = Csr::from_dense(n, k, &u_d);
            let v = Csr::from_dense(m, k, &v_d);
            // dense: tr(Uᵀ A V) = Σ_ij A_ij (U V^T)_ij
            let uvt = dense_mm(&u_d, (n, k), &transpose_dense(&v_d, m, k), m);
            let want: f64 = (0..n * m).map(|p| a_d[p] as f64 * uvt[p] as f64).sum();
            let got = tr_cross(&a, &u, &v);
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "tr_cross {got} vs {want}"
            );
        });
    }

    #[test]
    fn tr_gram_product_symmetric() {
        let gu = vec![1.0, 2.0, 2.0, 5.0];
        let gv = vec![3.0, 1.0, 1.0, 4.0];
        // tr([[1,2],[2,5]]·[[3,1],[1,4]]) = tr([[5,9],[11,22]]) = 27
        assert!((tr_gram_product(&gu, &gv, 2) - 27.0).abs() < 1e-9);
    }

    #[test]
    fn parallel_products_bit_identical_to_serial() {
        prop::check("par-vs-serial", 1600, 24, |rng: &mut Rng| {
            let n = rng.range(1, 40);
            let m = rng.range(1, 40);
            let k = rng.range(1, 6);
            let threads = rng.range(1, 6);
            let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.2));
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.5));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.5));
            let a_csc = a.to_csc();
            assert_eq!(atb_par(&a_csc, &u, threads), atb(&a_csc, &u));
            assert_eq!(ab_par(&a, &v, threads), ab(&a, &v));
            assert_eq!(gram_par(&u, threads), gram(&u));
            assert_eq!(gram_par(&v, threads), gram(&v));
        });
    }

    #[test]
    fn range_kernels_agree_with_full_products_at_any_block_size() {
        // the blocked half-step pipeline streams atb_into/ab_into over
        // fixed row chunks; concatenating the chunks must reproduce the
        // one-shot product bit-for-bit at every block size
        prop::check("blocked-ranges-vs-full", 1700, 24, |rng: &mut Rng| {
            let n = rng.range(1, 30);
            let m = rng.range(1, 30);
            let k = rng.range(1, 6);
            let block = rng.range(1, 9);
            let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.3));
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.5));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.5));
            let a_csc = a.to_csc();
            let ud = dense_factor(&u);
            let vd = dense_factor(&v);

            let mut scratch = RowBlock::new(m, k);
            let mut atb_blocked = RowBlock::new(m, k);
            for (lo, hi) in crate::coordinator::pool::fixed_chunks(m, block) {
                atb_into(&a_csc, &u, ud.as_deref(), lo, hi, &mut scratch);
                for (slot, &rid) in scratch.row_ids.iter().enumerate() {
                    atb_blocked.push_row(rid as usize, scratch.row_data(slot));
                }
            }
            assert_eq!(atb_blocked, atb(&a_csc, &u), "atb block={block}");

            let mut scratch = RowBlock::new(n, k);
            let mut ab_blocked = RowBlock::new(n, k);
            for (lo, hi) in crate::coordinator::pool::fixed_chunks(n, block) {
                ab_into(&a, &v, vd.as_deref(), lo, hi, &mut scratch);
                for (slot, &rid) in scratch.row_ids.iter().enumerate() {
                    ab_blocked.push_row(rid as usize, scratch.row_data(slot));
                }
            }
            assert_eq!(ab_blocked, ab(&a, &v), "ab block={block}");
        });
    }

    #[test]
    fn gram_par_spans_chunk_boundaries() {
        // more rows than one GRAM_CHUNK_ROWS chunk, exercising the ordered
        // merge of several partial triangles
        let mut rng = Rng::new(0x6AA);
        let rows = GRAM_CHUNK_ROWS + 37;
        let x_d = prop::gen_sparse_dense(&mut rng, rows, 3, 0.3);
        let x = Csr::from_dense(rows, 3, &x_d);
        let serial = gram(&x);
        for threads in [2usize, 4, 7] {
            assert_eq!(gram_par(&x, threads), serial, "threads {threads}");
        }
    }

    #[test]
    fn cross_gram_matches_dense() {
        prop::check("cross-gram-vs-dense", 1100, 32, |rng: &mut Rng| {
            let n = rng.range(1, 15);
            let kx = rng.range(1, 5);
            let ky = rng.range(1, 5);
            let x_d = prop::gen_sparse_dense(rng, n, kx, 0.5);
            let y_d = prop::gen_sparse_dense(rng, n, ky, 0.5);
            let x = Csr::from_dense(n, kx, &x_d);
            let y = Csr::from_dense(n, ky, &y_d);
            let got = cross_gram(&x, &y);
            let want = dense_mm(&transpose_dense(&x_d, n, kx), (kx, n), &y_d, ky);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "cross_gram {g} vs {w}");
            }
        });
    }

    #[test]
    fn csr_times_small_matches_dense() {
        prop::check("csr-times-small", 1200, 32, |rng: &mut Rng| {
            let n = rng.range(1, 15);
            let kx = rng.range(1, 5);
            let ko = rng.range(1, 5);
            let x_d = prop::gen_sparse_dense(rng, n, kx, 0.5);
            let m: Vec<f32> = (0..kx * ko).map(|_| rng.normal() as f32).collect();
            let x = Csr::from_dense(n, kx, &x_d);
            let got = csr_times_small(&x, &m, ko).to_csr().to_dense();
            let want = dense_mm(&x_d, (n, kx), &m, ko);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "csr_times_small {g} vs {w}");
            }
        });
    }

    #[test]
    fn rowblock_sub_union_of_rows() {
        let mut a = RowBlock::new(5, 2);
        a.push_row(1, &[1.0, 2.0]);
        a.push_row(3, &[5.0, 6.0]);
        let mut b = RowBlock::new(5, 2);
        b.push_row(0, &[1.0, 1.0]);
        b.push_row(3, &[2.0, 9.0]);
        let d = rowblock_sub(&a, &b);
        assert_eq!(d.row_ids, vec![0, 1, 3]);
        assert_eq!(d.row_data(0), &[-1.0, -1.0]);
        assert_eq!(d.row_data(1), &[1.0, 2.0]);
        assert_eq!(d.row_data(2), &[3.0, -3.0]);
    }

    #[test]
    fn empty_operands() {
        let a = Csr::zeros(3, 4);
        let u = Csr::zeros(3, 2);
        assert_eq!(atb(&a.to_csc(), &u).active_rows(), 0);
        assert_eq!(ab(&a, &Csr::zeros(4, 2)).active_rows(), 0);
        assert_eq!(gram(&u), vec![0.0; 4]);
    }

    #[test]
    fn fused_deflation_matches_csr_times_small_plus_rowblock_sub() {
        // the blocked sequential solver fuses Eq. 4.7/4.8's deflation into
        // the streaming kernel; it must reproduce the unfused
        // csr_times_small + rowblock_sub pipeline bit-for-bit — including
        // rows active only on one side
        prop::check("fused-deflation", 2100, 48, |rng: &mut Rng| {
            let n = rng.range(1, 20);
            let m = rng.range(1, 20);
            let k_cur = rng.range(1, 4);
            let k2 = rng.range(1, 4);
            let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.3));
            let f = Csr::from_dense(m, k2, &prop::gen_sparse_dense(rng, m, k2, 0.5));
            let d = Csr::from_dense(n, k_cur, &prop::gen_sparse_dense(rng, n, k_cur, 0.4));
            let mm: Vec<f32> = (0..k_cur * k2).map(|_| rng.normal() as f32).collect();

            let want = rowblock_sub(&ab(&a, &f), &csr_times_small(&d, &mm, k2));
            let fd = dense_factor(&f);
            for threads in [1usize, 4] {
                let got =
                    stream_mul_par_with(&a, &f, fd.as_deref(), Some((&d, &mm)), threads);
                assert_eq!(got.row_ids, want.row_ids, "threads {threads}");
                let got_bits: Vec<u32> = got.data.iter().map(|v| v.to_bits()).collect();
                let want_bits: Vec<u32> = want.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got_bits, want_bits, "threads {threads}");
            }
        });
    }

    #[test]
    fn restructured_stream_mul_bit_matches_reference() {
        // chunked dense accumulators + touched-index sparse clears vs the
        // straight-line loop, across k widths below/at/above ACC_LANES,
        // both factor layouts, and the fused deflation path
        prop::check("stream-mul-vs-ref", 2300, 48, |rng: &mut Rng| {
            let n = rng.range(1, 25);
            let m = rng.range(1, 25);
            let k = rng.range(1, 2 * ACC_LANES + 4);
            let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.3));
            let f = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.5));
            let fd = dense_factor(&f);
            let d = Csr::from_dense(n, 2, &prop::gen_sparse_dense(rng, n, 2, 0.4));
            let mm: Vec<f32> = (0..2 * k).map(|_| rng.normal() as f32).collect();
            for dense in [None, fd.as_deref()] {
                for defl in [None, Some((&d, &mm[..]))] {
                    let mut cur = RowCursor::new();
                    let mut got = RowBlock::new(n, k);
                    stream_mul_into(&a, &f, dense, defl, 0, n, &mut cur, &mut got);
                    let mut cur = RowCursor::new();
                    let mut want = RowBlock::new(n, k);
                    reference::stream_mul_into_ref(&a, &f, dense, defl, 0, n, &mut cur, &mut want);
                    assert_eq!(got.row_ids, want.row_ids);
                    let got_bits: Vec<u32> = got.data.iter().map(|v| v.to_bits()).collect();
                    let want_bits: Vec<u32> = want.data.iter().map(|v| v.to_bits()).collect();
                    let case = (dense.is_some(), defl.is_some());
                    assert_eq!(got_bits, want_bits, "case {case:?}");
                }
            }
        });
    }

    #[test]
    fn gram_dense_fastpath_bit_matches_reference() {
        prop::check("gram-vs-ref", 2400, 48, |rng: &mut Rng| {
            let n = rng.range(1, 30);
            let k = rng.range(1, 12);
            // densities straddling the fast-path threshold
            let density = [0.2, 0.5, 0.9][rng.range(0, 3)];
            let x = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, density));
            let want: Vec<u32> = reference::gram_ref(&x).iter().map(|v| v.to_bits()).collect();
            for threads in [1usize, 2, 4, 7] {
                let got: Vec<u32> = gram_par(&x, threads).iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, want, "threads {threads}");
            }
        });
    }

    #[test]
    fn gram_nonfinite_rows_fall_back_and_still_match_reference() {
        // a NaN/inf stored value makes ±0.0 products NaN — the fast path
        // must refuse such rows and take the all-pairs loop, which the
        // reference runs unconditionally
        let mut dense = vec![1.0f32; 12]; // 4 rows × k=3, fully dense
        dense[1] = f32::NAN;
        dense[7] = f32::INFINITY;
        let x = Csr::from_dense(4, 3, &dense);
        let got: Vec<u32> = gram(&x).iter().map(|v| v.to_bits()).collect();
        let want: Vec<u32> = reference::gram_ref(&x).iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn tr_cross_touched_clear_bit_matches_reference() {
        prop::check("tr-cross-vs-ref", 2500, 48, |rng: &mut Rng| {
            let n = rng.range(1, 25);
            let m = rng.range(1, 25);
            let k = rng.range(1, 8);
            let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.4));
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.4));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.6));
            for chunk in [1usize, 3, n + 5] {
                let got = tr_cross_source(&a, &u, &v, chunk);
                let want = reference::tr_cross_source_ref(&a, &u, &v, chunk);
                assert_eq!(got.to_bits(), want.to_bits(), "chunk {chunk}");
            }
        });
    }

    #[test]
    fn tr_cross_source_chunking_is_bit_identical() {
        prop::check("tr-cross-chunked", 2200, 48, |rng: &mut Rng| {
            let n = rng.range(1, 25);
            let m = rng.range(1, 25);
            let k = rng.range(1, 5);
            let a = Csr::from_dense(n, m, &prop::gen_sparse_dense(rng, n, m, 0.4));
            let u = Csr::from_dense(n, k, &prop::gen_sparse_dense(rng, n, k, 0.6));
            let v = Csr::from_dense(m, k, &prop::gen_sparse_dense(rng, m, k, 0.6));
            let want = tr_cross(&a, &u, &v);
            for chunk in [1usize, 3, 8, n + 5] {
                let got = tr_cross_source(&a, &u, &v, chunk);
                assert_eq!(got.to_bits(), want.to_bits(), "chunk {chunk}");
            }
        });
    }
}
