//! Compressed sparse row storage — the workhorse format.
//!
//! Factor matrices (`U`: terms×topics, `V`: docs×topics) and the data
//! matrix `A` all live in CSR; `A` additionally keeps a CSC twin (built
//! once) so both ALS half-products stream contiguously.

use super::coo::Coo;
use super::csc::Csc;
use crate::coordinator::pool;

/// One row range's filtered entries, produced by the parallel retain
/// passes: `(indices, values, fragment-local cumulative entry count per
/// row)`.
pub(crate) type RowFragment = (Vec<u32>, Vec<f32>, Vec<usize>);

#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// `indptr[r]..indptr[r+1]` indexes row r's entries. len = rows+1.
    pub indptr: Vec<usize>,
    /// Column index per entry, ascending within a row.
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Csr {
            rows,
            cols,
            indptr: vec![0; rows + 1],
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Build from a dense row-major slice, dropping exact zeros.
    pub fn from_dense(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        let mut coo = Coo::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                coo.push(r, c, data[r * cols + c]);
            }
        }
        coo.to_csr()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of entries that are exactly zero (the paper's "sparsity").
    pub fn sparsity(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 1.0;
        }
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// (column indices, values) of row r.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Point lookup by binary search within the row. O(log nnz_row).
    pub fn get(&self, r: usize, c: usize) -> f32 {
        let (idx, val) = self.row(r);
        match idx.binary_search(&(c as u32)) {
            Ok(pos) => val[pos],
            Err(_) => 0.0,
        }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (idx, val) = self.row(r);
            for (&c, &v) in idx.iter().zip(val) {
                out[r * self.cols + c as usize] = v;
            }
        }
        out
    }

    /// Transpose via counting sort — O(nnz + rows + cols).
    pub fn transpose(&self) -> Csr {
        let mut indptr = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            indptr[c as usize + 1] += 1;
        }
        for c in 0..self.cols {
            indptr[c + 1] += indptr[c];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0f32; self.nnz()];
        for r in 0..self.rows {
            let (idx, val) = self.row(r);
            for (&c, &v) in idx.iter().zip(val) {
                let pos = cursor[c as usize];
                indices[pos] = r as u32;
                values[pos] = v;
                cursor[c as usize] += 1;
            }
        }
        Csr {
            rows: self.cols,
            cols: self.rows,
            indptr,
            indices,
            values,
        }
    }

    /// Reinterpret the transpose as CSC of the same logical matrix.
    pub fn to_csc(&self) -> Csc {
        let t = self.transpose();
        Csc {
            rows: self.rows,
            cols: self.cols,
            indptr: t.indptr,
            indices: t.indices,
            values: t.values,
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// ||self - other||_F without materializing the difference.
    pub fn fro_diff(&self, other: &Csr) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut acc = 0.0f64;
        for r in 0..self.rows {
            let (ia, va) = self.row(r);
            let (ib, vb) = other.row(r);
            let (mut p, mut q) = (0usize, 0usize);
            while p < ia.len() || q < ib.len() {
                let d = if q >= ib.len() || (p < ia.len() && ia[p] < ib[q]) {
                    let d = va[p] as f64;
                    p += 1;
                    d
                } else if p >= ia.len() || ib[q] < ia[p] {
                    let d = -(vb[q] as f64);
                    q += 1;
                    d
                } else {
                    let d = va[p] as f64 - vb[q] as f64;
                    p += 1;
                    q += 1;
                    d
                };
                acc += d * d;
            }
        }
        acc.sqrt()
    }

    /// Count nonzeros in each column.
    pub fn col_nnz(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.cols];
        for &c in &self.indices {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Keep only entries satisfying the predicate (in-place refilter).
    pub fn retain(&mut self, mut keep: impl FnMut(usize, u32, f32) -> bool) {
        let mut w = 0usize;
        let mut new_indptr = vec![0usize; self.rows + 1];
        for r in 0..self.rows {
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            for p in lo..hi {
                if keep(r, self.indices[p], self.values[p]) {
                    self.indices[w] = self.indices[p];
                    self.values[w] = self.values[p];
                    w += 1;
                }
            }
            new_indptr[r + 1] = w;
        }
        self.indices.truncate(w);
        self.values.truncate(w);
        self.indptr = new_indptr;
    }

    /// Parallel [`Csr::retain`] for *row-local* predicates: `keep` must
    /// be a pure function of `(row, col, value)` (no scan-order state —
    /// order-sensitive filters like the top-t `Exact` tie budget split
    /// their state per range first; see
    /// [`topk`](super::topk::enforce_top_t_per_column_par)). Rows are
    /// partitioned into contiguous ranges, each range filtered
    /// independently, and the fragments concatenate in range order —
    /// bit-identical to the serial scan at any thread count.
    pub fn retain_par(
        &mut self,
        threads: usize,
        keep: impl Fn(usize, u32, f32) -> bool + Sync,
    ) {
        if threads <= 1 || self.rows < 2 {
            return self.retain(keep);
        }
        let ranges = pool::split_ranges(self.rows, threads);
        let shared: &Csr = self;
        let frags = pool::scoped_map_ranges(threads, &ranges, |lo, hi| {
            let mut indices = Vec::new();
            let mut values = Vec::new();
            let mut row_ends = Vec::with_capacity(hi - lo);
            for r in lo..hi {
                let (idx, val) = shared.row(r);
                for (&c, &v) in idx.iter().zip(val) {
                    if keep(r, c, v) {
                        indices.push(c);
                        values.push(v);
                    }
                }
                row_ends.push(indices.len());
            }
            (indices, values, row_ends)
        });
        self.replace_from_fragments(frags);
    }

    /// Rebuild storage from per-row-range fragments `(indices, values,
    /// row_ends)` covering every row in ascending order (`row_ends` is
    /// the fragment-local cumulative entry count per row). Shared by the
    /// parallel retain passes.
    pub(crate) fn replace_from_fragments(&mut self, frags: Vec<RowFragment>) {
        let total: usize = frags.iter().map(|f| f.0.len()).sum();
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(total);
        let mut values = Vec::with_capacity(total);
        let mut row = 0usize;
        for (fi, fv, ends) in frags {
            let base = indices.len();
            indices.extend_from_slice(&fi);
            values.extend_from_slice(&fv);
            for e in ends {
                row += 1;
                indptr[row] = base + e;
            }
        }
        debug_assert_eq!(row, self.rows, "fragments must cover every row");
        self.indptr = indptr;
        self.indices = indices;
        self.values = values;
    }

    /// Append the raw little-endian serialization of this matrix:
    /// `rows u64 · cols u64 · nnz u64 · indptr (rows+1 × u64) ·
    /// indices (nnz × u32) · values (nnz × f32 bit patterns)`.
    /// Exact inverse of [`Csr::read_bytes`]; value bits round-trip
    /// unchanged, so a deserialized factor is bit-identical.
    pub fn write_bytes(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&(self.rows as u64).to_le_bytes());
        out.extend_from_slice(&(self.cols as u64).to_le_bytes());
        out.extend_from_slice(&(self.nnz() as u64).to_le_bytes());
        for &p in &self.indptr {
            out.extend_from_slice(&(p as u64).to_le_bytes());
        }
        for &i in &self.indices {
            out.extend_from_slice(&i.to_le_bytes());
        }
        for &v in &self.values {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }

    /// Parse a matrix previously written by [`Csr::write_bytes`], advancing
    /// `pos` past the consumed bytes. Bounds are checked before any
    /// allocation and the result is structurally validated, so corrupt or
    /// truncated input yields an error, never a panic or an OOM.
    pub fn read_bytes(bytes: &[u8], pos: &mut usize) -> Result<Csr, String> {
        fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8], String> {
            let end = pos
                .checked_add(n)
                .filter(|&e| e <= bytes.len())
                .ok_or_else(|| format!("truncated CSR: need {n} bytes at offset {pos}"))?;
            let s = &bytes[*pos..end];
            *pos = end;
            Ok(s)
        }
        fn u64_at(bytes: &[u8], pos: &mut usize) -> Result<u64, String> {
            Ok(u64::from_le_bytes(take(bytes, pos, 8)?.try_into().unwrap()))
        }
        let rows = u64_at(bytes, pos)? as usize;
        let cols = u64_at(bytes, pos)? as usize;
        let nnz = u64_at(bytes, pos)? as usize;
        // reject impossible sizes before allocating
        let need = rows
            .checked_add(1)
            .and_then(|r| r.checked_mul(8))
            .and_then(|a| nnz.checked_mul(8).and_then(|b| a.checked_add(b)))
            .ok_or_else(|| "CSR header claims absurd sizes".to_string())?;
        if bytes.len() - *pos < need {
            return Err(format!(
                "truncated CSR: header claims {need} payload bytes, {} remain",
                bytes.len() - *pos
            ));
        }
        let mut indptr = Vec::with_capacity(rows + 1);
        for _ in 0..rows + 1 {
            indptr.push(u64_at(bytes, pos)? as usize);
        }
        let mut indices = Vec::with_capacity(nnz);
        for chunk in take(bytes, pos, nnz * 4)?.chunks_exact(4) {
            indices.push(u32::from_le_bytes(chunk.try_into().unwrap()));
        }
        let mut values = Vec::with_capacity(nnz);
        for chunk in take(bytes, pos, nnz * 4)?.chunks_exact(4) {
            values.push(f32::from_bits(u32::from_le_bytes(chunk.try_into().unwrap())));
        }
        let m = Csr {
            rows,
            cols,
            indptr,
            indices,
            values,
        };
        m.validate().map_err(|e| format!("corrupt CSR: {e}"))?;
        Ok(m)
    }

    /// Structural validation — used by property tests and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.rows + 1 {
            return Err("indptr length".into());
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() != self.values.len() {
            return Err("indptr bounds".into());
        }
        if self.indices.len() != self.values.len() {
            return Err("indices/values length mismatch".into());
        }
        for r in 0..self.rows {
            if self.indptr[r] > self.indptr[r + 1] {
                return Err(format!("indptr not monotone at row {r}"));
            }
            let (idx, _) = self.row(r);
            for w in idx.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} columns not strictly ascending"));
                }
            }
            if let Some(&last) = idx.last() {
                if last as usize >= self.cols {
                    return Err(format!("row {r} column out of bounds"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [1 0 2]
        // [0 0 0]
        // [3 4 0]
        Csr::from_dense(3, 3, &[1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0, 0.0])
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        assert_eq!(
            m.to_dense(),
            vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0, 0.0]
        );
        m.validate().unwrap();
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
        m.transpose().validate().unwrap();
    }

    #[test]
    fn transpose_values() {
        let t = sample().transpose();
        assert_eq!(t.get(0, 2), 3.0);
        assert_eq!(t.get(2, 0), 2.0);
        assert_eq!(t.get(1, 1), 0.0);
    }

    #[test]
    fn sparsity_measure() {
        assert!((sample().sparsity() - (1.0 - 4.0 / 9.0)).abs() < 1e-12);
        assert_eq!(Csr::zeros(0, 0).sparsity(), 1.0);
    }

    #[test]
    fn fro_norms() {
        let m = sample();
        let want = (1.0f64 + 4.0 + 9.0 + 16.0).sqrt();
        assert!((m.fro_norm() - want).abs() < 1e-6);
        assert!(m.fro_diff(&m) < 1e-12);
        let z = Csr::zeros(3, 3);
        assert!((m.fro_diff(&z) - want).abs() < 1e-6);
    }

    #[test]
    fn fro_diff_disjoint_patterns() {
        let a = Csr::from_dense(1, 3, &[1.0, 0.0, 0.0]);
        let b = Csr::from_dense(1, 3, &[0.0, 2.0, 0.0]);
        assert!((a.fro_diff(&b) - (5.0f64).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn retain_filters() {
        let mut m = sample();
        m.retain(|_r, _c, v| v > 2.5);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(2, 0), 3.0);
        assert_eq!(m.get(2, 1), 4.0);
        assert_eq!(m.get(0, 0), 0.0);
        m.validate().unwrap();
    }

    #[test]
    fn col_nnz_counts() {
        assert_eq!(sample().col_nnz(), vec![2, 1, 1]);
    }

    #[test]
    fn retain_par_matches_serial_at_every_thread_count() {
        use crate::util::prop;
        use crate::util::rng::Rng;
        prop::check("retain-par-vs-serial", 0x8e7a, 48, |rng: &mut Rng| {
            let rows = rng.range(1, 30);
            let cols = rng.range(1, 8);
            let m = Csr::from_dense(rows, cols, &prop::gen_sparse_dense(rng, rows, cols, 0.5));
            let cut = rng.f32();
            let keep = |r: usize, c: u32, v: f32| v > cut || (r + c as usize) % 3 == 0;
            let mut serial = m.clone();
            serial.retain(keep);
            for threads in [1usize, 2, 4, 7] {
                let mut par = m.clone();
                par.retain_par(threads, keep);
                assert_eq!(par, serial, "threads {threads}");
                par.validate().unwrap();
            }
        });
    }

    #[test]
    fn validate_catches_corruption() {
        let mut m = sample();
        m.indices[0] = 99;
        assert!(m.validate().is_err());
    }

    #[test]
    fn byte_roundtrip_is_bit_identical() {
        let m = sample();
        let mut bytes = Vec::new();
        m.write_bytes(&mut bytes);
        let mut pos = 0;
        let back = Csr::read_bytes(&bytes, &mut pos).unwrap();
        assert_eq!(back, m);
        assert_eq!(pos, bytes.len());
        // empty matrices round-trip too
        let z = Csr::zeros(4, 7);
        let mut bytes = Vec::new();
        z.write_bytes(&mut bytes);
        let mut pos = 0;
        assert_eq!(Csr::read_bytes(&bytes, &mut pos).unwrap(), z);
    }

    #[test]
    fn byte_roundtrip_preserves_value_bits() {
        // subnormals and negative zero must survive exactly
        let m = Csr::from_dense(1, 3, &[f32::MIN_POSITIVE / 2.0, -0.0, 1.5]);
        let mut bytes = Vec::new();
        m.write_bytes(&mut bytes);
        let back = Csr::read_bytes(&bytes, &mut 0).unwrap();
        assert_eq!(
            back.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            m.values.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn read_bytes_rejects_truncation_and_corruption() {
        let m = sample();
        let mut bytes = Vec::new();
        m.write_bytes(&mut bytes);
        // every strict prefix fails cleanly
        for cut in 0..bytes.len() {
            assert!(
                Csr::read_bytes(&bytes[..cut], &mut 0).is_err(),
                "prefix of {cut} bytes parsed"
            );
        }
        // absurd header sizes are rejected before allocation
        let mut huge = Vec::new();
        huge.extend_from_slice(&u64::MAX.to_le_bytes());
        huge.extend_from_slice(&u64::MAX.to_le_bytes());
        huge.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(Csr::read_bytes(&huge, &mut 0).is_err());
        // structural corruption (column out of bounds) is caught
        let mut bad = bytes.clone();
        let idx_start = 8 * 3 + 8 * 4; // header + indptr
        bad[idx_start] = 0xff;
        assert!(Csr::read_bytes(&bad, &mut 0).is_err());
    }
}
