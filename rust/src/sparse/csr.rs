//! Compressed sparse row storage — the workhorse format.
//!
//! Factor matrices (`U`: terms×topics, `V`: docs×topics) and the data
//! matrix `A` all live in CSR; `A` additionally keeps a CSC twin (built
//! once) so both ALS half-products stream contiguously.

use super::coo::Coo;
use super::csc::Csc;

#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// `indptr[r]..indptr[r+1]` indexes row r's entries. len = rows+1.
    pub indptr: Vec<usize>,
    /// Column index per entry, ascending within a row.
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Csr {
            rows,
            cols,
            indptr: vec![0; rows + 1],
            indices: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Build from a dense row-major slice, dropping exact zeros.
    pub fn from_dense(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        let mut coo = Coo::new(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                coo.push(r, c, data[r * cols + c]);
            }
        }
        coo.to_csr()
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of entries that are exactly zero (the paper's "sparsity").
    pub fn sparsity(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 1.0;
        }
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// (column indices, values) of row r.
    #[inline]
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Point lookup by binary search within the row. O(log nnz_row).
    pub fn get(&self, r: usize, c: usize) -> f32 {
        let (idx, val) = self.row(r);
        match idx.binary_search(&(c as u32)) {
            Ok(pos) => val[pos],
            Err(_) => 0.0,
        }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let (idx, val) = self.row(r);
            for (&c, &v) in idx.iter().zip(val) {
                out[r * self.cols + c as usize] = v;
            }
        }
        out
    }

    /// Transpose via counting sort — O(nnz + rows + cols).
    pub fn transpose(&self) -> Csr {
        let mut indptr = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            indptr[c as usize + 1] += 1;
        }
        for c in 0..self.cols {
            indptr[c + 1] += indptr[c];
        }
        let mut cursor = indptr.clone();
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0.0f32; self.nnz()];
        for r in 0..self.rows {
            let (idx, val) = self.row(r);
            for (&c, &v) in idx.iter().zip(val) {
                let pos = cursor[c as usize];
                indices[pos] = r as u32;
                values[pos] = v;
                cursor[c as usize] += 1;
            }
        }
        Csr {
            rows: self.cols,
            cols: self.rows,
            indptr,
            indices,
            values,
        }
    }

    /// Reinterpret the transpose as CSC of the same logical matrix.
    pub fn to_csc(&self) -> Csc {
        let t = self.transpose();
        Csc {
            rows: self.rows,
            cols: self.cols,
            indptr: t.indptr,
            indices: t.indices,
            values: t.values,
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Squared Frobenius norm.
    pub fn fro_norm_sq(&self) -> f64 {
        self.values.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    /// ||self - other||_F without materializing the difference.
    pub fn fro_diff(&self, other: &Csr) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut acc = 0.0f64;
        for r in 0..self.rows {
            let (ia, va) = self.row(r);
            let (ib, vb) = other.row(r);
            let (mut p, mut q) = (0usize, 0usize);
            while p < ia.len() || q < ib.len() {
                let d = if q >= ib.len() || (p < ia.len() && ia[p] < ib[q]) {
                    let d = va[p] as f64;
                    p += 1;
                    d
                } else if p >= ia.len() || ib[q] < ia[p] {
                    let d = -(vb[q] as f64);
                    q += 1;
                    d
                } else {
                    let d = va[p] as f64 - vb[q] as f64;
                    p += 1;
                    q += 1;
                    d
                };
                acc += d * d;
            }
        }
        acc.sqrt()
    }

    /// Count nonzeros in each column.
    pub fn col_nnz(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.cols];
        for &c in &self.indices {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Keep only entries satisfying the predicate (in-place refilter).
    pub fn retain(&mut self, mut keep: impl FnMut(usize, u32, f32) -> bool) {
        let mut w = 0usize;
        let mut new_indptr = vec![0usize; self.rows + 1];
        for r in 0..self.rows {
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            for p in lo..hi {
                if keep(r, self.indices[p], self.values[p]) {
                    self.indices[w] = self.indices[p];
                    self.values[w] = self.values[p];
                    w += 1;
                }
            }
            new_indptr[r + 1] = w;
        }
        self.indices.truncate(w);
        self.values.truncate(w);
        self.indptr = new_indptr;
    }

    /// Structural validation — used by property tests and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.rows + 1 {
            return Err("indptr length".into());
        }
        if self.indptr[0] != 0 || *self.indptr.last().unwrap() != self.values.len() {
            return Err("indptr bounds".into());
        }
        if self.indices.len() != self.values.len() {
            return Err("indices/values length mismatch".into());
        }
        for r in 0..self.rows {
            if self.indptr[r] > self.indptr[r + 1] {
                return Err(format!("indptr not monotone at row {r}"));
            }
            let (idx, _) = self.row(r);
            for w in idx.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("row {r} columns not strictly ascending"));
                }
            }
            if let Some(&last) = idx.last() {
                if last as usize >= self.cols {
                    return Err(format!("row {r} column out of bounds"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [1 0 2]
        // [0 0 0]
        // [3 4 0]
        Csr::from_dense(3, 3, &[1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0, 0.0])
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        assert_eq!(
            m.to_dense(),
            vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 3.0, 4.0, 0.0]
        );
        m.validate().unwrap();
    }

    #[test]
    fn transpose_involution() {
        let m = sample();
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
        m.transpose().validate().unwrap();
    }

    #[test]
    fn transpose_values() {
        let t = sample().transpose();
        assert_eq!(t.get(0, 2), 3.0);
        assert_eq!(t.get(2, 0), 2.0);
        assert_eq!(t.get(1, 1), 0.0);
    }

    #[test]
    fn sparsity_measure() {
        assert!((sample().sparsity() - (1.0 - 4.0 / 9.0)).abs() < 1e-12);
        assert_eq!(Csr::zeros(0, 0).sparsity(), 1.0);
    }

    #[test]
    fn fro_norms() {
        let m = sample();
        let want = (1.0f64 + 4.0 + 9.0 + 16.0).sqrt();
        assert!((m.fro_norm() - want).abs() < 1e-6);
        assert!(m.fro_diff(&m) < 1e-12);
        let z = Csr::zeros(3, 3);
        assert!((m.fro_diff(&z) - want).abs() < 1e-6);
    }

    #[test]
    fn fro_diff_disjoint_patterns() {
        let a = Csr::from_dense(1, 3, &[1.0, 0.0, 0.0]);
        let b = Csr::from_dense(1, 3, &[0.0, 2.0, 0.0]);
        assert!((a.fro_diff(&b) - (5.0f64).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn retain_filters() {
        let mut m = sample();
        m.retain(|_r, _c, v| v > 2.5);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(2, 0), 3.0);
        assert_eq!(m.get(2, 1), 4.0);
        assert_eq!(m.get(0, 0), 0.0);
        m.validate().unwrap();
    }

    #[test]
    fn col_nnz_counts() {
        assert_eq!(sample().col_nnz(), vec![2, 1, 1]);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut m = sample();
        m.indices[0] = 99;
        assert!(m.validate().is_err());
    }
}
