//! [`RowBlock`]: sparse row support with dense `k`-wide rows.
//!
//! This is the natural shape of an ALS half-step intermediate:
//! `B = Aᵀ U` has a nonzero row for every document that shares a term with
//! the current factor, and the subsequent `B · (UᵀU)⁻¹` fills each active
//! row densely (k ≤ 64). Keeping inactive rows unmaterialized is exactly
//! the paper's "intermediates stay sparse" memory win; the active rows
//! being dense keeps the small solve vectorizable.

use super::csr::Csr;
use super::ops::{ACC_LANES, GRAM_CHUNK_ROWS};
use crate::coordinator::pool;

#[derive(Clone, Debug, PartialEq)]
pub struct RowBlock {
    pub rows: usize,
    pub k: usize,
    /// Active row ids, strictly ascending.
    pub row_ids: Vec<u32>,
    /// Dense row data, `row_ids.len() * k`, row-major.
    pub data: Vec<f32>,
}

impl RowBlock {
    pub fn new(rows: usize, k: usize) -> Self {
        RowBlock {
            rows,
            k,
            row_ids: Vec::new(),
            data: Vec::new(),
        }
    }

    #[inline]
    pub fn active_rows(&self) -> usize {
        self.row_ids.len()
    }

    /// Stored scalar count — what the memory tracker charges for this
    /// intermediate (active rows × k, regardless of exact zeros inside).
    #[inline]
    pub fn stored_len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn row_data(&self, slot: usize) -> &[f32] {
        &self.data[slot * self.k..(slot + 1) * self.k]
    }

    #[inline]
    pub fn row_data_mut(&mut self, slot: usize) -> &mut [f32] {
        let k = self.k;
        &mut self.data[slot * k..(slot + 1) * k]
    }

    /// Drop every active row, keeping the allocations. The blocked
    /// half-step pipeline reuses one scratch RowBlock per worker across
    /// row blocks (see [`crate::coordinator::pool::scoped_map_ranges_with`]),
    /// so per-worker candidate memory stays at its high-water block, never
    /// the whole matrix.
    pub fn clear(&mut self) {
        self.row_ids.clear();
        self.data.clear();
    }

    pub fn push_row(&mut self, row_id: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), self.k);
        debug_assert!(
            self.row_ids.last().map_or(true, |&last| (last as usize) < row_id),
            "rows must be pushed in ascending order"
        );
        self.row_ids.push(row_id as u32);
        self.data.extend_from_slice(row);
    }

    /// In-place right-multiplication by a dense (k, k) row-major matrix:
    /// each active row r becomes `r · m`. This is the `B · G⁻¹` solve step.
    pub fn matmul_small(&mut self, m: &[f32]) {
        self.matmul_small_par(m, 1);
    }

    /// Parallel [`Self::matmul_small`]: contiguous slot ranges across
    /// `threads` scoped workers. Each row's product is computed with the
    /// same instruction sequence on any worker, so the result is
    /// bit-identical to serial at every thread count.
    ///
    /// The product accumulates through [`ACC_LANES`]-wide register
    /// partials over contiguous strides of `m` (same restructure as the
    /// SpMM dense path — see [`super::ops`]). Per output column the
    /// inputs are still summed in ascending-`i` order, so the bits are
    /// unchanged; the `ri != 0.0` skip is semantic, not a perf gate — a
    /// degenerate Gram inverse can carry NaN rows that an explicit-zero
    /// input row must not touch (`0.0 · NaN = NaN`).
    pub fn matmul_small_par(&mut self, m: &[f32], threads: usize) {
        let k = self.k;
        assert_eq!(m.len(), k * k);
        if k == 0 {
            return;
        }
        pool::scoped_partition_map_mut(threads, &mut self.data, k, |_, piece| {
            let mut scratch = vec![0.0f32; k];
            for row in piece.chunks_exact_mut(k) {
                let mut start = 0usize;
                while start + ACC_LANES <= k {
                    let mut lanes = [0.0f32; ACC_LANES];
                    for (i, &ri) in row.iter().enumerate() {
                        if ri != 0.0 {
                            let mrow = &m[i * k + start..i * k + start + ACC_LANES];
                            for (lane, &mv) in lanes.iter_mut().zip(mrow) {
                                *lane += ri * mv;
                            }
                        }
                    }
                    scratch[start..start + ACC_LANES].copy_from_slice(&lanes);
                    start += ACC_LANES;
                }
                if start < k {
                    let tail = k - start;
                    let mut lanes = [0.0f32; ACC_LANES];
                    for (i, &ri) in row.iter().enumerate() {
                        if ri != 0.0 {
                            let mrow = &m[i * k + start..i * k + k];
                            for (lane, &mv) in lanes.iter_mut().zip(mrow) {
                                *lane += ri * mv;
                            }
                        }
                    }
                    scratch[start..].copy_from_slice(&lanes[..tail]);
                }
                row.copy_from_slice(&scratch);
            }
        });
    }

    /// Project to the nonnegative orthant (negatives → 0) in place.
    pub fn project_nonneg(&mut self) {
        self.project_nonneg_par(1);
    }

    /// Parallel [`Self::project_nonneg`] — elementwise, so trivially
    /// bit-identical to serial at every thread count.
    pub fn project_nonneg_par(&mut self, threads: usize) {
        pool::scoped_partition_map_mut(threads, &mut self.data, 1, |_, piece| {
            for v in piece {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        });
    }

    /// Gram matrix Xᵀ X of the logical (rows, k) matrix, dense (k, k).
    /// Same fixed-chunk accumulation as [`Self::gram_par`], so the two
    /// agree bit-for-bit.
    pub fn gram(&self) -> Vec<f32> {
        self.gram_par(1)
    }

    /// Parallel gram: fixed-width slot chunks, f64 partial triangles
    /// merged in ascending chunk order (see the determinism contract in
    /// [`crate::coordinator::pool`]).
    pub fn gram_par(&self, threads: usize) -> Vec<f32> {
        let k = self.k;
        let chunks = pool::fixed_chunks(self.active_rows(), GRAM_CHUNK_ROWS);
        let partials = pool::scoped_map_ranges(threads, &chunks, |lo, hi| {
            let mut g = vec![0.0f64; k * k];
            for slot in lo..hi {
                let row = self.row_data(slot);
                for i in 0..k {
                    let ri = row[i] as f64;
                    if ri != 0.0 {
                        for j in i..k {
                            g[i * k + j] += ri * row[j] as f64;
                        }
                    }
                }
            }
            g
        });
        let mut g = vec![0.0f64; k * k];
        for part in partials {
            for (acc, v) in g.iter_mut().zip(part) {
                *acc += v;
            }
        }
        for i in 0..k {
            for j in 0..i {
                g[i * k + j] = g[j * k + i];
            }
        }
        g.into_iter().map(|x| x as f32).collect()
    }

    /// Freeze into CSR, dropping exact zeros.
    pub fn to_csr(&self) -> Csr {
        let k = self.k;
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        let mut prev_row = 0usize;
        for (slot, &rid) in self.row_ids.iter().enumerate() {
            let rid = rid as usize;
            for r in prev_row..rid {
                indptr[r + 1] = values.len();
                let _ = r;
            }
            let row = self.row_data(slot);
            for (c, &v) in row.iter().enumerate() {
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr[rid + 1] = values.len();
            prev_row = rid + 1;
        }
        for r in prev_row..self.rows {
            indptr[r + 1] = values.len();
        }
        Csr {
            rows: self.rows,
            cols: k,
            indptr,
            indices,
            values,
        }
    }

    pub fn from_csr(m: &Csr) -> RowBlock {
        let mut rb = RowBlock::new(m.rows, m.cols);
        let mut scratch = vec![0.0f32; m.cols];
        for r in 0..m.rows {
            let (idx, val) = m.row(r);
            if idx.is_empty() {
                continue;
            }
            scratch.iter_mut().for_each(|x| *x = 0.0);
            for (&c, &v) in idx.iter().zip(val) {
                scratch[c as usize] = v;
            }
            rb.push_row(r, &scratch);
        }
        rb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RowBlock {
        let mut rb = RowBlock::new(5, 2);
        rb.push_row(1, &[1.0, -2.0]);
        rb.push_row(3, &[0.0, 4.0]);
        rb
    }

    #[test]
    fn push_and_freeze() {
        let m = sample().to_csr();
        assert_eq!(m.rows, 5);
        assert_eq!(m.cols, 2);
        assert_eq!(m.get(1, 0), 1.0);
        assert_eq!(m.get(1, 1), -2.0);
        assert_eq!(m.get(3, 1), 4.0);
        assert_eq!(m.nnz(), 3);
        m.validate().unwrap();
    }

    #[test]
    fn csr_roundtrip() {
        let rb = sample();
        let rb2 = RowBlock::from_csr(&rb.to_csr());
        assert_eq!(rb2.row_ids, rb.row_ids);
        // -2.0 survives; the explicit 0.0 in slot 1 is dropped then refilled
        assert_eq!(rb2.to_csr(), rb.to_csr());
    }

    #[test]
    fn project_nonneg() {
        let mut rb = sample();
        rb.project_nonneg();
        assert!(rb.data.iter().all(|&v| v >= 0.0));
        assert_eq!(rb.row_data(0), &[1.0, 0.0]);
    }

    #[test]
    fn matmul_small_identity() {
        let mut rb = sample();
        let before = rb.data.clone();
        rb.matmul_small(&[1.0, 0.0, 0.0, 1.0]);
        assert_eq!(rb.data, before);
    }

    #[test]
    fn matmul_small_values() {
        let mut rb = RowBlock::new(2, 2);
        rb.push_row(0, &[1.0, 2.0]);
        // m = [[0, 1], [1, 0]] swaps coordinates
        rb.matmul_small(&[0.0, 1.0, 1.0, 0.0]);
        assert_eq!(rb.row_data(0), &[2.0, 1.0]);
    }

    #[test]
    fn gram_matches_dense() {
        let rb = sample();
        let g = rb.gram();
        // X = [[1,-2],[0,4]] => XtX = [[1,-2],[-2,20]]
        assert_eq!(g, vec![1.0, -2.0, -2.0, 20.0]);
    }

    #[test]
    fn stored_len_counts_active_rows() {
        assert_eq!(sample().stored_len(), 4); // 2 active rows × k=2
    }

    #[test]
    fn clear_empties_but_keeps_capacity() {
        let mut rb = sample();
        let cap = rb.data.capacity();
        rb.clear();
        assert_eq!(rb.active_rows(), 0);
        assert_eq!(rb.stored_len(), 0);
        assert!(rb.data.capacity() >= cap);
        // refilling from row 0 is legal after a clear
        rb.push_row(0, &[9.0, 9.0]);
        assert_eq!(rb.row_data(0), &[9.0, 9.0]);
    }

    #[test]
    fn parallel_ops_bit_identical_to_serial() {
        use crate::util::prop;
        use crate::util::rng::Rng;
        prop::check("rowblock-par-vs-serial", 1300, 24, |rng: &mut Rng| {
            let rows = rng.range(1, 40);
            let k = rng.range(1, 6);
            let threads = rng.range(1, 8);
            let data = prop::gen_sparse_dense(rng, rows, k, 0.5);
            let base = RowBlock::from_csr(&Csr::from_dense(rows, k, &data));
            let m: Vec<f32> = (0..k * k).map(|_| rng.normal() as f32).collect();

            let mut serial = base.clone();
            serial.matmul_small(&m);
            serial.project_nonneg();
            let mut par = base.clone();
            par.matmul_small_par(&m, threads);
            par.project_nonneg_par(threads);
            assert_eq!(serial, par, "threads {threads}");
            assert_eq!(base.gram(), base.gram_par(threads));
        });
    }
}
