//! Sparse-matrix substrate: COO / CSR / CSC storage, products, norms and
//! the top-t selection primitives that implement the paper's enforced
//! sparsity.
//!
//! The paper's experiments run on MATLAB's sparse format (CSC); we provide
//! CSR and CSC (the term-document matrix is kept in both, built once, so
//! both `A·V` and `Aᵀ·U` stream through contiguous memory) plus
//! [`rowblock::RowBlock`], the natural shape of an ALS half-step
//! intermediate: sparse row support with dense `k`-wide rows.

pub mod coo;
pub mod csc;
pub mod csr;
pub mod ops;
pub mod rowblock;
pub mod source;
pub mod topk;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use rowblock::RowBlock;
pub use source::{RowCursor, RowSource, RowsRef};
pub use topk::TieMode;
