//! The crate's unified error surface.
//!
//! Every failure the `esnmf` binary can hit funnels into one public
//! [`EsnmfError`] enum, so the CLI boundary (`main.rs`) maps *categories*
//! of failure to stable exit codes instead of printing whatever ad-hoc
//! string a call site happened to format:
//!
//! | category | variants | exit code |
//! |---|---|---|
//! | caller mistake | [`EsnmfError::Usage`], [`EsnmfError::Config`] | 2 |
//! | bad data at rest / on the wire | [`EsnmfError::Snapshot`], [`EsnmfError::Store`], [`EsnmfError::Wire`] | 3 |
//! | protocol violation between live processes | [`EsnmfError::Protocol`] | 4 |
//! | everything else | [`EsnmfError::Io`], [`EsnmfError::Other`] | 1 |
//!
//! The typed sub-errors ([`SnapshotError`], [`StoreError`], [`WireError`])
//! convert in via `From`, so `?` works unannotated through the CLI and
//! the distributed plane. `anyhow`-producing internals convert through
//! [`EsnmfError::Other`] at the boundary — the string is kept, the
//! category information simply is not claimed where none exists.

use std::fmt;

use crate::io::wire::WireError;
use crate::io::{SnapshotError, StoreError};

/// Everything that can fail across the crate's public surface.
#[derive(Debug)]
pub enum EsnmfError {
    /// Malformed command line (unknown flag, missing argument, bad value).
    Usage(String),
    /// A syntactically valid but unusable configuration (conflicting
    /// flags, a knob out of range, a file-config key with a bad value).
    Config(String),
    /// A `.esnmf` model snapshot failed to load or validate.
    Snapshot(SnapshotError),
    /// A `.estdm` corpus store failed to open, verify, or read.
    Store(StoreError),
    /// A wire payload (worker frame, snapshot/store section) failed to
    /// decode.
    Wire(WireError),
    /// A live peer broke the protocol contract: wrong handshake, digest
    /// mismatch between coordinator and worker, an unexpected reply type,
    /// or a worker-reported compute refusal.
    Protocol(String),
    /// Operating-system I/O failure outside the typed formats.
    Io(std::io::Error),
    /// Uncategorized failure (the `anyhow` boundary).
    Other(String),
    /// A wrapped error with a "what were we doing" prefix. Keeps the
    /// inner category (and exit code) — context never reclassifies.
    Context {
        what: String,
        source: Box<EsnmfError>,
    },
}

impl EsnmfError {
    /// Stable process exit code for this failure category (see the
    /// module docs table).
    pub fn exit_code(&self) -> i32 {
        match self {
            EsnmfError::Usage(_) | EsnmfError::Config(_) => 2,
            EsnmfError::Snapshot(_) | EsnmfError::Store(_) | EsnmfError::Wire(_) => 3,
            EsnmfError::Protocol(_) => 4,
            EsnmfError::Io(_) | EsnmfError::Other(_) => 1,
            EsnmfError::Context { source, .. } => source.exit_code(),
        }
    }

    /// Wrap `self` with a "what were we doing" prefix (shown as
    /// `what: inner`), preserving the category and exit code.
    pub fn context(self, what: impl fmt::Display) -> Self {
        EsnmfError::Context {
            what: what.to_string(),
            source: Box::new(self),
        }
    }

    /// Shorthand for a [`EsnmfError::Usage`] from any displayable.
    pub fn usage(msg: impl fmt::Display) -> Self {
        EsnmfError::Usage(msg.to_string())
    }

    /// Shorthand for a [`EsnmfError::Config`] from any displayable.
    pub fn config(msg: impl fmt::Display) -> Self {
        EsnmfError::Config(msg.to_string())
    }

    /// Shorthand for a [`EsnmfError::Protocol`] from any displayable.
    pub fn protocol(msg: impl fmt::Display) -> Self {
        EsnmfError::Protocol(msg.to_string())
    }
}

impl fmt::Display for EsnmfError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EsnmfError::Usage(msg) => write!(f, "{msg}"),
            EsnmfError::Config(msg) => write!(f, "{msg}"),
            EsnmfError::Snapshot(e) => write!(f, "{e}"),
            EsnmfError::Store(e) => write!(f, "{e}"),
            EsnmfError::Wire(e) => write!(f, "wire: {e}"),
            EsnmfError::Protocol(msg) => write!(f, "protocol: {msg}"),
            EsnmfError::Io(e) => write!(f, "i/o: {e}"),
            EsnmfError::Other(msg) => write!(f, "{msg}"),
            EsnmfError::Context { what, source } => write!(f, "{what}: {source}"),
        }
    }
}

impl std::error::Error for EsnmfError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            EsnmfError::Snapshot(e) => Some(e),
            EsnmfError::Store(e) => Some(e),
            EsnmfError::Io(e) => Some(e),
            EsnmfError::Context { source, .. } => Some(source.as_ref()),
            _ => None,
        }
    }
}

impl From<SnapshotError> for EsnmfError {
    fn from(e: SnapshotError) -> Self {
        EsnmfError::Snapshot(e)
    }
}

impl From<StoreError> for EsnmfError {
    fn from(e: StoreError) -> Self {
        EsnmfError::Store(e)
    }
}

impl From<WireError> for EsnmfError {
    fn from(e: WireError) -> Self {
        EsnmfError::Wire(e)
    }
}

impl From<std::io::Error> for EsnmfError {
    fn from(e: std::io::Error) -> Self {
        EsnmfError::Io(e)
    }
}

impl From<anyhow::Error> for EsnmfError {
    fn from(e: anyhow::Error) -> Self {
        // `{:#}` keeps the whole context chain in one line, matching what
        // the pre-typed CLI boundary printed
        EsnmfError::Other(format!("{e:#}"))
    }
}

impl From<String> for EsnmfError {
    fn from(msg: String) -> Self {
        EsnmfError::Other(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_codes_partition_by_category() {
        assert_eq!(EsnmfError::usage("x").exit_code(), 2);
        assert_eq!(EsnmfError::config("x").exit_code(), 2);
        assert_eq!(EsnmfError::from(SnapshotError::BadMagic).exit_code(), 3);
        assert_eq!(
            EsnmfError::from(WireError::Corrupt("x".into())).exit_code(),
            3
        );
        assert_eq!(EsnmfError::protocol("x").exit_code(), 4);
        assert_eq!(EsnmfError::Other("x".into()).exit_code(), 1);
    }

    #[test]
    fn context_keeps_category_and_prefixes_display() {
        let e = EsnmfError::from(SnapshotError::BadMagic).context("loading snapshot nope.esnmf");
        assert_eq!(e.exit_code(), 3, "context must not reclassify");
        let s = e.to_string();
        assert!(s.starts_with("loading snapshot nope.esnmf: "), "{s}");
    }

    #[test]
    fn display_keeps_the_inner_message() {
        let e = EsnmfError::from(anyhow::anyhow!("root").context("outer"));
        let s = e.to_string();
        assert!(s.contains("outer") && s.contains("root"), "{s}");
        assert!(EsnmfError::usage("unknown option(s): --oops")
            .to_string()
            .contains("--oops"));
    }
}
