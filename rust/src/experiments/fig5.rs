//! Figure 5: accuracy when sparsity is enforced *during* each ALS
//! iteration (Algorithm 2) versus only once *after* ALS (Algorithm 1 +
//! post-hoc top-t) — pubmed-sim, k=5.

use super::{corpus_tdm, fmt, nnz_sweep, print_table, ExpConfig};
use crate::eval::mean_topic_accuracy;
use crate::nmf::{factorize, NmfOptions, SparsityMode};
use crate::sparse::{topk, TieMode};
use crate::util::json::{arr, num, obj, s, Json};
use crate::Result;

pub fn run(cfg: &ExpConfig) -> Result<Json> {
    let tdm = corpus_tdm("pubmed", cfg)?;
    let labels = tdm.doc_labels.clone().expect("pubmed-sim is labeled");
    let n_journals = tdm.label_names.len();
    let k = 5;
    let iters = cfg.iters(50);
    let points = if cfg.fast { 4 } else { 8 };
    let sweep = nnz_sweep(2 * k, tdm.n_docs() * k, points);

    // one dense run reused for every "after" point
    let dense = factorize(
        &tdm,
        &NmfOptions::new(k)
            .with_iters(iters)
            .with_seed(cfg.seed)
            .with_track_error(false),
    );

    let mut rows = Vec::new();
    let mut series = Vec::new();
    for &t in &sweep {
        // during (Algorithm 2)
        let during = factorize(
            &tdm,
            &NmfOptions::new(k)
                .with_iters(iters)
                .with_seed(cfg.seed)
                .with_sparsity(SparsityMode::both(t, t))
                .with_track_error(false),
        );
        let acc_during = mean_topic_accuracy(&during.v, &labels, n_journals);

        // after (Algorithm 1, then top-t once)
        let mut u_after = dense.u.clone();
        let mut v_after = dense.v.clone();
        topk::enforce_top_t_csr(&mut u_after, t, TieMode::KeepTies);
        topk::enforce_top_t_csr(&mut v_after, t, TieMode::KeepTies);
        let acc_after = mean_topic_accuracy(&v_after, &labels, n_journals);

        rows.push(vec![t.to_string(), fmt(acc_during), fmt(acc_after)]);
        series.push(obj(vec![
            ("nnz", num(t as f64)),
            ("acc_during", num(acc_during)),
            ("acc_after", num(acc_after)),
        ]));
    }

    print_table(
        &format!("Fig. 5 — pubmed-sim k={k}: enforce during ALS vs after ALS"),
        &["nnz", "acc(during ALS)", "acc(after ALS)"],
        &rows,
    );
    Ok(obj(vec![("experiment", s("fig5")), ("sweep", arr(series))]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Scale;

    #[test]
    fn fig5_during_at_least_as_accurate() {
        let cfg = ExpConfig {
            scale: Scale::Tiny,
            seed: 11,
            fast: true,
        };
        let out = run(&cfg).unwrap();
        let sweep = out.get("sweep").unwrap().as_arr().unwrap();
        // paper shape: "during" ≈ "after" (during typically ≥); demand the
        // mean not be clearly worse
        let (mut d_sum, mut a_sum) = (0.0, 0.0);
        for p in sweep {
            d_sum += p.get("acc_during").unwrap().as_f64().unwrap();
            a_sum += p.get("acc_after").unwrap().as_f64().unwrap();
        }
        assert!(
            d_sum >= a_sum - 0.1 * sweep.len() as f64,
            "during {d_sum} vs after {a_sum}"
        );
    }
}
