//! The experiment harness: one module per paper figure/table, shared by
//! the CLI (`esnmf experiment <id>`) and the `cargo bench` targets.
//!
//! Every experiment prints the paper-shaped rows to stdout and returns a
//! machine-readable [`Json`] blob (written to `results/` by the CLI).
//! DESIGN.md maps each id to the paper artifact and the expected shape.

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;

use crate::corpus::{self, Scale};
use crate::text::TermDocMatrix;
use crate::util::json::Json;
use crate::Result;
use anyhow::bail;

/// Experiment ids in paper order.
pub const ALL: &[&str] = &[
    "fig1", "fig2", "fig3", "table1", "fig4", "fig5", "fig6", "fig7", "fig8",
    "fig9",
];

/// Common knobs for every experiment.
#[derive(Clone, Copy, Debug)]
pub struct ExpConfig {
    pub scale: Scale,
    pub seed: u64,
    /// shrink sweeps/iterations for CI smoke runs
    pub fast: bool,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            scale: Scale::Small,
            seed: 42,
            fast: false,
        }
    }
}

impl ExpConfig {
    pub fn iters(&self, full: usize) -> usize {
        if self.fast {
            (full / 10).max(2)
        } else {
            full
        }
    }
}

/// Run one experiment by id.
pub fn run(id: &str, cfg: &ExpConfig) -> Result<Json> {
    match id {
        "fig1" => fig1::run(cfg),
        "fig2" => fig2::run(cfg),
        "fig3" => fig3::run(cfg),
        "table1" => fig7::run_table1(cfg),
        "fig4" => fig4::run(cfg),
        "fig5" => fig5::run(cfg),
        "fig6" => fig6::run(cfg),
        "fig7" => fig7::run(cfg),
        "fig8" => fig8::run(cfg),
        "fig9" => fig9::run(cfg),
        other => bail!("unknown experiment {other:?}; available: {ALL:?}"),
    }
}

/// Build the preset corpus used by an experiment.
pub fn corpus_tdm(name: &str, cfg: &ExpConfig) -> Result<TermDocMatrix> {
    let spec = match name {
        "reuters" => corpus::reuters_sim(cfg.scale),
        "wikipedia" => corpus::wikipedia_sim(cfg.scale),
        "pubmed" => corpus::pubmed_sim(cfg.scale),
        other => bail!("unknown corpus preset {other:?}"),
    };
    Ok(corpus::generate_tdm(&spec, cfg.seed))
}

/// A geometric sweep of nonzero budgets from `lo` up to `hi`
/// (inclusive-ish), `points` entries.
pub fn nnz_sweep(lo: usize, hi: usize, points: usize) -> Vec<usize> {
    assert!(lo >= 1 && hi >= lo && points >= 2);
    let ratio = (hi as f64 / lo as f64).powf(1.0 / (points - 1) as f64);
    let mut out: Vec<usize> = (0..points)
        .map(|i| (lo as f64 * ratio.powi(i as i32)).round() as usize)
        .collect();
    out.dedup();
    out
}

/// Print a markdown-ish table.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n### {title}");
    println!("{}", header.join(" | "));
    println!("{}", vec!["---"; header.len()].join(" | "));
    for row in rows {
        println!("{}", row.join(" | "));
    }
}

pub fn fmt(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 0.01 && x.abs() < 10000.0 {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_geometric_and_monotone() {
        let s = nnz_sweep(10, 10_000, 7);
        assert_eq!(s.first(), Some(&10));
        assert!(*s.last().unwrap() >= 9_900);
        for w in s.windows(2) {
            assert!(w[0] < w[1], "{s:?}");
        }
    }

    #[test]
    fn sweep_handles_tight_range() {
        let s = nnz_sweep(5, 6, 4);
        assert!(!s.is_empty());
        assert!(s.iter().all(|&x| (5..=6).contains(&x)));
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run("fig99", &ExpConfig::default()).is_err());
    }

    #[test]
    fn corpus_presets_resolve() {
        let cfg = ExpConfig {
            scale: Scale::Tiny,
            seed: 1,
            fast: true,
        };
        for name in ["reuters", "wikipedia", "pubmed"] {
            let tdm = corpus_tdm(name, &cfg).unwrap();
            assert!(tdm.n_docs() > 0, "{name}");
        }
        assert!(corpus_tdm("nope", &cfg).is_err());
    }
}
