//! Figure 6: maximum stored nonzeros (U and V combined, intermediates
//! included) versus the enforced NNZ, for initial guesses of varying
//! sparsity — pubmed-sim, k=5. The memory claim of the paper.

use super::{corpus_tdm, nnz_sweep, print_table, ExpConfig};
use crate::nmf::{factorize, NmfOptions, SparsityMode};
use crate::util::json::{arr, num, obj, s, Json};
use crate::Result;

pub fn run(cfg: &ExpConfig) -> Result<Json> {
    let tdm = corpus_tdm("pubmed", cfg)?;
    let k = 5;
    let iters = cfg.iters(30);
    let dense_init = tdm.n_terms() * k;
    let init_levels = [
        dense_init / 100,
        dense_init / 10,
        dense_init, // fully dense guess
    ];
    let points = if cfg.fast { 4 } else { 8 };
    let sweep = nnz_sweep(2 * k, (tdm.n_docs() * k).min(tdm.n_terms() * k), points);

    let mut rows = Vec::new();
    let mut series = Vec::new();
    for &t in &sweep {
        let mut record = vec![t.to_string()];
        let mut blob = vec![("nnz", num(t as f64))];
        for (idx, &init_nnz) in init_levels.iter().enumerate() {
            let mut opts = NmfOptions::new(k)
                .with_iters(iters)
                .with_seed(cfg.seed)
                .with_sparsity(SparsityMode::both(t, t))
                .with_track_error(false);
            if init_nnz < dense_init {
                opts = opts.with_init_nnz(init_nnz);
            }
            let r = factorize(&tdm, &opts);
            record.push(r.memory.max_combined_nnz.to_string());
            blob.push(match idx {
                0 => ("max_nnz_init_1pct", num(r.memory.max_combined_nnz as f64)),
                1 => ("max_nnz_init_10pct", num(r.memory.max_combined_nnz as f64)),
                _ => ("max_nnz_init_dense", num(r.memory.max_combined_nnz as f64)),
            });
        }
        series.push(obj(blob));
        rows.push(record);
    }

    let dense_storage = (tdm.n_terms() + tdm.n_docs()) * k;
    print_table(
        &format!(
            "Fig. 6 — pubmed-sim k={k}: max stored NNZ (U+V) vs enforced NNZ (dense storage would be {dense_storage})"
        ),
        &["enforced nnz", "init 1% dense", "init 10% dense", "init fully dense"],
        &rows,
    );
    Ok(obj(vec![
        ("experiment", s("fig6")),
        ("sweep", arr(series)),
        ("dense_storage", num(dense_storage as f64)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Scale;

    #[test]
    fn fig6_sparse_init_bounds_memory() {
        let cfg = ExpConfig {
            scale: Scale::Tiny,
            seed: 13,
            fast: true,
        };
        let out = run(&cfg).unwrap();
        let dense_storage = out.get("dense_storage").unwrap().as_f64().unwrap();
        let sweep = out.get("sweep").unwrap().as_arr().unwrap();
        let first = sweep.first().unwrap();
        // paper shape: at small enforced t, the sparse-init peak is far
        // below dense storage, and below the dense-init peak
        let sparse_peak = first.get("max_nnz_init_1pct").unwrap().as_f64().unwrap();
        let dense_peak = first.get("max_nnz_init_dense").unwrap().as_f64().unwrap();
        assert!(sparse_peak < dense_storage, "{sparse_peak} vs {dense_storage}");
        assert!(sparse_peak <= dense_peak, "{sparse_peak} vs {dense_peak}");
    }
}
