//! Figure 8: clustering accuracy of sequential ALS and column-wise
//! enforcement versus per-topic NNZ — pubmed-sim, k=5.

use super::{corpus_tdm, fmt, nnz_sweep, print_table, ExpConfig};
use crate::eval::mean_topic_accuracy;
use crate::nmf::{
    factorize, factorize_sequential, NmfOptions, SequentialOptions, SparsityMode,
};
use crate::util::json::{arr, num, obj, s, Json};
use crate::Result;

pub fn run(cfg: &ExpConfig) -> Result<Json> {
    let tdm = corpus_tdm("pubmed", cfg)?;
    let labels = tdm.doc_labels.clone().expect("pubmed-sim is labeled");
    let n_journals = tdm.label_names.len();
    let k = 5;
    let points = if cfg.fast { 4 } else { 7 };
    let sweep = nnz_sweep(2, tdm.n_docs(), points); // per-topic document budget

    let mut rows = Vec::new();
    let mut series = Vec::new();
    for &t_col in &sweep {
        // column-wise Algorithm 2 (enforce V per column so membership is
        // controlled per topic, as the accuracy measure reads V)
        let colwise = factorize(
            &tdm,
            &NmfOptions::new(k)
                .with_iters(cfg.iters(50))
                .with_seed(cfg.seed)
                .with_sparsity(SparsityMode::PerColumn {
                    t_u_col: None,
                    t_v_col: Some(t_col),
                })
                .with_track_error(false),
        );
        let acc_col = mean_topic_accuracy(&colwise.v, &labels, n_journals);

        // sequential with the same per-topic budget
        let seq = factorize_sequential(
            &tdm,
            &SequentialOptions::new(k, cfg.iters(10))
                .with_budgets(tdm.n_terms(), t_col)
                .with_seed(cfg.seed),
        );
        let acc_seq = mean_topic_accuracy(&seq.v, &labels, n_journals);

        rows.push(vec![t_col.to_string(), fmt(acc_col), fmt(acc_seq)]);
        series.push(obj(vec![
            ("nnz_per_topic", num(t_col as f64)),
            ("acc_colwise", num(acc_col)),
            ("acc_sequential", num(acc_seq)),
        ]));
    }

    print_table(
        &format!("Fig. 8 — pubmed-sim k={k}: accuracy of column-wise and sequential"),
        &["nnz/topic", "acc(column-wise)", "acc(sequential)"],
        &rows,
    );
    Ok(obj(vec![("experiment", s("fig8")), ("sweep", arr(series))]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Scale;

    #[test]
    fn fig8_accuracies_in_unit_range() {
        let cfg = ExpConfig {
            scale: Scale::Tiny,
            seed: 17,
            fast: true,
        };
        let out = run(&cfg).unwrap();
        for p in out.get("sweep").unwrap().as_arr().unwrap() {
            for key in ["acc_colwise", "acc_sequential"] {
                let a = p.get(key).unwrap().as_f64().unwrap();
                assert!((-1.0..=1.0).contains(&a), "{key} = {a}");
            }
        }
    }
}
