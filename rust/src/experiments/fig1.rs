//! Figure 1: dense projected ALS densifies U, V and U·Vᵀ even though A is
//! very sparse — the motivation table, for reuters-sim and wikipedia-sim.

use super::{corpus_tdm, print_table, ExpConfig};
use crate::eval::SparsityReport;
use crate::nmf::{factorize, NmfOptions};
use crate::util::json::{arr, num, obj, s, Json};
use crate::Result;

pub fn run(cfg: &ExpConfig) -> Result<Json> {
    let mut blobs = Vec::new();
    for dataset in ["reuters", "wikipedia"] {
        let tdm = corpus_tdm(dataset, cfg)?;
        let opts = NmfOptions::new(5)
            .with_iters(cfg.iters(30))
            .with_seed(cfg.seed)
            .with_track_error(false);
        let r = factorize(&tdm, &opts);
        let report = SparsityReport::compute(&tdm.a, &r.u, &r.v);
        print_table(
            &format!("Fig. 1 — {dataset}-sim sparsity after dense projected ALS (k=5)"),
            &["Matrix", "Sparsity", "NNZ"],
            &[
                vec!["A".into(), format!("{:.2}%", report.a_sparsity * 100.0), report.a_nnz.to_string()],
                vec!["U".into(), format!("{:.2}%", report.u_sparsity * 100.0), report.u_nnz.to_string()],
                vec!["V".into(), format!("{:.2}%", report.v_sparsity * 100.0), report.v_nnz.to_string()],
                vec!["UV^T".into(), format!("{:.2}%", report.uvt_sparsity * 100.0), report.uvt_nnz.to_string()],
            ],
        );
        blobs.push(obj(vec![
            ("dataset", s(dataset)),
            ("a_sparsity", num(report.a_sparsity)),
            ("u_sparsity", num(report.u_sparsity)),
            ("v_sparsity", num(report.v_sparsity)),
            ("uvt_sparsity", num(report.uvt_sparsity)),
        ]));
    }
    Ok(obj(vec![("experiment", s("fig1")), ("datasets", arr(blobs))]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Scale;

    #[test]
    fn fig1_shape_holds_at_tiny_scale() {
        let cfg = ExpConfig {
            scale: Scale::Tiny,
            seed: 3,
            fast: true,
        };
        let out = run(&cfg).unwrap();
        let datasets = out.get("datasets").unwrap().as_arr().unwrap();
        for d in datasets {
            let a = d.get("a_sparsity").unwrap().as_f64().unwrap();
            let u = d.get("u_sparsity").unwrap().as_f64().unwrap();
            // the paper's point: A is much sparser than the dense-ALS U
            assert!(a > 0.8, "A sparsity {a}");
            assert!(u < a, "U ({u}) should densify below A ({a})");
        }
    }
}
