//! Table 1 and Figure 7: uneven nonzero distribution from global
//! enforcement on wikipedia-sim, and the two fixes (column-wise
//! enforcement, sequential ALS) producing even topics.

use super::{corpus_tdm, print_table, ExpConfig};
use crate::eval::topics::{column_nnz_cv, format_topic_table, topic_term_table};
use crate::nmf::{
    factorize, factorize_sequential, NmfOptions, SequentialOptions, SparsityMode,
};
use crate::util::json::{arr, num, obj, s, Json};
use crate::Result;

const K: usize = 5;
const T_TOTAL: usize = 50; // 50 nonzeros in U, as in Table 1 / Fig. 7

fn col_counts_row(u: &crate::sparse::Csr) -> Vec<String> {
    u.col_nnz().iter().map(|c| c.to_string()).collect()
}

/// Table 1: global 50-nonzero enforcement on U → skewed topics.
pub fn run_table1(cfg: &ExpConfig) -> Result<Json> {
    let tdm = corpus_tdm("wikipedia", cfg)?;
    let r = factorize(
        &tdm,
        &NmfOptions::new(K)
            .with_iters(cfg.iters(50))
            .with_seed(cfg.seed)
            .with_sparsity(SparsityMode::u_only(T_TOTAL))
            .with_track_error(false),
    );
    println!("\n### Table 1 — wikipedia-sim, U limited to {T_TOTAL} nonzeros (global)");
    print!("{}", format_topic_table(&topic_term_table(&r.u, &tdm.terms, 5), K));
    print_table(
        "per-topic nonzero counts (global enforcement skews)",
        &["t1", "t2", "t3", "t4", "t5"],
        &[col_counts_row(&r.u)],
    );
    let cv = column_nnz_cv(&r.u);
    println!("column-nnz coefficient of variation: {cv:.3}");
    Ok(obj(vec![
        ("experiment", s("table1")),
        ("column_nnz_cv", num(cv)),
        (
            "col_nnz",
            arr(r.u.col_nnz().iter().map(|&c| num(c as f64)).collect()),
        ),
    ]))
}

/// Figure 7: column-wise and sequential enforcement give even topics.
pub fn run(cfg: &ExpConfig) -> Result<Json> {
    let tdm = corpus_tdm("wikipedia", cfg)?;
    let per_col = T_TOTAL / K;

    let colwise = factorize(
        &tdm,
        &NmfOptions::new(K)
            .with_iters(cfg.iters(50))
            .with_seed(cfg.seed)
            .with_sparsity(SparsityMode::PerColumn {
                t_u_col: Some(per_col),
                t_v_col: None,
            })
            .with_track_error(false),
    );
    println!("\n### Fig. 7 — enforce sparsity by column ({per_col} nnz per topic)");
    print!("{}", format_topic_table(&topic_term_table(&colwise.u, &tdm.terms, 5), K));

    let seq = factorize_sequential(
        &tdm,
        &SequentialOptions::new(K, cfg.iters(20))
            .with_budgets(per_col, tdm.n_docs())
            .with_seed(cfg.seed),
    );
    println!("\n### Fig. 7 — sequential ALS ({per_col} nnz per topic)");
    print!("{}", format_topic_table(&topic_term_table(&seq.u, &tdm.terms, 5), K));

    let cv_col = column_nnz_cv(&colwise.u);
    let cv_seq = column_nnz_cv(&seq.u);
    print_table(
        "per-topic nonzero counts",
        &["method", "t1", "t2", "t3", "t4", "t5", "cv"],
        &[
            {
                let mut row = vec!["column-wise".to_string()];
                row.extend(col_counts_row(&colwise.u));
                row.push(format!("{cv_col:.3}"));
                row
            },
            {
                let mut row = vec!["sequential".to_string()];
                row.extend(col_counts_row(&seq.u));
                row.push(format!("{cv_seq:.3}"));
                row
            },
        ],
    );
    Ok(obj(vec![
        ("experiment", s("fig7")),
        ("colwise_cv", num(cv_col)),
        ("sequential_cv", num(cv_seq)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Scale;

    #[test]
    fn fig7_fixes_are_more_even_than_table1() {
        let cfg = ExpConfig {
            scale: Scale::Tiny,
            seed: 15,
            fast: true,
        };
        let skew = run_table1(&cfg).unwrap();
        let fixes = run(&cfg).unwrap();
        let cv_global = skew.get("column_nnz_cv").unwrap().as_f64().unwrap();
        let cv_col = fixes.get("colwise_cv").unwrap().as_f64().unwrap();
        // column-wise enforcement is even by construction
        assert!(
            cv_col <= cv_global + 1e-9,
            "colwise cv {cv_col} vs global cv {cv_global}"
        );
    }
}
