//! Figure 2: error/residual per iteration with a 55-nonzero U versus fully
//! dense, plus the two 5-term topic tables, on reuters-sim (k=5).

use super::{corpus_tdm, fmt, print_table, ExpConfig};
use crate::eval::topics::{format_topic_table, topic_term_table};
use crate::nmf::{factorize, NmfOptions, SparsityMode};
use crate::util::json::{arr, num, obj, s, Json};
use crate::Result;

pub fn run(cfg: &ExpConfig) -> Result<Json> {
    let tdm = corpus_tdm("reuters", cfg)?;
    let iters = cfg.iters(75);
    let base = NmfOptions::new(5).with_iters(iters).with_seed(cfg.seed);

    let sparse = factorize(
        &tdm,
        &base.clone().with_sparsity(SparsityMode::u_only(55)),
    );
    let dense = factorize(&tdm, &base);

    let rows: Vec<Vec<String>> = (0..iters)
        .map(|i| {
            vec![
                (i + 1).to_string(),
                fmt(sparse.residuals[i]),
                fmt(sparse.errors[i]),
                fmt(dense.residuals[i]),
                fmt(dense.errors[i]),
            ]
        })
        .collect();
    print_table(
        "Fig. 2 — reuters-sim k=5: sparse-U(55) vs dense, per ALS iteration",
        &["iter", "residual(sparse U)", "error(sparse U)", "residual(dense)", "error(dense)"],
        &rows,
    );

    println!("\nSparsity-enforced U (55 nonzeros, 5 topics):");
    print!("{}", format_topic_table(&topic_term_table(&sparse.u, &tdm.terms, 5), 5));
    println!("\nFully dense U:");
    print!("{}", format_topic_table(&topic_term_table(&dense.u, &tdm.terms, 5), 5));

    let to_json = |xs: &[f64]| arr(xs.iter().map(|&x| num(x)).collect());
    Ok(obj(vec![
        ("experiment", s("fig2")),
        ("sparse_residuals", to_json(&sparse.residuals)),
        ("sparse_errors", to_json(&sparse.errors)),
        ("dense_residuals", to_json(&dense.residuals)),
        ("dense_errors", to_json(&dense.errors)),
        ("sparse_u_nnz", num(sparse.u.nnz() as f64)),
        ("dense_u_nnz", num(dense.u.nnz() as f64)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Scale;

    #[test]
    fn fig2_sparse_u_converges_no_slower_and_errs_higher() {
        let cfg = ExpConfig {
            scale: Scale::Tiny,
            seed: 5,
            fast: false,
        };
        // use a short but not smoke-short run for a meaningful comparison
        let cfg = ExpConfig { fast: true, ..cfg };
        let out = run(&cfg).unwrap();
        let sparse_nnz = out.get("sparse_u_nnz").unwrap().as_f64().unwrap();
        let dense_nnz = out.get("dense_u_nnz").unwrap().as_f64().unwrap();
        assert!(sparse_nnz <= 55.0);
        assert!(dense_nnz > sparse_nnz);
        // paper shape: the enforced run's final error ≥ dense final error
        let se = out.get("sparse_errors").unwrap().as_arr().unwrap();
        let de = out.get("dense_errors").unwrap().as_arr().unwrap();
        let s_last = se.last().unwrap().as_f64().unwrap();
        let d_last = de.last().unwrap().as_f64().unwrap();
        assert!(s_last >= d_last - 0.05, "sparse {s_last} vs dense {d_last}");
    }
}
