//! Figure 9: wall-clock time for 100 ALS iterations finding a 5-topic NMF
//! of pubmed-sim — whole-matrix enforcement vs column-wise vs sequential
//! (20 iterations × 5 topics).

use super::{corpus_tdm, print_table, ExpConfig};
use crate::nmf::{
    factorize, factorize_sequential, NmfOptions, SequentialOptions, SparsityMode,
};
use crate::util::json::{num, obj, s, Json};
use crate::util::timer::fmt_seconds;
use crate::Result;

pub fn run(cfg: &ExpConfig) -> Result<Json> {
    let tdm = corpus_tdm("pubmed", cfg)?;
    let k = 5;
    let total_iters = cfg.iters(100);
    let t_u = 50;
    let t_v = 500.min(tdm.n_docs());

    // normal: whole-matrix enforcement (Algorithm 2). The paper's figure
    // is single-core and the sequential solver below is serial, so the
    // ALS runs are pinned to 1 thread for an apples-to-apples ratio
    // (benches/fig9_timing.rs carries the multicore comparison points).
    let normal = factorize(
        &tdm,
        &NmfOptions::new(k)
            .with_iters(total_iters)
            .with_seed(cfg.seed)
            .with_sparsity(SparsityMode::both(t_u, t_v))
            .with_track_error(false)
            .with_threads(1),
    );

    // column-wise enforcement
    let colwise = factorize(
        &tdm,
        &NmfOptions::new(k)
            .with_iters(total_iters)
            .with_seed(cfg.seed)
            .with_sparsity(SparsityMode::PerColumn {
                t_u_col: Some(t_u / k),
                t_v_col: Some(t_v / k),
            })
            .with_track_error(false)
            .with_threads(1),
    );

    // sequential: total_iters split over k single-topic blocks
    let seq = factorize_sequential(
        &tdm,
        &SequentialOptions::new(k, total_iters / k)
            .with_budgets(t_u / k, t_v / k)
            .with_seed(cfg.seed),
    );

    print_table(
        &format!(
            "Fig. 9 — pubmed-sim k={k}: time for {total_iters} ALS iterations"
        ),
        &["method", "time", "final U nnz", "final V nnz"],
        &[
            vec![
                "normal (whole-matrix)".into(),
                fmt_seconds(normal.elapsed_s),
                normal.u.nnz().to_string(),
                normal.v.nnz().to_string(),
            ],
            vec![
                "column-wise".into(),
                fmt_seconds(colwise.elapsed_s),
                colwise.u.nnz().to_string(),
                colwise.v.nnz().to_string(),
            ],
            vec![
                "sequential".into(),
                fmt_seconds(seq.elapsed_s),
                seq.u.nnz().to_string(),
                seq.v.nnz().to_string(),
            ],
        ],
    );
    Ok(obj(vec![
        ("experiment", s("fig9")),
        ("normal_s", num(normal.elapsed_s)),
        ("colwise_s", num(colwise.elapsed_s)),
        ("sequential_s", num(seq.elapsed_s)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Scale;

    #[test]
    fn fig9_sequential_is_fastest() {
        let cfg = ExpConfig {
            scale: Scale::Tiny,
            seed: 19,
            fast: true,
        };
        let out = run(&cfg).unwrap();
        let normal = out.get("normal_s").unwrap().as_f64().unwrap();
        let seq = out.get("sequential_s").unwrap().as_f64().unwrap();
        // paper shape: sequential is clearly faster than whole-matrix ALS
        // (tiny-scale timing noise tolerated with a generous margin)
        assert!(
            seq <= normal * 1.5,
            "sequential {seq}s vs normal {normal}s"
        );
    }
}
