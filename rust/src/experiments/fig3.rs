//! Figure 3: residual and error after 75 ALS iterations versus the number
//! of nonzeros allowed, enforcing sparsity for U only, V only, and both.

use super::{corpus_tdm, fmt, nnz_sweep, print_table, ExpConfig};
use crate::nmf::{factorize, NmfOptions, SparsityMode};
use crate::util::json::{arr, num, obj, s, Json};
use crate::Result;

pub fn run(cfg: &ExpConfig) -> Result<Json> {
    let tdm = corpus_tdm("reuters", cfg)?;
    let k = 5;
    let iters = cfg.iters(75);
    let max_u = tdm.n_terms() * k;
    let max_v = tdm.n_docs() * k;
    let points = if cfg.fast { 4 } else { 8 };
    let sweep = nnz_sweep(2 * k, max_u.min(max_v), points);

    let mut rows = Vec::new();
    let mut series = Vec::new();
    for &t in &sweep {
        let mut record = vec![t.to_string()];
        let mut blob = vec![("nnz", num(t as f64))];
        for (label, mode) in [
            ("U", SparsityMode::u_only(t)),
            ("V", SparsityMode::v_only(t)),
            ("UV", SparsityMode::both(t, t)),
        ] {
            let opts = NmfOptions::new(k)
                .with_iters(iters)
                .with_seed(cfg.seed)
                .with_sparsity(mode);
            let r = factorize(&tdm, &opts);
            record.push(fmt(r.final_residual()));
            record.push(fmt(r.final_error()));
            blob.push(match label {
                "U" => ("u_residual", num(r.final_residual())),
                "V" => ("v_residual", num(r.final_residual())),
                _ => ("uv_residual", num(r.final_residual())),
            });
            blob.push(match label {
                "U" => ("u_error", num(r.final_error())),
                "V" => ("v_error", num(r.final_error())),
                _ => ("uv_error", num(r.final_error())),
            });
        }
        series.push(obj(blob));
        rows.push(record);
    }

    print_table(
        &format!("Fig. 3 — reuters-sim k={k}: residual/error after {iters} iterations vs NNZ"),
        &[
            "nnz", "res(U sparse)", "err(U sparse)", "res(V sparse)",
            "err(V sparse)", "res(both)", "err(both)",
        ],
        &rows,
    );
    Ok(obj(vec![
        ("experiment", s("fig3")),
        ("sweep", arr(series)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Scale;

    #[test]
    fn fig3_low_nnz_converges_faster() {
        let cfg = ExpConfig {
            scale: Scale::Tiny,
            seed: 7,
            fast: true,
        };
        let out = run(&cfg).unwrap();
        let sweep = out.get("sweep").unwrap().as_arr().unwrap();
        assert!(sweep.len() >= 3);
        // paper shape: very sparse runs converge at least as fast (lower
        // or equal residual) as the densest point of the sweep
        let first = sweep.first().unwrap();
        let last = sweep.last().unwrap();
        let r_lo = first.get("u_residual").unwrap().as_f64().unwrap();
        let r_hi = last.get("u_residual").unwrap().as_f64().unwrap();
        assert!(
            r_lo <= r_hi * 10.0,
            "sparse residual {r_lo} should not be wildly above dense {r_hi}"
        );
    }
}
