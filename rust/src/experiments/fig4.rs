//! Figure 4: mean document-clustering accuracy (Eq. 3.3) vs NNZ on
//! pubmed-sim, enforcing sparsity for U, V, and both (k=5, 50 iterations).

use super::{corpus_tdm, fmt, nnz_sweep, print_table, ExpConfig};
use crate::eval::mean_topic_accuracy;
use crate::nmf::{factorize, NmfOptions, SparsityMode};
use crate::util::json::{arr, num, obj, s, Json};
use crate::Result;

pub fn run(cfg: &ExpConfig) -> Result<Json> {
    let tdm = corpus_tdm("pubmed", cfg)?;
    let labels = tdm.doc_labels.clone().expect("pubmed-sim is labeled");
    let n_journals = tdm.label_names.len();
    let k = 5;
    let iters = cfg.iters(50);
    let points = if cfg.fast { 4 } else { 8 };
    let sweep = nnz_sweep(2 * k, tdm.n_docs() * k, points);

    let mut rows = Vec::new();
    let mut series = Vec::new();
    for &t in &sweep {
        let mut record = vec![t.to_string()];
        let mut blob = vec![("nnz", num(t as f64))];
        for (label, mode) in [
            ("u", SparsityMode::u_only(t)),
            ("v", SparsityMode::v_only(t)),
            ("uv", SparsityMode::both(t, t)),
        ] {
            let opts = NmfOptions::new(k)
                .with_iters(iters)
                .with_seed(cfg.seed)
                .with_sparsity(mode)
                .with_track_error(false);
            let r = factorize(&tdm, &opts);
            let acc = mean_topic_accuracy(&r.v, &labels, n_journals);
            record.push(fmt(acc));
            blob.push(match label {
                "u" => ("acc_u", num(acc)),
                "v" => ("acc_v", num(acc)),
                _ => ("acc_uv", num(acc)),
            });
        }
        series.push(obj(blob));
        rows.push(record);
    }
    // dense baseline
    let dense = factorize(
        &tdm,
        &NmfOptions::new(k)
            .with_iters(iters)
            .with_seed(cfg.seed)
            .with_track_error(false),
    );
    let dense_acc = mean_topic_accuracy(&dense.v, &labels, n_journals);
    rows.push(vec![
        "dense".into(),
        fmt(dense_acc),
        fmt(dense_acc),
        fmt(dense_acc),
    ]);

    print_table(
        &format!("Fig. 4 — pubmed-sim k={k}: mean clustering accuracy vs NNZ ({iters} iters)"),
        &["nnz", "acc(U sparse)", "acc(V sparse)", "acc(both)"],
        &rows,
    );
    Ok(obj(vec![
        ("experiment", s("fig4")),
        ("sweep", arr(series)),
        ("dense_accuracy", num(dense_acc)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Scale;

    #[test]
    fn fig4_sparse_beats_dense_accuracy() {
        let cfg = ExpConfig {
            scale: Scale::Tiny,
            seed: 9,
            fast: true,
        };
        let out = run(&cfg).unwrap();
        let dense = out.get("dense_accuracy").unwrap().as_f64().unwrap();
        let sweep = out.get("sweep").unwrap().as_arr().unwrap();
        let sparse_best = sweep
            .iter()
            .map(|p| p.get("acc_v").unwrap().as_f64().unwrap())
            .fold(f64::NEG_INFINITY, f64::max);
        // paper shape: accuracy is higher for sparser factors
        assert!(
            sparse_best >= dense - 0.05,
            "best sparse {sparse_best} vs dense {dense}"
        );
    }
}
