//! Run configuration: a TOML-subset file format plus the typed
//! [`RunConfig`] the CLI builds (from file and/or flags).
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (quoted), integer, float and boolean values, `#` comments. That covers
//! every knob the system exposes without a serde dependency.

pub mod parse;
pub mod run;

pub use parse::{ConfigFile, Value};
pub use run::{Algorithm, RunConfig};
